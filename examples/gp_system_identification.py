"""The paper's end-to-end use case: system identification of a coupled
mass-spring-damper chain with a tiled, device-resident GP.

    PYTHONPATH=src python examples/gp_system_identification.py [--n 2048]
"""

import argparse
import time

import numpy as np

from repro.core import GaussianProcess
from repro.data.msd import MSDConfig, make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048, help="training samples")
    ap.add_argument("--n-test", type=int, default=512)
    ap.add_argument("--tile", type=int, default=256)
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="jnp")
    args = ap.parse_args()

    cfg = MSDConfig()
    print(f"simulating MSD chain: {cfg.n_masses} masses, D={cfg.n_regressors} regressors")
    x_tr, y_tr, x_te, y_te = make_dataset(args.n, args.n_test, cfg, seed=0)

    gp = GaussianProcess(x_tr, y_tr, tile_size=args.tile, op_backend=args.backend)

    t0 = time.perf_counter()
    mean, var = gp.predict_with_uncertainty(x_te)
    mean = np.asarray(mean)
    t1 = time.perf_counter()

    mse = float(np.mean((mean - y_te) ** 2))
    r2 = 1 - mse / float(np.var(y_te))
    sd = np.sqrt(np.asarray(var) + float(gp.params.noise))
    cover = float(np.mean(np.abs(mean - y_te) < 2 * sd))
    print(f"n={args.n} tiles/dim={args.n // args.tile}  predict+uncertainty "
          f"wall: {t1 - t0:.2f}s (includes jit)")
    print(f"r2 = {r2:.3f}   2-sigma coverage = {cover:.2%}")

    # monolithic (cuSOLVER-analogue) cross-check
    gp_m = GaussianProcess(x_tr, y_tr, pipeline="monolithic")
    mu_m = np.asarray(gp_m.predict(x_te))
    print(f"max |tiled - monolithic| = {np.abs(mean - mu_m).max():.2e}")


if __name__ == "__main__":
    main()
