"""Quickstart: tiled GP regression in five lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import GaussianProcess, SEKernelParams

rng = np.random.default_rng(0)
x_train = rng.uniform(-3, 3, (256, 1)).astype(np.float32)
y_train = np.sin(x_train[:, 0]) + 0.1 * rng.standard_normal(256).astype(np.float32)
x_test = np.linspace(-3, 3, 100)[:, None].astype(np.float32)

# The paper's pipeline: tiled covariance assembly -> tiled Cholesky ->
# triangular solves -> predictive mean + uncertainty, one device program.
gp = GaussianProcess(x_train, y_train, tile_size=64)
mean, var = gp.predict_with_uncertainty(x_test)

err = np.abs(np.asarray(mean) - np.sin(x_test[:, 0]))
print(f"mean abs error vs ground truth: {err.mean():.4f}")
print(f"avg predictive std:             {np.sqrt(np.asarray(var)).mean():.4f}")

# hyperparameter optimization (beyond the paper's fixed values)
gp.optimize(steps=50, lr=0.1)
mean2, _ = gp.predict_with_uncertainty(x_test)
err2 = np.abs(np.asarray(mean2) - np.sin(x_test[:, 0]))
print(f"after NLML optimization:        {err2.mean():.4f}  params={gp.params}")

# Large n: method="lowrank" swaps the O(n^3) exact solver for the O(n m^2)
# Nystrom tier (DESIGN.md §14) — same API, m_inducing controls the
# accuracy/speed trade-off (benchmarks/fig14_lowrank_tradeoff.py charts it).
n_big = 8192
x_big = rng.uniform(-3, 3, (n_big, 1)).astype(np.float32)
y_big = np.sin(x_big[:, 0]) + 0.1 * rng.standard_normal(n_big).astype(np.float32)
gp_lr = GaussianProcess(x_big, y_big, tile_size=256, method="lowrank", m_inducing=256)
mean_lr = gp_lr.predict(x_test)
err_lr = np.abs(np.asarray(mean_lr) - np.sin(x_test[:, 0]))
print(f"lowrank (n=8192, m=256):        {err_lr.mean():.4f}")
