"""Composite kernels through the tiled pipeline: C * Matern52 + White.

The ARBO-style surrogate — an output-scaled Matérn 5/2 plus an explicit
white-noise term — built from the kernel zoo's composition algebra
(DESIGN.md §13), trained via the tiled NLML (autodiff VJP fallback), and
served through a predict-observe-update loop where each round's new
observations are absorbed online by the block Cholesky append (no
re-factorization).  `repro.obs` telemetry (DESIGN.md §15) is on for the
whole run; the tail prints what the loop actually did — warm vs cold
posterior builds, executor dispatches, factorization-health incidents,
and the plan/jit lru-cache tallies.

    PYTHONPATH=src python examples/composite_workload.py
"""

import numpy as np

import repro.obs as obs
from repro.core import GaussianProcess, Matern52, Scaled, Sum, White

obs.enable()

rng = np.random.default_rng(0)


def f(x):  # the function being surrogate-modeled
    return np.sin(3.0 * x[:, 0]) * np.cos(2.0 * x[:, 1])


x_train = rng.uniform(-1, 1, (192, 2)).astype(np.float32)
y_train = (f(x_train) + 0.05 * rng.standard_normal(192)).astype(np.float32)
x_test = rng.uniform(-1, 1, (128, 2)).astype(np.float32)

# kernel algebra: Sum / Product / Scaled compose over nested params pytrees;
# the composite is hashable, so it keys the jit and posterior caches directly
kernel = Sum(Scaled(Matern52()), White())
gp = GaussianProcess(x_train, y_train, tile_size=64, kernel=kernel)

mean, var = gp.predict_with_uncertainty(x_test)
err = np.abs(np.asarray(mean) - f(x_test))
print(f"untrained composite:  mae={err.mean():.4f}  "
      f"avg std={np.sqrt(np.asarray(var)).mean():.4f}")

# tiled NLML + Adam over the full params pytree (scale, Matérn, noise leaves)
gp.optimize(steps=60, lr=0.1)
mean, var = gp.predict_with_uncertainty(x_test)
err = np.abs(np.asarray(mean) - f(x_test))
print(f"after NLML training:  mae={err.mean():.4f}  "
      f"avg std={np.sqrt(np.asarray(var)).mean():.4f}")

# predict-observe-update: each round streams fresh observations into the
# cached factor via the tiled block Cholesky append
for round_idx in range(3):
    x_new = rng.uniform(-1, 1, (32, 2)).astype(np.float32)
    y_new = (f(x_new) + 0.05 * rng.standard_normal(32)).astype(np.float32)
    gp.update(x_new, y_new)
    mean, _ = gp.predict_with_uncertainty(x_test)
    err = np.abs(np.asarray(mean) - f(x_test))
    print(f"round {round_idx}: n={gp.y_train.shape[0]}  mae={err.mean():.4f}")

# what the loop did, from the telemetry registry (DESIGN.md §15)
snap = obs.snapshot()
c = snap["counters"]
print(
    f"obs: posterior cache warm={c.get('cache.posterior.warm', 0):.0f} "
    f"cold={c.get('cache.posterior.cold', 0):.0f}, executor dispatches="
    f"{sum(v for k, v in c.items() if k.startswith('executor.dispatch.')):.0f}, "
    f"health incidents={sum(v for k, v in c.items() if k.startswith('health.')):.0f}"
)
print("obs: cache stats:")
for name, st in obs.cache_stats().items():
    if st["hits"] or st["misses"]:
        print(f"  {name}: hits={st['hits']} misses={st['misses']} size={st['size']}")
obs.disable()
