"""GP prediction serving: factor once, serve batched prediction requests.

The paper's workload is inference (predict + uncertainty); the serving shape
is: a trained GP (assembled + factored covariance, device-resident) answering
batches of prediction requests at low latency.

    PYTHONPATH=src python examples/serve_gp.py [--n 4096] [--batches 32]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cholesky as chol
from repro.core import predict as pred
from repro.core import triangular
from repro.core.kernels_math import SEKernelParams
from repro.data.msd import MSDConfig, make_dataset, nfir_features, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--tile", type=int, default=512)
    ap.add_argument("--batch", type=int, default=256, help="requests per batch")
    ap.add_argument("--batches", type=int, default=32)
    args = ap.parse_args()

    cfg = MSDConfig()
    x_tr, y_tr, _, _ = make_dataset(args.n, 1, cfg, seed=0)
    params = SEKernelParams.paper_defaults()
    m = args.tile

    # ---- offline: assemble + factor once (the expensive O(n^3) part) ------
    t0 = time.perf_counter()
    xc = pred.pad_features(jnp.asarray(x_tr), m)
    yc = pred.pad_vector(jnp.asarray(y_tr), m)
    factor = jax.jit(lambda xc: pred.assemble_packed_covariance(xc, params, args.n))
    lp = jax.jit(chol.tiled_cholesky)(factor(xc))
    beta = triangular.forward_substitution(lp, yc)
    alpha = jax.block_until_ready(triangular.backward_substitution(lp, beta))
    print(f"factor+solve (offline): {time.perf_counter() - t0:.2f}s for n={args.n}")

    # ---- online: serve batches of requests --------------------------------
    @jax.jit
    def serve(xt_batch, alpha):
        xtc = pred.pad_features(xt_batch, m)
        kstar = pred.assemble_cross_tiles(xtc, xc, params, xt_batch.shape[0], args.n)
        return triangular.tiled_matvec(kstar, alpha).reshape(-1)[: xt_batch.shape[0]]

    rng = np.random.default_rng(1)
    lat = []
    for i in range(args.batches):
        u, y = simulate(args.batch + cfg.n_regressors - 1, cfg, seed=100 + i)
        xt, _ = nfir_features(u, y, cfg.n_regressors)
        xt = jnp.asarray(xt.astype(np.float32))
        t0 = time.perf_counter()
        out = jax.block_until_ready(serve(xt, alpha))
        lat.append(time.perf_counter() - t0)
    lat = np.asarray(lat[1:]) * 1e3  # drop jit batch
    print(
        f"served {args.batches} batches × {args.batch} requests: "
        f"p50={np.percentile(lat, 50):.2f}ms p99={np.percentile(lat, 99):.2f}ms "
        f"({args.batch / np.median(lat) * 1e3:.0f} req/s)"
    )


if __name__ == "__main__":
    main()
