"""GP prediction serving: factor once, serve batched prediction requests.

The paper's workload is inference (predict + uncertainty); the serving shape
is: a trained GP (assembled + factored covariance, device-resident) answering
batches of prediction requests at low latency.

Built on the fused-program `GaussianProcess` API (DESIGN.md §7): the offline
phase is one cold fused predict (ONE multi-stage program that also populates
the posterior cache), and the online loop is a jitted warm tail
(`predict_from_state` — cross covariance + mean off the cached factor).

``--fleet B`` serves B independent GPs through `GPBatch` (DESIGN.md §9):
one problem-batched program factors the whole fleet, and each online batch
answers B × batch requests in a single launch sequence — compare its
req/s against the single-GP numbers to see the wavefront-width win.

``--ragged B`` serves B GPs of *different* sizes through `GPFleet` + the
continuous-batching loop (DESIGN.md §11): problems are bucketed by tile
geometry, each wave drains a mixed queue of prediction and observation
requests (one ragged fused launch per occupied bucket), and buckets are
re-formed between waves as problems grow and migrate.

``--online`` turns the server into a *streaming* one (DESIGN.md §10):
prediction requests interleave with observation arrivals, absorbed by
`GaussianProcess.update` — the O(n^2 b) block Cholesky append — under a
`sliding_window` cap that evicts the oldest tile when the window overflows.
It reports the served latency alongside update-vs-full-refactorization
latency, the number the streaming subsystem exists to shrink.

    PYTHONPATH=src python examples/serve_gp.py [--n 4096] [--batches 32]
    PYTHONPATH=src python examples/serve_gp.py --fleet 8 --n 512
    PYTHONPATH=src python examples/serve_gp.py --online --n 1024 --arrive 32
    PYTHONPATH=src python examples/serve_gp.py --ragged 12 --n 512 --tile 64

``--metrics out.jsonl`` enables `repro.obs` telemetry (DESIGN.md §15) for
the run and streams every event — executor wave dispatches, `serve.wave`
records, factorization-health incidents, a final lru-cache snapshot — to a
JSON-lines file:

    PYTHONPATH=src python examples/serve_gp.py --ragged 8 --metrics metrics.jsonl
"""

import argparse
import time

import jax
import numpy as np

import repro.obs as obs
from repro.core import GaussianProcess, GPBatch, GPFleet
from repro.core import predict as pred
from repro.data.msd import MSDConfig, make_dataset, nfir_features, simulate
from repro.serve import ContinuousBatcher


def request_batches(cfg, batch, batches, seed0=100):
    """Fresh NFIR feature batches simulating online prediction requests."""
    for i in range(batches):
        u, y = simulate(batch + cfg.n_regressors - 1, cfg, seed=seed0 + i)
        xt, _ = nfir_features(u, y, cfg.n_regressors)
        yield xt.astype(np.float32)


def report(label, lat, requests):
    lat = np.asarray(lat[1:]) * 1e3  # drop the jit-compile batch
    print(
        f"{label}: p50={np.percentile(lat, 50):.2f}ms "
        f"p99={np.percentile(lat, 99):.2f}ms "
        f"({requests / np.median(lat) * 1e3:.0f} req/s)"
    )


def serve_single(args, cfg):
    x_tr, y_tr, _, _ = make_dataset(args.n, 1, cfg, seed=0)

    # ---- offline: ONE cold fused predict factors + caches the posterior ---
    t0 = time.perf_counter()
    gp = GaussianProcess(x_tr, y_tr, tile_size=args.tile)
    warm_probe = next(request_batches(cfg, args.batch, 1))
    jax.block_until_ready(gp.predict(warm_probe))
    print(f"fused factor+cache (offline): {time.perf_counter() - t0:.2f}s for n={args.n}")

    # ---- online: jitted warm tail off the cached PosteriorState -----------
    state = gp.posterior()
    serve = jax.jit(lambda xt: pred.predict_from_state(state, xt))
    lat = []
    for xt in request_batches(cfg, args.batch, args.batches):
        t0 = time.perf_counter()
        jax.block_until_ready(serve(xt))
        lat.append(time.perf_counter() - t0)
    report(f"served {args.batches} batches x {args.batch} requests", lat, args.batch)


def serve_fleet(args, cfg):
    b = args.fleet
    xs, ys = [], []
    for i in range(b):
        x_tr, y_tr, _, _ = make_dataset(args.n, 1, cfg, seed=i)
        xs.append(x_tr)
        ys.append(y_tr)
    x_stack = np.stack(xs)
    y_stack = np.stack(ys)

    # ---- offline: ONE problem-batched program factors the whole fleet -----
    t0 = time.perf_counter()
    fleet = GPBatch(x_stack, y_stack, tile_size=args.tile)
    warm_probe = next(request_batches(cfg, args.batch, 1))
    jax.block_until_ready(fleet.predict(warm_probe))  # shared block broadcast
    print(
        f"fleet fused factor+cache (offline): {time.perf_counter() - t0:.2f}s "
        f"for B={b} x n={args.n}"
    )

    # ---- online: every request batch is answered for ALL B GPs at once ----
    state = fleet.posterior()
    serve = jax.jit(lambda xt: pred.predict_from_state_batched(state, xt))
    lat = []
    for xt in request_batches(cfg, args.batch, args.batches):
        stacked = np.broadcast_to(xt, (b,) + xt.shape)
        t0 = time.perf_counter()
        jax.block_until_ready(serve(stacked))
        lat.append(time.perf_counter() - t0)
    report(
        f"served {args.batches} batches x {args.batch} requests x B={b} GPs",
        lat,
        args.batch * b,
    )


def serve_ragged(args, cfg):
    """Continuous batching over a ragged fleet (DESIGN.md §11).

    B problems with a skewed size mix (most small, a heavy tail up to --n)
    share bucketed fused programs; every wave mixes prediction requests with
    observation arrivals, so problems grow — and migrate buckets — live."""
    rng = np.random.default_rng(7)
    b = args.ragged
    # skewed mix: sizes log-uniform in [tile/2, n] — many small, few large
    lo, hi = max(args.tile // 2, 8), max(args.n, args.tile)
    ns = np.exp(rng.uniform(np.log(lo), np.log(hi), b)).astype(int)
    xs, ys = [], []
    for i, n in enumerate(ns):
        x_tr, y_tr, _, _ = make_dataset(int(n), 1, cfg, seed=i)
        xs.append(x_tr)
        ys.append(y_tr)

    t0 = time.perf_counter()
    fleet = GPFleet(xs, ys, tile_size=args.tile)
    srv = ContinuousBatcher(fleet)
    warm_probe = next(request_batches(cfg, args.batch, 1))
    jax.block_until_ready(fleet.predict(warm_probe))  # factor every bucket
    caps = {c: len(i) for c, i in fleet.bucket_assignment().items()}
    print(
        f"ragged fleet factor+cache (offline): {time.perf_counter() - t0:.2f}s "
        f"for B={b}, sizes {int(ns.min())}..{int(ns.max())}, buckets {caps}"
    )

    migrations = 0
    for w, xt in enumerate(request_batches(cfg, args.batch, args.batches)):
        # every wave: each problem gets a slice of the request batch ...
        splits = np.array_split(np.arange(xt.shape[0]), b)
        for i, rows in enumerate(splits):
            if rows.size:
                srv.submit_predict(i, xt[rows])
        # ... and a few problems receive labelled arrivals
        for i in rng.choice(b, size=max(b // 4, 1), replace=False):
            u, yv = simulate(args.arrive + cfg.n_regressors - 1, cfg, seed=5000 + 97 * w + i)
            x_new, y_new = nfir_features(u, yv, cfg.n_regressors)
            srv.submit_observe(int(i), x_new.astype(np.float32), y_new.astype(np.float32))
        stats = srv.step()
        migrations += stats.migrations
    srv.flush()  # fetch the last wave's one-wave-late dispatched results
    s = srv.summary()
    print(
        f"ragged: served {int(s['requests'])} requests in {int(s['waves'])} waves "
        f"(p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms, {s['req_per_s']:.0f} req/s)"
    )
    print(
        f"ragged: {migrations} bucket migrations, final sizes "
        f"{min(fleet.sizes)}..{max(fleet.sizes)}, buckets "
        f"{ {c: len(i) for c, i in fleet.bucket_assignment().items()} }"
    )


def serve_online(args, cfg):
    """Streaming serving: requests interleave with observation arrivals."""
    x_tr, y_tr, _, _ = make_dataset(args.n, 1, cfg, seed=0)

    gp = GaussianProcess(
        x_tr, y_tr, tile_size=args.tile, sliding_window=args.n
    )
    warm_probe = next(request_batches(cfg, args.batch, 1))
    t0 = time.perf_counter()
    jax.block_until_ready(gp.predict(warm_probe))
    print(f"fused factor+cache (offline): {time.perf_counter() - t0:.2f}s for n={args.n}")

    # one full refit of the same window (the jitted fused q_tiles=0
    # program — the honest O(n^3) baseline), warmed before timing
    def refit():
        env, _ = pred.nlml_program_env(gp.x_train, gp.y_train, gp.params, args.tile)
        return env["alpha"]

    jax.block_until_ready(refit())
    t0 = time.perf_counter()
    jax.block_until_ready(refit())
    t_refit = time.perf_counter() - t0

    serve_lat, upd_lat = [], []
    for i, xt in enumerate(request_batches(cfg, args.batch, args.batches)):
        t0 = time.perf_counter()
        jax.block_until_ready(gp.predict(xt))
        serve_lat.append(time.perf_counter() - t0)
        # observation arrivals: the request batch's first rows come back
        # labelled; absorb them under the sliding window
        u, yv = simulate(args.arrive + cfg.n_regressors - 1, cfg, seed=1000 + i)
        x_new, y_new = nfir_features(u, yv, cfg.n_regressors)
        t0 = time.perf_counter()
        gp.update(x_new.astype(np.float32), y_new.astype(np.float32))
        jax.block_until_ready(gp.posterior().alpha)
        upd_lat.append(time.perf_counter() - t0)
    report(f"online: served {args.batches} batches x {args.batch}", serve_lat, args.batch)
    upd = np.asarray(upd_lat[1:]) * 1e3
    print(
        f"online: absorbed {args.arrive} obs/batch in p50={np.percentile(upd, 50):.2f}ms "
        f"p99={np.percentile(upd, 99):.2f}ms vs full refactorize {t_refit * 1e3:.2f}ms "
        f"({t_refit * 1e3 / np.percentile(upd, 50):.1f}x)"
    )
    assert gp.y_train.shape[0] <= args.n, "sliding window must cap the set"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--tile", type=int, default=512)
    ap.add_argument("--batch", type=int, default=256, help="requests per batch")
    ap.add_argument("--batches", type=int, default=32)
    ap.add_argument(
        "--fleet",
        type=int,
        default=0,
        metavar="B",
        help="serve B independent GPs through one GPBatch program",
    )
    ap.add_argument(
        "--ragged",
        type=int,
        default=0,
        metavar="B",
        help="serve B differently-sized GPs through GPFleet + continuous batching",
    )
    ap.add_argument(
        "--online",
        action="store_true",
        help="interleave observation arrivals with requests (streaming updates)",
    )
    ap.add_argument(
        "--arrive", type=int, default=32, help="observations arriving per batch (--online/--ragged)"
    )
    ap.add_argument(
        "--metrics",
        metavar="OUT.jsonl",
        default=None,
        help="enable repro.obs telemetry and stream events to a JSONL file",
    )
    args = ap.parse_args()

    if args.metrics:
        obs.enable(args.metrics)
    cfg = MSDConfig()
    try:
        if args.ragged > 0:
            serve_ragged(args, cfg)
        elif args.online:
            serve_online(args, cfg)
        elif args.fleet > 0:
            serve_fleet(args, cfg)
        else:
            serve_single(args, cfg)
        if args.metrics:
            # health + cache tallies ride along as final events so the JSONL
            # is self-contained (no second file for the snapshot)
            snap = obs.snapshot()
            obs.event(
                "serve.health",
                counters={
                    k: v for k, v in snap["counters"].items()
                    if k.startswith("health.")
                },
            )
            obs.event("obs.cache_stats", caches=obs.cache_stats())
            print(f"metrics: wrote {len(obs.registry().events)}+ events to {args.metrics}")
    finally:
        if args.metrics:
            obs.disable()


if __name__ == "__main__":
    main()
