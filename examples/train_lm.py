"""LM training driver on the shared runtime (any --arch from the zoo).

Reduced configs run on CPU; full configs are for the TPU meshes (use
launch/dryrun.py to validate those).  Demonstrates the fault-tolerant
trainer: kill it mid-run and rerun the same command — it resumes.

    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 100
    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --size 100m --steps 200
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.synthetic import token_batches
from repro.models import transformer as tf
from repro.optim import Adam, cosine_warmup
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer


def build_config(arch: str, size: str):
    if size == "smoke":
        return configs.get_smoke_config(arch)
    if size == "100m":
        # ~100M-parameter variant of the chosen family
        base = configs.get_smoke_config(arch)
        return dataclasses.replace(
            base,
            n_layers=max(8, len(base.pattern) * 4),
            d_model=512,
            n_heads=8,
            n_kv_heads=min(8, max(base.n_kv_heads, 2)),
            head_dim=64,
            d_ff=2048 if base.d_ff else 0,
            moe_d_ff=512 if base.n_experts else 0,
            vocab_size=32768,
            rnn_width=512 if base.rnn_width else None,
        )
    return configs.get_config(arch)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list(configs.ARCH_IDS))
    ap.add_argument("--size", default="smoke", choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = build_config(args.arch, args.size)
    if cfg.input_mode == "embeddings":
        raise SystemExit(f"{args.arch} takes stub embeddings; use the dry-run for it")
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    opt = Adam(learning_rate=cosine_warmup(args.lr, args.steps // 10, args.steps))
    step_fn, _ = make_train_step(cfg, opt, donate=False)

    def data_fn(step):
        t, l = next(token_batches(cfg.vocab_size, args.batch, args.seq, seed=step))
        return jnp.asarray(t), jnp.asarray(l)

    trainer = Trainer(
        step_fn, params, opt.init(params), data_fn,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, log_every=10,
    )
    rep = trainer.run(args.steps)
    print(
        f"done: {rep.steps} steps, loss {rep.losses[0]:.4f} -> {rep.last_loss:.4f}, "
        f"median step {rep.median_step_time()*1e3:.1f} ms, "
        f"stragglers {rep.stragglers}"
    )


if __name__ == "__main__":
    main()
