"""Pallas kernel: tiled covariance assembly for any registered kernel family.

The paper assembles the covariance matrix with custom CUDA kernels, one tile
per task, asynchronously alongside the factorization.  This is the TPU
analogue: one `pallas_call` assembles a *batch* of tiles — the whole packed
lower triangle, or one cross-covariance tile grid — with each grid step
computing one (m × mb) tile entirely in VMEM.

Design notes (HBM→VMEM→MXU):
  * the kernel family is pluggable (DESIGN.md §13): the tile body calls
    ``kernel.kfree(params, xa, xb)`` with the hyperparameter pytree lowered
    to *host constants* — every family's math (expanded-form distances on
    the MXU, exp/sqrt/log on the VPU) is Pallas-body safe, and baking the
    params keeps the kernel free of scalar operands.  Traced params can't be
    baked; the executor routes those to the differentiable jnp tile instead.
  * feature blocks are small ((m, D), D ≲ 16 for SI workloads), so the
    operand tiles always fit VMEM (m=512, D=16 → 32 KiB per operand).
  * global row/col offsets for diagonal/padding masks arrive as (1,)-blocks
    of i32 arrays indexed by the same grid step.
  * symmetric (training) tiles pin the global diagonal to the exact
    ``diag + noise`` constant instead of trusting the cancellation-prone
    |a|²+|b|²−2a·bᵀ distance form (bitwise ``v + σ²`` even for
    large-magnitude f32 inputs).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cov_tile_kernel(
    xa_ref,
    xb_ref,
    row0_ref,
    col0_ref,
    nvr_ref,
    nvc_ref,
    o_ref,
    *,
    kernel,
    params,
    diag: float,
    symmetric: bool,
):
    xa = xa_ref[0]                      # (m, D)
    xb = xb_ref[0]                      # (mb, D)
    row0 = row0_ref[0]
    col0 = col0_ref[0]
    n_valid_r = nvr_ref[0]
    n_valid_c = nvc_ref[0]
    k = kernel.kfree(params, xa, xb)
    gi = row0 + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)
    gj = col0 + jax.lax.broadcasted_iota(jnp.int32, k.shape, 1)
    on_diag = gi == gj
    valid = (gi < n_valid_r) & (gj < n_valid_c)
    if symmetric:
        k = jnp.where(on_diag, jnp.asarray(diag, k.dtype), k)
        k = jnp.where(valid, k, on_diag.astype(k.dtype))
    else:
        k = jnp.where(valid, k, jnp.zeros((), k.dtype))
    o_ref[0] = k.astype(o_ref.dtype)


def cov_tiles(
    xa_stack: jax.Array,    # (T, m, D)  row feature chunks per tile
    xb_stack: jax.Array,    # (T, mb, D) col feature chunks per tile
    row0: jax.Array,        # (T,) i32 global row offsets
    col0: jax.Array,        # (T,) i32 global col offsets
    *,
    kernel=None,
    params=None,
    lengthscale: Optional[float] = None,
    vertical: Optional[float] = None,
    noise: Optional[float] = None,
    n_valid_r,
    n_valid_c,
    symmetric: bool,
    interpret: bool = True,
) -> jax.Array:
    """Assemble a batch of covariance tiles: returns (T, m, mb).

    Pass ``kernel=`` (a ``repro.core.kernels_math.Kernel``) with its
    ``params`` pytree — the params must be concrete; they are baked into the
    kernel as compile-time constants.  The legacy SE spelling
    (``lengthscale=/vertical=/noise=`` floats) is still accepted.

    ``n_valid_r``/``n_valid_c`` may be scalars (one mask for every tile) or
    (T,) arrays (a per-tile mask — the ragged-batch path, where tiles of B
    different problems share one grid and each carries its problem's
    validity frontier).  Either way they become (1,)-block i32 operands
    indexed by the grid step, exactly like ``row0``/``col0``.
    """
    from repro.core import kernels_math as km

    if kernel is None:
        kernel = km.SQUARED_EXPONENTIAL
        params = km.SEKernelParams(
            float(lengthscale), float(vertical), float(noise)
        )
    else:
        kernel = km.resolve_kernel(kernel)
        params = km.concrete_params(params)
    t, m, d = xa_stack.shape
    mb = xb_stack.shape[1]
    nvr = jnp.broadcast_to(jnp.asarray(n_valid_r, jnp.int32), (t,))
    nvc = jnp.broadcast_to(jnp.asarray(n_valid_c, jnp.int32), (t,))
    kern = functools.partial(
        _cov_tile_kernel,
        kernel=kernel,
        params=params,
        diag=float(kernel.diag(params)) + float(kernel.noise(params)),
        symmetric=symmetric,
    )
    return pl.pallas_call(
        kern,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, m, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, mb, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, m, mb), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m, mb), xa_stack.dtype),
        interpret=interpret,
    )(xa_stack, xb_stack, row0.astype(jnp.int32), col0.astype(jnp.int32), nvr, nvc)
