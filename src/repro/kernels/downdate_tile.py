"""Pallas kernel: fused carry transform of the block Cholesky up/downdate.

The rank-b update/downdate sweep (DESIGN.md §10) rewrites each sub-diagonal
carry block once per column:

    W_i <- (W_i - L'(i,j) Y_j) C_j^{-T}

i.e. one (m × m)·(m × m) matmul (MXU) followed by a right triangular solve
against the small correction factor C_j = chol(I ∓ Y_jᵀY_j).  Fusing both
into one VMEM pass avoids materializing the intermediate W_i - L'(i,j) Y_j
in HBM between two launches — the carry is touched once per column per row,
so this is the bandwidth-critical op of the update sweep (the analogue of
the trailing update in the factorization).

The solve loop is the same column recurrence as the TRSM panel kernel
(X · Cᵀ = B):  X[:, j] = (B[:, j] - Σ_{k<j} X[:, k] C[j, k]) / C[j, j],
every step one masked (m × m)·(m,) matvec — no scalar code.  Accumulation
is in f32 (f64 preserved when given, matching the POTRF tile kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _carry_kernel(w_ref, l_ref, y_ref, c_ref, o_ref):
    dt = jnp.promote_types(w_ref.dtype, jnp.float32)  # keep f64 if given
    w = w_ref[0].astype(dt)
    l = l_ref[0].astype(dt)
    y = y_ref[0].astype(dt)
    c = c_ref[0].astype(dt)
    b = w - l @ y                                     # MXU: the carry residual
    m = c.shape[0]
    idx = lax.iota(jnp.int32, m)
    x0 = jnp.zeros_like(b)

    def body(j, x):
        crow = lax.dynamic_slice_in_dim(c, j, 1, axis=0)[0]           # (m,)
        cjj = lax.dynamic_index_in_dim(crow, j, keepdims=False)
        crow = jnp.where(idx < j, crow, 0.0)                          # k < j
        s = x @ crow                                                  # (m,)
        bcol = lax.dynamic_slice_in_dim(b, j, 1, axis=1)[:, 0]
        col = (bcol - s) / cjj
        return lax.dynamic_update_slice_in_dim(x, col[:, None], j, axis=1)

    x = lax.fori_loop(0, m, body, x0)
    o_ref[0] = x.astype(o_ref.dtype)


def carry_update(
    w: jax.Array,
    l_new: jax.Array,
    y: jax.Array,
    c: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """(W - L' Y) C^{-T} for one carry tile; all operands (m, m)."""
    m = w.shape[-1]
    spec = pl.BlockSpec((1, m, m), lambda i: (0, 0, 0))
    return pl.pallas_call(
        _carry_kernel,
        grid=(1,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((1, m, m), w.dtype),
        interpret=interpret,
    )(w[None], l_new[None], y[None], c[None])[0]


def carry_update_batched(
    w_stack: jax.Array,
    l_stack: jax.Array,
    y_stack: jax.Array,
    c_stack: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """One launch covering a whole wave of carry transforms (G, m, m)."""
    g, m, _ = w_stack.shape
    spec = pl.BlockSpec((1, m, m), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _carry_kernel,
        grid=(g,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((g, m, m), w_stack.dtype),
        interpret=interpret,
    )(w_stack, l_stack, y_stack, c_stack)
