"""Pallas TPU kernels for the tiled GP hot spots.

The paper optimizes covariance assembly with custom CUDA kernels and runs the
tile BLAS through cuBLAS/cuSOLVER.  Here each tile-op class is an explicit
VMEM-tiled Pallas kernel (validated in interpret mode on CPU, lowered through
Mosaic on TPU):

  cov_assembly.py     batched SE-kernel covariance tiles (+ diag/padding masks)
  potrf_tile.py       single-tile Cholesky in VMEM
  trsm_tile.py        tile triangular solve X·Lᵀ = B (+ panel-batched form)
  trailing_update.py  fused batched SYRK/GEMM  C −= A·Bᵀ  (MXU-blocked)
  flash_attention.py  forward flash attention (online softmax, GQA) — the
                      identified fix for the prefill-cell memory roofline

ops.py — jit'd wrappers / dispatch;  ref.py — pure-jnp oracles for tests.
"""
