"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the corresponding kernel is tested
against (tests/test_kernels_*.py sweep shapes and dtypes and assert allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_cov_tile(
    xa: jax.Array,
    xb: jax.Array,
    row0: int,
    col0: int,
    *,
    lengthscale: float,
    vertical: float,
    noise: float,
    n_valid_r: int,
    n_valid_c: int,
    symmetric: bool,
) -> jax.Array:
    """One (m, mb) covariance tile of the padded SE kernel matrix.

    symmetric=True: training matrix semantics — the global diagonal pinned
    to the exact ``vertical + noise`` (never computed through the
    cancellation-prone expanded distance form), identity on the padded
    region.  False: cross-covariance — padded region is zero.
    """
    d2 = (
        jnp.sum(xa * xa, -1)[:, None]
        + jnp.sum(xb * xb, -1)[None, :]
        - 2.0 * (xa @ xb.T)
    )
    d2 = jnp.maximum(d2, 0.0)
    k = vertical * jnp.exp(-0.5 / lengthscale * d2)
    gi = row0 + jnp.arange(xa.shape[0])[:, None]
    gj = col0 + jnp.arange(xb.shape[0])[None, :]
    on_diag = gi == gj
    valid = (gi < n_valid_r) & (gj < n_valid_c)
    if symmetric:
        k = jnp.where(on_diag, jnp.asarray(vertical + noise, k.dtype), k)
        return jnp.where(valid, k, on_diag.astype(k.dtype))
    return jnp.where(valid, k, jnp.zeros((), k.dtype))


def ref_potrf(a: jax.Array) -> jax.Array:
    """Lower Cholesky factor of one SPD tile."""
    return jnp.linalg.cholesky(a)


def ref_trsm(ljj: jax.Array, b: jax.Array) -> jax.Array:
    """Solve X @ L^T = B with L lower triangular (right-looking panel op)."""
    return jax.lax.linalg.triangular_solve(
        ljj, b, left_side=False, lower=True, transpose_a=True
    )


def ref_trailing_update(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched C_i <- C_i - A_i @ B_i^T (SYRK when a is b)."""
    return c - jnp.einsum("bik,bjk->bij", a, b)
