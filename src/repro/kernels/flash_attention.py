"""Pallas kernel: forward flash attention (online softmax, VMEM-blocked).

The §Perf analysis (EXPERIMENTS.md) shows every prefill cell is bound by the
(B, H, S, T) attention-score HBM traffic of the XLA implementation — S²-sized
buffers stream through HBM even when q-chunked.  This kernel removes that
traffic entirely: scores exist only as a (bq × bk) block in VMEM; HBM sees
just Q, K, V, O (4·S·hd per head instead of S²).

Canonical online-softmax recurrence over kv blocks (k innermost grid dim,
running stats in VMEM scratch):

    m' = max(m, rowmax(S_blk));  c = exp(m − m')
    l  = l·c + rowsum(exp(S_blk − m'))
    acc = acc·c + exp(S_blk − m') @ V_blk
    output (last block) = acc / l

Causality is handled per-block: fully-masked blocks are skipped via the
grid's lower-triangular structure check inside the kernel (`pl.when`).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0**30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, bq: int, bk: int, nk: int, softcap,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (j * bk <= i * bq + bq - 1)  # any unmasked entry?
    if causal:
        run_pred = j * bk <= i * bq + (bq - 1)
    else:
        run_pred = True

    @pl.when(run_pred)
    def _block():
        q = q_ref[...].astype(jnp.float32)              # (bq, hd)
        k = k_ref[...].astype(jnp.float32)              # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        if causal:
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                  # (bq, bk)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[...].astype(jnp.float32)               # (bk, hd)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_single(
    q: jax.Array,       # (S, hd)
    k: jax.Array,       # (T, hd)
    v: jax.Array,       # (T, hd)
    *,
    causal: bool = True,
    softcap=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """One head: O(S·hd) HBM traffic, scores only ever block-resident."""
    s, hd = q.shape
    t = k.shape[0]
    bq, bk = min(block_q, s), min(block_k, t)
    if s % bq or t % bk:
        raise ValueError(f"seq {s}/{t} must divide blocks {bq}/{bk}")
    nq, nk = s // bq, t // bk
    kern = functools.partial(
        _flash_kernel,
        scale=1.0 / math.sqrt(hd),
        causal=causal,
        bq=bq,
        bk=bk,
        nk=nk,
        softcap=softcap,
    )
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kern,
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((bq, hd), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, hd), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, hd), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, hd), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(
    q: jax.Array,       # (B, S, H, hd)
    k: jax.Array,       # (B, T, KV, hd)
    v: jax.Array,       # (B, T, KV, hd)
    *,
    causal: bool = True,
    softcap=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Batched GQA wrapper: maps heads onto their KV group."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)

    fn = functools.partial(
        flash_attention_single,
        causal=causal,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    # vmap over batch, kv-head, and group dims
    inner = jax.vmap(fn, in_axes=(0, None, None))            # group
    per_kv = jax.vmap(inner, in_axes=(0, 0, 0))              # kv head
    per_b = jax.vmap(per_kv, in_axes=(0, 0, 0))              # batch
    out = per_b(
        qg.transpose(0, 2, 3, 1, 4),                          # (B,KV,G,S,hd)
        k.transpose(0, 2, 1, 3),                              # (B,KV,T,hd)
        v.transpose(0, 2, 1, 3),
    )                                                         # (B,KV,G,S,hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
