"""Jit'd dispatch wrappers for the Pallas kernels.

Exposes the same per-tile signatures as the jnp backend in
``repro.core.cholesky`` (so the level scheduler can vmap them uniformly) plus
the batched entry points and the covariance-assembly routines used by
``repro.core.predict``.

``interpret=True`` is selected automatically off-TPU: the kernel bodies
execute in Python on CPU, which is how this container validates them; on a
real TPU the same `pallas_call`s lower through Mosaic.

Differentiability (DESIGN.md §8): the per-tile ops carry ``jax.custom_vjp``
hooks whose backward passes differentiate the *jnp reference* implementation
of the same tile op (``jnp.linalg.cholesky`` / ``triangular_solve`` / the
rank-update matmuls).  The Pallas kernel is only the forward primal, so the
tiled NLML program stays traceable under ``jax.grad`` with
``op_backend="pallas"`` — gradients are mathematically identical to the jnp
backend because both backends compute the same function.  Covariance
*assembly* still bakes hyperparameters in as compile-time constants; when
the hyperparameters are traced (a gradient trace) the executor falls back to
the differentiable jnp assembly tile automatically
(``repro.core.executor._cov_batch_fn``).

Problem batching (DESIGN.md §9): these per-tile signatures are what makes
the executor's problem-batch dimension free on the Pallas backend.  A tile
op never knows *which* problem a tile belongs to, so the executor's
``batch_dispatch="flat"`` mode reshapes the gathered ``(B, G, m, m)``
operands to ``(B*G, m, m)`` and the single ``jax.vmap`` level that batches
a level's tiles becomes the Pallas grid axis covering all B problems — B is
absorbed into the grid of ONE kernel launch.  ``batch_dispatch="vmap"``
instead nests a second ``jax.vmap`` over the problem axis (two batching
dims on the ``pallas_call``).  Both are measured by
``benchmarks/fig9_batched_fleet.py``; the *assembly* kernels stay
single-problem because their baked-in hyperparameters cannot vary across
the batch (per-problem params use the jnp tile kernel,
``executor._cov_batch_fn_batched``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling
from repro.kernels import cov_assembly as _cov
from repro.kernels import downdate_tile as _down
from repro.kernels import lrgemm_tile as _lrgemm
from repro.kernels import potrf_tile as _potrf
from repro.kernels import trailing_update as _trail
from repro.kernels import trsm_tile as _trsm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Per-tile ops (vmap-compatible, mirror repro.core.cholesky jnp backend).
# ---------------------------------------------------------------------------


def _cast(x, dt):
    return x if dt is None else x.astype(dt)


# jnp reference tile ops used for the custom-VJP backward passes.  Both
# backends compute the same mathematical function per tile, so the reference
# VJP is the exact gradient of the Pallas forward.

def _potrf_ref(a):
    return jnp.linalg.cholesky(a)


def _trsm_ref(ljj, b):
    return jax.lax.linalg.triangular_solve(
        ljj, b, left_side=False, lower=True, transpose_a=True
    )


def _syrk_ref(update_dtype):
    def f(kii, lij):
        a = _cast(lij, update_dtype)
        return kii - (a @ a.T).astype(kii.dtype)

    return f


def _gemm_ref(update_dtype):
    def f(kik, lij, lkj):
        a, b = _cast(lij, update_dtype), _cast(lkj, update_dtype)
        return kik - (a @ b.T).astype(kik.dtype)

    return f


def _with_ref_vjp(primal, ref):
    """Wrap a Pallas tile op so its VJP differentiates the jnp reference."""
    f = jax.custom_vjp(primal)

    def fwd(*args):
        return primal(*args), args

    def bwd(args, g):
        _, vjp = jax.vjp(ref, *args)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def _potrf_impl(a: jax.Array) -> jax.Array:
    return _potrf.potrf(a, interpret=_interpret())


def _trsm_impl(ljj: jax.Array, b: jax.Array) -> jax.Array:
    return _trsm.trsm(ljj, b, interpret=_interpret())


potrf = _with_ref_vjp(_potrf_impl, _potrf_ref)
trsm = _with_ref_vjp(_trsm_impl, _trsm_ref)


@functools.lru_cache(maxsize=None)
def _syrk_cv(update_dtype):
    def impl(kii, lij):
        out = _trail.trailing_update(
            kii[None],
            _cast(lij, update_dtype)[None],
            _cast(lij, update_dtype)[None],
            block=_pick_block(kii.shape[-1]),
            interpret=_interpret(),
        )[0]
        return out.astype(kii.dtype)

    return _with_ref_vjp(impl, _syrk_ref(update_dtype))


@functools.lru_cache(maxsize=None)
def _gemm_cv(update_dtype):
    def impl(kik, lij, lkj):
        out = _trail.trailing_update(
            kik[None],
            _cast(lij, update_dtype)[None],
            _cast(lkj, update_dtype)[None],
            block=_pick_block(kik.shape[-1]),
            interpret=_interpret(),
        )[0]
        return out.astype(kik.dtype)

    return _with_ref_vjp(impl, _gemm_ref(update_dtype))


def syrk(kii: jax.Array, lij: jax.Array, update_dtype=None) -> jax.Array:
    return _syrk_cv(update_dtype)(kii, lij)


def gemm(kik: jax.Array, lij: jax.Array, lkj: jax.Array, update_dtype=None) -> jax.Array:
    return _gemm_cv(update_dtype)(kik, lij, lkj)


def _pick_block(m: int) -> int:
    # largest power-of-two block <= min(m, 256); MXU-aligned when m >= 128
    b = 1
    while b * 2 <= min(m, 256):
        b *= 2
    return b


def _lrgemm_ref(a, v):
    return a @ v


def _lrgemm_impl(a: jax.Array, v: jax.Array) -> jax.Array:
    return _lrgemm.lrgemm(a, v, interpret=_interpret())


# low-rank contraction tile (DESIGN.md §14); the reference VJP keeps the
# lowrank NLML differentiable under op_backend="pallas"
lrgemm = _with_ref_vjp(_lrgemm_impl, _lrgemm_ref)


def carry_update(w: jax.Array, l_new: jax.Array, y: jax.Array, c: jax.Array) -> jax.Array:
    """Fused up/downdate carry transform  (W - L' Y) C^{-T}  (DESIGN.md §10).

    The streaming-update sweep is not differentiated (it maintains a cached
    posterior, it is not a training path), so no reference VJP is attached.
    """
    return _down.carry_update(w, l_new, y, c, interpret=_interpret())


# ---------------------------------------------------------------------------
# Batched entry points (one kernel launch per scheduler level).
# ---------------------------------------------------------------------------


def trsm_panel(ljj: jax.Array, b_stack: jax.Array) -> jax.Array:
    return _trsm.trsm_batched(ljj, b_stack, interpret=_interpret())


def trailing_update_batch(c_stack, a_stack, b_stack, *, update_dtype=None):
    return _trail.trailing_update(
        c_stack,
        _cast(a_stack, update_dtype),
        _cast(b_stack, update_dtype),
        block=_pick_block(c_stack.shape[-1]),
        interpret=_interpret(),
    ).astype(c_stack.dtype)


# ---------------------------------------------------------------------------
# Covariance assembly (paper's custom CUDA kernels → Pallas).
# ---------------------------------------------------------------------------


def assemble_packed_covariance(
    x_chunks: jax.Array, params, n_valid, kernel=None
) -> jax.Array:
    """(M, m, D) padded chunks -> packed lower covariance tiles (T, m, m).

    ``kernel`` picks the registered covariance family (None -> the paper's
    SE).  Hyperparameters must be concrete (the Pallas path bakes them in as
    compile-time constants; use the jnp backend for NLML differentiation).
    ``n_valid`` may be a Python int or a traced scalar — it reaches the
    kernel as a (1,)-block i32 operand, not a compile-time constant.
    """
    from repro.core import kernels_math as km

    m_tiles, m, _ = x_chunks.shape
    rows, cols = tiling._packed_coords(m_tiles)
    return _cov.cov_tiles(
        x_chunks[rows],
        x_chunks[cols],
        jnp.asarray(rows * m, jnp.int32),
        jnp.asarray(cols * m, jnp.int32),
        kernel=km.resolve_kernel(kernel),
        params=params,
        n_valid_r=n_valid,
        n_valid_c=n_valid,
        symmetric=True,
        interpret=_interpret(),
    )


def assemble_cross_tiles(
    xt_chunks: jax.Array, x_chunks: jax.Array, params, nt_valid, n_valid, kernel=None
) -> jax.Array:
    """K_{X̂,X} tile grid (Mhat, M, m, m) via one batched kernel launch."""
    from repro.core import kernels_math as km

    mh, m, _ = xt_chunks.shape
    mt = x_chunks.shape[0]
    rows = np.repeat(np.arange(mh), mt)
    cols = np.tile(np.arange(mt), mh)
    flat = _cov.cov_tiles(
        xt_chunks[rows],
        x_chunks[cols],
        jnp.asarray(rows * m, jnp.int32),
        jnp.asarray(cols * m, jnp.int32),
        kernel=km.resolve_kernel(kernel),
        params=params,
        n_valid_r=nt_valid,
        n_valid_c=n_valid,
        symmetric=False,
        interpret=_interpret(),
    )
    return flat.reshape(mh, mt, m, m)
