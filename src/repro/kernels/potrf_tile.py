"""Pallas kernel: single-tile Cholesky factorization (POTRF) in VMEM.

One diagonal tile (m × m) is loaded into VMEM and factored with an unblocked,
fully-vectorized right-looking loop: at step j the pivot column is scaled and
a rank-1 outer-product update is applied to the trailing block via masked
whole-tile VPU ops (no scalar loops — every step is (m,) / (m, m) wide).

On real TPU hardware a production POTRF would internally block for the MXU
(e.g. 128-wide panels with DGEMM updates); POTRF is however only M of the
M(M+1)(M+2)/6 tile tasks (<2% of FLOPs for M ≥ 8) — the MXU-critical path is
the trailing update kernel, not this one.  Tile sizes up to 1024 fit VMEM
comfortably (1024² f32 = 4 MiB).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _potrf_kernel(a_ref, o_ref):
    a = a_ref[...]
    a = a.astype(jnp.promote_types(a.dtype, jnp.float32))  # keep f64 if given
    n = a.shape[0]
    idx = lax.iota(jnp.int32, n)

    def body(j, a):
        col = lax.dynamic_slice_in_dim(a, j, 1, axis=1)[:, 0]         # (n,)
        piv = jnp.sqrt(lax.dynamic_index_in_dim(col, j, keepdims=False))
        lcol = jnp.where(idx > j, col / piv, 0.0)                     # strict
        a = a - lcol[:, None] * lcol[None, :]                         # rank-1
        new_col = jnp.where(idx > j, lcol, jnp.where(idx == j, piv, col))
        return lax.dynamic_update_slice_in_dim(a, new_col[:, None], j, axis=1)

    a = lax.fori_loop(0, n, body, a)
    o_ref[...] = jnp.tril(a).astype(o_ref.dtype)


def potrf(a: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Lower Cholesky factor of one SPD tile (m, m)."""
    m = a.shape[-1]
    return pl.pallas_call(
        _potrf_kernel,
        in_specs=[pl.BlockSpec((m, m), lambda: (0, 0))],
        out_specs=pl.BlockSpec((m, m), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), a.dtype),
        interpret=interpret,
    )(a)
