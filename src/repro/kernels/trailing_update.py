"""Pallas kernel: fused trailing-submatrix update (batched SYRK/GEMM).

The MXU-critical operation of the tiled Cholesky: for every trailing tile
(I, K) of step J,   C_IK ← C_IK − L_IJ · L_KJᵀ.   The level scheduler batches
all updates of one step into a single `pallas_call` whose grid is

    (batch, m/bm, m/bn, m/bk)

with a canonical K-innermost accumulation: the output block stays resident in
VMEM across the k steps (block revisiting), operand blocks stream HBM→VMEM,
and each inner step is one (bm × bk)·(bk × bn)ᵀ MXU contraction.  Block sizes
default to 256 (multiples of the 128-wide MXU); operands at bm=bn=bk=256 use
3 · 256 KiB of VMEM — far below the ~16 MiB budget, leaving room for
double-buffered pipelining by the Mosaic compiler.

SYRK (diagonal tiles) reuses the same kernel with A == B; the symmetric
half-FLOP saving is intentionally not exploited (uniform batched shape beats
a divergent special case on the MXU — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(c_ref, a_ref, b_ref, o_ref, *, nk: int, out_dtype):
    k = pl.program_id(3)
    upd = jax.lax.dot_general(
        a_ref[0],
        b_ref[0],
        (((1,), (1,)), ((), ())),            # contract on dim 1 of both: A·Bᵀ
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == 0)
    def _init():
        o_ref[0] = (c_ref[0].astype(jnp.float32) - upd).astype(out_dtype)

    @pl.when(k != 0)
    def _acc():
        o_ref[0] = (o_ref[0].astype(jnp.float32) - upd).astype(out_dtype)


def trailing_update(
    c_stack: jax.Array,     # (B, m, m) trailing tiles C_IK
    a_stack: jax.Array,     # (B, m, m) panel tiles L_IJ
    b_stack: jax.Array,     # (B, m, m) panel tiles L_KJ
    *,
    block: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Batched C − A·Bᵀ with VMEM-blocked MXU accumulation."""
    bsz, m, _ = c_stack.shape
    bm = bn = bk = min(block, m)
    if m % bm:
        raise ValueError(f"tile size {m} must divide block {bm}")
    nk = m // bk
    kern = functools.partial(_update_kernel, nk=nk, out_dtype=c_stack.dtype)
    return pl.pallas_call(
        kern,
        grid=(bsz, m // bm, m // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda b, i, j, k: (b, i, j)),
            pl.BlockSpec((1, bm, bk), lambda b, i, j, k: (b, i, k)),
            pl.BlockSpec((1, bn, bk), lambda b, i, j, k: (b, j, k)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct(c_stack.shape, c_stack.dtype),
        interpret=interpret,
    )(c_stack, a_stack, b_stack)
