"""Pallas kernel: tile triangular solve  X · Lᵀ = B  (right-looking TRSM).

This is the panel operation of the tiled Cholesky: given the freshly factored
diagonal tile L (lower) and a sub-diagonal tile B, compute
X = B · L^{-T}, i.e. column j of X is

    X[:, j] = ( B[:, j] − Σ_{k<j} X[:, k] · L[j, k] ) / L[j, j]

Both operands live in VMEM; each step does one (m × m)·(m,) masked matvec on
the VPU/MXU plus a scale — no scalar code.  The batched form used by the
level scheduler maps the tile batch onto the leading grid dimension.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _trsm_kernel(l_ref, b_ref, o_ref):
    l = l_ref[...].astype(jnp.float32)          # (m, m) lower
    b = b_ref[0].astype(jnp.float32)            # (m, m) RHS
    m = l.shape[0]
    idx = lax.iota(jnp.int32, m)
    x0 = jnp.zeros_like(b)

    def body(j, x):
        lrow = lax.dynamic_slice_in_dim(l, j, 1, axis=0)[0]           # (m,)
        ljj = lax.dynamic_index_in_dim(lrow, j, keepdims=False)
        lrow = jnp.where(idx < j, lrow, 0.0)                          # k < j
        s = x @ lrow                                                  # (m,)
        bcol = lax.dynamic_slice_in_dim(b, j, 1, axis=1)[:, 0]
        col = (bcol - s) / ljj
        return lax.dynamic_update_slice_in_dim(x, col[:, None], j, axis=1)

    x = lax.fori_loop(0, m, body, x0)
    o_ref[0] = x.astype(o_ref.dtype)


def trsm(ljj: jax.Array, b: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Solve X @ Lᵀ = B for one tile; ljj (m, m) lower, b (m, m)."""
    m = ljj.shape[-1]
    return pl.pallas_call(
        _trsm_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m, m), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, m), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, m, m), b.dtype),
        interpret=interpret,
    )(ljj, b[None])[0]


def trsm_batched(ljj: jax.Array, b_stack: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Solve X_i @ Lᵀ = B_i for a stack of tiles: the whole TRSM panel of one
    factorization step as a single kernel launch (level-batched execution)."""
    t, m, _ = b_stack.shape
    return pl.pallas_call(
        _trsm_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m, m), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m, m), b_stack.dtype),
        interpret=interpret,
    )(ljj, b_stack)
