"""Pallas kernel: low-rank contraction tile  c = A · v  (LRGEMM, DESIGN.md §14).

The n-side work of the Nyström inner system is a stack of independent tile
matvecs over the K_un grid: task (p, j) contracts cross-covariance tile
K_un[p, j] (rows = inducing points, cols = training points) with training
chunk v_j.  One (m × m)·(m,) product on the MXU per grid step; the executor
either vmaps the single-tile entry (its batch axis becomes the Pallas grid)
or issues :func:`lrgemm_tiles` directly for a pre-gathered stack.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lrgemm_kernel(a_ref, v_ref, o_ref):
    a = a_ref[0].astype(jnp.float32)            # (m, mb) tile
    v = v_ref[0].astype(jnp.float32)            # (mb,) chunk
    o_ref[0] = (a @ v).astype(o_ref.dtype)


def lrgemm(a: jax.Array, v: jax.Array, *, interpret: bool = True) -> jax.Array:
    """One tile contraction a (m, mb) @ v (mb,) -> (m,)."""
    m, mb = a.shape
    return pl.pallas_call(
        _lrgemm_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, m, mb), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, mb), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, m), a.dtype),
        interpret=interpret,
    )(a[None], v[None])[0]


def lrgemm_tiles(
    a_stack: jax.Array, v_stack: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """The whole LRGEMM family as ONE launch: a_stack (G, m, mb), v_stack
    (G, mb) -> (G, m), tile batch on the leading grid dimension."""
    g, m, mb = a_stack.shape
    return pl.pallas_call(
        _lrgemm_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, m, mb), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, mb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, m), a_stack.dtype),
        interpret=interpret,
    )(a_stack, v_stack)
