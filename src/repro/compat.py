"""Shims over jax public-API drift (0.4.x vs >= 0.5 surfaces).

The repo targets the current jax surface (``jax.set_mesh``,
``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh``); older pins keep those under
``jax.experimental`` or lack them entirely.  Every shim degrades to the
same semantics on the old API:

* ``shard_map``       — top-level export, else the experimental home.
* ``make_mesh``       — all-Auto mesh; old jax has no axis types (every
                        axis behaves Auto), so the kwarg is simply dropped.
* ``set_mesh``        — ``jax.set_mesh`` where present; else the mesh is
                        tracked module-locally for :func:`shard_hint`.
* ``abstract_mesh``   — the ambient mesh's abstract view or None.
* ``shard_hint``      — with_sharding_constraint against the ambient mesh;
                        a no-op wherever a constraint is unrepresentable
                        (no mesh, or manual axes under old-jax shard_map).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec

try:  # jax >= 0.5: top-level export
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_SM_PARAMS = set(_inspect.signature(_shard_map).parameters)


def shard_map(*args, **kwargs):
    """jax.shard_map with the replication-check kwarg name normalized
    (``check_vma`` on new jax, ``check_rep`` on the experimental API)."""
    if "check_vma" in kwargs and "check_vma" not in _SM_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(*args, **kwargs)

try:  # jax >= 0.5.1
    from jax.sharding import AxisType
except ImportError:
    AxisType = None

_state = {"mesh": None}


def make_mesh(shape, axes):
    """An explicit all-Auto mesh on any jax version."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh) -> None:
    """Install the mesh context used by activation sharding constraints."""
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    else:
        _state["mesh"] = mesh


def abstract_mesh():
    """The ambient mesh (abstract view), or None outside any mesh context."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        m = get()
        return None if m is None or not m.axis_names else m
    m = _state["mesh"]
    return None if m is None else m.abstract_mesh


def auto_axis_names(mesh) -> set:
    """Mesh axes eligible for sharding constraints (Auto axes).

    Old jax has no axis types; every axis of a tracked mesh behaves Auto.
    """
    types = getattr(mesh, "axis_types", None)
    if AxisType is None or types is None:
        return set(mesh.axis_names)
    return {n for n, t in zip(mesh.axis_names, types) if t == AxisType.Auto}


def axis_size(name) -> int:
    """Static size of a mapped mesh axis (``lax.axis_size`` on new jax).

    Old jax lacks the helper; ``psum`` of the literal 1 constant-folds to the
    axis size there, staying a static Python int usable in shapes.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return int(jax.lax.psum(1, name))


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on any jax version.

    Old jax returns a one-element list of per-program dicts; new jax returns
    the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def shard_hint(x, spec: PartitionSpec):
    """``with_sharding_constraint(x, spec)`` against the ambient mesh.

    On new jax the bare PartitionSpec binds to the set_mesh context.  On old
    jax the constraint needs the concrete tracked mesh; inside shard_map
    (manual axes) such a constraint is unrepresentable and the hint must be
    a no-op, which surfaces as a trace-time error we swallow.
    """
    if hasattr(jax, "set_mesh"):
        return jax.lax.with_sharding_constraint(x, spec)
    m = _state["mesh"]
    if m is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))
    except Exception:
        return x
