"""Learning-rate schedules."""

from __future__ import annotations

import math

import jax.numpy as jnp


def cosine_warmup(peak: float, warmup: int, total: int, floor: float = 0.1):
    """Linear warmup to ``peak`` then cosine decay to ``floor * peak``."""

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(math.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return schedule
