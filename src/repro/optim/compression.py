"""Gradient compression with error feedback (distributed-optimization trick).

Int8 uniform quantization with a per-tensor-chunk max-abs scale.  The
quantization residual is carried in a local *error-feedback* buffer and added
to the next step's gradient, which is what keeps compressed SGD/Adam
convergent (Seide et al. 2014; Karimireddy et al. 2019).

Two entry points:

* ``compress``/``decompress`` — the pure quantizer (unit-tested, bounded
  error: |g − deq(q(g))| ≤ scale/2 elementwise).
* ``compressed_psum`` — a shard_map building block: quantize local grads,
  all_gather the int8 payload + scales over the DP axis (4× less wire volume
  than an fp32 all-reduce ring transfer), dequantize and average locally.

The manual-DP train-step variant in ``repro.train.train_step`` uses this on
the ``pod`` axis — the slow cross-DCI hop — which is where 4× compression
buys real wall-clock at multi-pod scale.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def compress(g: jax.Array, chunk: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """g (any shape) -> (int8 payload (n_chunks, chunk), f32 scales (n_chunks,))."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(chunks), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(chunks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array, shape, size: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_with_feedback(g: jax.Array, err: jax.Array, chunk: int = 4096):
    """Error-feedback wrapper: returns (q, scale, new_err)."""
    g_corr = g.astype(jnp.float32) + err
    q, scale = compress(g_corr, chunk)
    deq = decompress(q, scale, g.shape, g.size)
    return q, scale, g_corr - deq


def compressed_psum(g: jax.Array, err: jax.Array, axis: str, chunk: int = 4096):
    """Mean of g over mesh axis `axis` via int8 all-gather; error feedback.

    Call inside shard_map.  Returns (g_mean, new_err).
    """
    q, scale, new_err = compress_with_feedback(g, err, chunk)
    qs = lax.all_gather(q, axis, axis=0)                 # (P, n_chunks, chunk)
    ss = lax.all_gather(scale, axis, axis=0)             # (P, n_chunks)
    total = jnp.einsum("pnc,pn->nc", qs.astype(jnp.float32), ss)
    n = lax.psum(1, axis)
    mean = (total / n).reshape(-1)[: g.size].reshape(g.shape)
    return mean.astype(g.dtype), new_err
