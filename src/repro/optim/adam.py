"""AdamW with float32 state, global-norm clipping, and decoupled weight decay.

States are kept in float32 regardless of the parameter dtype (bf16 training
keeps fp32 moments — the standard mixed-precision recipe); state pytrees
mirror the parameter tree so sharding rules apply transparently (each moment
inherits its parameter's PartitionSpec).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


@dataclasses.dataclass(frozen=True)
class Adam:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        return self.learning_rate(step) if callable(self.learning_rate) else self.learning_rate

    def update(self, grads, state, params) -> Tuple[Any, dict]:
        step = state["step"] + 1
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        t = step.astype(jnp.float32)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t
        lr = self._lr(step)

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}
