"""Optimizers and distributed-optimization utilities."""

from repro.optim.adam import Adam
from repro.optim.adafactor import Adafactor
from repro.optim.schedules import cosine_warmup

__all__ = ["Adam", "Adafactor", "cosine_warmup"]
