"""Adafactor (factored second moments) — the 480B-scale memory-frugal choice.

For a (r, c) parameter the second moment is stored as a rank-1 factorization
(row means + col means): O(r + c) instead of O(r·c).  Higher-rank tensors
factor over their two largest dims.  1-D params fall back to full moments.
No momentum by default (beta1=0 saves another full-size buffer) — this is
what makes arctic-480b trainable in 16 GB/chip (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def _factored_dims(shape) -> Optional[Tuple[int, int]]:
    if len(shape) < 2:
        return None
    dims = sorted(range(len(shape)), key=lambda i: shape[i])[-2:]
    return min(dims), max(dims)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-2
    decay: float = 0.8            # t^-decay running-average schedule
    eps: float = 1e-30
    clip_threshold: float = 1.0   # RMS update clipping
    min_dim_size_to_factor: int = 32

    def init(self, params) -> dict:
        def leaf(p):
            fd = _factored_dims(p.shape)
            if fd is not None and min(p.shape[fd[0]], p.shape[fd[1]]) >= self.min_dim_size_to_factor:
                r_shape = tuple(s for i, s in enumerate(p.shape) if i != fd[1])
                c_shape = tuple(s for i, s in enumerate(p.shape) if i != fd[0])
                return {
                    "vr": jnp.zeros(r_shape, jnp.float32),
                    "vc": jnp.zeros(c_shape, jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree.map(leaf, params, is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        return self.learning_rate(step) if callable(self.learning_rate) else self.learning_rate

    def update(self, grads, state, params) -> Tuple[Any, dict]:
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)
        lr = self._lr(step)

        def upd(p, g, v):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            fd = _factored_dims(p.shape)
            if "vr" in v:
                r, c = fd
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=c)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=r)
                denom_r = jnp.expand_dims(vr / jnp.mean(vr, axis=r, keepdims=True), c)
                denom_c = jnp.expand_dims(vc, r)
                u = g32 * jax.lax.rsqrt(denom_r * denom_c + self.eps)
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                u = g32 * jax.lax.rsqrt(vv + self.eps)
                new_v = {"v": vv}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_params, {"v": new_v, "step": step}
