"""chatglm3-6b [dense] — 2D/partial RoPE (fraction 0.5), GQA kv=2, QKV bias.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024
[arXiv:2406.12793; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,
    qkv_bias=True,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="arXiv:2406.12793; hf:THUDM/chatglm3-6b",
)

SMOKE = ModelConfig(
    name="chatglm3-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    rope_fraction=0.5,
    qkv_bias=True,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
)
