"""llava-next-34b [vlm] — transformer backbone only; anyres vision frontend
is a STUB (input_specs() provides precomputed patch embeddings).

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-34b-hf backbone (Yi-34B); unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=5000000.0,
    tie_embeddings=False,
    input_mode="embeddings",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="hf:llava-hf/llava-v1.6-34b-hf (Yi-34B backbone); unverified",
)

SMOKE = ModelConfig(
    name="llava-smoke",
    family="vlm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
    input_mode="embeddings",
)
