"""olmo-1b [dense] — non-parametric LayerNorm, SwiGLU, RoPE, no biases.

16L d_model=2048 16H (GQA kv=16 = MHA) d_ff=8192 vocab=50304
[arXiv:2402.00838; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="layernorm_np",
    mlp="swiglu",
    tie_embeddings=True,
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="arXiv:2402.00838; hf:allenai/OLMo-1B",
)

SMOKE = ModelConfig(
    name="olmo-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    norm="layernorm_np",
    mlp="swiglu",
    tie_embeddings=True,
)
