"""arctic-480b [moe] — 128 experts top-2 with a dense-FFN residual stream.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 +
dense residual  [hf:Snowflake/snowflake-arctic-base]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
    n_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,
    capacity_factor=1.25,
    router_group_size=4096,
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="hf:Snowflake/snowflake-arctic-base",
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=96,
    dense_residual=True,
    capacity_factor=2.0,
    router_group_size=64,
)
