"""qwen1.5-0.5b [dense] — QKV bias, RMSNorm, SwiGLU.

24L d_model=1024 16H (GQA kv=16 = MHA) d_ff=2816 vocab=151936
[hf:Qwen/Qwen1.5-0.5B]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=1000000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="hf:Qwen/Qwen1.5-0.5B",
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=256,
    qkv_bias=True,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=True,
)
