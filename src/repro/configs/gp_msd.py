"""The paper's own workload: tiled GP regression on mass-spring-damper SI data.

Problem sizes mirror the paper's evaluation (n up to 32768 on one device;
Fig. 3/4 use n=32768) plus the distributed sizes that motivate the multi-pod
extension (n beyond single-chip HBM).
"""

from repro.configs.base import GPShapeConfig

# Paper-scale single-device cells (Figs. 3, 4, 6, 7); tile sizes follow the
# paper's best configs (32 tiles/dim at n=32768).
GP_PAPER_32K = GPShapeConfig("gp_32k", n_train=32768, n_test=32768, tile_size=1024)
GP_PAPER_16K = GPShapeConfig("gp_16k", n_train=16384, n_test=16384, tile_size=512)

# Distributed cells (paper future work): K no longer fits one chip's HBM.
#   n=262144: K = 275 GB f32  -> 256 chips;  n=524288: K = 1.1 TB -> 512 chips
# Tile sizes keep the block-cyclic grid balanced: M = 16 × P rows so the
# split-TRSM path stays active (Mp divisible by Q, see core/distributed.py).
GP_DIST_32K = GPShapeConfig("gp_dist_32k", n_train=32768, n_test=16384, tile_size=128)
GP_DIST_256K = GPShapeConfig("gp_256k", n_train=262144, n_test=16384, tile_size=1024)
GP_DIST_512K = GPShapeConfig("gp_512k", n_train=524288, n_test=32768, tile_size=1024)

ALL_GP_SHAPES = (GP_PAPER_16K, GP_PAPER_32K, GP_DIST_32K, GP_DIST_256K, GP_DIST_512K)
