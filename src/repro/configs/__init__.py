"""Architecture registry: ``--arch <id>`` resolution for every driver."""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    GPShapeConfig,
    ModelConfig,
    ShapeConfig,
)

_MODULES: Dict[str, str] = {
    "gemma2-2b": "repro.configs.gemma2_2b",
    "olmo-1b": "repro.configs.olmo_1b",
    "qwen1.5-0.5b": "repro.configs.qwen15_0_5b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "arctic-480b": "repro.configs.arctic_480b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "musicgen-large": "repro.configs.musicgen_large",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)

# Sub-quadratic archs that run the long_500k decode cell; all others skip it
# (pure full-attention — noted in DESIGN.md §4 / EXPERIMENTS.md).
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "recurrentgemma-2b")


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).SMOKE


def shapes_for(arch: str) -> Tuple[ShapeConfig, ...]:
    """The assigned shape cells for one architecture (long_500k gated)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch in LONG_CONTEXT_ARCHS:
        out.append(LONG_500K)
    return tuple(out)


def all_cells():
    """Every (arch, shape) dry-run cell, long_500k included where applicable."""
    for arch in ARCH_IDS:
        for shape in shapes_for(arch):
            yield arch, shape
