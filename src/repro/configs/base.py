"""Model / shape / run configuration schema.

One ``ModelConfig`` describes any architecture in the zoo (dense / MoE /
hybrid-recurrent / SSM / modality-stub).  Shape cells (``ShapeConfig``) are
the assigned input-shape set; ``arch × shape`` pairs form the dry-run grid.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default: d_model // n_heads

    # layer pattern: cycled over depth.  kinds: global, local, rglru, mamba2
    pattern: Tuple[str, ...] = ("global",)
    window: int = 4096               # local-attention window
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # chatglm applies RoPE to half the head dim
    pos_emb: str = "rope"            # rope | sinusoidal | none
    qkv_bias: bool = False
    qk_norm: bool = False            # qwen3-style per-head RMS norm on q/k
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm | layernorm_np
    post_norm: bool = False          # gemma2 extra post-block norms
    mlp: str = "swiglu"              # swiglu | geglu | gelu
    embed_scale: bool = False        # gemma-style sqrt(d_model) input scaling
    tie_embeddings: bool = True

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False     # arctic: dense FFN parallel to MoE
    capacity_factor: float = 1.25
    router_group_size: int = 4096    # tokens per dispatch group

    # recurrent (RG-LRU / Griffin)
    rnn_width: Optional[int] = None  # default d_model
    conv_width: int = 4

    # mamba2 / SSD
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128

    # modality frontend: tokens, or precomputed embeddings (vlm/audio stubs)
    input_mode: str = "tokens"

    # numerics
    param_dtype: str = "float32"
    activation_dtype: str = "float32"
    loss_chunk: int = 0              # chunked-vocab loss; 0 = unchunked
    attn_chunk: int = 0              # q-chunked attention; 0 = full

    # notes for DESIGN/EXPERIMENTS (provenance of the numbers)
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def rnn_width_(self) -> int:
        return self.rnn_width if self.rnn_width is not None else self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Concrete kind of each of the n_layers layers (pattern cycled)."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers), exact per shape."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for kind in self.layer_kinds():
            if kind in ("global", "local"):
                qkv = d * hd * (self.n_heads + 2 * self.n_kv_heads)
                if self.qkv_bias:
                    qkv += hd * (self.n_heads + 2 * self.n_kv_heads)
                total += qkv + self.n_heads * hd * d
            elif kind == "rglru":
                w = self.rnn_width_
                # two input projections, depthwise conv, dense a/i gates,
                # per-channel Λ and biases, output projection
                total += 2 * d * w + self.conv_width * w + 2 * w * w + 3 * w + w * d
            elif kind == "mamba2":
                d_in = self.ssm_expand * d
                h = d_in // self.ssm_headdim
                total += d * (2 * d_in + 2 * self.ssm_state + h) + d_in * d
            # FFN
            if self.n_experts and kind != "rglru" and kind != "mamba2":
                total += self.n_experts * self._ffn_params(self.moe_d_ff)
                total += d * self.n_experts  # router
                if self.dense_residual:
                    total += self._ffn_params(self.d_ff)
            elif kind in ("global", "local"):
                total += self._ffn_params(self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        per_layer_moe = self.n_experts * self._ffn_params(self.moe_d_ff)
        active_moe = self.experts_per_token * self._ffn_params(self.moe_d_ff)
        n_moe_layers = sum(1 for k in self.layer_kinds() if k in ("global", "local"))
        return total - n_moe_layers * (per_layer_moe - active_moe)

    def _ffn_params(self, ff: int) -> int:
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        return mult * self.d_model * ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class GPShapeConfig:
    """Problem sizes for the paper's own (GP) dry-run cells."""

    name: str
    n_train: int
    n_test: int
    tile_size: int

    @property
    def m_tiles(self) -> int:
        assert self.n_train % self.tile_size == 0
        return self.n_train // self.tile_size
