"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.
The EnCodec frontend is a STUB (input_specs() provides precomputed frame
embeddings); the backbone decodes audio-codebook tokens (vocab 2048).

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf:facebook/musicgen-large]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    norm="layernorm",
    mlp="gelu",
    pos_emb="sinusoidal",
    tie_embeddings=False,
    input_mode="embeddings",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="arXiv:2306.05284; hf:facebook/musicgen-large",
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    norm="layernorm",
    mlp="gelu",
    pos_emb="sinusoidal",
    tie_embeddings=False,
    input_mode="embeddings",
)
