"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, QK-norm, head_dim=128.

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936, MoE 128e top-8
[hf:Qwen/Qwen3-235B-A22B family; config per assignment]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    qk_norm=True,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=1000000.0,
    tie_embeddings=False,
    n_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    capacity_factor=1.25,
    router_group_size=4096,
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="hf:Qwen/Qwen3-30B-A3B scaled per assignment",
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    qk_norm=True,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=96,
    capacity_factor=2.0,
    router_group_size=64,
)
