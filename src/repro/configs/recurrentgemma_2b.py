"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 vocab=256000,
window=2048, rnn_width=2560  [arXiv:2402.19427; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    norm="rmsnorm",
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    rnn_width=2560,
    conv_width=4,
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,                  # 1 full cycle + (rglru, rglru) remainder
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    pattern=("rglru", "rglru", "local"),
    window=16,
    norm="rmsnorm",
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    rnn_width=64,
    conv_width=4,
)
