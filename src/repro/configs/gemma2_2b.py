"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000
[arXiv:2408.00118; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    norm="rmsnorm",
    post_norm=True,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    attn_chunk=512,
    source="arXiv:2408.00118; hf:google/gemma-2-2b",
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    pattern=("local", "global"),
    window=16,
    attn_softcap=50.0,
    final_softcap=30.0,
    norm="rmsnorm",
    post_norm=True,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
)
