"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128,
expand=2, headdim=64, chunk=128  [arXiv:2405.21060; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,            # attention-free; SSD heads derived from expand/headdim
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    pattern=("mamba2",),
    norm="rmsnorm",
    pos_emb="none",
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
    loss_chunk=1024,
    source="arXiv:2405.21060 (mamba2-1.3b); unverified",
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=256,
    pattern=("mamba2",),
    norm="rmsnorm",
    pos_emb="none",
    tie_embeddings=True,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_chunk=8,
)
