"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The Griffin recurrent block: two parallel projections of the input — a GeLU
gate branch and a recurrence branch that passes through a short causal
depthwise conv and the Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(x_t W_a + b_a)            recurrence gate
    i_t = sigmoid(x_t W_x + b_x)            input gate
    a_t = exp(-c * softplus(Λ) * r_t)       per-channel decay (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Sequence mode evaluates the linear recurrence with an associative scan
(log-depth on TPU); decode mode is a single fused step carrying (h, conv
state), which is what makes the 500k-token decode cell O(1) per step.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import trunc_normal

_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype) -> dict:
    d, w, cw = cfg.d_model, cfg.rnn_width_, cfg.conv_width
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    sw = 1.0 / math.sqrt(w)
    # Λ init so that a ∈ (0.9, 0.999) at r = 1 (Griffin appendix)
    u = jax.random.uniform(ks[6], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))
    return {
        "w_gate_branch": trunc_normal(ks[0], (d, w), s, dtype),
        "w_x_branch": trunc_normal(ks[1], (d, w), s, dtype),
        "conv_w": trunc_normal(ks[2], (cw, w), 1.0 / math.sqrt(cw), dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": trunc_normal(ks[3], (w, w), sw, dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": trunc_normal(ks[4], (w, w), sw, dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lambda": lam.astype(jnp.float32),
        "w_out": trunc_normal(ks[5], (w, d), sw, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, S, W), w (cw, W)."""
    cw = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(cw):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[i]
    return out + b


def _gates(p: dict, xb: jax.Array):
    r = jax.nn.sigmoid(xb.astype(jnp.float32) @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xb.astype(jnp.float32) @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xb.astype(jnp.float32))
    return a, b


def apply_rglru_seq(p: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x (B, S, d) -> (out (B, S, d), final state for decode continuation)."""
    from repro.models.layers import DP, constrain

    gate = jax.nn.gelu(constrain(x @ p["w_gate_branch"], DP, None, "model"), approximate=True)
    xb = _causal_conv(constrain(x @ p["w_x_branch"], DP, None, "model"),
                      p["conv_w"], p["conv_b"])
    a, b = _gates(p, xb)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    out = (gate * h) @ p["w_out"]
    state = {
        "h": h[:, -1].astype(jnp.float32),
        "conv": (x @ p["w_x_branch"])[:, -(cfg.conv_width - 1) :],
    }
    return out, state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.rnn_width_
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def apply_rglru_step(
    p: dict, x: jax.Array, state: dict, cfg: ModelConfig
) -> Tuple[jax.Array, dict]:
    """Single-token decode: x (B, 1, d)."""
    gate = jax.nn.gelu(x @ p["w_gate_branch"], approximate=True)      # (B,1,w)
    xproj = x @ p["w_x_branch"]                                        # (B,1,w)
    window = jnp.concatenate([state["conv"], xproj], axis=1)           # (B,cw,w)
    # window is [oldest..newest]; seq conv applies w[0] to the newest tap
    xb = jnp.einsum("bcw,cw->bw", window, p["conv_w"][::-1]) + p["conv_b"]
    a, b = _gates(p, xb)
    h = a * state["h"] + b
    out = (gate[:, 0] * h.astype(x.dtype)) @ p["w_out"]
    new_state = {"h": h, "conv": window[:, 1:]}
    return out[:, None, :], new_state
