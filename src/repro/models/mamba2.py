"""Mamba-2 block: the SSD (state-space duality) chunked algorithm.

Implements the Mamba-2 mixer (arXiv:2405.21060): input projection to
(z, x, B, C, dt), short causal conv on (x, B, C), scalar-identity SSM with
per-head decay a_t = exp(Δ_t·A), evaluated with the chunked SSD algorithm:

  * intra-chunk: quadratic "masked attention" form — (c × c) decay-masked
    C·Bᵀ scores per chunk, all MXU einsums;
  * inter-chunk: per-chunk final states carried by an associative scan.

Sequence mode returns the final SSM state so prefill can seed decoding;
decode mode is a constant-memory single step (the long_500k cell).  All
decay/exp math runs in float32; contraction operands stay in the activation
dtype for the MXU.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import trunc_normal


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_headdim
    return d_in, heads, cfg.ssm_headdim, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, h, p_, n = _dims(cfg)
    cw = cfg.conv_width
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    a_init = jax.random.uniform(ks[4], (h,), minval=1.0, maxval=16.0)
    dt0 = jnp.exp(
        jax.random.uniform(ks[5], (h,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "w_in": trunc_normal(ks[0], (d, 2 * d_in + 2 * n + h), s, dtype),
        "conv_w": trunc_normal(ks[1], (cw, d_in + 2 * n), 1.0 / math.sqrt(cw), dtype),
        "conv_b": jnp.zeros((d_in + 2 * n,), dtype),
        "a_log": jnp.log(a_init).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "w_out": trunc_normal(ks[2], (d_in, d), 1.0 / math.sqrt(d_in), dtype),
    }


def _split_proj(p, x, cfg: ModelConfig):
    from repro.models.layers import DP, constrain

    d_in, h, _, n = _dims(cfg)
    proj = x @ p["w_in"]
    if proj.ndim == 3:
        proj = constrain(proj, DP, None, "model")
    z, xc, bm, cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    return z, xc, bm, cm, dt


def _gated_out(p, y, z, cfg: ModelConfig):
    """RMSNorm(y * silu(z)) @ w_out."""
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    g = g * jax.lax.rsqrt(jnp.mean(g * g, -1, keepdims=True) + 1e-6)
    return (g.astype(y.dtype) * p["norm_scale"]) @ p["w_out"]


def _causal_conv(x, w, b):
    cw = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(cw):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[i]
    return jax.nn.silu(out + b)


def apply_mamba2_seq(p: dict, x_in: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x_in (B, S, d) -> (out (B, S, d), state for decode continuation)."""
    b, s, _ = x_in.shape
    d_in, h, pd, n = _dims(cfg)
    c = min(cfg.ssm_chunk, s)
    if s % c:  # fall back to the largest divisor of s (chunk size is perf-only)
        c = max(d for d in range(1, c + 1) if s % d == 0)
    nc = s // c

    z, xc, bm, cm, dt_raw = _split_proj(p, x_in, cfg)
    xbc_pre = jnp.concatenate([xc, bm, cm], -1)          # pre-conv (decode state)
    xbc = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"])
    xc, bm, cm = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xh = xc.reshape(b, s, h, pd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    da = -jnp.exp(p["a_log"]) * dt                                      # (B,S,H) <= 0

    # chunk views
    xz = xh.reshape(b, nc, c, h, pd)
    dtz = dt.reshape(b, nc, c, h)
    daz = da.reshape(b, nc, c, h)
    bz = bm.reshape(b, nc, c, n)
    cz = cm.reshape(b, nc, c, n)
    cs = jnp.cumsum(daz, axis=2)                                        # (B,NC,c,H)

    # ---- intra-chunk (quadratic, decay-masked attention form) ----------
    li = cs[:, :, :, None, :] - cs[:, :, None, :, :]                    # (B,NC,i,j,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    lmask = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)    # f32
    scores = jnp.einsum("bzin,bzjn->bzij", cz, bz)
    dtx = xz * dtz[..., None].astype(xz.dtype)                          # (B,NC,c,H,P)
    y_diag = jnp.einsum(
        "bzij,bzijh,bzjhp->bzihp",
        scores.astype(jnp.float32),
        lmask,
        dtx.astype(jnp.float32),
    )

    # ---- chunk states + inter-chunk recurrence -------------------------
    decay_states = jnp.exp(cs[:, :, -1:, :] - cs)                       # (B,NC,c,H)
    sstates = jnp.einsum(
        "bzjn,bzjh,bzjhp->bzhnp", bz.astype(jnp.float32), (decay_states * dtz), xz.astype(jnp.float32)
    )                                                                   # (B,NC,H,N,P)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                              # (B,NC,H)

    def combine(l, r):
        al, hl = l
        ar, hr = r
        return al * ar, ar[..., None, None] * hl + hr

    _, h_inc = jax.lax.associative_scan(combine, (chunk_decay, sstates), axis=1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_inc[:, :1]), h_inc[:, :-1]], axis=1
    )                                                                   # exclusive
    y_off = jnp.einsum(
        "bzin,bzhnp->bzihp", cz.astype(jnp.float32), h_prev
    ) * jnp.exp(cs)[..., None]

    y = (y_diag + y_off).reshape(b, s, h, pd)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.astype(x_in.dtype).reshape(b, s, d_in)
    out = _gated_out(p, y, z, cfg)
    state = {
        "h": h_inc[:, -1],                                              # (B,H,N,P) f32
        "conv": xbc_pre[:, -(cfg.conv_width - 1) :],
    }
    return out, state


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, h, pd, n = _dims(cfg)
    return {
        "h": jnp.zeros((batch, h, n, pd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * n), dtype),
    }


def apply_mamba2_step(
    p: dict, x_in: jax.Array, state: dict, cfg: ModelConfig
) -> Tuple[jax.Array, dict]:
    """Single-token decode: x_in (B, 1, d); O(H·N·P) state update."""
    b = x_in.shape[0]
    d_in, h, pd, n = _dims(cfg)
    z, xc, bm, cm, dt_raw = _split_proj(p, x_in, cfg)
    xbc_new = jnp.concatenate([xc, bm, cm], -1)                         # (B,1,·)
    window = jnp.concatenate([state["conv"], xbc_new], axis=1)          # (B,cw,·)
    # window is [oldest..newest]; seq conv applies w[0] to the newest tap
    xbc = jax.nn.silu(
        jnp.einsum("bcw,cw->bw", window, p["conv_w"][::-1]) + p["conv_b"]
    )
    xc1, bm1, cm1 = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xh = xc1.reshape(b, h, pd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["a_log"]) * dt)                              # (B,H)
    hnew = a[..., None, None] * state["h"] + jnp.einsum(
        "bn,bhp->bhnp", bm1.astype(jnp.float32), xh * dt[..., None]
    )
    y = jnp.einsum("bn,bhnp->bhp", cm1.astype(jnp.float32), hnew)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x_in.dtype)
    out = _gated_out(p, y, z, cfg)
    return out, {"h": hnew, "conv": window[:, 1:]}
