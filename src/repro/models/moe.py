"""Mixture-of-Experts FFN: token-choice top-k routing with capacity.

Routing is GShard/Switch-style token-choice with a capacity limit, but the
dispatch uses index tables (scatter/gather) instead of one-hot einsums: the
einsum formulation costs O(T·E·C·d) FLOPs — orders of magnitude above the
useful expert FLOPs — while the table formulation is a pure data-movement
gather + batched expert GEMMs of exactly O(k·cf·T·d·ff).  On TPU the batched
(E, C, d)·(E, d, ff) contraction maps onto the MXU with experts sharded over
the ``model`` axis (expert parallelism); GSPMD inserts the all-to-all.

Tokens are routed in groups of ``router_group_size`` (sharded over batch/data)
so capacity is enforced per group and the index tables stay small.

`arctic`-style dense residual: a dense FFN runs in parallel with the MoE and
both outputs are summed (config flag ``dense_residual``).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_mlp, trunc_normal


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    p = {
        "router": trunc_normal(ks[0], (d, e), s_in, jnp.float32),
        "w_gate": trunc_normal(ks[1], (e, d, ff), s_in, dtype),
        "w_up": trunc_normal(ks[2], (e, d, ff), s_in, dtype),
        "w_down": trunc_normal(ks[3], (e, ff, d), s_out, dtype),
    }
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[4], cfg.mlp, d, cfg.d_ff, dtype)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(cfg.experts_per_token * tokens * cfg.capacity_factor / cfg.n_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _route_group(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """One routing group: x (T, d) -> y (T, d)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(t, cfg)

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                     # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # position of each (token, choice) inside its expert's capacity buffer;
    # k passes of a (T, E) one-hot cumsum keep peak memory at T*E.
    positions = []
    counts = jnp.zeros((e,), jnp.int32)
    for i in range(k):
        oh = jax.nn.one_hot(expert_idx[:, i], e, dtype=jnp.int32)       # (T, E)
        pos_i = jnp.take_along_axis(
            jnp.cumsum(oh, axis=0) - 1 + counts[None, :], expert_idx[:, i : i + 1], 1
        )[:, 0]
        counts = counts + jnp.sum(oh, axis=0)
        positions.append(pos_i)
    position = jnp.stack(positions, axis=1)                             # (T, k)

    keep = position < cap
    dest = jnp.where(keep, expert_idx * cap + position, e * cap)        # sentinel

    # scatter token ids into the (E*C,) source table (sentinel row T = zeros)
    table = jnp.full((e * cap + 1,), t, jnp.int32)
    token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k))
    table = table.at[dest.reshape(-1)].set(token_ids.reshape(-1), mode="drop")
    table = table[: e * cap]

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    # groups are (data × model)-sharded (see apply_moe): the dispatch gather
    # and the expert FFN run device-local; expert weights arrive via a
    # weight-sized all-gather
    expert_in = x_pad[table].reshape(e, cap, d)

    # batched expert FFN (MXU)
    act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
    g = act(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    h = g * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(e * cap, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)

    gathered = out[jnp.where(keep, dest, e * cap)]                      # (T, k, d)
    w = (gate_vals * keep).astype(x.dtype)
    return jnp.einsum("tkd,tk->td", gathered, w)


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B, S, d) -> (B, S, d); groups of router_group_size tokens.

    Sharding note (EXPERIMENTS.md §Perf, hillclimb #2): three dispatch
    layouts were measured on qwen3-moe-235b — (a) groups over data, expert
    dim unconstrained [58.7 GB wire/cycle], (b) explicit E-over-model
    constraints [65.9 GB], (c) groups over data×model with weight gathering
    [185 GB, GSPMD replicates the combine gather].  (a) wins under the
    current partitioner and is used here; a manual shard_map all-to-all
    dispatch is the identified path below GSPMD's floor."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    g = min(cfg.router_group_size, b * s)
    if (b * s) % g:
        g = b * s  # fall back to a single group for odd token counts
    groups = flat.reshape(-1, g, d)
    y = jax.vmap(lambda xx: _route_group(p, xx, cfg))(groups)
    y = y.reshape(b, s, d)
    if cfg.dense_residual:
        from repro.models.layers import apply_mlp

        y = y + apply_mlp(p["dense"], x, cfg.mlp)
    return y


def aux_load_balance_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style auxiliary load-balance loss (fraction·probability dot)."""
    logits = (x.reshape(-1, x.shape[-1]).astype(jnp.float32)) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    _, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    frac = jnp.mean(
        jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
