"""Shared layer primitives: norms, MLPs, rotary embeddings, initializers.

All functions are pure (params passed explicitly as dict pytrees).  Norms and
softmax-adjacent math run in float32 regardless of the activation dtype —
standard TPU mixed-precision practice.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def trunc_normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def constrain(x, *axes_per_dim):
    """Activation sharding hint, active only under a mesh context
    (jax.set_mesh from the launch/step factories).  Axes missing from the
    mesh or not dividing the dim are dropped — safe on any mesh/none.

    This is the Megatron/MaxText-style activation-rule mechanism: without
    these hints GSPMD happily contracts an FSDP-sharded weight dim and
    all-reduces *activation-sized* partials (measured: 131 GB/cycle on
    llava-34b) instead of all-gathering the weight shards (0.3 GB).
    """
    from repro import compat

    mesh = compat.abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    # only Auto axes may appear in a constraint (inside shard_map the axes
    # are Manual and the hint must be a no-op)
    auto = compat.auto_axis_names(mesh)
    if not auto:
        return x
    spec = []
    for dim, axes in zip(x.shape, axes_per_dim):
        if axes is None:
            spec.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        kept = tuple(a for a in axes if a in auto)
        size = 1
        for a in kept:
            size *= mesh.shape[a]
        if not kept or dim % size != 0:
            spec.append(None)
        else:
            spec.append(kept if len(kept) > 1 else kept[0])
    from jax.sharding import PartitionSpec as P

    return compat.shard_hint(x, P(*spec))


DP = ("pod", "data")  # canonical batch axes


# ---------------------------------------------------------------------------
# Norms.  kind: rmsnorm | layernorm | layernorm_np (non-parametric, OLMo)
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}          # gemma-style (1+scale)
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "layernorm_np":
        return {}                                          # OLMo: no affine params
    raise ValueError(kind)


def apply_norm(params: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
        out = x32 * (1.0 + params["scale"].astype(jnp.float32))
    elif kind in ("layernorm", "layernorm_np"):
        mu = jnp.mean(x32, -1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), -1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(
                jnp.float32
            )
    else:
        raise ValueError(kind)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# MLPs.  swiglu / geglu: gated two-matrix up-projection; gelu: plain.
# ---------------------------------------------------------------------------


def init_mlp(key, kind: str, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": trunc_normal(k1, (d, ff), s_in, dtype),
            "w_up": trunc_normal(k2, (d, ff), s_in, dtype),
            "w_down": trunc_normal(k3, (ff, d), s_out, dtype),
        }
    if kind == "gelu":
        return {
            "w_up": trunc_normal(k1, (d, ff), s_in, dtype),
            "w_down": trunc_normal(k2, (ff, d), s_out, dtype),
        }
    raise ValueError(kind)


def apply_mlp(params: dict, x: jax.Array, kind: str) -> jax.Array:
    """Gated/plain MLP with Megatron-style activation constraints: the ff
    intermediate is model-sharded (weights get all-gathered — FSDP), the
    down-projection output returns to batch-only sharding."""
    hint = (DP, None, "model") if x.ndim == 3 else (DP, "model")
    out_hint = (DP, None, None) if x.ndim == 3 else (DP, None)
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else lambda v: jax.nn.gelu(v, approximate=True)
        g = act(constrain(x @ params["w_gate"], *hint))
        h = g * constrain(x @ params["w_up"], *hint)
        return constrain(h @ params["w_down"], *out_hint)
    if kind == "gelu":
        h = jax.nn.gelu(constrain(x @ params["w_up"], *hint), approximate=True)
        return constrain(h @ params["w_down"], *out_hint)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings (full or partial head-dim fraction).
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(
    x: jax.Array,              # (B, S, H, hd)
    positions: jax.Array,      # (B, S) int32
    *,
    fraction: float = 1.0,
    theta: float = 10000.0,
) -> jax.Array:
    hd = x.shape[-1]
    inv, rot = rope_frequencies(hd, fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv        # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    if rot < hd:
        out = jnp.concatenate([out, x[..., rot:]], axis=-1)
    return out


def sinusoidal_pos_emb(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) -> (B, S, d) classic transformer sinusoids (MusicGen-style)."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
