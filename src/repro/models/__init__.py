"""Model substrate: layers, attention, MoE, recurrent blocks, backbones."""
