"""Generic decoder backbone covering the whole architecture zoo.

A model is a cycled ``pattern`` of block kinds — e.g. ("global",) for plain
transformers, ("local", "global") for gemma2, ("rglru", "rglru", "local")
for recurrentgemma, ("mamba2",) for mamba2 — stacked ``n_layers`` deep.

Layers are grouped by full pattern cycles and executed with ``lax.scan``
over stacked parameters (one traced cycle regardless of depth: a 94-layer
MoE compiles as fast as a 2-layer one) with optional remat for training.
The cycle remainder (e.g. recurrentgemma's 26 = 8×3 + 2) runs unrolled.

Three entry modes:
  * train  : full sequence, no caches, chunked-vocab cross-entropy loss
  * prefill: full sequence, returns last-position logits + decode caches
  * step   : single-token decode against caches (KV ring buffers for
             attention, recurrent states for RG-LRU / Mamba-2)
"""

from __future__ import annotations

import functools
import math
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    init_mlp,
    init_norm,
    sinusoidal_pos_emb,
    softcap,
    trunc_normal,
)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


from repro.models.layers import constrain as _constrain  # shared activation rules


# ---------------------------------------------------------------------------
# Block init / apply.
# ---------------------------------------------------------------------------


def init_block(key, kind: str, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if kind in ("global", "local"):
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rec"] = rg.init_rglru(ks[0], cfg, dtype)
    elif kind == "mamba2":
        p["ssm"] = m2.init_mamba2(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    has_ffn = kind != "mamba2" and cfg.d_ff > 0
    if has_ffn:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if cfg.n_experts and kind in ("global", "local"):
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.mlp, cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_norm:
        p["post_norm1"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if has_ffn:
            p["post_norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
    return p


def apply_block(
    p: dict,
    kind: str,
    x: jax.Array,
    positions,
    cfg: ModelConfig,
    *,
    mode: str,
    cache=None,
    pos=None,
):
    """Returns (x, new_cache) — new_cache is None in train mode."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    new_cache = None
    if kind in ("global", "local"):
        local = kind == "local"
        if mode == "step":
            h, new_cache = attn.attend_decode(p["attn"], h, pos, cache, cfg, local=local)
        else:
            h, kv = attn.attend_full(p["attn"], h, positions, cfg, local=local)
            if mode == "prefill":
                new_cache = _kv_to_ring(kv, cfg, local)
    elif kind == "rglru":
        if mode == "step":
            h, new_cache = rg.apply_rglru_step(p["rec"], h, cache, cfg)
        else:
            h, state = rg.apply_rglru_seq(p["rec"], h, cfg)
            if mode == "prefill":
                new_cache = state
    elif kind == "mamba2":
        if mode == "step":
            h, new_cache = m2.apply_mamba2_step(p["ssm"], h, cache, cfg)
        else:
            h, state = m2.apply_mamba2_seq(p["ssm"], h, cfg)
            if mode == "prefill":
                new_cache = state
    if cfg.post_norm:
        h = apply_norm(p["post_norm1"], h, cfg.norm)
    x = x + h
    if "mlp" in p or "moe" in p:
        h = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            h = moe_mod.apply_moe(p["moe"], h, cfg)
        else:
            h = apply_mlp(p["mlp"], h, cfg.mlp)
        if cfg.post_norm:
            h = apply_norm(p["post_norm2"], h, cfg.norm)
        x = x + h
    return x, new_cache


def _kv_to_ring(kv, cfg: ModelConfig, local: bool, cache_len: Optional[int] = None):
    """Prefill (k, v) of shape (B, S, KV, hd) -> ring-buffer decode cache.

    ``cache_len`` sizes the global-attention cache (default S+1 so one new
    token can be appended without evicting position 0); local layers always
    use a window-sized ring.
    """
    k, v = kv
    s = k.shape[1]
    if local:
        w = min(cfg.window, s)
    else:
        w = cache_len if cache_len is not None else s + 1
    keep = min(w, s)
    idx = (jnp.arange(s - keep, s)) % w
    ck = jnp.zeros((k.shape[0], w) + k.shape[2:], k.dtype).at[:, idx].set(k[:, s - keep :])
    cv = jnp.zeros((v.shape[0], w) + v.shape[2:], v.dtype).at[:, idx].set(v[:, s - keep :])
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Model init.
# ---------------------------------------------------------------------------


def _cycles(cfg: ModelConfig) -> Tuple[int, int]:
    plen = len(cfg.pattern)
    return cfg.n_layers // plen, cfg.n_layers % plen


def init_model(key, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg.param_dtype)
    n_cycles, rem = _cycles(cfg)
    keys = jax.random.split(key, 3 + len(cfg.pattern) + rem)
    params: dict = {
        "embed": trunc_normal(
            keys[0], (cfg.vocab_size, cfg.d_model), 1.0 / math.sqrt(cfg.d_model), dtype
        ),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = trunc_normal(
            keys[1], (cfg.d_model, cfg.vocab_size), 1.0 / math.sqrt(cfg.d_model), dtype
        )
    groups = []
    for i, kind in enumerate(cfg.pattern):
        ck = jax.random.split(keys[2 + i], n_cycles)
        groups.append(jax.vmap(lambda kk: init_block(kk, kind, cfg, dtype))(ck))
    params["groups"] = groups
    tail = []
    for j in range(rem):
        kind = cfg.pattern[j]
        tail.append(init_block(keys[2 + len(cfg.pattern) + j], kind, cfg, dtype))
    params["tail"] = tail
    return params


# ---------------------------------------------------------------------------
# Caches.
# ---------------------------------------------------------------------------


def _block_cache_template(kind: str, cfg: ModelConfig, batch: int, max_len: int, dtype):
    if kind in ("global", "local"):
        return attn.init_cache(cfg, batch, max_len, kind == "local", dtype)
    if kind == "rglru":
        return rg.init_rglru_state(cfg, batch, dtype)
    if kind == "mamba2":
        return m2.init_mamba2_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = _dtype(cfg.activation_dtype)
    n_cycles, rem = _cycles(cfg)
    groups = []
    for kind in cfg.pattern:
        tmpl = _block_cache_template(kind, cfg, batch, max_len, dtype)
        groups.append(
            jax.tree.map(lambda t: jnp.zeros((n_cycles,) + t.shape, t.dtype), tmpl)
        )
    tail = [
        _block_cache_template(cfg.pattern[j], cfg, batch, max_len, dtype)
        for j in range(rem)
    ]
    return {"groups": groups, "tail": tail}


# ---------------------------------------------------------------------------
# Forward passes.
# ---------------------------------------------------------------------------


def _embed_in(params, cfg: ModelConfig, inputs, positions):
    dtype = _dtype(cfg.activation_dtype)
    if inputs.dtype in (jnp.int32, jnp.int64):
        # gather from the d-sharded table (see dist/sharding.py): both the
        # lookup and its scatter-add gradient partition cleanly on d.
        x = jnp.take(params["embed"], inputs, axis=0).astype(dtype)
        x = _constrain(x, ("pod", "data"), None, None)
    else:
        x = inputs.astype(dtype)  # modality-stub embeddings (vlm / audio)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_pos_emb(positions, cfg.d_model).astype(dtype)
    return x


def _backbone(params, cfg: ModelConfig, x, positions, *, mode, caches=None, pos=None, remat=False):
    n_cycles, rem = _cycles(cfg)
    plen = len(cfg.pattern)

    def cycle_body(x, cycle_params, cycle_caches):
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            c = None if cycle_caches is None else cycle_caches[i]
            x, nc = apply_block(
                cycle_params[i], kind, x, positions, cfg, mode=mode, cache=c, pos=pos
            )
            new_caches.append(nc)
        if mode == "train":
            # sequence-shard the scan carry (Megatron-SP): the per-cycle
            # residual stacks saved for backward are the dominant train-cell
            # memory (e.g. llava-34b: (60,16,4096,7168)·bf16 ≈ 56 GB/device
            # replicated over `model`); S-sharding divides them by 16.
            x = _constrain(x, ("pod", "data"), "model", None)
        return x, new_caches

    body = cycle_body
    if remat:
        body = jax.checkpoint(cycle_body)

    if n_cycles > 0:
        if mode == "train":
            def scan_fn(x, cp):
                x, _ = body(x, cp, None)
                return x, None

            x, _ = jax.lax.scan(scan_fn, x, tuple(params["groups"]))
            new_group_caches = None
        elif mode == "prefill":
            def scan_fn(x, cp):
                x, ncs = body(x, cp, None)
                return x, tuple(ncs)

            x, new_group_caches = jax.lax.scan(scan_fn, x, tuple(params["groups"]))
        else:  # step
            def scan_fn(x, cp_cc):
                cp, cc = cp_cc
                x, ncs = body(x, cp, cc)
                return x, tuple(ncs)

            x, new_group_caches = jax.lax.scan(
                scan_fn, x, (tuple(params["groups"]), tuple(caches["groups"]))
            )
    else:
        new_group_caches = [] if mode != "train" else None

    new_tail = []
    for j in range(rem):
        kind = cfg.pattern[j]
        c = None if caches is None else caches["tail"][j]
        x, nc = apply_block(
            params["tail"][j], kind, x, positions, cfg, mode=mode, cache=c, pos=pos
        )
        new_tail.append(nc)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    new_caches = (
        None if mode == "train" else {"groups": list(new_group_caches), "tail": new_tail}
    )
    return x, new_caches


def _logits(params, cfg: ModelConfig, x):
    # einsum, never .T: transposing a sharded table defeats the SPMD
    # partitioner ("involuntary full rematerialization") — the contraction
    # form partitions cleanly for both the forward and the cotangent.
    if cfg.tie_embeddings:
        # the stored table is d-sharded (gather-friendly); the head wants a
        # vocab-sharded operand.  The constraint is the explicit reshard
        # point (one cheap all-to-all) in BOTH directions — without it the
        # partitioner all-gathers the full-vocab f32 dlogits instead.
        emb_head = _constrain(params["embed"].astype(x.dtype), "model", None)
        logits = jnp.einsum("...d,vd->...v", x, emb_head)
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"].astype(x.dtype))
    # vocab dim of the logits is model-sharded (the table itself is not
    # vocab-sharded — see dist/sharding.py); batch over the DP axes.
    if logits.ndim == 3:
        logits = _constrain(logits, ("pod", "data"), None, "model")
    else:
        logits = _constrain(logits, ("pod", "data"), "model")
    return softcap(logits, cfg.final_softcap)


def loss_fn(params, cfg: ModelConfig, inputs, labels) -> jax.Array:
    """Mean next-token cross entropy; vocab-chunked over the sequence."""
    b, s = labels.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = _embed_in(params, cfg, inputs, positions)
    x, _ = _backbone(params, cfg, x, positions, mode="train", remat=True)
    c = cfg.loss_chunk if cfg.loss_chunk and s % cfg.loss_chunk == 0 else s
    nc = s // c
    xc = x.reshape(b, nc, c, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)

    @jax.checkpoint  # recompute chunk logits in backward: peak = one chunk
    def chunk_ce(xx, ll):
        logits = _logits(params, cfg, xx).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction keeps the vocab dim sharded (a take_along_axis
        # gather would force GSPMD to all-gather the full logits)
        oh = jax.nn.one_hot(ll, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("bsv,bsv->bs", logits, oh)
        return jnp.sum(lse - gold)

    def chunk_loss(carry, xl):
        xx, ll = xl
        return carry + chunk_ce(xx, ll), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)


def prefill_fn(params, cfg: ModelConfig, inputs):
    """Full-sequence forward: returns (last-position logits (B, V), caches)."""
    b, s = inputs.shape[0], inputs.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = _embed_in(params, cfg, inputs, positions)
    x, caches = _backbone(params, cfg, x, positions, mode="prefill")
    return _logits(params, cfg, x[:, -1]), caches


def decode_fn(params, cfg: ModelConfig, token, pos, caches):
    """One decode step: token (B, 1) ids (or (B, 1, d) embeds), scalar pos."""
    b = token.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = _embed_in(params, cfg, token, positions)
    x, new_caches = _backbone(
        params, cfg, x, positions, mode="step", caches=caches, pos=pos
    )
    return _logits(params, cfg, x[:, 0]), new_caches
