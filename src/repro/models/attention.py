"""Attention: MHA/GQA/MQA, global & sliding-window, softcap, chunked, decode.

One implementation covers the zoo's variants:

  * grouped-query attention (n_kv_heads < n_heads), MQA (=1), MHA (=heads)
  * global (causal) and local (sliding-window) masks — gemma2 alternates,
    recurrentgemma uses local-only attention layers
  * gemma2 attention-logit softcapping
  * optional QKV biases (qwen1.5 / chatglm3) and q/k head RMS norm (qwen3)
  * partial-rotary RoPE (chatglm3: fraction 0.5)
  * q-chunked execution (``attn_chunk``) bounding score memory to
    (B, KV, G, chunk, T) for long-sequence prefill
  * single-token decode against a KV cache; local layers use a ring-buffer
    cache of window size so a 500k-step decode keeps O(window) state

Softmax and score accumulation are float32; score matmuls run in the
activation dtype (bf16 on TPU) feeding the MXU.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, apply_rope, softcap, trunc_normal

NEG_INF = -2.0**30


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": trunc_normal(ks[0], (d, h, hd), s, dtype),
        "wk": trunc_normal(ks[1], (d, kv, hd), s, dtype),
        "wv": trunc_normal(ks[2], (d, kv, hd), s, dtype),
        "wo": trunc_normal(ks[3], (h, hd, d), 1.0 / math.sqrt(h * hd), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((hd,), dtype)}
        p["k_norm"] = {"scale": jnp.zeros((hd,), dtype)}
    return p


def _project_qkv(p: dict, x: jax.Array, positions, cfg: ModelConfig):
    from repro.models.layers import DP, constrain

    # Megatron-style: model-shard projections on the heads dim where it
    # divides (constrain auto-drops otherwise; small-KV GQA tensors stay
    # replicated across `model`, which is cheap)
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), DP, None, "model", None)
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), DP, None, "model", None)
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), DP, None, "model", None)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    return q, k, v


def _scores_softmax_out(q, k, v, mask, cfg: ModelConfig):
    """q (B,Sq,H,hd), k/v (B,T,KV,hd), mask (B?,Sq,T) bool -> (B,Sq,H,hd).

    Scores stay in the activation dtype (bf16 on TPU) so the (B,H,Sq,T)
    buffer is half-size; the softmax itself upcasts to f32 element-wise —
    XLA fuses the upcast/exp/normalize chain so no f32 score buffer is ever
    materialized in HBM.  (A Pallas flash-attention kernel would avoid the
    HBM score buffer entirely; see EXPERIMENTS.md §Perf.)
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) * jnp.asarray(scale, q.dtype)
    scores = softcap(scores, cfg.attn_softcap)
    neg = jnp.asarray(NEG_INF, scores.dtype)
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    # softmax with activation-dtype buffers; upcasts live INSIDE the
    # reductions (max is exact in bf16; the sum uses an f32 accumulator via
    # the reduce dtype) so no (B,H,Sq,T) f32 score copy is ever materialized.
    # Flash-style VMEM blocking is the Pallas follow-up — EXPERIMENTS §Perf.
    mx = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    ex = jnp.exp(scores - mx)
    denom = jnp.sum(ex, axis=-1, keepdims=True, dtype=jnp.float32)
    probs = ex * (1.0 / denom).astype(ex.dtype)  # big buffers stay bf16
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, sq, h, hd)


def _causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: Optional[int]):
    """(B,Sq),(B,T) position ids -> (B,Sq,T) bool mask."""
    m = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        m &= k_pos[:, None, :] > q_pos[:, :, None] - window
    return m


def attend_full(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    local: bool,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Training / prefill attention over the full sequence.

    Returns (output, (k, v)) so prefill can seed the decode cache.
    """
    from repro.models.layers import DP, constrain

    q, k, v = _project_qkv(p, x, positions, cfg)
    window = cfg.window if local else None
    if cfg.attn_chunk and x.shape[1] > cfg.attn_chunk:
        out = _attend_chunked(q, k, v, positions, cfg, window)
    else:
        mask = _causal_mask(positions, positions, window)
        out = _scores_softmax_out(q, k, v, mask, cfg)
    y = constrain(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), DP, None, None)
    return y, (k, v)


def _attend_chunked(q, k, v, positions, cfg: ModelConfig, window):
    """lax.scan over query chunks — bounds score memory for 32k+ prefill."""
    b, s, h, hd = q.shape
    c = cfg.attn_chunk
    assert s % c == 0, f"seq {s} must divide attn_chunk {c}"
    nq = s // c
    qc = q.reshape(b, nq, c, h, hd).transpose(1, 0, 2, 3, 4)        # (nq,B,c,H,hd)
    pc = positions.reshape(b, nq, c).transpose(1, 0, 2)             # (nq,B,c)

    @jax.checkpoint  # recompute chunk scores in backward: peak = one chunk
    def chunk(qi, pi):
        mask = _causal_mask(pi, positions, window)
        return _scores_softmax_out(qi, k, v, mask, cfg)

    def body(_, qp):
        return None, chunk(*qp)

    _, outs = jax.lax.scan(body, None, (qc, pc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# Decode (single token, KV cache).  Local layers use a ring buffer of window
# slots; global layers a full-length cache.
# ---------------------------------------------------------------------------


def cache_shape(cfg: ModelConfig, batch: int, max_len: int, local: bool):
    w = min(cfg.window, max_len) if local else max_len
    return (batch, w, cfg.n_kv_heads, cfg.head_dim_)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, local: bool, dtype):
    shp = cache_shape(cfg, batch, max_len, local)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def attend_decode(
    p: dict,
    x: jax.Array,            # (B, 1, d)
    pos: jax.Array,          # scalar int32 — current position
    cache: dict,
    cfg: ModelConfig,
    *,
    local: bool,
) -> Tuple[jax.Array, dict]:
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, positions, cfg)
    w = cache["k"].shape[1]
    slot = pos % w
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    # position held by ring slot t:  largest p' <= pos with p' % w == t
    t = jnp.arange(w)
    k_pos = pos - (pos - t) % w                                  # (w,)
    valid = (k_pos >= 0) & (k_pos <= pos)
    if local:
        valid &= k_pos > pos - cfg.window
    mask = jnp.broadcast_to(valid[None, None, :], (b, 1, w))
    out = _scores_softmax_out(q, ck, cv, mask, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}
