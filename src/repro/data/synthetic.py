"""Synthetic data generators: LM token streams and GP function draws."""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def token_batches(
    vocab_size: int,
    batch: int,
    seq_len: int,
    *,
    seed: int = 0,
    n_batches: int | None = None,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Deterministic synthetic LM batches: a learnable Markov-ish stream.

    Tokens follow t_{i+1} = (a * t_i + b + noise) mod V with per-sequence
    (a, b) so a model can reduce loss below uniform — useful for verifying
    that end-to-end training actually learns (loss decreases) without any
    external corpus.  Yields (tokens, labels) with labels = next token.
    """
    rng = np.random.default_rng(seed)
    i = 0
    while n_batches is None or i < n_batches:
        a = rng.integers(1, 8, size=(batch, 1))
        b = rng.integers(0, vocab_size, size=(batch, 1))
        t0 = rng.integers(0, vocab_size, size=(batch, 1))
        seq = np.empty((batch, seq_len + 1), np.int32)
        seq[:, :1] = t0
        for s in range(seq_len):
            noise = rng.integers(0, 2, size=(batch, 1))
            seq[:, s + 1 : s + 2] = (a * seq[:, s : s + 1] + b + noise) % vocab_size
        yield seq[:, :-1], seq[:, 1:]
        i += 1


def gp_function_draw(
    n: int, d: int = 1, *, lengthscale: float = 1.0, noise: float = 0.05, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Draw (X, y) from a GP prior — ground-truthable regression data."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-3.0, 3.0, size=(n, d))
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    k = np.exp(-0.5 * d2 / lengthscale) + 1e-8 * np.eye(n)
    y = np.linalg.cholesky(k) @ rng.standard_normal(n)
    return x.astype(np.float32), (y + rng.normal(0, noise, n)).astype(np.float32)
