"""Data substrate: simulators, dataset builders, host→device pipelines."""
