"""Coupled mass-spring-damper simulator + NFIR dataset (paper Section 1).

The paper's system-identification workload: a chain of masses coupled by
springs and dampers; an input force u(t) drives the first mass and the
observed output y(t) is the position of the last mass, which depends
*non-linearly* on the force (a hardening cubic spring term provides the
non-linearity, as is standard for MSD SI benchmarks).  Training/test data are
input-output pairs sampled at a constant rate; the feature vector of an NFIR
model is the window of the D most recent inputs (D "regressors" of lagged
forces), the target is the current output position.

GPRat ships an equivalent simulator ("Datasets of arbitrary size can be
generated with GPRat's mass-spring-damper simulator"); this is its JAX/numpy
port, integrated with a fixed-step RK4.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class MSDConfig:
    n_masses: int = 3
    mass: float = 1.0
    spring: float = 5.0          # linear spring constant
    spring_cubic: float = 1.0    # hardening non-linearity (source of non-linear SI)
    damper: float = 1.5
    dt: float = 0.5              # observation rate (constant, as in the paper)
    substeps: int = 20           # RK4 integrator substeps per observation
    n_regressors: int = 16       # D lagged inputs per NFIR feature vector
    noise_std: float = 0.05     # observation noise on y
    force_scale: float = 4.0
    force_cutoff: float = 0.25   # low-pass smoothing factor of the random force


def _accel(pos: np.ndarray, vel: np.ndarray, u: float, cfg: MSDConfig) -> np.ndarray:
    """Chain dynamics: m q̈_i = spring forces + damping + external force on mass 0."""
    nm = cfg.n_masses
    # extension of spring i connects mass i-1 to mass i (spring 0 to the wall)
    ext = np.empty(nm)
    ext[0] = pos[0]
    ext[1:] = pos[1:] - pos[:-1]
    f_spring = -(cfg.spring * ext + cfg.spring_cubic * ext**3)
    vel_ext = np.empty(nm)
    vel_ext[0] = vel[0]
    vel_ext[1:] = vel[1:] - vel[:-1]
    f_damp = -cfg.damper * vel_ext
    f = f_spring + f_damp
    # reaction of the spring above (each spring also pulls its upper mass)
    f[:-1] -= f_spring[1:] + f_damp[1:]
    f[0] += u
    return f / cfg.mass


def simulate(
    n_steps: int, cfg: MSDConfig = MSDConfig(), seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Simulate the chain under a smoothed random force.

    Returns (u, y): input force and output position of the last mass, both
    (n_steps,) float64 observed at rate 1/dt.
    """
    rng = np.random.default_rng(seed)
    pos = np.zeros(cfg.n_masses)
    vel = np.zeros(cfg.n_masses)
    u_seq = np.empty(n_steps)
    y_seq = np.empty(n_steps)
    u = 0.0
    h = cfg.dt / cfg.substeps
    for t in range(n_steps):
        # smoothed random walk force (band-limited excitation)
        u = (1 - cfg.force_cutoff) * u + cfg.force_cutoff * rng.normal(
            0.0, cfg.force_scale
        )
        for _ in range(cfg.substeps):
            # RK4 on (pos, vel) with constant u over the substep
            k1v = _accel(pos, vel, u, cfg)
            k1x = vel
            k2v = _accel(pos + 0.5 * h * k1x, vel + 0.5 * h * k1v, u, cfg)
            k2x = vel + 0.5 * h * k1v
            k3v = _accel(pos + 0.5 * h * k2x, vel + 0.5 * h * k2v, u, cfg)
            k3x = vel + 0.5 * h * k2v
            k4v = _accel(pos + h * k3x, vel + h * k3v, u, cfg)
            k4x = vel + h * k3v
            pos = pos + (h / 6.0) * (k1x + 2 * k2x + 2 * k3x + k4x)
            vel = vel + (h / 6.0) * (k1v + 2 * k2v + 2 * k3v + k4v)
        u_seq[t] = u
        y_seq[t] = pos[-1]
    y_seq = y_seq + rng.normal(0.0, cfg.noise_std, size=n_steps)
    return u_seq, y_seq


def nfir_features(
    u: np.ndarray, y: np.ndarray, n_regressors: int
) -> Tuple[np.ndarray, np.ndarray]:
    """NFIR feature matrix: x_t = [u_t, u_{t-1}, ..., u_{t-D+1}], target y_t."""
    n = len(u) - n_regressors + 1
    idx = np.arange(n)[:, None] + np.arange(n_regressors)[None, :]
    x = u[idx][:, ::-1]                       # most recent input first
    return np.ascontiguousarray(x), y[n_regressors - 1 :].copy()


def make_dataset(
    n_train: int,
    n_test: int,
    cfg: MSDConfig = MSDConfig(),
    seed: int = 0,
    dtype=np.float32,
    normalize: bool = True,
):
    """Train/test NFIR datasets from independent simulator rollouts.

    ``normalize`` z-scores inputs and targets with *training* statistics —
    required for the paper's fixed hyperparameters (l=1, v=1, σ²=0.1) to be
    in a sensible regime for arbitrary system scales.
    """
    d = cfg.n_regressors
    u_tr, y_tr = simulate(n_train + d - 1, cfg, seed=seed)
    u_te, y_te = simulate(n_test + d - 1, cfg, seed=seed + 1)
    if normalize:
        u_mu, u_sd = u_tr.mean(), u_tr.std() + 1e-12
        y_mu, y_sd = y_tr.mean(), y_tr.std() + 1e-12
        # feature scale: with D z-scored lags, E|x-x'|^2 = 2D; rescale so the
        # paper's fixed lengthscale l=1 sees O(1) squared distances.
        f_sd = u_sd * np.sqrt(2.0 * d)
        u_tr, u_te = (u_tr - u_mu) / f_sd, (u_te - u_mu) / f_sd
        y_tr, y_te = (y_tr - y_mu) / y_sd, (y_te - y_mu) / y_sd
    x_train, yy_train = nfir_features(u_tr, y_tr, d)
    x_test, yy_test = nfir_features(u_te, y_te, d)
    return (
        x_train.astype(dtype),
        yy_train.astype(dtype),
        x_test.astype(dtype),
        yy_test.astype(dtype),
    )
