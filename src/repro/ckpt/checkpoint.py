"""Fault-tolerant checkpointing: atomic, elastic, optionally asynchronous.

Design points for 1000+-node posture (documented against the single-host
implementation shipped here):

* **Atomicity** — state is written to ``<dir>/tmp.<step>`` and renamed to
  ``<dir>/step_<step>`` only after every leaf and the manifest are fsync'd;
  a crash mid-save never corrupts the latest checkpoint.  Restore scans for
  the newest complete directory.
* **Elasticity** — leaves are stored as *host-complete* ``.npy`` arrays
  (gathered per leaf, streamed to bound peak host memory), so a checkpoint
  written on a (16, 16) mesh restores onto (2, 16, 16), (4, 2) or a single
  device: restore takes target shardings and ``jax.device_put``s each leaf.
  At true 480B scale the same layout generalizes to per-shard files keyed by
  (leaf, shard-index) with a distributed rename barrier — the manifest format
  already carries the tree structure needed for that.
* **Async** — ``save(..., blocking=False)`` snapshots leaves to host then
  writes on a background thread, overlapping I/O with the next train steps.
* **Retention** — ``keep`` newest checkpoints are retained; older ones are
  removed after a successful save (never before).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        """state: any pytree of arrays (params / opt state / rng / metadata)."""
        self.wait()  # one in-flight async save at a time
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state)
        # snapshot to host first (cheap on CPU; on TPU this is the D2H copy)
        host = [(_path_str(p), np.asarray(jax.device_get(x))) for p, x in leaves_with_paths]
        treedef_str = str(treedef)

        def write():
            tmp = os.path.join(self.directory, f"tmp.{step}")
            final = os.path.join(self.directory, f"step_{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            names = []
            for i, (pstr, arr) in enumerate(host):
                fname = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fname), arr)
                names.append({"path": pstr, "file": fname, "dtype": str(arr.dtype),
                              "shape": list(arr.shape)})
            manifest = {"step": step, "leaves": names, "treedef": treedef_str}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._cleanup()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _cleanup(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, template: Any, step: Optional[int] = None, shardings: Any = None
    ) -> Tuple[int, Any]:
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (same structure, optional) places
        each leaf — this is the elastic path: any mesh/device count works.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        if len(leaves_with_paths) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, template "
                f"{len(leaves_with_paths)} — structure mismatch"
            )
        by_path = {e["path"]: e for e in manifest["leaves"]}
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        out = []
        for i, (p, tmpl) in enumerate(leaves_with_paths):
            entry = by_path.get(_path_str(p))
            if entry is None:
                raise KeyError(f"leaf {_path_str(p)} missing from checkpoint")
            arr = np.load(os.path.join(d, entry["file"]))
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"{_path_str(p)}: checkpoint shape {arr.shape} != template {tmpl.shape}"
                )
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.device_put(arr))
        return step, jax.tree_util.tree_unflatten(treedef, out)
