"""Distribution rules: parameter/optimizer/input/cache sharding layouts."""
