"""Sharding rules shared by the train/serve step factories and the dry-run.

One place decides how every array family is laid out over the mesh, so the
step factories (``repro.train``) and the probe programs (``repro.launch``)
can never disagree:

* **Parameters / optimizer state** — greedy FSDP+TP: the largest dim of a
  leaf that divides the ``model`` axis is tensor-parallel-sharded, the
  largest remaining dim divisible by the ``data`` axis is FSDP-sharded.
  Dims that don't divide stay replicated, so every spec is always valid on
  any mesh (including the single-device test meshes, where everything
  degenerates to replication).
* **Batch-like inputs** (tokens, labels, embeddings, caches) — sharded over
  the data-parallel axes ``("pod", "data")`` (whichever exist in the mesh
  and divide the batch).

Shardings never change program semantics under GSPMD — only layout — so
these rules are free to be heuristics; the dry-run's memory/cost accounting
is what judges their quality.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP_AXES: Tuple[str, ...] = ("pod", "data")  # batch axes, outermost first
FSDP_AXIS = "data"
TP_AXIS = "model"


def _present(mesh: Mesh, axes: Sequence[str]) -> Tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.shape and mesh.shape[a] > 1)


def _dp_axes_for(mesh: Mesh, batch: int) -> Tuple[str, ...]:
    """The largest prefix-product of DP axes that divides the batch."""
    dp = _present(mesh, DP_AXES)
    while dp and batch % math.prod(mesh.shape[a] for a in dp):
        dp = dp[1:]  # drop the outermost axis until the product divides
    return dp


def batch_spec(mesh: Mesh, batch: int, *rest) -> P:
    """PartitionSpec for a batch-leading array; ``rest`` entries pass through."""
    dp = _dp_axes_for(mesh, batch)
    return P(dp if dp else None, *rest)


def _leaf_spec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Greedy FSDP+TP spec for one parameter-like leaf."""
    spec = [None] * len(shape)
    for axis in (TP_AXIS, FSDP_AXIS):
        if axis not in mesh.shape or mesh.shape[axis] <= 1:
            continue
        size = mesh.shape[axis]
        for d in sorted(range(len(shape)), key=lambda d: -shape[d]):
            if spec[d] is None and shape[d] % size == 0 and shape[d] >= size:
                spec[d] = axis
                break
    return P(*spec)


def param_shardings(params_shape, mesh: Mesh):
    """NamedSharding pytree matching a params (or grads) shape pytree."""
    return jax.tree.map(
        lambda l: NamedSharding(mesh, _leaf_spec(tuple(l.shape), mesh)),
        params_shape,
    )


def opt_state_shardings(opt_shape, params_shape, mesh: Mesh):
    """Optimizer-state shardings: moment buffers follow the same shape rule
    as parameters; scalar state (step counts) is replicated."""
    del params_shape  # the rule is purely shape-driven, kept for interface
    return jax.tree.map(
        lambda l: NamedSharding(mesh, _leaf_spec(tuple(l.shape), mesh)),
        opt_shape,
    )


def input_shardings(cfg, shape, mesh: Mesh):
    """(inputs, labels) shardings for one train/prefill shape cell."""
    b = shape.global_batch
    if getattr(cfg, "input_mode", "tokens") == "embeddings":
        in_sh = NamedSharding(mesh, batch_spec(mesh, b, None, None))
    else:
        in_sh = NamedSharding(mesh, batch_spec(mesh, b, None))
    lab_sh = NamedSharding(mesh, batch_spec(mesh, b, None))
    return in_sh, lab_sh


# ---------------------------------------------------------------------------
# Fleet sharding: pure data parallelism over the problem-batch axis B.
# ---------------------------------------------------------------------------
#
# Every named buffer of the batched executor programs ("packed", "y",
# "alpha", "cross", "mean", "v", "prior" — plus the append row and the
# rank-update carries) leads with B, and problems are independent: the
# gather/scatter env ops act on axis 1 and the einsums contract everything
# *but* z.  Sharding axis 0 over the DP axes is therefore communication-free
# data parallelism — GSPMD never inserts a collective on the forward
# programs.  Plans stay shard-invariant because the mesh only enters the
# layout (with_sharding_constraint), never the task DAG.


def fleet_axes(mesh: Mesh, batch: int) -> Tuple[str, ...]:
    """DP axes the problem-batch axis shards over ((), i.e. replicate, when
    no DP axis divides B)."""
    return _dp_axes_for(mesh, batch)


def fleet_spec(mesh: Mesh, batch: int, ndim: int = 1) -> P:
    """PartitionSpec for one B-leading fleet buffer: B over the DP axes,
    every trailing (tile/row) dim replicated."""
    dp = _dp_axes_for(mesh, batch)
    return P(dp if dp else None, *([None] * (ndim - 1)))


def fleet_sharding(mesh: Mesh, batch: int, ndim: int = 1) -> NamedSharding:
    return NamedSharding(mesh, fleet_spec(mesh, batch, ndim))


def fleet_hint(x, mesh: Optional[Mesh]):
    """``with_sharding_constraint`` pinning a B-leading buffer's layout.

    A no-op when ``mesh`` is None (the single-device path stays untouched)
    and degenerate (replicated) when no DP axis divides ``x.shape[0]`` —
    the constraint is always representable, so callers never branch.
    Works inside jit (the canonical use: constraining the executor's env
    buffers at init so GSPMD propagates the layout through the whole
    program) and eagerly (where it reshards immediately).
    """
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, fleet_sharding(mesh, x.shape[0], x.ndim)
    )


def device_put_fleet(x, mesh: Optional[Mesh]):
    """Commit a host/stacked array to the fleet layout (B over DP axes)."""
    if mesh is None:
        return x
    return jax.device_put(x, fleet_sharding(mesh, x.shape[0], x.ndim))


def cache_shardings(cfg, batch: int, mesh: Mesh, caches_shape):
    """Decode-cache shardings: batch dim over DP axes, rest replicated.

    Cache leaves are heterogenous (KV ring buffers, recurrent states, conv
    windows) but all lead with the batch dim, which is the only one safe to
    shard generically.
    """
    del cfg
    dp = _dp_axes_for(mesh, batch)

    def leaf(l):
        spec = [None] * l.ndim
        if dp and l.ndim and l.shape[0] == batch:
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, caches_shape)
