"""NLML-trend drift monitor (DESIGN.md §15; closes ROADMAP PR-5 follow-up a).

A streaming GP's hyperparameters go stale when the data distribution
drifts: the warm append keeps the factor *exact* for the grown dataset,
but the NLML per point creeps up because the kernel no longer matches the
data.  :class:`DriftMonitor` watches a scalar NLML-per-point stream with a
double EWMA — a smoothed *level* and a smoothed *trend* (the EWMA of the
innovations) — and fires when the trend exceeds a threshold: a persistent
upward slope, not a single noisy wave.

The serving loop (:class:`repro.serve.ContinuousBatcher`) consults it
after absorbing each observation wave and, on a trigger, schedules an
off-hot-path ``optimize()`` between waves.  The monitor is pure Python —
usable standalone on any NLML stream (e.g. a training loop's eval hook).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass
class DriftMonitor:
    """EWMA level/trend monitor over a per-point NLML stream.

    ``observe(nlml)`` returns True when a re-optimize should be scheduled:
    the smoothed upward trend exceeded ``threshold`` (nats/point per
    observation), at least ``warmup`` observations have been seen, and at
    least ``cooldown`` observations have passed since the last trigger.
    After the triggered re-optimize completes, call :meth:`reset` — the
    new hyperparameters define a new NLML level and the old trend state
    is meaningless against it.
    """

    alpha: float = 0.3        # EWMA smoothing for both level and trend
    threshold: float = 0.05   # smoothed slope that counts as drift
    warmup: int = 3           # observations before the trend is trusted
    cooldown: int = 8         # min observations between triggers

    triggers: int = dataclasses.field(default=0, init=False)
    _level: Optional[float] = dataclasses.field(default=None, init=False)
    _trend: float = dataclasses.field(default=0.0, init=False)
    _count: int = dataclasses.field(default=0, init=False)
    _since: int = dataclasses.field(default=1 << 30, init=False)

    def observe(self, nlml: float) -> bool:
        v = float(nlml)
        if not math.isfinite(v):
            return False  # a NaN wave is a health event, not a trend sample
        self._count += 1
        self._since += 1
        if self._level is None:
            self._level = v
            return False
        delta = v - self._level  # innovation vs the smoothed level
        self._level += self.alpha * delta
        self._trend = (1.0 - self.alpha) * self._trend + self.alpha * delta
        if self._count <= self.warmup or self._since <= self.cooldown:
            return False
        if self._trend > self.threshold:
            self.triggers += 1
            self._since = 0
            self._trend = 0.0
            return True
        return False

    @property
    def level(self) -> Optional[float]:
        """The current smoothed NLML level (None before the first sample)."""
        return self._level

    @property
    def trend(self) -> float:
        """The current smoothed slope (nats/point per observation)."""
        return self._trend

    def reset(self) -> None:
        """Forget level/trend (call after a re-optimize lands); the trigger
        count survives — it is the monitor's lifetime statistic — and so
        does the observations-since-trigger clock, so ``cooldown`` keeps
        gating re-triggers across the reset (warmup re-applies too: the new
        level needs fresh samples before its trend is trusted)."""
        self._level = None
        self._trend = 0.0
        self._count = 0
