"""Trace spans for the hot paths (DESIGN.md §15).

Spans wrap host *dispatch* boundaries in ``jax.profiler.TraceAnnotation``
so the library's stages show up as named ranges in a jax profiler / perfetto
capture — the live analogue of the paper's per-stage breakdown.  When
telemetry is disabled (the default) :func:`span` returns a shared no-op
context manager: no allocation, no profiler calls, nothing.

Spans are never opened inside jitted code: under jit the Python body runs
only at trace time, so an in-program annotation would label tracing, not
execution (why-no-instrumentation-inside-jit, DESIGN.md §15).  For scoping
*within* a traced program jax's ``named_scope`` is the right tool — the
:class:`Tracer` exposes it for completeness — but the repro's own
instrumentation stays at dispatch boundaries.
"""

from __future__ import annotations

import contextlib
import functools

import jax

# NOT ``from repro.obs import registry`` — the package re-exports a
# same-named *function*, which shadows the submodule attribute.
from repro.obs.registry import enabled as _obs_enabled

try:  # pragma: no cover - present on every supported jax
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except ImportError:  # pragma: no cover
    _TraceAnnotation = None

_NULL = contextlib.nullcontext()


def span(name: str):
    """Context manager: a profiler trace annotation when enabled, no-op off."""
    if not _obs_enabled() or _TraceAnnotation is None:
        return _NULL
    return _TraceAnnotation(name)


class Tracer:
    """Span factory with a fixed name prefix.

    >>> tr = Tracer("repro.serve")
    >>> with tr.span("wave"):
    ...     dispatch_wave()
    """

    def __init__(self, prefix: str = "repro") -> None:
        self.prefix = prefix

    def span(self, name: str):
        return span(f"{self.prefix}.{name}")

    def named_scope(self, name: str):
        """jax.named_scope — for use INSIDE traced code (names jaxpr ops);
        unconditional because it costs nothing at execution time."""
        return jax.named_scope(f"{self.prefix}.{name}")

    def annotate(self, name: str):
        """Decorator form of :meth:`span`."""

        def deco(fn):
            @functools.wraps(fn)
            def wrapped(*args, **kwargs):
                with self.span(name):
                    return fn(*args, **kwargs)

            return wrapped

        return deco
