"""Process-local telemetry registry (DESIGN.md §15).

Counters, gauges, fixed-bucket histograms and a structured JSON-lines event
log, plus a registry of the library's ``lru_cache``d plan/jit factories so
plan-invariance regressions are observable at runtime (``cache_stats``).

**Zero-cost-when-off contract.**  The module-level helpers (:func:`inc`,
:func:`observe`, :func:`event`, ...) check one module-level boolean before
doing ANY work — no dict lookups, no string formatting, no allocation.  Hot
paths that need to *build* an instrument name or an event payload must guard
with ``if obs.enabled():`` so even that construction is skipped when
telemetry is off.  Nothing here ever touches device values: recording
happens at host dispatch boundaries only, never inside jitted code and
never by materializing an async result (see DESIGN.md §15 for why).

:class:`Registry` itself is an unconditional storage object — the serving
loop keeps a private always-on instance for its own wave accounting
(:meth:`repro.serve.ContinuousBatcher.summary` reads from it) while the
module-level global registry is the process-wide, flag-gated one.
"""

from __future__ import annotations

import bisect
import json
import math
import re
import time
from collections import deque
from typing import Callable, Dict, Optional, Sequence

# Geometric edges spanning 1e-3 .. 1e5 (µs-to-minutes when observing ms).
DEFAULT_EDGES = tuple(float(10.0 ** (k / 4.0)) for k in range(-12, 21))
# Linear edges for fractions in [0, 1] (occupancy, padded-FLOP waste).
FRACTION_EDGES = tuple(i / 20.0 for i in range(1, 21))
# Power-of-two-ish edges for small integer depths (queues, inflight waves).
COUNT_EDGES = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0,
               1024.0, 4096.0)

MAX_EVENTS = 4096  # in-memory ring; the JSONL sink keeps everything


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max.

    ``edges`` are upper bounds; an implicit +inf bucket catches overflow.
    Percentiles interpolate linearly inside the hit bucket, clamped to the
    exact observed [min, max] — so an empty histogram yields NaN, a single
    sample yields that sample for every q, and q -> percentile(q) is
    monotone (the tiny/empty-sample fix the serving summary relies on).
    """

    __slots__ = ("edges", "counts", "sum", "count", "min", "max")

    def __init__(self, edges: Sequence[float] = DEFAULT_EDGES) -> None:
        self.edges = tuple(sorted(float(e) for e in edges))
        if not self.edges:
            raise ValueError("histogram needs at least one bucket edge")
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def percentile(self, q: float) -> float:
        if self.count == 0:
            return float("nan")
        rank = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.edges[i - 1] if i > 0 else self.min
                hi = self.edges[i] if i < len(self.edges) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                frac = min(max((rank - cum) / c, 0.0), 1.0)
                return lo + (hi - lo) * frac
            cum += c
        return self.max

    def to_dict(self) -> dict:
        empty = self.count == 0
        return {
            "edges": list(self.edges),
            "counts": list(self.counts),
            "sum": self.sum,
            "count": self.count,
            "min": None if empty else self.min,
            "max": None if empty else self.max,
            "p50": None if empty else self.percentile(50),
            "p99": None if empty else self.percentile(99),
        }


class Registry:
    """One namespace of named instruments + an event ring buffer."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self.events: deque = deque(maxlen=MAX_EVENTS)
        self._sink = None

    # -- instruments (get-or-create; first registration wins the edges) ----

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, edges: Optional[Sequence[float]] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                DEFAULT_EDGES if edges is None else edges
            )
        return h

    # -- events -------------------------------------------------------------

    def event(self, kind: str, **fields) -> None:
        rec = {"ts": time.time(), "kind": kind}
        rec.update(fields)
        self.events.append(rec)
        if self._sink is not None:
            self._sink.write(json.dumps(rec, default=str) + "\n")

    def open_sink(self, path: str) -> None:
        self.close_sink()
        self._sink = open(path, "w")

    def close_sink(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: h.to_dict() for k, h in sorted(self._histograms.items())
            },
            "events": list(self.events),
        }

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), default=str)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format, names prefixed ``repro_``."""
        lines = []
        for name, c in sorted(self._counters.items()):
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} counter")
            lines.append(f"{pn} {_prom_val(c.value)}")
        for name, g in sorted(self._gauges.items()):
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {_prom_val(g.value)}")
        for name, h in sorted(self._histograms.items()):
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} histogram")
            cum = 0
            for edge, cnt in zip(h.edges, h.counts):
                cum += cnt
                lines.append(f'{pn}_bucket{{le="{edge:g}"}} {cum}')
            lines.append(f'{pn}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{pn}_sum {_prom_val(h.sum)}")
            lines.append(f"{pn}_count {h.count}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()
        self.events.clear()
        self.close_sink()


def _prom_name(name: str) -> str:
    return "repro_" + re.sub(r"[^a-zA-Z0-9_]", "_", name)


def _prom_val(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    return f"{int(v)}" if float(v).is_integer() else f"{v:g}"


# ---------------------------------------------------------------------------
# The process-global registry, gated by the module-level enabled flag.
# ---------------------------------------------------------------------------

_enabled = False
_global = Registry()
_caches: Dict[str, Callable] = {}


def registry() -> Registry:
    """The process-global :class:`Registry` (read it even when disabled)."""
    return _global


def enabled() -> bool:
    return _enabled


def enable(jsonl_path: Optional[str] = None) -> None:
    """Turn telemetry on; with ``jsonl_path``, stream every event to a
    JSON-lines file as well as the in-memory ring buffer."""
    global _enabled
    _enabled = True
    if jsonl_path:
        _global.open_sink(jsonl_path)


def disable() -> None:
    """Turn telemetry off (and close any JSONL sink)."""
    global _enabled
    _enabled = False
    _global.close_sink()


def reset() -> None:
    """Drop every instrument and event; the enabled flag is untouched."""
    _global.clear()


def inc(name: str, n: float = 1.0) -> None:
    if _enabled:
        _global.counter(name).inc(n)


def set_gauge(name: str, v: float) -> None:
    if _enabled:
        _global.gauge(name).set(v)


def observe(name: str, v: float, edges: Optional[Sequence[float]] = None) -> None:
    if _enabled:
        _global.histogram(name, edges).observe(v)


def event(kind: str, **fields) -> None:
    if _enabled:
        _global.event(kind, **fields)


def health_event(name: str, **fields) -> None:
    """Count + log one factorization-health incident (refactorize fallback,
    NaN-guard trip, jitter retry) under ``health.<name>``."""
    if _enabled:
        _global.counter(f"health.{name}").inc()
        _global.event(f"health.{name}", **fields)


def snapshot() -> dict:
    return _global.snapshot()


def to_json() -> str:
    return _global.to_json()


def to_prometheus() -> str:
    return _global.to_prometheus()


# ---------------------------------------------------------------------------
# lru-cache registry: executor/predict/update register their cached plan and
# jit factories at import time; cache_stats() snapshots hits/misses/sizes.
# ---------------------------------------------------------------------------


def register_cache(name: str, fn: Callable) -> None:
    """Register an ``functools.lru_cache``d factory for :func:`cache_stats`.

    Registration is unconditional (import-time, not flag-gated) — reading a
    ``cache_info()`` later is free until someone asks for the snapshot.
    """
    _caches[name] = fn


def cache_stats() -> Dict[str, dict]:
    """``{name: {hits, misses, size}}`` across every registered lru cache."""
    out = {}
    for name, fn in sorted(_caches.items()):
        info = fn.cache_info()
        out[name] = {
            "hits": info.hits,
            "misses": info.misses,
            "size": info.currsize,
        }
    return out
