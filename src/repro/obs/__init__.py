"""repro.obs — zero-cost-when-off telemetry (DESIGN.md §15).

A process-local metrics registry (counters / gauges / fixed-bucket
histograms / JSON-lines events), profiler trace spans for the host
dispatch boundaries, an lru-cache statistics snapshot, and the NLML-trend
drift monitor.  A leaf package: it never imports ``repro.core`` (core
imports it), so instrumentation can thread through every layer without
cycles.

    import repro.obs as obs

    obs.enable("metrics.jsonl")      # flip the one global flag
    ...                              # run the instrumented stack
    print(obs.to_prometheus())       # or obs.to_json() / obs.snapshot()
    print(obs.cache_stats())         # plan/jit lru hit rates
    obs.disable()

Disabled (the default), every helper returns after a single module-level
boolean check — the instrumented hot paths run bit-identically to an
uninstrumented build (benchmarks/fig15_obs_overhead.py measures it).
"""

from repro.obs.drift import DriftMonitor
from repro.obs.registry import (
    COUNT_EDGES,
    DEFAULT_EDGES,
    FRACTION_EDGES,
    MAX_EVENTS,
    Counter,
    Gauge,
    Histogram,
    Registry,
    cache_stats,
    disable,
    enable,
    enabled,
    event,
    health_event,
    inc,
    observe,
    register_cache,
    registry,
    reset,
    set_gauge,
    snapshot,
    to_json,
    to_prometheus,
)
from repro.obs.tracer import Tracer, span

__all__ = [
    "COUNT_EDGES",
    "DEFAULT_EDGES",
    "FRACTION_EDGES",
    "Counter",
    "DriftMonitor",
    "Gauge",
    "Histogram",
    "MAX_EVENTS",
    "Registry",
    "Tracer",
    "cache_stats",
    "disable",
    "enable",
    "enabled",
    "event",
    "health_event",
    "inc",
    "observe",
    "register_cache",
    "registry",
    "reset",
    "set_gauge",
    "snapshot",
    "span",
    "to_json",
    "to_prometheus",
]
