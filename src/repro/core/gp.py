"""User-facing Gaussian-process API (GPRat-style).

Mirrors the GPRat Python API surface: construct with data + hyperparameters,
then ``predict`` / ``predict_with_uncertainty`` / ``predict_full_cov``.
Backend selection:

* ``pipeline="tiled"``      — the paper's tiled pipeline (default)
* ``pipeline="monolithic"`` — the cuSOLVER-reference analogue

* ``op_backend="jnp"``      — XLA ops per tile task
* ``op_backend="pallas"``   — explicit Pallas VMEM kernels per tile task

The tiled pipeline caches its :class:`repro.core.predict.PosteriorState`
(packed Cholesky factor + alpha) across ``predict`` calls; the cache is
invalidated automatically when hyperparameters change (see ``posterior``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kernels_math as km
from repro.core import predict as pred


@dataclasses.dataclass
class GaussianProcess:
    x_train: jax.Array
    y_train: jax.Array
    params: km.SEKernelParams = dataclasses.field(
        default_factory=km.SEKernelParams.paper_defaults
    )
    tile_size: int = 256
    n_streams: Optional[int] = None
    pipeline: str = "tiled"
    op_backend: str = "jnp"
    update_dtype: Optional[object] = None
    dtype: object = jnp.float32

    def __post_init__(self):
        self.x_train = jnp.atleast_2d(jnp.asarray(self.x_train, self.dtype))
        if self.x_train.shape[0] == 1 and self.x_train.ndim == 2:
            # allow (n,) inputs for 1-D problems
            pass
        self.y_train = jnp.asarray(self.y_train, self.dtype).reshape(-1)
        if self.x_train.shape[0] != self.y_train.shape[0]:
            self.x_train = self.x_train.T
        assert self.x_train.shape[0] == self.y_train.shape[0]
        self._posterior: Optional[pred.PosteriorState] = None
        self._posterior_key = None

    # -- cached posterior ---------------------------------------------------

    def _cache_key(self):
        p = self.params
        # jax arrays are immutable, so object identity of the training data
        # is a sound staleness signal (rebinding x_train/y_train invalidates)
        return (
            id(self.x_train),
            id(self.y_train),
            float(p.lengthscale),
            float(p.vertical),
            float(p.noise),
            self.tile_size,
            self.n_streams,
            self.op_backend,
            str(self.update_dtype),
            str(jnp.dtype(self.dtype)),
        )

    def posterior(self) -> pred.PosteriorState:
        """The packed Cholesky factor + alpha, cached across ``predict`` calls.

        Recomputed only when hyperparameters or pipeline knobs change (e.g.
        after :meth:`optimize`); repeated predictions at new test points skip
        the O(n^3) assemble/factor/solve stage entirely.
        """
        key = self._cache_key()
        if self._posterior is None or self._posterior_key != key:
            self._posterior = pred.posterior_state(
                self.x_train,
                self.y_train,
                self.params,
                self.tile_size,
                n_streams=self.n_streams,
                backend=self.op_backend,
                update_dtype=self.update_dtype,
                dtype=self.dtype,
            )
            self._posterior_key = key
        return self._posterior

    def invalidate_cache(self) -> None:
        self._posterior = None
        self._posterior_key = None

    # -- prediction ---------------------------------------------------------

    def predict(self, x_test: jax.Array) -> jax.Array:
        x_test = self._prep(x_test)
        if self.pipeline == "monolithic":
            return pred.predict_monolithic(
                self.x_train, self.y_train, x_test, self.params, dtype=self.dtype
            )
        return pred.predict_from_state(
            self.posterior(),
            x_test,
            n_streams=self.n_streams,
            backend=self.op_backend,
            dtype=self.dtype,
        )

    def predict_full_cov(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """The paper's *Predict with Full Covariance Matrix* operation."""
        x_test = self._prep(x_test)
        if self.pipeline == "monolithic":
            return pred.predict_monolithic(
                self.x_train,
                self.y_train,
                x_test,
                self.params,
                full_cov=True,
                dtype=self.dtype,
            )
        return pred.predict_from_state(
            self.posterior(),
            x_test,
            full_cov=True,
            n_streams=self.n_streams,
            backend=self.op_backend,
            dtype=self.dtype,
        )

    def predict_with_uncertainty(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        mean, sigma = self.predict_full_cov(x_test)
        return mean, jnp.diagonal(sigma)

    # -- hyperparameters ----------------------------------------------------

    def log_marginal_likelihood(self) -> jax.Array:
        from repro.core import mll

        return -mll.negative_log_marginal_likelihood(
            self.x_train, self.y_train, self.params, dtype=self.dtype
        )

    def optimize(self, steps: int = 100, lr: float = 0.05) -> "GaussianProcess":
        """Fit hyperparameters by Adam on the negative log marginal likelihood."""
        from repro.core import mll

        new_params, _ = mll.optimize_hyperparameters(
            self.x_train, self.y_train, self.params, steps=steps, lr=lr, dtype=self.dtype
        )
        self.params = new_params
        self.invalidate_cache()  # the factor belongs to the old hyperparameters
        return self

    def _prep(self, x_test: jax.Array) -> jax.Array:
        x_test = jnp.asarray(x_test, self.dtype)
        if x_test.ndim == 1:
            x_test = x_test[:, None]
        return x_test
