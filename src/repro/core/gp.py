"""User-facing Gaussian-process API (GPRat-style).

Mirrors the GPRat Python API surface: construct with data + hyperparameters,
then ``predict`` / ``predict_with_uncertainty`` / ``predict_full_cov``.
Backend selection:

* ``pipeline="tiled"``      — the paper's tiled pipeline (default)
* ``pipeline="monolithic"`` — the cuSOLVER-reference analogue

* ``op_backend="jnp"``      — XLA ops per tile task
* ``op_backend="pallas"``   — explicit Pallas VMEM kernels per tile task

* ``fused=True`` (default)  — cold predictions run the whole pipeline as ONE
  multi-stage program with cross-stage wavefronts (DESIGN.md §7)
* ``fused=False``           — staged per-stage baseline

The tiled pipeline caches its :class:`repro.core.predict.PosteriorState`
(packed Cholesky factor + alpha — with ``fused`` it is a slice of the fused
program's buffer environment) across ``predict`` calls; the cache is
invalidated automatically when hyperparameters change (see ``posterior``).
Warm predictions at new test points reuse the cached factor through the
staged cross-covariance/mean stages, skipping the O(n^3) work entirely.

:class:`GPBatch` is the fleet front-end (DESIGN.md §9): B independent GPs
with stacked ``(B, n, D)`` inputs and per-problem hyperparameters, executed
as ONE problem-batched fused program — same validation / posterior-cache /
invalidation contract as :class:`GaussianProcess`, same executor Plan as a
single GP, every launch B times wider.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.core import kernels_math as km
from repro.core import lowrank
from repro.core import predict as pred
from repro.core import tiling


def _lowrank_state_with_retry(build, base_jitter: float) -> lowrank.LowRankState:
    """Cold Nyström build with escalating-jitter retries (DESIGN.md §15).

    ``chol(K_uu + jitter I)`` can fail when the inducing set has duplicate
    or near-duplicate rows and the jitter is too small — the whitened
    factors come back NaN and every downstream predict/NLML is poisoned.
    Retry the build with the jitter escalated tenfold (at most twice).  The
    finiteness probe reads only the two packed m×m inner factors — O(m²)
    and once per cold build, never on the per-predict path — and each
    incident is recorded as a ``health.lowrank_jitter_retry`` event.
    """
    jit = float(base_jitter)
    state = build(jit)
    for _ in range(2):
        if bool(
            jnp.all(jnp.isfinite(state.luu_packed))
            & jnp.all(jnp.isfinite(state.lb_packed))
        ):
            return state
        jit = max(jit, lowrank.DEFAULT_JITTER) * 10.0
        obs.health_event("lowrank_jitter_retry", jitter=jit)
        state = build(jit)
    return state


def _params_key(params):
    """Hashable digest of a kernel-params pytree for posterior cache keys.

    Works for any registered kernel (ARD vectors, nested composite trees):
    every leaf's host bytes, in tree order.  Leaves must be concrete here —
    the front-ends only ever hold concrete hyperparameters.
    """
    return tuple(
        np.asarray(leaf).tobytes() for leaf in jax.tree_util.tree_leaves(params)
    )


def _validate_fleet_params(params, kernel, b: int, cls: str) -> None:
    """Every hyperparameter leaf: base shape (shared) or (B,)+base (per-problem)."""
    base = kernel.base_ndims(params)
    for (path, leaf), nd in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_leaves(base),
    ):
        if jnp.ndim(leaf) > nd and jnp.shape(leaf)[0] != b:
            name = jax.tree_util.keystr(path)
            raise ValueError(
                f"{cls} params{name} must be shared (rank {nd}) or "
                f"per-problem with leading axis ({b},); got shape "
                f"{jnp.shape(leaf)}"
            )


@dataclasses.dataclass
class GaussianProcess:
    x_train: jax.Array
    y_train: jax.Array
    params: Optional[object] = None  # None -> kernel.default_params()
    tile_size: int = 256
    n_streams: Optional[int] = None
    pipeline: str = "tiled"
    op_backend: str = "jnp"
    update_dtype: Optional[object] = None
    dtype: object = jnp.float32
    fused: bool = True
    sliding_window: Optional[int] = None  # keep at most n_max observations
    # covariance family: None/registry name/Kernel instance (DESIGN.md §13).
    # The kernel id joins the posterior cache key and every jit cache key;
    # executor Plans stay kernel-invariant so switching families reuses them.
    kernel: Optional[object] = None
    # approximation tier (DESIGN.md §14): "exact" (default) factorizes the
    # full n×n covariance; "lowrank" runs the tiled Nyström/DTC tier —
    # O(n m²) build on an m_inducing-point inner system, O(m²) per test
    # point, streaming updates through the rank-m system (never O(n³)).
    # method="lowrank" takes precedence over ``pipeline``/``fused``.
    method: str = "exact"
    m_inducing: Optional[int] = None
    strategy: str = "subset"  # inducing selection: "subset" | "kmeans-lite"
    inducing: Optional[object] = None  # explicit inducing inputs (m_inducing, D)
    jitter: Optional[float] = None  # K_uu regularizer; None -> lowrank.DEFAULT_JITTER

    def __post_init__(self):
        self.kernel = km.resolve_kernel(self.kernel)
        if self.params is None:
            self.params = self.kernel.default_params()
        if self.method not in ("exact", "lowrank"):
            raise ValueError(
                f"method must be 'exact' or 'lowrank', got {self.method!r}"
            )
        if self.method == "lowrank" and self.m_inducing is None:
            raise ValueError("method='lowrank' requires m_inducing")
        if self.sliding_window is not None and self.sliding_window < 1:
            raise ValueError(f"sliding_window must be >= 1, got {self.sliding_window}")
        x = jnp.asarray(self.x_train, self.dtype)
        if x.ndim == 1:  # (n,) convenience for 1-D problems
            x = x[:, None]
        self.y_train = jnp.asarray(self.y_train, self.dtype).reshape(-1)
        n = self.y_train.shape[0]
        if x.ndim != 2 or x.shape[0] != n:
            raise ValueError(
                f"x_train must be (n, D) or (n,) with n == len(y_train) == {n}; "
                f"got shape {tuple(x.shape)}. Pass x_train.T explicitly if your "
                "features are stored (D, n) — it is not transposed silently."
            )
        self.x_train = x
        self._posterior: Optional[pred.PosteriorState] = None
        self._posterior_key = None
        self._lowrank: Optional[lowrank.LowRankState] = None
        self._lowrank_key = None

    # -- cached posterior ---------------------------------------------------

    def _cache_key(self):
        # jax arrays are immutable, so object identity of the training data
        # is a sound staleness signal (rebinding x_train/y_train invalidates)
        return (
            id(self.x_train),
            id(self.y_train),
            self.kernel,
            _params_key(self.params),
            self.tile_size,
            self.n_streams,
            self.op_backend,
            str(self.update_dtype),
            str(jnp.dtype(self.dtype)),
            self.method,
            self.m_inducing,
            self.strategy,
            None if self.jitter is None else float(self.jitter),
            None if self.inducing is None else id(self.inducing),
        )

    def posterior(self) -> pred.PosteriorState:
        """The packed Cholesky factor + alpha, cached across ``predict`` calls.

        Recomputed only when hyperparameters or pipeline knobs change (e.g.
        after :meth:`optimize`); repeated predictions at new test points skip
        the O(n^3) assemble/factor/solve stage entirely.
        """
        key = self._cache_key()
        if self._posterior is None or self._posterior_key != key:
            obs.inc("cache.posterior.cold")
            self._posterior = pred.posterior_state(
                self.x_train,
                self.y_train,
                self.params,
                self.tile_size,
                n_streams=self.n_streams,
                backend=self.op_backend,
                update_dtype=self.update_dtype,
                dtype=self.dtype,
                kernel=self.kernel,
            )
            self._posterior_key = key
        else:
            obs.inc("cache.posterior.warm")
        return self._posterior

    def _effective_jitter(self) -> float:
        return lowrank.DEFAULT_JITTER if self.jitter is None else float(self.jitter)

    def lowrank_posterior(self) -> lowrank.LowRankState:
        """The cached Nyström state (method="lowrank"): inducing chunks, the
        whitened m×m inner factors, and the projected weights — rebuilt only
        when data/hyperparameters/knobs change, exactly like :meth:`posterior`.
        """
        key = self._cache_key()
        if self._lowrank is None or self._lowrank_key != key:
            obs.inc("cache.lowrank.cold")
            self._lowrank = _lowrank_state_with_retry(
                lambda jit: lowrank.lowrank_state(
                    self.x_train,
                    self.y_train,
                    self.params,
                    self.m_inducing,
                    self.tile_size,
                    strategy=self.strategy,
                    inducing=self.inducing,
                    jitter=jit,
                    n_streams=self.n_streams,
                    backend=self.op_backend,
                    update_dtype=self.update_dtype,
                    dtype=self.dtype,
                    kernel=self.kernel,
                ),
                self._effective_jitter(),
            )
            self._lowrank_key = key
        else:
            obs.inc("cache.lowrank.warm")
        return self._lowrank

    def invalidate_cache(self) -> None:
        self._posterior = None
        self._posterior_key = None
        self._lowrank = None
        self._lowrank_key = None

    # -- streaming updates (DESIGN.md §10) ----------------------------------

    def _cache_warm(self) -> bool:
        return self._posterior is not None and self._posterior_key == self._cache_key()

    def _lowrank_warm(self) -> bool:
        return self._lowrank is not None and self._lowrank_key == self._cache_key()

    def update(self, x_new: jax.Array, y_new: jax.Array) -> "GaussianProcess":
        """Absorb new observations online in O(n^2 b) — no re-factorization.

        Appends ``(x_new, y_new)`` to the training set; when the posterior
        cache is warm the cached factor/weights are *extended* in place via
        the tiled block Cholesky append (``PosteriorState.extend``), so the
        next ``predict`` skips straight to the warm tail.  A cold cache (or
        a numerically failed append — NaN heads) falls back to the
        established contract: the cache is invalidated and the next
        prediction re-factorizes.  With ``sliding_window=n_max``, the oldest
        observations are evicted (:meth:`forget`) once n exceeds n_max — in
        whole-tile chunks, so eviction stays on the O(n^2) fast path.
        """
        from repro.core import update as upd

        x_new = self._prep(x_new)
        y_new = jnp.asarray(y_new, self.dtype).reshape(-1)
        if x_new.shape[0] != y_new.shape[0]:
            raise ValueError(
                f"update needs matching x_new (b, D) and y_new (b,); got "
                f"{tuple(x_new.shape)} and {tuple(y_new.shape)}"
            )
        if x_new.shape[0] == 0:
            return self
        if self.method == "lowrank":
            # absorb through the rank-m inner system: O(b m² + m³), no O(n³)
            warm = self._lowrank_warm()
            state = self._lowrank
            self.x_train = jnp.concatenate([self.x_train, x_new], axis=0)
            self.y_train = jnp.concatenate([self.y_train, y_new], axis=0)
            if warm:
                try:
                    self._lowrank = lowrank.absorb(
                        state,
                        x_new,
                        y_new,
                        sign=1,
                        n_streams=self.n_streams,
                        backend=self.op_backend,
                        update_dtype=self.update_dtype,
                    )
                    self._lowrank_key = self._cache_key()
                except upd.CholeskyUpdateError:
                    obs.health_event("refactorize_fallback", site="gp.update.lowrank")
                    self.invalidate_cache()
            else:
                self.invalidate_cache()
            if self.sliding_window is not None:
                excess = self.y_train.shape[0] - self.sliding_window
                if excess > 0:
                    # no tile alignment needed: eviction is a rank-m downdate
                    self.forget(min(excess, self.y_train.shape[0] - 1))
            return self
        warm = self.pipeline == "tiled" and self._cache_warm()
        state = self._posterior
        self.x_train = jnp.concatenate([self.x_train, x_new], axis=0)
        self.y_train = jnp.concatenate([self.y_train, y_new], axis=0)
        if warm and x_new.shape[0] > 0:
            try:
                self._posterior = state.extend(
                    x_new,
                    y_new,
                    n_streams=self.n_streams,
                    backend=self.op_backend,
                    update_dtype=self.update_dtype,
                )
                self._posterior_key = self._cache_key()
            except upd.CholeskyUpdateError:
                obs.health_event("refactorize_fallback", site="gp.update")
                self.invalidate_cache()  # next predict refactorizes
        else:
            self.invalidate_cache()
        if self.sliding_window is not None:
            excess = self.y_train.shape[0] - self.sliding_window
            if excess > 0:
                # evict in whole-tile chunks so the O(n^2) downdate fast
                # path applies: round the overflow up to a tile multiple
                # (n stays <= n_max; slightly more than the overflow may
                # go).  A window smaller than one tile evicts exactly.
                m = self.tile_size
                self.forget(min(-(-excess // m) * m, self.y_train.shape[0] - 1))
        return self

    def forget(self, k: int) -> "GaussianProcess":
        """Evict the k oldest observations (sliding-window downdate).

        Tile-aligned k on a warm cache runs the O(n^2 k) rank-update sweep
        (``PosteriorState.shrink``); anything else (unaligned k, cold
        cache, numerical failure) invalidates the cache so the next
        prediction re-factorizes the kept window.
        """
        from repro.core import update as upd

        n = self.y_train.shape[0]
        if not 0 <= k < n:
            raise ValueError(f"forget(k) needs 0 <= k < n = {n}; got {k}")
        if k == 0:
            return self
        if self.method == "lowrank":
            # rank-m downdate of the inner system (absorb with sign=-1);
            # works for any k — no tile alignment requirement
            warm = self._lowrank_warm()
            state = self._lowrank
            x_old, y_old = self.x_train[:k], self.y_train[:k]
            self.x_train = self.x_train[k:]
            self.y_train = self.y_train[k:]
            if warm:
                try:
                    self._lowrank = lowrank.absorb(
                        state,
                        x_old,
                        y_old,
                        sign=-1,
                        n_streams=self.n_streams,
                        backend=self.op_backend,
                        update_dtype=self.update_dtype,
                    )
                    self._lowrank_key = self._cache_key()
                except upd.CholeskyUpdateError:
                    obs.health_event("refactorize_fallback", site="gp.forget.lowrank")
                    self.invalidate_cache()
            else:
                self.invalidate_cache()
            return self
        warm = self.pipeline == "tiled" and self._cache_warm()
        state = self._posterior
        self.x_train = self.x_train[k:]
        self.y_train = self.y_train[k:]
        # whole leading tiles on a warm cache; k < n already leaves >= 1 row
        if warm and k % self.tile_size == 0:
            try:
                self._posterior = state.shrink(
                    k, n_streams=self.n_streams, backend=self.op_backend
                )
                self._posterior_key = self._cache_key()
            except upd.CholeskyUpdateError:
                obs.health_event("refactorize_fallback", site="gp.forget")
                self.invalidate_cache()
        else:
            self.invalidate_cache()
        return self

    # -- prediction ---------------------------------------------------------

    def _predict_tiled(self, x_test: jax.Array, full_cov: bool):
        """Route a tiled prediction: cached factor -> staged tail stages;
        cold + ``fused`` -> one whole-pipeline program whose buffer env also
        populates the posterior cache; cold staged -> posterior() then tail."""
        key = self._cache_key()
        if self._posterior is not None and self._posterior_key == key:
            obs.inc("cache.posterior.warm")
            state = self._posterior
        elif self.fused:
            obs.inc("cache.posterior.cold")
            result, state = pred.predict_fused(
                self.x_train,
                self.y_train,
                x_test,
                self.params,
                self.tile_size,
                full_cov=full_cov,
                n_streams=self.n_streams,
                backend=self.op_backend,
                update_dtype=self.update_dtype,
                dtype=self.dtype,
                with_state=True,
                kernel=self.kernel,
            )
            self._posterior, self._posterior_key = state, key
            return result
        else:
            state = self.posterior()
        return pred.predict_from_state(
            state,
            x_test,
            full_cov=full_cov,
            n_streams=self.n_streams,
            backend=self.op_backend,
            dtype=self.dtype,
        )

    def _predict_lowrank(self, x_test: jax.Array, full_cov: bool):
        return lowrank.predict_from_lowrank_state(
            self.lowrank_posterior(),
            x_test,
            full_cov=full_cov,
            n_streams=self.n_streams,
            backend=self.op_backend,
            dtype=self.dtype,
        )

    def predict(self, x_test: jax.Array) -> jax.Array:
        x_test = self._prep(x_test)
        if self.method == "lowrank":
            return self._predict_lowrank(x_test, full_cov=False)
        if self.pipeline == "monolithic":
            return pred.predict_monolithic(
                self.x_train, self.y_train, x_test, self.params,
                dtype=self.dtype, kernel=self.kernel,
            )
        return self._predict_tiled(x_test, full_cov=False)

    def predict_full_cov(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """The paper's *Predict with Full Covariance Matrix* operation."""
        x_test = self._prep(x_test)
        if self.method == "lowrank":
            return self._predict_lowrank(x_test, full_cov=True)
        if self.pipeline == "monolithic":
            return pred.predict_monolithic(
                self.x_train,
                self.y_train,
                x_test,
                self.params,
                full_cov=True,
                dtype=self.dtype,
                kernel=self.kernel,
            )
        return self._predict_tiled(x_test, full_cov=True)

    def predict_with_uncertainty(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        mean, sigma = self.predict_full_cov(x_test)
        return mean, jnp.diagonal(sigma)

    # -- hyperparameters ----------------------------------------------------

    def nlml(self) -> jax.Array:
        """Negative log marginal likelihood from the *cached* tiled posterior.

        Reuses (or populates) the posterior cache: the quadratic term is
        ``y^T alpha`` over the cached weight chunks and the log-determinant
        comes from the packed factor's diagonal tiles — no monolithic
        re-factorization (mll.nlml_from_state).  Identity padding makes both
        terms exact for any n.
        """
        from repro.core import mll

        if self.method == "lowrank":
            return lowrank.nlml_from_lowrank_state(
                self.lowrank_posterior(), dtype=self.dtype
            )
        if self.pipeline == "monolithic":
            return mll.negative_log_marginal_likelihood(
                self.x_train, self.y_train, self.params,
                dtype=self.dtype, kernel=self.kernel,
            )
        return mll.nlml_from_state(self.posterior(), self.y_train, dtype=self.dtype)

    def log_marginal_likelihood(self) -> jax.Array:
        """``-nlml()`` — for ``pipeline="tiled"`` this reuses the cached tiled
        posterior (no monolithic Cholesky), consistent with :meth:`nlml`;
        previously it always ran the monolithic path regardless of pipeline."""
        return -self.nlml()

    def optimize(
        self, steps: int = 100, lr: float = 0.05, *, method: Optional[str] = None
    ) -> "GaussianProcess":
        """Fit hyperparameters by Adam on the negative log marginal likelihood.

        The optimizer is one jitted ``lax.scan`` (mll.adam_scan).  ``method``
        defaults to the GP's pipeline: ``pipeline="tiled"`` trains through
        the differentiable tiled program (``mll.nlml_tiled`` — zero
        monolithic Cholesky calls, same tile_size/n_streams/op_backend/
        update_dtype knobs as prediction); ``pipeline="monolithic"``
        differentiates the dense reference NLML.
        """
        from repro.core import mll

        if method is None:
            if self.method == "lowrank":
                method = "lowrank"
            else:
                method = "tiled" if self.pipeline == "tiled" else "monolithic"
        new_params, _ = mll.optimize_hyperparameters(
            self.x_train,
            self.y_train,
            self.params,
            steps=steps,
            lr=lr,
            dtype=self.dtype,
            method=method,
            tile_size=self.tile_size,
            n_streams=self.n_streams,
            op_backend=self.op_backend,
            update_dtype=self.update_dtype,
            kernel=self.kernel,
            m_inducing=self.m_inducing,
            strategy=self.strategy,
            inducing=self.inducing,
            jitter=self.jitter,
        )
        self.params = new_params
        self.invalidate_cache()  # the factor belongs to the old hyperparameters
        return self

    def _prep(self, x_test: jax.Array) -> jax.Array:
        x_test = jnp.asarray(x_test, self.dtype)
        if x_test.ndim == 1:
            x_test = x_test[:, None]
        return x_test


@dataclasses.dataclass
class GPBatch:
    """B independent GPs executed as ONE problem-batched fused program.

    Stacked inputs: ``x_train`` (B, n, D) (or (B, n) for 1-D problems),
    ``y_train`` (B, n) — ragged-free, every problem shares n and D so the
    whole fleet shares one executor Plan (the DAG depends only on the tile
    geometry, never on B; see DESIGN.md §9).  ``params`` leaves may be
    scalars (shared across the fleet — keeps the Pallas assembly kernels
    usable, with B folded into their grid) or vectors (B,) (per-problem —
    assembly routes through the vmapped jnp tile kernel).  Scalars are kept
    as scalars; :meth:`optimize` always returns per-problem (B,) leaves.

    Same contract as :class:`GaussianProcess`: shape validation raises
    instead of silently transposing, the stacked
    :class:`repro.core.predict.PosteriorState` is cached across ``predict``
    calls and invalidated when hyperparameters or pipeline knobs change,
    and :meth:`optimize` trains all B GPs' hyperparameters in one jitted
    Adam scan with independent optimizer states.
    """

    x_train: jax.Array
    y_train: jax.Array
    params: Optional[object] = None  # None -> kernel.default_params()
    tile_size: int = 256
    n_streams: Optional[int] = None
    op_backend: str = "jnp"
    update_dtype: Optional[object] = None
    dtype: object = jnp.float32
    batch_dispatch: str = "flat"
    # optional jax.sharding.Mesh: shard the problem axis B over its DP axes
    # (pure data parallelism — problems are independent, so every launch
    # partitions along B with zero collectives; DESIGN.md §12).  The mesh
    # changes layout only: results, Plans, and trace counts are identical
    # to the single-device path.
    mesh: Optional[object] = None
    kernel: Optional[object] = None  # covariance family (DESIGN.md §13)
    # approximation tier (DESIGN.md §14): "lowrank" runs the whole fleet's
    # Nyström builds/heads as ONE problem-batched program (B folded into the
    # bulk-op launches, Plans shared with the single-GP lowrank tier).
    method: str = "exact"
    m_inducing: Optional[int] = None
    strategy: str = "subset"
    inducing: Optional[object] = None  # (m_inducing, D) shared or (B, m_inducing, D)
    jitter: Optional[float] = None

    def __post_init__(self):
        self.kernel = km.resolve_kernel(self.kernel)
        if self.params is None:
            self.params = self.kernel.default_params()
        if self.method not in ("exact", "lowrank"):
            raise ValueError(
                f"method must be 'exact' or 'lowrank', got {self.method!r}"
            )
        if self.method == "lowrank" and self.m_inducing is None:
            raise ValueError("method='lowrank' requires m_inducing")
        x = jnp.asarray(self.x_train, self.dtype)
        if x.ndim == 2:  # (B, n) convenience for 1-D problems
            x = x[..., None]
        y = jnp.asarray(self.y_train, self.dtype)
        if x.ndim != 3 or y.ndim != 2 or x.shape[:2] != y.shape:
            raise ValueError(
                f"GPBatch needs stacked x_train (B, n, D) or (B, n) and "
                f"y_train (B, n) with matching leading axes; got "
                f"x {tuple(jnp.asarray(self.x_train).shape)}, "
                f"y {tuple(y.shape)}. Stack ragged problems to a common n "
                "(they are not padded silently)."
            )
        self.x_train = x
        self.y_train = y
        b = x.shape[0]
        _validate_fleet_params(self.params, self.kernel, b, "GPBatch")
        self._posterior: Optional[pred.PosteriorState] = None
        self._posterior_key = None
        self._lowrank: Optional[lowrank.LowRankState] = None
        self._lowrank_key = None
        self._params_bytes = None  # (params object, host bytes) memo

    @property
    def batch_size(self) -> int:
        return self.x_train.shape[0]

    # -- cached posterior ---------------------------------------------------

    def _cache_key(self):
        p = self.params
        # memoize the device->host transfer of the param leaves: params are
        # immutable jax arrays/floats, so the identity of the params pytree
        # (kept referenced here, so its id cannot be reused) is a sound
        # staleness signal — rebinding self.params (optimize()) refreshes it
        if self._params_bytes is None or self._params_bytes[0] is not p:
            self._params_bytes = (p, _params_key(p))
        return (
            id(self.x_train),
            id(self.y_train),
            self.kernel,
            self._params_bytes[1],
            self.tile_size,
            self.n_streams,
            self.op_backend,
            str(self.update_dtype),
            str(jnp.dtype(self.dtype)),
            self.batch_dispatch,
            self.mesh,
            self.method,
            self.m_inducing,
            self.strategy,
            None if self.jitter is None else float(self.jitter),
            None if self.inducing is None else id(self.inducing),
        )

    def posterior(self) -> pred.PosteriorState:
        """Stacked factors + weights (leading B axis), cached across calls.

        Runs the q_tiles=0 prefix of the problem-batched program (assembly →
        factorization → both substitutions) — the NLML program IS the
        prediction program with zero test tiles, so this shares every
        plan/jit cache with prediction.
        """
        key = self._cache_key()
        if self._posterior is None or self._posterior_key != key:
            obs.inc("cache.posterior.cold")
            env, yc = pred.nlml_program_env(
                self.x_train,
                self.y_train,
                self.params,
                self.tile_size,
                n_streams=self.n_streams,
                backend=self.op_backend,
                update_dtype=self.update_dtype,
                dtype=self.dtype,
                batch_dispatch=self.batch_dispatch,
                mesh=self.mesh,
                kernel=self.kernel,
            )
            self._posterior = pred.PosteriorState(
                lpacked=env["packed"],
                alpha=env["alpha"],
                x_chunks=tiling.pad_features(self.x_train, self.tile_size, dtype=self.dtype),
                n=self.x_train.shape[1],
                m=self.tile_size,
                params=self.params,
                beta=env["y"],
                y_chunks=yc,
                kernel=self.kernel,
            )
            self._posterior_key = key
        else:
            obs.inc("cache.posterior.warm")
        return self._posterior

    def _lowrank_inducing(self):
        """Explicit inducing inputs normalized to stacked (B, m_inducing, D)."""
        if self.inducing is None:
            return None
        ind = jnp.asarray(self.inducing, self.dtype)
        if ind.ndim == 2:  # shared set, broadcast across the fleet
            ind = jnp.broadcast_to(ind[None], (self.batch_size,) + ind.shape)
        return ind

    def lowrank_posterior(self) -> lowrank.LowRankState:
        """Stacked Nyström states (leading B axis), cached across calls."""
        key = self._cache_key()
        if self._lowrank is None or self._lowrank_key != key:
            obs.inc("cache.lowrank.cold")
            self._lowrank = _lowrank_state_with_retry(
                lambda jit: lowrank.lowrank_state(
                    self.x_train,
                    self.y_train,
                    self.params,
                    self.m_inducing,
                    self.tile_size,
                    strategy=self.strategy,
                    inducing=self._lowrank_inducing(),
                    jitter=jit,
                    n_streams=self.n_streams,
                    backend=self.op_backend,
                    update_dtype=self.update_dtype,
                    dtype=self.dtype,
                    batch_dispatch=self.batch_dispatch,
                    kernel=self.kernel,
                ),
                lowrank.DEFAULT_JITTER if self.jitter is None
                else float(self.jitter),
            )
            self._lowrank_key = key
        else:
            obs.inc("cache.lowrank.warm")
        return self._lowrank

    def _lowrank_warm(self) -> bool:
        return self._lowrank is not None and self._lowrank_key == self._cache_key()

    def invalidate_cache(self) -> None:
        self._posterior = None
        self._posterior_key = None
        self._lowrank = None
        self._lowrank_key = None

    # -- streaming updates (DESIGN.md §10) ----------------------------------

    def update(self, x_new: jax.Array, y_new: jax.Array) -> "GPBatch":
        """Fleet-wide online absorption: every problem appends b points.

        x_new (B, b, D) (or (B, b) for 1-D fleets) / y_new (B, b) — the
        shared count b keeps the fleet on one tile geometry, so the whole
        append runs as ONE problem-batched sweep through the same plans as
        a single GP (every launch B times wider).  Warm caches are extended
        in O(n^2 b); a cold cache or a numerically failed append (any
        problem) invalidates and the next prediction re-factorizes the
        fleet.
        """
        from repro.core import update as upd

        x_new = jnp.asarray(x_new, self.dtype)
        if x_new.ndim == 2 and self.x_train.shape[-1] == 1:
            x_new = x_new[..., None]
        y_new = jnp.asarray(y_new, self.dtype)
        b = self.batch_size
        if (
            x_new.ndim != 3
            or x_new.shape[0] != b
            or x_new.shape[-1] != self.x_train.shape[-1]
            or y_new.shape != x_new.shape[:-1]
        ):
            raise ValueError(
                f"GPBatch.update needs stacked x_new (B, b, D) and y_new "
                f"(B, b) with B == {b}; got x {tuple(jnp.asarray(x_new).shape)}, "
                f"y {tuple(y_new.shape)}"
            )
        if x_new.shape[1] == 0:
            return self
        if self.method == "lowrank":
            warm = self._lowrank_warm()
            state = self._lowrank
            self.x_train = jnp.concatenate([self.x_train, x_new], axis=1)
            self.y_train = jnp.concatenate([self.y_train, y_new], axis=1)
            if warm:
                try:
                    self._lowrank = lowrank.absorb(
                        state,
                        x_new,
                        y_new,
                        sign=1,
                        n_streams=self.n_streams,
                        backend=self.op_backend,
                        update_dtype=self.update_dtype,
                        batch_dispatch=self.batch_dispatch,
                    )
                    self._lowrank_key = self._cache_key()
                except upd.CholeskyUpdateError:
                    obs.health_event(
                        "refactorize_fallback", site="batch.update.lowrank"
                    )
                    self.invalidate_cache()
            else:
                self.invalidate_cache()
            return self
        warm = self._cache_warm()
        state = self._posterior
        self.x_train = jnp.concatenate([self.x_train, x_new], axis=1)
        self.y_train = jnp.concatenate([self.y_train, y_new], axis=1)
        if warm and x_new.shape[1] > 0:
            try:
                self._posterior = state.extend(
                    x_new,
                    y_new,
                    n_streams=self.n_streams,
                    backend=self.op_backend,
                    update_dtype=self.update_dtype,
                    batch_dispatch=self.batch_dispatch,
                    mesh=self.mesh,
                )
                self._posterior_key = self._cache_key()
            except upd.CholeskyUpdateError:
                obs.health_event("refactorize_fallback", site="batch.update")
                self.invalidate_cache()
        else:
            self.invalidate_cache()
        return self

    def forget(self, k: int) -> "GPBatch":
        """Evict every problem's k oldest observations (fleet downdate)."""
        from repro.core import update as upd

        n = self.y_train.shape[1]
        if not 0 <= k < n:
            raise ValueError(f"forget(k) needs 0 <= k < n = {n}; got {k}")
        if k == 0:
            return self
        if self.method == "lowrank":
            warm = self._lowrank_warm()
            state = self._lowrank
            x_old, y_old = self.x_train[:, :k], self.y_train[:, :k]
            self.x_train = self.x_train[:, k:]
            self.y_train = self.y_train[:, k:]
            if warm:
                try:
                    self._lowrank = lowrank.absorb(
                        state,
                        x_old,
                        y_old,
                        sign=-1,
                        n_streams=self.n_streams,
                        backend=self.op_backend,
                        update_dtype=self.update_dtype,
                        batch_dispatch=self.batch_dispatch,
                    )
                    self._lowrank_key = self._cache_key()
                except upd.CholeskyUpdateError:
                    obs.health_event(
                        "refactorize_fallback", site="batch.forget.lowrank"
                    )
                    self.invalidate_cache()
            else:
                self.invalidate_cache()
            return self
        warm = self._cache_warm()
        state = self._posterior
        self.x_train = self.x_train[:, k:]
        self.y_train = self.y_train[:, k:]
        if warm and k % self.tile_size == 0:
            try:
                self._posterior = state.shrink(
                    k,
                    n_streams=self.n_streams,
                    backend=self.op_backend,
                    batch_dispatch=self.batch_dispatch,
                    mesh=self.mesh,
                )
                self._posterior_key = self._cache_key()
            except upd.CholeskyUpdateError:
                obs.health_event("refactorize_fallback", site="batch.forget")
                self.invalidate_cache()
        else:
            self.invalidate_cache()
        return self

    def _cache_warm(self) -> bool:
        return self._posterior is not None and self._posterior_key == self._cache_key()

    # -- prediction ---------------------------------------------------------

    def _predict_batched(self, x_test: jax.Array, full_cov: bool):
        """Cold: ONE problem-batched fused program (populates the posterior
        cache from its buffer env).  Warm: batched cross/mean tail off the
        cached stacked factor."""
        if self.method == "lowrank":
            return lowrank.predict_from_lowrank_state(
                self.lowrank_posterior(),
                x_test,
                full_cov=full_cov,
                n_streams=self.n_streams,
                backend=self.op_backend,
                dtype=self.dtype,
                batch_dispatch=self.batch_dispatch,
            )
        key = self._cache_key()
        if self._posterior is not None and self._posterior_key == key:
            obs.inc("cache.posterior.warm")
            return pred.predict_from_state_batched(
                self._posterior,
                x_test,
                full_cov=full_cov,
                n_streams=self.n_streams,
                dtype=self.dtype,
                mesh=self.mesh,
            )
        obs.inc("cache.posterior.cold")
        result, state = pred.predict_fused_batched(
            self.x_train,
            self.y_train,
            x_test,
            self.params,
            self.tile_size,
            full_cov=full_cov,
            n_streams=self.n_streams,
            backend=self.op_backend,
            update_dtype=self.update_dtype,
            dtype=self.dtype,
            with_state=True,
            batch_dispatch=self.batch_dispatch,
            mesh=self.mesh,
            kernel=self.kernel,
        )
        self._posterior, self._posterior_key = state, key
        return result

    def predict(self, x_test: jax.Array) -> jax.Array:
        """Predictive means (B, n̂) for stacked test points (B, n̂, D).

        A shared (n̂, D) test block is broadcast to every problem."""
        return self._predict_batched(self._prep(x_test), full_cov=False)

    def predict_full_cov(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Means (B, n̂) and posterior covariances (B, n̂, n̂)."""
        return self._predict_batched(self._prep(x_test), full_cov=True)

    def predict_with_uncertainty(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        mean, sigma = self.predict_full_cov(x_test)
        return mean, jnp.diagonal(sigma, axis1=-2, axis2=-1)

    # -- hyperparameters ----------------------------------------------------

    def nlml(self) -> jax.Array:
        """Per-problem NLML vector (B,) from the cached stacked posterior."""
        from repro.core import mll

        if self.method == "lowrank":
            return lowrank.nlml_from_lowrank_state(
                self.lowrank_posterior(), dtype=self.dtype
            )
        return mll.nlml_from_state(self.posterior(), self.y_train, dtype=self.dtype)

    def log_marginal_likelihood(self) -> jax.Array:
        return -self.nlml()

    def optimize(self, steps: int = 100, lr: float = 0.05) -> "GPBatch":
        """Adam on all B NLMLs in ONE jitted scan (independent Adam states,
        per-problem losses — mll.optimize_hyperparameters_batched)."""
        from repro.core import mll

        new_params, _ = mll.optimize_hyperparameters_batched(
            self.x_train,
            self.y_train,
            self.params,
            steps=steps,
            lr=lr,
            dtype=self.dtype,
            method="lowrank" if self.method == "lowrank" else "tiled",
            tile_size=self.tile_size,
            n_streams=self.n_streams,
            op_backend=self.op_backend,
            update_dtype=self.update_dtype,
            batch_dispatch=self.batch_dispatch,
            kernel=self.kernel,
            m_inducing=self.m_inducing,
            strategy=self.strategy,
            inducing=None if self.method != "lowrank" else self._lowrank_inducing(),
            jitter=self.jitter,
        )
        self.params = new_params
        self.invalidate_cache()  # the factors belong to the old hyperparameters
        return self

    def _prep(self, x_test: jax.Array) -> jax.Array:
        """Normalize test inputs to stacked (B, n̂, D).

        Accepted forms: (B, n̂, D) stacked; (n̂, D) shared across the fleet
        (broadcast); (n̂,) shared 1-D points; and — for 1-D fleets only —
        (B, n̂) stacked per-problem points, mirroring the constructor's
        (B, n) convenience.  When D == 1 and the leading axis equals B, a
        2-D input is read as *stacked* (the constructor's convention), so
        pass shared points for a size-B 1-D fleet as (n̂, 1) with n̂ != B or
        stack them explicitly.
        """
        x_test = jnp.asarray(x_test, self.dtype)
        d = self.x_train.shape[-1]
        b = self.batch_size
        if x_test.ndim == 1:  # shared 1-D test points
            x_test = x_test[:, None]
        if x_test.ndim == 2:
            if d == 1 and x_test.shape[0] == b:
                x_test = x_test[..., None]          # stacked (B, n̂) 1-D points
            elif x_test.shape[-1] == d:
                x_test = jnp.broadcast_to(          # shared (n̂, D) block
                    x_test[None], (b,) + x_test.shape
                )
        if x_test.ndim != 3 or x_test.shape[0] != b or x_test.shape[-1] != d:
            raise ValueError(
                f"x_test must be (n̂, {d}) shared, (B, n̂, {d}) stacked"
                + (", (n̂,) shared or (B, n̂) stacked 1-D points" if d == 1 else "")
                + f" with B == {b}; got {tuple(x_test.shape)}"
            )
        return x_test


@dataclasses.dataclass
class _Bucket:
    """One bucket of a :class:`GPFleet`: a ragged slice sharing a geometry."""

    idx: Tuple[int, ...]                       # fleet indices, bucket order
    state: Optional[object]                    # stacked ragged state (warm):
    #   PosteriorState (exact) or lowrank.LowRankState (method="lowrank")
    key: object                                # fleet cache key at build time


@dataclasses.dataclass
class GPFleet:
    """B independent GPs of *different* sizes, bucketed by tile geometry.

    The ragged front-end (DESIGN.md §11): problems are grouped into buckets
    whose tile-count capacities come from ``tiling.bucket_boundaries``
    (default powers of two), zero-padded to the bucket capacity, and each
    bucket runs as ONE ragged problem-batched fused program with per-problem
    ``n_valid`` frontiers as *traced* operands.  One jit trace and one
    lru-cached executor Plan per bucket geometry serve every size mix and
    every batch width — never one per problem.

    ``update`` absorbs ragged arrival counts b_i in-place per bucket
    (``update.extend_state_ragged``) and transparently *migrates* problems
    that outgrow their bucket: the factor is re-embedded into the larger
    geometry as ``blockdiag(L, I)`` — a pure gather (``tiling.embed_packed``,
    zero FLOPs) — before the warm append, so migration never re-factorizes.

    Same caching contract as :class:`GPBatch`; hyperparameter leaves may be
    scalars (shared) or (B,) vectors (per-problem, gathered per bucket).
    """

    x_train: Sequence            # length-B list of (n_i, D) or (n_i,) arrays
    y_train: Sequence            # length-B list of (n_i,) arrays
    params: Optional[object] = None  # None -> kernel.default_params()
    tile_size: int = 64
    n_streams: Optional[int] = None
    op_backend: str = "jnp"
    update_dtype: Optional[object] = None
    dtype: object = jnp.float32
    batch_dispatch: str = "flat"
    boundaries: object = tiling.DEFAULT_BUCKETS
    # optional jax.sharding.Mesh: shard each bucket's stacked problem axis
    # over the mesh's DP axes (DESIGN.md §12).  Bucket programs are already
    # B-invariant, so the same Plans/traces drive any device count; buckets
    # whose width doesn't divide the mesh fall back to replication
    # per-bucket (fleet_spec), never to an error.
    mesh: Optional[object] = None
    kernel: Optional[object] = None  # covariance family (DESIGN.md §13)
    # approximation tier (DESIGN.md §14).  Under "lowrank" every bucket's
    # cached state is mu-sized (inducing chunks + m×m inner factors — nothing
    # n-sized), so bucket *migration* needs no factor re-embedding at all:
    # transfer is a pure row gather of the stacked state, then a ragged
    # absorb of the arrivals.
    method: str = "exact"
    m_inducing: Optional[int] = None
    strategy: str = "subset"
    inducing: Optional[object] = None  # (m_inducing, D) shared across the fleet
    jitter: Optional[float] = None

    def __post_init__(self):
        self.kernel = km.resolve_kernel(self.kernel)
        if self.params is None:
            self.params = self.kernel.default_params()
        if self.method not in ("exact", "lowrank"):
            raise ValueError(
                f"method must be 'exact' or 'lowrank', got {self.method!r}"
            )
        if self.method == "lowrank" and self.m_inducing is None:
            raise ValueError("method='lowrank' requires m_inducing")
        xs, ys = [], []
        if len(self.x_train) != len(self.y_train) or not len(self.x_train):
            raise ValueError(
                f"GPFleet needs equal-length, non-empty x/y lists; got "
                f"{len(self.x_train)} and {len(self.y_train)}"
            )
        d = None
        for i, (x, y) in enumerate(zip(self.x_train, self.y_train)):
            x = jnp.asarray(x, self.dtype)
            if x.ndim == 1:
                x = x[:, None]
            y = jnp.asarray(y, self.dtype).reshape(-1)
            if x.ndim != 2 or x.shape[0] != y.shape[0] or y.shape[0] < 1:
                raise ValueError(
                    f"problem {i}: x must be (n, D) or (n,) with n == "
                    f"len(y) >= 1; got x {tuple(x.shape)}, y {tuple(y.shape)}"
                )
            if d is None:
                d = x.shape[1]
            elif x.shape[1] != d:
                raise ValueError(
                    f"problem {i}: feature dim {x.shape[1]} != {d} — all "
                    "fleet problems must share D"
                )
            xs.append(x)
            ys.append(y)
        self._xs: List[jax.Array] = xs
        self._ys: List[jax.Array] = ys
        b = len(xs)
        _validate_fleet_params(self.params, self.kernel, b, "GPFleet")
        self._buckets: Dict[int, _Bucket] = {}
        self._version = 0
        self._params_bytes = None

    @property
    def batch_size(self) -> int:
        return len(self._xs)

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(y.shape[0] for y in self._ys)

    def bucket_assignment(self) -> Dict[int, List[int]]:
        """Current ``{cap_tiles: [fleet indices]}`` map (recomputed)."""
        return tiling.bucket_problems(self.sizes, self.tile_size, self.boundaries)

    # -- cached per-bucket posteriors ---------------------------------------

    def _cache_key(self):
        p = self.params
        if self._params_bytes is None or self._params_bytes[0] is not p:
            self._params_bytes = (p, _params_key(p))
        return (
            self._version,
            self.kernel,
            self._params_bytes[1],
            self.tile_size,
            self.n_streams,
            self.op_backend,
            str(self.update_dtype),
            str(jnp.dtype(self.dtype)),
            self.batch_dispatch,
            self.boundaries if not isinstance(self.boundaries, (list, tuple))
            else tuple(self.boundaries),
            self.mesh,
            self.method,
            self.m_inducing,
            self.strategy,
            None if self.jitter is None else float(self.jitter),
            None if self.inducing is None else id(self.inducing),
        )

    def invalidate_cache(self) -> None:
        self._buckets = {}

    def _bucket_params(self, idx):
        """Per-problem leaves gathered into the bucket's rows, shared leaves
        passed through — a ``tree_map`` over the params pytree, so any
        registered kernel's params (ARD vectors, composite trees) bucket
        correctly (km.gather_params)."""
        return km.gather_params(self.params, jnp.asarray(idx), self.kernel)

    def _stack(self, idx, cap_tiles):
        """Zero-pad the bucket's problems to the capacity and stack them."""
        capn = cap_tiles * self.tile_size
        xs = jnp.stack(
            [jnp.pad(self._xs[i], ((0, capn - self._xs[i].shape[0]), (0, 0)))
             for i in idx]
        )
        ys = jnp.stack(
            [jnp.pad(self._ys[i], (0, capn - self._ys[i].shape[0]))
             for i in idx]
        )
        nv = jnp.asarray([self._ys[i].shape[0] for i in idx], jnp.int32)
        return xs, ys, nv

    def _bucket_state(self, cap_tiles, idx):
        """Warm cached stacked state for one bucket, (re)built cold on miss."""
        key = self._cache_key()
        rec = self._buckets.get(cap_tiles)
        if rec is not None and rec.key == key and rec.idx == tuple(idx) \
                and rec.state is not None:
            obs.inc("cache.bucket.warm")
            return rec.state
        obs.inc("cache.bucket.cold")
        xs, ys, nv = self._stack(idx, cap_tiles)
        bp = self._bucket_params(idx)
        if self.method == "lowrank":
            ind = self.inducing
            if ind is not None:
                ind = jnp.asarray(ind, self.dtype)
                if ind.ndim == 2:  # one shared set, broadcast over the bucket
                    ind = jnp.broadcast_to(ind[None], (len(idx),) + ind.shape)
                else:
                    ind = ind[jnp.asarray(idx)]
            state = _lowrank_state_with_retry(
                lambda jit: lowrank.lowrank_state(
                    xs, ys, bp, self.m_inducing, self.tile_size,
                    strategy=self.strategy, inducing=ind,
                    jitter=jit,
                    n_streams=self.n_streams, backend=self.op_backend,
                    update_dtype=self.update_dtype, dtype=self.dtype,
                    batch_dispatch=self.batch_dispatch, n_valid=nv,
                    kernel=self.kernel,
                ),
                lowrank.DEFAULT_JITTER if self.jitter is None
                else float(self.jitter),
            )
            self._buckets[cap_tiles] = _Bucket(tuple(idx), state, key)
            return state
        env, yc = pred.nlml_program_env(
            xs, ys, bp, self.tile_size,
            n_streams=self.n_streams, backend=self.op_backend,
            update_dtype=self.update_dtype, dtype=self.dtype,
            batch_dispatch=self.batch_dispatch, n_valid=nv, mesh=self.mesh,
            kernel=self.kernel,
        )
        state = pred.PosteriorState(
            lpacked=env["packed"], alpha=env["alpha"],
            x_chunks=tiling.pad_features(xs, self.tile_size, dtype=self.dtype),
            n=cap_tiles * self.tile_size, m=self.tile_size, params=bp,
            beta=env["y"], y_chunks=yc, n_valid=nv, kernel=self.kernel,
        )
        self._buckets[cap_tiles] = _Bucket(tuple(idx), state, key)
        return state

    # -- prediction ---------------------------------------------------------

    def _prep_shared(self, x_test) -> jax.Array:
        x_test = jnp.asarray(x_test, self.dtype)
        d = self._xs[0].shape[-1]
        if x_test.ndim == 1:
            x_test = x_test[:, None]
        if x_test.ndim != 2 or x_test.shape[-1] != d:
            raise ValueError(
                f"GPFleet shared x_test must be (n̂, {d})"
                + (" or (n̂,)" if d == 1 else "")
                + f"; got {tuple(jnp.asarray(x_test).shape)}. "
                "Use predict_each for per-problem test sets."
            )
        return x_test

    def _predict_shared(self, x_test, full_cov):
        """One shared (n̂, D) test block evaluated under every problem."""
        x_test = self._prep_shared(x_test)
        nh = x_test.shape[0]
        b = self.batch_size
        mean = jnp.zeros((b, nh), self.dtype)
        sigma = jnp.zeros((b, nh, nh), self.dtype) if full_cov else None
        for cap, idx in self.bucket_assignment().items():
            state = self._bucket_state(cap, idx)
            xt = jnp.broadcast_to(x_test[None], (len(idx),) + x_test.shape)
            if self.method == "lowrank":
                out = lowrank.predict_from_lowrank_state(
                    state, xt, full_cov=full_cov, n_streams=self.n_streams,
                    backend=self.op_backend, dtype=self.dtype,
                    batch_dispatch=self.batch_dispatch,
                )
            else:
                out = pred.predict_from_state_batched(
                    state, xt, full_cov=full_cov,
                    n_streams=self.n_streams, dtype=self.dtype, mesh=self.mesh,
                )
            gather = jnp.asarray(idx)
            if full_cov:
                mean = mean.at[gather].set(out[0])
                sigma = sigma.at[gather].set(out[1])
            else:
                mean = mean.at[gather].set(out)
        return (mean, sigma) if full_cov else mean

    def predict(self, x_test) -> jax.Array:
        """Means (B, n̂) for one shared (n̂, D) test block."""
        return self._predict_shared(x_test, full_cov=False)

    def predict_full_cov(self, x_test) -> Tuple[jax.Array, jax.Array]:
        return self._predict_shared(x_test, full_cov=True)

    def predict_with_uncertainty(self, x_test) -> Tuple[jax.Array, jax.Array]:
        mean, sigma = self.predict_full_cov(x_test)
        return mean, jnp.diagonal(sigma, axis1=-2, axis2=-1)

    def predict_each(self, x_test_list, *, full_cov: bool = False):
        """Per-problem test sets (list of (n̂_i, D)); ragged n̂_i are padded
        to each bucket's max and masked with ``nt_valid`` — one batched warm
        launch per bucket, results sliced back to each problem's own n̂_i.

        Returns a length-B list of (n̂_i,) means (or ``(mean, cov)`` tuples
        with cov (n̂_i, n̂_i) when ``full_cov``)."""
        b = self.batch_size
        if len(x_test_list) != b:
            raise ValueError(
                f"predict_each needs one test set per problem ({b}); "
                f"got {len(x_test_list)}"
            )
        d = self._xs[0].shape[-1]
        tests = []
        for i, xt in enumerate(x_test_list):
            xt = jnp.asarray(xt, self.dtype)
            if xt.ndim == 1:
                xt = xt[:, None]
            if xt.ndim != 2 or xt.shape[-1] != d:
                raise ValueError(
                    f"test set {i} must be (n̂, {d}); got {tuple(xt.shape)}"
                )
            tests.append(xt)
        out: List[object] = [None] * b
        empty = jnp.zeros((0,), self.dtype)
        empty_cov = jnp.zeros((0, 0), self.dtype)
        for cap, idx in self.bucket_assignment().items():
            nts = [tests[i].shape[0] for i in idx]
            if not any(nts):  # no pending queries touch this bucket
                for i in idx:
                    out[i] = (empty, empty_cov) if full_cov else empty
                continue
            state = self._bucket_state(cap, idx)
            nt_max = max(nts)
            xt = jnp.stack(
                [jnp.pad(tests[i], ((0, nt_max - tests[i].shape[0]), (0, 0)))
                 for i in idx]
            )
            if self.method == "lowrank":
                res = lowrank.predict_from_lowrank_state(
                    state, xt, full_cov=full_cov, n_streams=self.n_streams,
                    backend=self.op_backend, dtype=self.dtype,
                    nt_valid=jnp.asarray(nts, jnp.int32),
                    batch_dispatch=self.batch_dispatch,
                )
            else:
                res = pred.predict_from_state_batched(
                    state, xt, full_cov=full_cov, n_streams=self.n_streams,
                    dtype=self.dtype, nt_valid=jnp.asarray(nts, jnp.int32),
                    mesh=self.mesh,
                )
            for pos, i in enumerate(idx):
                if full_cov:
                    out[i] = (
                        res[0][pos, : nts[pos]],
                        res[1][pos, : nts[pos], : nts[pos]],
                    )
                else:
                    out[i] = res[pos, : nts[pos]]
        return out

    # -- NLML ---------------------------------------------------------------

    def nlml(self) -> jax.Array:
        """Per-problem NLML vector (B,), one masked head per bucket."""
        from repro.core import mll

        b = self.batch_size
        out = jnp.zeros((b,), self.dtype)
        for cap, idx in self.bucket_assignment().items():
            state = self._bucket_state(cap, idx)
            if self.method == "lowrank":
                vals = lowrank.nlml_from_lowrank_state(state, dtype=self.dtype)
            else:
                _, ys, nv = self._stack(idx, cap)
                vals = mll.nlml_from_state(state, ys, dtype=self.dtype, n_valid=nv)
            out = out.at[jnp.asarray(idx)].set(vals.astype(self.dtype))
        return out

    def log_marginal_likelihood(self) -> jax.Array:
        return -self.nlml()

    def optimize(self, steps: int = 100, lr: float = 0.05) -> "GPFleet":
        """Fit every problem's hyperparameters (the off-hot-path re-optimize
        the serving loop's drift monitor schedules — DESIGN.md §15).

        Each problem trains independently at its *own exact size* — no
        padding rows in the training loss, unlike a bucket-stacked scan —
        via the single-problem Adam scan (mll.optimize_hyperparameters) on
        its gathered leaves.  The fitted pytrees are stacked back into
        per-problem ``(B,) + base`` leaves: any leaf that started shared
        comes back per-problem, because independently fitted problems
        drift apart.  Caches invalidate; the next predict/nlml
        re-factorizes each bucket under the new hyperparameters.
        """
        from repro.core import mll

        method = "lowrank" if self.method == "lowrank" else "tiled"
        fitted = []
        for i in range(self.batch_size):
            pi = km.gather_params(self.params, jnp.asarray(i), self.kernel)
            new_pi, _ = mll.optimize_hyperparameters(
                self._xs[i],
                self._ys[i],
                pi,
                steps=steps,
                lr=lr,
                dtype=self.dtype,
                method=method,
                tile_size=self.tile_size,
                n_streams=self.n_streams,
                op_backend=self.op_backend,
                update_dtype=self.update_dtype,
                kernel=self.kernel,
                m_inducing=self.m_inducing,
                strategy=self.strategy,
                inducing=self.inducing,
                jitter=self.jitter,
            )
            fitted.append(new_pi)
        self.params = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack([jnp.asarray(l) for l in leaves]), *fitted
        )
        obs.inc("fleet.optimize")
        self.invalidate_cache()  # factors belong to the old hyperparameters
        return self

    # -- ragged streaming updates (DESIGN.md §11) ---------------------------

    def update(self, x_new_list, y_new_list) -> "GPFleet":
        """Absorb ragged arrivals: problem i gains ``len(y_new_list[i])``
        points (0 allowed).  Problems that stay inside their bucket extend
        warm in O(n^2 b); problems that outgrow it migrate — the factor is
        re-embedded into the destination geometry as ``blockdiag(L, I)``
        (pure gather) and extended there.  A cold or numerically failed
        bucket re-factorizes lazily on the next predict/nlml."""
        from repro.core import update as upd

        b = self.batch_size
        if len(x_new_list) != b or len(y_new_list) != b:
            raise ValueError(
                f"update needs one arrival block per problem ({b}); got "
                f"{len(x_new_list)} and {len(y_new_list)}"
            )
        d = self._xs[0].shape[-1]
        xn, yn = [], []
        for i, (x, y) in enumerate(zip(x_new_list, y_new_list)):
            x = jnp.asarray(x, self.dtype).reshape(-1, d)
            y = jnp.asarray(y, self.dtype).reshape(-1)
            if x.shape[0] != y.shape[0]:
                raise ValueError(
                    f"arrival {i}: x has {x.shape[0]} rows, y {y.shape[0]}"
                )
            xn.append(x)
            yn.append(y)
        counts = np.asarray([y.shape[0] for y in yn], np.int64)
        if not counts.any():
            return self
        if self.method == "lowrank":
            return self._update_lowrank(xn, yn, counts)

        old_assign = self.bucket_assignment()
        old_key = self._cache_key()
        # per-problem warm source rows: i -> (cap_old, state, row position)
        src: Dict[int, Tuple[int, pred.PosteriorState, int]] = {}
        for cap, idx in old_assign.items():
            rec = self._buckets.get(cap)
            if rec is not None and rec.key == old_key \
                    and rec.idx == tuple(idx) and rec.state is not None:
                for pos, i in enumerate(idx):
                    src[i] = (cap, rec.state, pos)

        old_ns = np.asarray(self.sizes, np.int64)
        for i in range(b):
            if counts[i]:
                self._xs[i] = jnp.concatenate([self._xs[i], xn[i]])
                self._ys[i] = jnp.concatenate([self._ys[i], yn[i]])
        self._version += 1
        new_key = self._cache_key()

        new_buckets: Dict[int, _Bucket] = {}
        for cap, idx in self.bucket_assignment().items():
            state = None
            if all(i in src for i in idx):
                try:
                    state = self._transfer_bucket(cap, idx, src, old_ns)
                    cnt = counts[np.asarray(idx)]
                    if cnt.any():
                        b_max = int(cnt.max())
                        xa = jnp.stack(
                            [jnp.pad(xn[i], ((0, b_max - xn[i].shape[0]), (0, 0)))
                             for i in idx]
                        )
                        ya = jnp.stack(
                            [jnp.pad(yn[i], (0, b_max - yn[i].shape[0]))
                             for i in idx]
                        )
                        state = upd.extend_state_ragged(
                            state, xa, ya, cnt,
                            n_streams=self.n_streams, backend=self.op_backend,
                            update_dtype=self.update_dtype,
                            batch_dispatch=self.batch_dispatch,
                            mesh=self.mesh,
                        )
                except upd.CholeskyUpdateError:
                    obs.health_event(
                        "refactorize_fallback", site="fleet.update", cap=cap
                    )
                    state = None
            new_buckets[cap] = _Bucket(tuple(idx), state, new_key)
        self._buckets = new_buckets
        return self

    def _transfer_bucket(self, cap, idx, src, old_ns) -> pred.PosteriorState:
        """Assemble a destination bucket's pre-append state from warm source
        rows, re-embedding factors that cross a geometry boundary as
        blockdiag(L, I) — a gather, zero FLOPs (``tiling.embed_packed``)."""
        m = self.tile_size
        d = self._xs[0].shape[-1]
        lp, al, xc, be, yc = [], [], [], [], []
        for i in idx:
            cap_s, st, pos = src[i]
            lpi = st.lpacked[pos]
            if cap_s != cap:
                lpi = tiling.embed_packed(lpi, cap_s, cap)
            pad = cap - cap_s
            lp.append(lpi)
            al.append(jnp.pad(st.alpha[pos], ((0, pad), (0, 0))))
            be.append(jnp.pad(st.beta[pos], ((0, pad), (0, 0))))
            yc.append(jnp.pad(st.y_chunks[pos], ((0, pad), (0, 0))))
            xc.append(jnp.pad(st.x_chunks[pos], ((0, pad), (0, 0), (0, 0))))
        return pred.PosteriorState(
            lpacked=jnp.stack(lp), alpha=jnp.stack(al), x_chunks=jnp.stack(xc),
            n=cap * m, m=m, params=self._bucket_params(idx),
            beta=jnp.stack(be), y_chunks=jnp.stack(yc),
            n_valid=jnp.asarray(old_ns[np.asarray(idx)], jnp.int32),
            kernel=self.kernel,
        )

    def _update_lowrank(self, xn, yn, counts) -> "GPFleet":
        """Ragged absorption through the rank-m inner systems.

        The low-rank bucket state is mu-sized (nothing n-sized lives in it),
        so a problem crossing a bucket boundary needs NO factor re-embedding:
        the destination state is a pure row gather of the warm source rows
        (``_gather_lowrank_rows``), followed by one ragged ``lowrank.absorb``
        per destination bucket.  A cold or numerically failed bucket rebuilds
        lazily on the next predict/nlml, same as the exact tier."""
        from repro.core import update as upd

        b = self.batch_size
        old_assign = self.bucket_assignment()
        old_key = self._cache_key()
        # per-problem warm source rows: i -> (state, row position)
        src: Dict[int, Tuple[object, int]] = {}
        for cap, idx in old_assign.items():
            rec = self._buckets.get(cap)
            if rec is not None and rec.key == old_key \
                    and rec.idx == tuple(idx) and rec.state is not None:
                for pos, i in enumerate(idx):
                    src[i] = (rec.state, pos)
        for i in range(b):
            if counts[i]:
                self._xs[i] = jnp.concatenate([self._xs[i], xn[i]])
                self._ys[i] = jnp.concatenate([self._ys[i], yn[i]])
        self._version += 1
        new_key = self._cache_key()
        new_buckets: Dict[int, _Bucket] = {}
        for cap, idx in self.bucket_assignment().items():
            state = None
            if all(i in src for i in idx):
                try:
                    state = self._gather_lowrank_rows(cap, idx, src)
                    cnt = counts[np.asarray(idx)]
                    if cnt.any():
                        b_max = int(cnt.max())
                        xa = jnp.stack(
                            [jnp.pad(xn[i], ((0, b_max - xn[i].shape[0]), (0, 0)))
                             for i in idx]
                        )
                        ya = jnp.stack(
                            [jnp.pad(yn[i], (0, b_max - yn[i].shape[0]))
                             for i in idx]
                        )
                        state = lowrank.absorb(
                            state, xa, ya, cnt, sign=1,
                            n_streams=self.n_streams, backend=self.op_backend,
                            update_dtype=self.update_dtype,
                            batch_dispatch=self.batch_dispatch,
                        )
                except upd.CholeskyUpdateError:
                    obs.health_event(
                        "refactorize_fallback", site="fleet.update.lowrank",
                        cap=cap,
                    )
                    state = None
            new_buckets[cap] = _Bucket(tuple(idx), state, new_key)
        self._buckets = new_buckets
        return self

    def _gather_lowrank_rows(self, cap, idx, src) -> lowrank.LowRankState:
        """Destination bucket's pre-absorb state from warm source rows — a
        gather, zero FLOPs (every per-problem piece is mu-sized)."""
        rows = [src[i] for i in idx]

        def g(field):
            return jnp.stack([getattr(st, field)[pos] for st, pos in rows])

        mv = jnp.asarray(
            [int(st.mu_valid[pos]) if st.mu_valid is not None
             else st.m_inducing for st, pos in rows],
            jnp.int32,
        )
        nv = jnp.asarray(
            [int(st.n_valid[pos]) if st.n_valid is not None else st.n
             for st, pos in rows],
            jnp.int32,
        )
        return lowrank.LowRankState(
            u_chunks=g("u_chunks"),
            luu_packed=g("luu_packed"),
            b_packed=g("b_packed"),
            lb_packed=g("lb_packed"),
            c_chunks=g("c_chunks"),
            gamma=g("gamma"),
            yty=g("yty"),
            n=cap * self.tile_size,
            m=self.tile_size,
            m_inducing=self.m_inducing,
            params=self._bucket_params(idx),
            jitter=rows[0][0].jitter,
            mu_valid=mv,
            n_valid=nv,
            kernel=self.kernel,
        )

