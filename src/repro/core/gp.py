"""User-facing Gaussian-process API (GPRat-style).

Mirrors the GPRat Python API surface: construct with data + hyperparameters,
then ``predict`` / ``predict_with_uncertainty`` / ``predict_full_cov``.
Backend selection:

* ``pipeline="tiled"``      — the paper's tiled pipeline (default)
* ``pipeline="monolithic"`` — the cuSOLVER-reference analogue

* ``op_backend="jnp"``      — XLA ops per tile task
* ``op_backend="pallas"``   — explicit Pallas VMEM kernels per tile task

* ``fused=True`` (default)  — cold predictions run the whole pipeline as ONE
  multi-stage program with cross-stage wavefronts (DESIGN.md §7)
* ``fused=False``           — staged per-stage baseline

The tiled pipeline caches its :class:`repro.core.predict.PosteriorState`
(packed Cholesky factor + alpha — with ``fused`` it is a slice of the fused
program's buffer environment) across ``predict`` calls; the cache is
invalidated automatically when hyperparameters change (see ``posterior``).
Warm predictions at new test points reuse the cached factor through the
staged cross-covariance/mean stages, skipping the O(n^3) work entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kernels_math as km
from repro.core import predict as pred


@dataclasses.dataclass
class GaussianProcess:
    x_train: jax.Array
    y_train: jax.Array
    params: km.SEKernelParams = dataclasses.field(
        default_factory=km.SEKernelParams.paper_defaults
    )
    tile_size: int = 256
    n_streams: Optional[int] = None
    pipeline: str = "tiled"
    op_backend: str = "jnp"
    update_dtype: Optional[object] = None
    dtype: object = jnp.float32
    fused: bool = True

    def __post_init__(self):
        x = jnp.asarray(self.x_train, self.dtype)
        if x.ndim == 1:  # (n,) convenience for 1-D problems
            x = x[:, None]
        self.y_train = jnp.asarray(self.y_train, self.dtype).reshape(-1)
        n = self.y_train.shape[0]
        if x.ndim != 2 or x.shape[0] != n:
            raise ValueError(
                f"x_train must be (n, D) or (n,) with n == len(y_train) == {n}; "
                f"got shape {tuple(x.shape)}. Pass x_train.T explicitly if your "
                "features are stored (D, n) — it is not transposed silently."
            )
        self.x_train = x
        self._posterior: Optional[pred.PosteriorState] = None
        self._posterior_key = None

    # -- cached posterior ---------------------------------------------------

    def _cache_key(self):
        p = self.params
        # jax arrays are immutable, so object identity of the training data
        # is a sound staleness signal (rebinding x_train/y_train invalidates)
        return (
            id(self.x_train),
            id(self.y_train),
            float(p.lengthscale),
            float(p.vertical),
            float(p.noise),
            self.tile_size,
            self.n_streams,
            self.op_backend,
            str(self.update_dtype),
            str(jnp.dtype(self.dtype)),
        )

    def posterior(self) -> pred.PosteriorState:
        """The packed Cholesky factor + alpha, cached across ``predict`` calls.

        Recomputed only when hyperparameters or pipeline knobs change (e.g.
        after :meth:`optimize`); repeated predictions at new test points skip
        the O(n^3) assemble/factor/solve stage entirely.
        """
        key = self._cache_key()
        if self._posterior is None or self._posterior_key != key:
            self._posterior = pred.posterior_state(
                self.x_train,
                self.y_train,
                self.params,
                self.tile_size,
                n_streams=self.n_streams,
                backend=self.op_backend,
                update_dtype=self.update_dtype,
                dtype=self.dtype,
            )
            self._posterior_key = key
        return self._posterior

    def invalidate_cache(self) -> None:
        self._posterior = None
        self._posterior_key = None

    # -- prediction ---------------------------------------------------------

    def _predict_tiled(self, x_test: jax.Array, full_cov: bool):
        """Route a tiled prediction: cached factor -> staged tail stages;
        cold + ``fused`` -> one whole-pipeline program whose buffer env also
        populates the posterior cache; cold staged -> posterior() then tail."""
        key = self._cache_key()
        if self._posterior is not None and self._posterior_key == key:
            state = self._posterior
        elif self.fused:
            result, state = pred.predict_fused(
                self.x_train,
                self.y_train,
                x_test,
                self.params,
                self.tile_size,
                full_cov=full_cov,
                n_streams=self.n_streams,
                backend=self.op_backend,
                update_dtype=self.update_dtype,
                dtype=self.dtype,
                with_state=True,
            )
            self._posterior, self._posterior_key = state, key
            return result
        else:
            state = self.posterior()
        return pred.predict_from_state(
            state,
            x_test,
            full_cov=full_cov,
            n_streams=self.n_streams,
            backend=self.op_backend,
            dtype=self.dtype,
        )

    def predict(self, x_test: jax.Array) -> jax.Array:
        x_test = self._prep(x_test)
        if self.pipeline == "monolithic":
            return pred.predict_monolithic(
                self.x_train, self.y_train, x_test, self.params, dtype=self.dtype
            )
        return self._predict_tiled(x_test, full_cov=False)

    def predict_full_cov(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """The paper's *Predict with Full Covariance Matrix* operation."""
        x_test = self._prep(x_test)
        if self.pipeline == "monolithic":
            return pred.predict_monolithic(
                self.x_train,
                self.y_train,
                x_test,
                self.params,
                full_cov=True,
                dtype=self.dtype,
            )
        return self._predict_tiled(x_test, full_cov=True)

    def predict_with_uncertainty(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        mean, sigma = self.predict_full_cov(x_test)
        return mean, jnp.diagonal(sigma)

    # -- hyperparameters ----------------------------------------------------

    def nlml(self) -> jax.Array:
        """Negative log marginal likelihood from the *cached* tiled posterior.

        Reuses (or populates) the posterior cache: the quadratic term is
        ``y^T alpha`` over the cached weight chunks and the log-determinant
        comes from the packed factor's diagonal tiles — no monolithic
        re-factorization (mll.nlml_from_state).  Identity padding makes both
        terms exact for any n.
        """
        from repro.core import mll

        if self.pipeline == "monolithic":
            return mll.negative_log_marginal_likelihood(
                self.x_train, self.y_train, self.params, dtype=self.dtype
            )
        return mll.nlml_from_state(self.posterior(), self.y_train, dtype=self.dtype)

    def log_marginal_likelihood(self) -> jax.Array:
        """``-nlml()`` — for ``pipeline="tiled"`` this reuses the cached tiled
        posterior (no monolithic Cholesky), consistent with :meth:`nlml`;
        previously it always ran the monolithic path regardless of pipeline."""
        return -self.nlml()

    def optimize(
        self, steps: int = 100, lr: float = 0.05, *, method: Optional[str] = None
    ) -> "GaussianProcess":
        """Fit hyperparameters by Adam on the negative log marginal likelihood.

        The optimizer is one jitted ``lax.scan`` (mll.adam_scan).  ``method``
        defaults to the GP's pipeline: ``pipeline="tiled"`` trains through
        the differentiable tiled program (``mll.nlml_tiled`` — zero
        monolithic Cholesky calls, same tile_size/n_streams/op_backend/
        update_dtype knobs as prediction); ``pipeline="monolithic"``
        differentiates the dense reference NLML.
        """
        from repro.core import mll

        if method is None:
            method = "tiled" if self.pipeline == "tiled" else "monolithic"
        new_params, _ = mll.optimize_hyperparameters(
            self.x_train,
            self.y_train,
            self.params,
            steps=steps,
            lr=lr,
            dtype=self.dtype,
            method=method,
            tile_size=self.tile_size,
            n_streams=self.n_streams,
            op_backend=self.op_backend,
            update_dtype=self.update_dtype,
        )
        self.params = new_params
        self.invalidate_cache()  # the factor belongs to the old hyperparameters
        return self

    def _prep(self, x_test: jax.Array) -> jax.Array:
        x_test = jnp.asarray(x_test, self.dtype)
        if x_test.ndim == 1:
            x_test = x_test[:, None]
        return x_test
