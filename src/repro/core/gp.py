"""User-facing Gaussian-process API (GPRat-style).

Mirrors the GPRat Python API surface: construct with data + hyperparameters,
then ``predict`` / ``predict_with_uncertainty`` / ``predict_full_cov``.
Backend selection:

* ``pipeline="tiled"``      — the paper's tiled pipeline (default)
* ``pipeline="monolithic"`` — the cuSOLVER-reference analogue

* ``op_backend="jnp"``      — XLA ops per tile task
* ``op_backend="pallas"``   — explicit Pallas VMEM kernels per tile task
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kernels_math as km
from repro.core import predict as pred


@dataclasses.dataclass
class GaussianProcess:
    x_train: jax.Array
    y_train: jax.Array
    params: km.SEKernelParams = dataclasses.field(
        default_factory=km.SEKernelParams.paper_defaults
    )
    tile_size: int = 256
    n_streams: Optional[int] = None
    pipeline: str = "tiled"
    op_backend: str = "jnp"
    update_dtype: Optional[object] = None
    dtype: object = jnp.float32

    def __post_init__(self):
        self.x_train = jnp.atleast_2d(jnp.asarray(self.x_train, self.dtype))
        if self.x_train.shape[0] == 1 and self.x_train.ndim == 2:
            # allow (n,) inputs for 1-D problems
            pass
        self.y_train = jnp.asarray(self.y_train, self.dtype).reshape(-1)
        if self.x_train.shape[0] != self.y_train.shape[0]:
            self.x_train = self.x_train.T
        assert self.x_train.shape[0] == self.y_train.shape[0]

    # -- prediction ---------------------------------------------------------

    def predict(self, x_test: jax.Array) -> jax.Array:
        x_test = self._prep(x_test)
        if self.pipeline == "monolithic":
            return pred.predict_monolithic(
                self.x_train, self.y_train, x_test, self.params, dtype=self.dtype
            )
        return pred.predict(
            self.x_train,
            self.y_train,
            x_test,
            self.params,
            self.tile_size,
            n_streams=self.n_streams,
            backend=self.op_backend,
            update_dtype=self.update_dtype,
            dtype=self.dtype,
        )

    def predict_full_cov(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """The paper's *Predict with Full Covariance Matrix* operation."""
        x_test = self._prep(x_test)
        if self.pipeline == "monolithic":
            return pred.predict_monolithic(
                self.x_train,
                self.y_train,
                x_test,
                self.params,
                full_cov=True,
                dtype=self.dtype,
            )
        return pred.predict(
            self.x_train,
            self.y_train,
            x_test,
            self.params,
            self.tile_size,
            full_cov=True,
            n_streams=self.n_streams,
            backend=self.op_backend,
            update_dtype=self.update_dtype,
            dtype=self.dtype,
        )

    def predict_with_uncertainty(self, x_test: jax.Array) -> Tuple[jax.Array, jax.Array]:
        mean, sigma = self.predict_full_cov(x_test)
        return mean, jnp.diagonal(sigma)

    # -- hyperparameters ----------------------------------------------------

    def log_marginal_likelihood(self) -> jax.Array:
        from repro.core import mll

        return -mll.negative_log_marginal_likelihood(
            self.x_train, self.y_train, self.params, dtype=self.dtype
        )

    def optimize(self, steps: int = 100, lr: float = 0.05) -> "GaussianProcess":
        """Fit hyperparameters by Adam on the negative log marginal likelihood."""
        from repro.core import mll

        new_params, _ = mll.optimize_hyperparameters(
            self.x_train, self.y_train, self.params, steps=steps, lr=lr, dtype=self.dtype
        )
        self.params = new_params
        return self

    def _prep(self, x_test: jax.Array) -> jax.Array:
        x_test = jnp.asarray(x_test, self.dtype)
        if x_test.ndim == 1:
            x_test = x_test[:, None]
        return x_test
