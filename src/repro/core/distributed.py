"""Distributed tiled GP: block-cyclic Cholesky + solves via shard_map.

This implements the paper's stated *future work* — "extend the library to
distributed multi-GPU environments to overcome single-node memory limits" —
on TPU meshes, scaling the tiled pipeline to 256-chip pods and 512-chip
multi-pod meshes.

Layout (ScaLAPACK-style 2-D block-cyclic):

    process grid (P, Q) = (prod(row_axes), prod(col_axes)) over the mesh
    tile (I, J) lives on process (I mod P, J mod Q), local slot (I//P, J//Q)
    local store: (Mp, Mq, m, m) with Mp = M/P, Mq = M/Q

Cyclic (not blocked) distribution keeps the trailing-submatrix update load
balanced as the factorization shrinks — the classic ScaLAPACK argument; with
a blocked layout the top-left processes idle after the first panels.

Per step J the SPMD program does:
  1. column broadcast:  psum-mask the K column J tiles across ``col_axes``
  2. panel factor:      POTRF redundantly (m^3, negligible); TRSM split
                        across process columns (Q-way) then re-gathered
  3. panel all-gather:  full L panel to every process (``row_axes`` gather)
  4. trailing update:   local batched GEMM/SYRK on owned tiles (masked)

Two execution modes:
  * ``unroll=False`` — ``lax.fori_loop`` body with full-grid masked updates;
    compact HLO, used by correctness tests (small M; masking waste is small).
  * ``unroll=True``  — trace-time loop with statically shrinking active
    slices; near-zero wasted FLOPs, used by the dry-run / roofline path.

The forward/backward substitutions for the predictive mean and the matrix
solve for predictive variances follow the same pattern (see functions below).
Everything is f32 by default (TPU has no f64 MXU; see DESIGN.md §2), with
optional bf16 trailing updates (mixed precision, paper future work).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import kernels_math as km

from repro import compat
from repro.compat import shard_map


# ---------------------------------------------------------------------------
# SPMD helpers.
# ---------------------------------------------------------------------------


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def _linear_index(axes: Sequence[str]):
    """Linearized device index over possibly-multiple mesh axes."""
    idx = lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * compat.axis_size(a) + lax.axis_index(a)
    return idx


def _gather_axes(x: jax.Array, axes: Sequence[str]) -> jax.Array:
    """all_gather over multiple axes; leading dim ordered by linear index."""
    for a in reversed(axes):
        x = lax.all_gather(x, a, axis=0, tiled=False)
    # after gathering a1 then a0 we have (S0, S1, ...) -> flatten
    sizes = [compat.axis_size(a) for a in axes]
    return x.reshape((int(np.prod(sizes)),) + x.shape[len(sizes):])


def _psum(x, axes: Sequence[str]):
    return lax.psum(x, tuple(axes))


# ---------------------------------------------------------------------------
# The distributed factorization (SPMD inner function).
# ---------------------------------------------------------------------------


def _panel_from_gather(col_gather: jax.Array, p: int) -> jax.Array:
    """(P, Mp, m, m) row-gathered column -> (M, m, m) in global tile order."""
    return jnp.swapaxes(col_gather, 0, 1).reshape(
        (-1,) + col_gather.shape[2:]
    )  # [ip, r] -> I = ip*P + r


def _chol_step(
    j,
    local: jax.Array,
    *,
    m_tiles: int,
    row_axes: Tuple[str, ...],
    col_axes: Tuple[str, ...],
    p: int,
    q: int,
    update_dtype=None,
) -> jax.Array:
    """One right-looking factorization step.

    ``j`` may be traced (fori_loop path: full-size masked ops every step) or
    a Python int (unrolled path: statically-shrinking active slices — the
    trailing update and the panel gather touch only tile rows/cols ≥ j, the
    §Perf hillclimb that removes the 3.2× masked-FLOP and 2× gather waste).
    """
    mp, mq, m, _ = local.shape
    pr = _linear_index(row_axes)
    pc = _linear_index(col_axes)
    static = isinstance(j, int)
    # conservative static bounds covering every device's active local slots
    ip0 = (j // p) if static else 0          # local rows with glob >= j (some device)
    kq0 = (j // q) if static else 0
    # panel indexing below requires the active column range to start at or
    # after the gathered row base; holds whenever q divides p (all meshes here)
    assert not static or kq0 * q >= (j // p) * p, (p, q, j)
    glob_i = jnp.arange(ip0, mp) * p + pr    # global row index of local slot
    glob_k = jnp.arange(kq0, mq) * q + pc    # global col index of local slot
    n_act = mp - ip0
    jq = j // q
    owner_q = j % q

    # -- 1. broadcast (active rows of) column j across process columns ------
    # mixed precision (paper future work): panel COMMUNICATION in the update
    # dtype (bf16 halves the dominant wire term); the diagonal tile is
    # re-broadcast in full precision for the POTRF/TRSM numerics.
    comm_dtype = update_dtype if update_dtype is not None else local.dtype
    col_local = lax.dynamic_slice_in_dim(local, jq, 1, axis=1)[ip0:, 0]   # (Na,m,m)
    col_local = jnp.where(pc == owner_q, col_local, jnp.zeros_like(col_local))
    col_bcast = _psum(col_local, col_axes)                                # (Na,m,m)
    # NOTE: the panel stays in comm_dtype through all consumers (an immediate
    # upcast would let the simplifier cancel the casts).  Backend caveat: the
    # CPU backend lowers ALL collectives in f32 (converts around every
    # all-gather — verified on a minimal case), so the wire saving is
    # invisible in CPU-compiled HLO; on TPU bf16 collectives are native and
    # the gather payload halves.  EXPERIMENTS.md §Perf accounts for this.
    def _comm_cast(x):
        if comm_dtype == local.dtype:
            return x
        return lax.optimization_barrier(x.astype(comm_dtype))

    col_gather = _gather_axes(_comm_cast(col_bcast), row_axes)           # (P,Na,m,m)
    orig_panel = _panel_from_gather(col_gather, p)
    base = ip0 * p

    # -- 2. panel factorization (redundant POTRF, split TRSM) ---------------
    # the diagonal tile travels in full precision (4 MB psum — negligible
    # wire) so POTRF/TRSM numerics are unaffected by bf16 panel comms
    dslot = j // p - ip0
    drow = (
        col_bcast[dslot]
        if static
        else lax.dynamic_index_in_dim(col_bcast, dslot, keepdims=False)
    )
    diag = _psum(
        jnp.where(pr == j % p, drow, jnp.zeros_like(drow)), row_axes
    )
    ljj = jnp.linalg.cholesky(diag)

    def trsm(b):
        return lax.linalg.triangular_solve(
            ljj, b, left_side=False, lower=True, transpose_a=True
        )

    if n_act >= q:
        # split the active rows across process columns, re-gather (padded to
        # a multiple of q so every shard solves the same static size)
        split = -(-n_act // q)
        pad = split * q - n_act
        col_pad = jnp.concatenate([col_bcast, col_bcast[:pad]], 0) if pad else col_bcast
        my = lax.dynamic_slice_in_dim(col_pad, pc * split, split, axis=0)
        solved = _comm_cast(jax.vmap(trsm)(my))
        solved = _gather_axes(solved, col_axes).reshape(
            (split * q,) + col_bcast.shape[1:]
        )
        solved = solved[:n_act]
    else:
        solved = _comm_cast(jax.vmap(trsm)(col_bcast))
    sol_gather = _gather_axes(solved, row_axes)
    sol_panel = _panel_from_gather(sol_gather, p)                         # (M-base,m,m)

    gi = jnp.arange(base, m_tiles)
    panel = jnp.where(
        (gi > j)[:, None, None],
        sol_panel,
        jnp.where((gi == j)[:, None, None], ljj.astype(comm_dtype)[None], orig_panel),
    )

    # -- 3. trailing update on owned active tiles ----------------------------
    a = panel[glob_i - base]                                              # (Na,m,m)
    b = panel[glob_k - base]                                              # (Nk,m,m)
    if update_dtype is not None:
        upd = jnp.einsum(
            "iab,kcb->ikac", a.astype(update_dtype), b.astype(update_dtype)
        ).astype(local.dtype)
    else:
        upd = jnp.einsum("iab,kcb->ikac", a, b)
    mask = (
        (glob_i[:, None] > j) & (glob_k[None, :] > j) & (glob_i[:, None] >= glob_k[None, :])
    )
    local = local.at[ip0:, kq0:].add(-jnp.where(mask[:, :, None, None], upd, 0.0))

    # -- 4. write back the factored column -----------------------------------
    cur = lax.dynamic_slice_in_dim(local, jq, 1, 1)[ip0:, 0]
    new_col = jnp.where(pc == owner_q, a, cur)
    col_full = lax.dynamic_slice_in_dim(local, jq, 1, 1)[:, 0].at[ip0:].set(new_col)
    local = lax.dynamic_update_slice_in_dim(local, col_full[:, None], jq, axis=1)
    return local


def _spmd_cholesky(
    local: jax.Array,
    *,
    m_tiles: int,
    row_axes: Tuple[str, ...],
    col_axes: Tuple[str, ...],
    p: int,
    q: int,
    unroll: bool,
    update_dtype=None,
) -> jax.Array:
    """In-place factorization of the local block-cyclic tile store."""
    step = functools.partial(
        _chol_step,
        m_tiles=m_tiles,
        row_axes=row_axes,
        col_axes=col_axes,
        p=p,
        q=q,
        update_dtype=update_dtype,
    )
    if unroll:
        for j in range(m_tiles):
            local = step(j, local)
        return local
    return lax.fori_loop(0, m_tiles, step, local)


def _spmd_forward_solve(local, y_rep, *, m_tiles, row_axes, col_axes, p, q):
    """Solve L b = y with L block-cyclic local tiles; y replicated (M, m).

    Sequential over tile rows; the inner reduction uses the already-solved
    replicated prefix, so each step is: local partial matvec -> psum -> solve.
    Returns replicated b (M, m).
    """
    mp, mq, m, _ = local.shape
    pr = _linear_index(row_axes)
    pc = _linear_index(col_axes)
    glob_i = jnp.arange(mp) * p + pr
    glob_k = jnp.arange(mq) * q + pc

    def step(i, b):
        # partial = sum over owned tiles (i, k) with k < i of L_ik @ b_k
        row_sel = (glob_i == i)                                    # (Mp,)
        col_sel = (glob_k < i)                                     # (Mq,)
        mask = (row_sel[:, None] & col_sel[None, :]).astype(local.dtype)
        contrib = jnp.einsum("ikab,kb,ik->a", local, b[glob_k], mask)
        acc = _psum(contrib, tuple(row_axes) + tuple(col_axes))
        # diagonal tile (i, i): owner broadcasts via psum-mask
        own = ((glob_i == i)[:, None] & (glob_k == i)[None, :]).astype(local.dtype)
        lii = _psum(jnp.einsum("ikab,ik->ab", local, own), tuple(row_axes) + tuple(col_axes))
        rhs = b[i] - acc
        bi = lax.linalg.triangular_solve(
            lii, rhs[:, None], left_side=True, lower=True
        )[:, 0]
        return b.at[i].set(bi)

    return lax.fori_loop(0, m_tiles, step, y_rep)


def _spmd_backward_solve(local, b_rep, *, m_tiles, row_axes, col_axes, p, q):
    """Solve L^T a = b; uses tiles (k, i) with k > i: (L^T)_{i,k} = L_{k,i}^T."""
    mp, mq, m, _ = local.shape
    pr = _linear_index(row_axes)
    pc = _linear_index(col_axes)
    glob_i = jnp.arange(mp) * p + pr
    glob_k = jnp.arange(mq) * q + pc

    def step(t, a):
        i = m_tiles - 1 - t
        row_sel = glob_i > i          # rows k > i (stored tiles L_{k,i})
        col_sel = glob_k == i
        mask = (row_sel[:, None] & col_sel[None, :]).astype(local.dtype)
        contrib = jnp.einsum("ikba,ik,ib->a", local, mask, a[glob_i])
        acc = _psum(contrib, tuple(row_axes) + tuple(col_axes))
        own = ((glob_i == i)[:, None] & (glob_k == i)[None, :]).astype(local.dtype)
        lii = _psum(jnp.einsum("ikab,ik->ab", local, own), tuple(row_axes) + tuple(col_axes))
        rhs = a[i] - acc
        ai = lax.linalg.triangular_solve(
            lii, rhs[:, None], left_side=True, lower=True, transpose_a=True
        )[:, 0]
        return a.at[i].set(ai)

    return lax.fori_loop(0, m_tiles, step, b_rep)


def _spmd_assemble(
    x_chunks: jax.Array,
    params: km.SEKernelParams,
    n_valid: int,
    *,
    row_axes,
    col_axes,
    p: int,
    q: int,
):
    """Assemble the local block-cyclic lower tiles from replicated x chunks.

    Only tiles with I >= K hold covariance; strictly-upper local tiles are
    zeroed (they are never read).  Fewer kernel evaluations than a dense
    assembly — the tiled-assembly saving the paper reports in Fig. 4.
    """
    m_tiles, m, _ = x_chunks.shape
    pr = _linear_index(row_axes)
    pc = _linear_index(col_axes)
    mp, mq = m_tiles // p, m_tiles // q
    glob_i = jnp.arange(mp) * p + pr
    glob_k = jnp.arange(mq) * q + pc

    def tile(i, k):
        xa, xb = x_chunks[i], x_chunks[k]
        kk = km.se_kernel(xa, xb, params)
        gi = i * m + jnp.arange(m)[:, None]
        gj = k * m + jnp.arange(m)[None, :]
        on_diag = gi == gj
        kk = kk + jnp.where(on_diag, params.noise, 0.0).astype(kk.dtype)
        valid = (gi < n_valid) & (gj < n_valid)
        kk = jnp.where(valid, kk, on_diag.astype(kk.dtype))
        return jnp.where(i >= k, kk, jnp.zeros_like(kk))

    return jax.vmap(lambda i: jax.vmap(lambda k: tile(i, k))(glob_k))(glob_i)


# ---------------------------------------------------------------------------
# Public entry points.
# ---------------------------------------------------------------------------


def grid_shape(mesh: Mesh, row_axes=("data",), col_axes=("model",)) -> Tuple[int, int]:
    return _axes_size(mesh, row_axes), _axes_size(mesh, col_axes)


def local_tiles_sharding(mesh: Mesh, row_axes=("data",), col_axes=("model",)):
    """Sharding for the (P*Mp, Q*Mq, m, m) global view of the cyclic store.

    The global array is laid out (row_proc-major, see distribute/collect);
    sharded on dims 0 and 1 so each device holds its (Mp, Mq, m, m) block.
    """
    return NamedSharding(mesh, P(tuple(row_axes), tuple(col_axes), None, None))


def distributed_gp_predict_fn(
    mesh: Mesh,
    *,
    m_tiles: int,
    tile_size: int,
    n_valid: int,
    n_test_valid: int,
    params: km.SEKernelParams,
    row_axes: Tuple[str, ...] = ("data",),
    col_axes: Tuple[str, ...] = ("model",),
    unroll: bool = False,
    update_dtype=None,
    variances: bool = True,
):
    """Build the jit-able distributed GP predict program.

    Inputs (replicated): x_chunks (M, m, D), y_chunks (M, m),
    xt_chunks (Mt, m, D).  Output: mean (Mt, m) [, var (Mt, m)] replicated.

    The covariance (the O(n^2) memory object) never exists unsharded; each
    device assembles and factors only its block-cyclic tiles.
    """
    p, q = grid_shape(mesh, row_axes, col_axes)
    if m_tiles % p or m_tiles % q:
        raise ValueError(f"m_tiles={m_tiles} must divide process grid {(p, q)}")

    def fn(x_chunks, y_chunks, xt_chunks):
        local = _spmd_assemble(
            x_chunks, params, n_valid, row_axes=row_axes, col_axes=col_axes, p=p, q=q
        )
        local = _spmd_cholesky(
            local,
            m_tiles=m_tiles,
            row_axes=row_axes,
            col_axes=col_axes,
            p=p,
            q=q,
            unroll=unroll,
            update_dtype=update_dtype,
        )
        beta = _spmd_forward_solve(
            local, y_chunks, m_tiles=m_tiles, row_axes=row_axes, col_axes=col_axes, p=p, q=q
        )
        alpha = _spmd_backward_solve(
            local, beta, m_tiles=m_tiles, row_axes=row_axes, col_axes=col_axes, p=p, q=q
        )
        # predictive mean: K_* @ alpha — test chunks replicated, cheap O(n n̂)
        mean = _predict_mean(xt_chunks, x_chunks, alpha, params, n_test_valid, n_valid)
        if not variances:
            return mean
        var = _spmd_variances(
            local,
            x_chunks,
            xt_chunks,
            params,
            n_valid,
            n_test_valid,
            m_tiles=m_tiles,
            row_axes=row_axes,
            col_axes=col_axes,
            p=p,
            q=q,
        )
        return mean, var

    in_specs = (P(), P(), P())
    out_specs = (P(), P()) if variances else P()
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


def _predict_mean(xt_chunks, x_chunks, alpha, params, nt_valid, n_valid):
    mt, m, _ = xt_chunks.shape

    def row(xa, r0):
        def col(xb, c0):
            kk = km.se_kernel(xa, xb, params)
            gi = r0 + jnp.arange(m)[:, None]
            gj = c0 + jnp.arange(m)[None, :]
            return jnp.where((gi < nt_valid) & (gj < n_valid), kk, 0.0)

        tiles = jax.vmap(col)(x_chunks, jnp.arange(x_chunks.shape[0]) * m)
        return jnp.einsum("kab,kb->a", tiles, alpha)

    return jax.vmap(row)(xt_chunks, jnp.arange(mt) * m)


def _var_step(j, b, *, local, m_tiles, row_axes, col_axes, p, q):
    """One row of the distributed matrix forward-solve L V = K_{X,X̂}."""
    pc = _linear_index(col_axes)
    jq = j // q
    owner_q = j % q
    col_local = lax.dynamic_slice_in_dim(local, jq, 1, axis=1)[:, 0]
    col_local = jnp.where(pc == owner_q, col_local, jnp.zeros_like(col_local))
    col_bcast = _psum(col_local, col_axes)
    col_gather = _gather_axes(col_bcast, row_axes)
    panel = _panel_from_gather(col_gather, p)          # (M, m, m) column j of L
    ljj = lax.dynamic_index_in_dim(panel, j, keepdims=False)
    vj = jax.vmap(
        lambda bb: lax.linalg.triangular_solve(ljj, bb, left_side=True, lower=True)
    )(lax.dynamic_index_in_dim(b, j, keepdims=False))  # (mtq, m, m)
    b = lax.dynamic_update_index_in_dim(b, vj, j, axis=0)
    # update rows i > j:  B_i -= L_ij @ V_j
    gi = jnp.arange(m_tiles)
    upd = jnp.einsum("iab,qbc->iqac", panel, vj)
    b = b - jnp.where((gi > j)[:, None, None, None], upd, 0.0)
    return b


def _spmd_variances(
    local, x_chunks, xt_chunks, params, n_valid, nt_valid, *, m_tiles, row_axes, col_axes, p, q
):
    """Predictive variances diag(K_t,t - V^T V) where L V = K_{X,X̂}.

    V is column-partitioned over the process grid's *column* axis: each
    process column owns n̂/Q test columns; rows are solved sequentially with
    the same broadcast pattern as the cholesky.  Variances need only the
    diagonal blocks of V^T V, which are local per column — a single final
    all-gather returns the replicated result.
    """
    mp, mq, m, _ = local.shape
    pr = _linear_index(row_axes)
    pc = _linear_index(col_axes)
    glob_i = jnp.arange(mp) * p + pr
    glob_k = jnp.arange(mq) * q + pc
    mt = xt_chunks.shape[0]
    if mt % q:
        raise ValueError(f"test tiles {mt} must divide process columns {q}")
    mtq = mt // q
    # local test chunk block: columns [pc*mtq, (pc+1)*mtq)
    xt_loc = lax.dynamic_slice_in_dim(xt_chunks, pc * mtq, mtq, axis=0)
    t0 = pc * mtq * m

    # local RHS tiles B_{i, c} = K(x_i, xt_c): (M, mtq, m, m) — row-replicated,
    # column-partitioned.  Solved in place into V.
    def rhs_row(i):
        def c(xb, cix):
            kk = km.se_kernel(x_chunks[i], xb, params)
            gi = i * m + jnp.arange(m)[:, None]
            gj = t0 + cix * m + jnp.arange(m)[None, :]
            return jnp.where((gi < n_valid) & (gj < nt_valid), kk, 0.0)

        return jax.vmap(c)(xt_loc, jnp.arange(mtq))

    b = jax.vmap(rhs_row)(jnp.arange(m_tiles))            # (M, mtq, m, m)
    step = functools.partial(
        _var_step, local=local, m_tiles=m_tiles, row_axes=row_axes,
        col_axes=col_axes, p=p, q=q,
    )
    v = lax.fori_loop(0, m_tiles, step, b)                # (M, mtq, m, m)
    # diagonal of W = V^T V for owned columns, then prior diag, then gather
    w_diag = jnp.einsum("iqab,iqab->qb", v, v)            # (mtq, m)
    gj = t0 + jnp.arange(mtq)[:, None] * m + jnp.arange(m)[None, :]
    prior_diag = (params.vertical * jnp.ones_like(w_diag)).astype(w_diag.dtype)
    var_loc = jnp.where(gj < nt_valid, prior_diag - w_diag, 0.0)
    var = _gather_axes(var_loc, col_axes).reshape(mt, m)
    # replicated across rows already identical; psum-average across rows not needed
    return var


def distributed_cholesky_fn(
    mesh: Mesh,
    *,
    m_tiles: int,
    row_axes: Tuple[str, ...] = ("data",),
    col_axes: Tuple[str, ...] = ("model",),
    unroll: bool = False,
    update_dtype=None,
):
    """shard_map program: global cyclic tile store -> factored store.

    The global array has shape (M, M, m, m) in *cyclic order*: element
    [a, b] is the tile at grid position (a % P ... ) — callers should use
    :func:`to_cyclic_layout` / :func:`from_cyclic_layout` to convert.
    """
    p, q = grid_shape(mesh, row_axes, col_axes)
    if m_tiles % p or m_tiles % q:
        raise ValueError(f"m_tiles={m_tiles} must divide grid {(p, q)}")

    def fn(local):
        return _spmd_cholesky(
            local,
            m_tiles=m_tiles,
            row_axes=row_axes,
            col_axes=col_axes,
            p=p,
            q=q,
            unroll=unroll,
            update_dtype=update_dtype,
        )

    spec = P(tuple(row_axes), tuple(col_axes), None, None)
    return shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)


def cholesky_step_probe_fn(
    mesh: Mesh,
    *,
    m_tiles: int,
    row_axes: Tuple[str, ...] = ("data",),
    col_axes: Tuple[str, ...] = ("model",),
    update_dtype=None,
):
    """One factorization step as a standalone shard_map program.

    Used by the dry-run cost accounting: ``cost(step) × M`` corrects the
    once-per-while-body undercount of ``cost_analysis`` on the fori_loop
    program (step cost is j-independent in the masked formulation).
    """
    p, q = grid_shape(mesh, row_axes, col_axes)

    def fn(local, j):
        return _chol_step(
            j, local, m_tiles=m_tiles, row_axes=row_axes, col_axes=col_axes,
            p=p, q=q, update_dtype=update_dtype,
        )

    spec = P(tuple(row_axes), tuple(col_axes), None, None)
    return shard_map(fn, mesh=mesh, in_specs=(spec, P()), out_specs=spec,
                     check_vma=False)


def variance_step_probe_fn(
    mesh: Mesh,
    *,
    m_tiles: int,
    row_axes: Tuple[str, ...] = ("data",),
    col_axes: Tuple[str, ...] = ("model",),
):
    """One matrix-forward-solve step (the uncertainty pipeline) standalone."""
    p, q = grid_shape(mesh, row_axes, col_axes)

    def fn(local, b, j):
        return _var_step(
            j, b, local=local, m_tiles=m_tiles, row_axes=row_axes,
            col_axes=col_axes, p=p, q=q,
        )

    spec = P(tuple(row_axes), tuple(col_axes), None, None)
    return shard_map(fn, mesh=mesh, in_specs=(spec, P(), P()), out_specs=P(),
                     check_vma=False)


def to_cyclic_layout(tiles: jax.Array, p: int, q: int) -> jax.Array:
    """(M, M, m, m) natural tile grid -> cyclic layout for the shard_map path.

    Natural tile (I, J) moves to position (I % P * Mp + I // P,
    J % Q * Mq + J // Q) so that a plain blocked PartitionSpec sharding puts
    it on process (I % P, J % Q) at local slot (I // P, J // Q).
    """
    m_tiles = tiles.shape[0]
    mp, mq = m_tiles // p, m_tiles // q
    pos_r = np.array([(i % p) * mp + i // p for i in range(m_tiles)])
    pos_c = np.array([(j % q) * mq + j // q for j in range(m_tiles)])
    return tiles[np.argsort(pos_r)][:, np.argsort(pos_c)]


def from_cyclic_layout(tiles: jax.Array, p: int, q: int) -> jax.Array:
    m_tiles = tiles.shape[0]
    mp, mq = m_tiles // p, m_tiles // q
    pos_r = np.array([(i % p) * mp + i // p for i in range(m_tiles)])
    pos_c = np.array([(j % q) * mq + j // q for j in range(m_tiles)])
    return tiles[pos_r][:, pos_c]
