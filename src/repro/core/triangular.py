"""Tiled triangular solves and tiled matmul helpers for the GP pipeline.

Forward substitution   L b = y        (paper: L beta = y)
Backward substitution  L^T a = b      (paper: L^T alpha = beta)
Matrix forward solve   L V = B        (paper: L V = K_{X,X̂}, for uncertainty)

All operate on the packed symmetric-lower tile store for L (see tiling.py)
and tile stacks for vectors/matrices.  The solves are driven by the same
schedule/executor machinery as the factorization: the scheduler emits the
solve DAG (TRSV diagonal solves, GEMV row propagations), the executor walks
its ASAP levels issuing one batched gather/einsum/scatter per level chunk
(see DESIGN.md §4).  The outer recurrence over tile-rows is inherently
sequential (2M - 1 levels); the inner propagation per level is one batched
matmul — no per-row Python restacking of previously solved chunks.

These standalone entry points are the *staged* path.  In the fused
prediction program (DESIGN.md §7) the same TRSV/GEMV task DAGs are embedded
into the whole-pipeline schedule with cross-stage edges, so solve rows start
the moment their factor tiles resolve instead of waiting for the full
factorization.

All helpers accept an optional leading problem-batch axis B (DESIGN.md §9):
a packed factor (B, T, m, m) with rhs (B, M, m) / (B, M, Q, m, mq) solves B
independent systems through the same lru-cached executor plan.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor, tiling


@functools.lru_cache(maxsize=None)
def _diag_slots(m_tiles: int) -> np.ndarray:
    """Packed slots of the diagonal tiles (i, i)."""
    return np.array(
        [tiling.packed_index(i, i, m_tiles) for i in range(m_tiles)], np.int32
    )


def _solve_lower(lii: jax.Array, rhs: jax.Array, *, transpose: bool = False) -> jax.Array:
    """Solve L x = rhs (or L^T x = rhs) for one diagonal tile; rhs (m,) or (m, k)."""
    vec = rhs.ndim == 1
    b = rhs[:, None] if vec else rhs
    x = jax.lax.linalg.triangular_solve(
        lii, b, left_side=True, lower=True, transpose_a=transpose
    )
    return x[:, 0] if vec else x


def _check_shapes(lpacked: jax.Array, chunks: jax.Array) -> None:
    m_tiles = chunks.shape[1] if lpacked.ndim == 4 else chunks.shape[0]
    assert tiling.num_packed_tiles(m_tiles) == lpacked.shape[-3]


def forward_substitution(
    lpacked: jax.Array, y_chunks: jax.Array, *, n_streams: Optional[int] = None
) -> jax.Array:
    """Solve L b = y.  lpacked: (T, m, m); y_chunks: (M, m) -> b chunks (M, m)."""
    _check_shapes(lpacked, y_chunks)
    return executor.run_solve(lpacked, y_chunks, lower=True, n_streams=n_streams)


def backward_substitution(
    lpacked: jax.Array, b_chunks: jax.Array, *, n_streams: Optional[int] = None
) -> jax.Array:
    """Solve L^T a = b.  Uses tiles (k, i) for k > i: (L^T)_{i,k} = L_{k,i}^T."""
    _check_shapes(lpacked, b_chunks)
    return executor.run_solve(lpacked, b_chunks, lower=False, n_streams=n_streams)


def forward_substitution_matrix(
    lpacked: jax.Array, b_tiles: jax.Array, *, n_streams: Optional[int] = None
) -> jax.Array:
    """Solve L V = B for a tiled matrix RHS.

    b_tiles: (M, Q, m, mq) tile grid of B (n × q).  Returns V tiles (M, Q, m, mq).
    """
    _check_shapes(lpacked, b_tiles)
    return executor.run_solve(lpacked, b_tiles, lower=True, n_streams=n_streams)


def backward_substitution_matrix(
    lpacked: jax.Array, b_tiles: jax.Array, *, n_streams: Optional[int] = None
) -> jax.Array:
    """Solve L^T X = B for a tiled matrix RHS (used by full posterior solve)."""
    _check_shapes(lpacked, b_tiles)
    return executor.run_solve(lpacked, b_tiles, lower=False, n_streams=n_streams)


def tiled_matvec(a_tiles: jax.Array, x_chunks: jax.Array) -> jax.Array:
    """(P, Q, m, mq) tile grid times (Q, mq) chunked vector -> (P, m).

    Batched: (B, P, Q, m, mq) x (B, Q, mq) -> (B, P, m).
    """
    if a_tiles.ndim == 5:
        return jnp.einsum("zpqab,zqb->zpa", a_tiles, x_chunks)
    return jnp.einsum("pqab,qb->pa", a_tiles, x_chunks)


def tiled_gram(v_tiles: jax.Array) -> jax.Array:
    """W = V^T V for V tiles (M, Q, m, mq) -> W tiles (Q, Q, mq, mq).

    Batched: (B, M, Q, m, mq) -> (B, Q, Q, mq, mq).
    """
    if v_tiles.ndim == 5:
        return jnp.einsum("zipab,ziqac->zpqbc", v_tiles, v_tiles)
    return jnp.einsum("ipab,iqac->pqbc", v_tiles, v_tiles)


def packed_matvec(
    lpacked: jax.Array, chunks: jax.Array, *, transpose: bool = False
) -> jax.Array:
    """y = L x (or L^T x) against the packed lower factor; chunks (M, m).

    Used by the streaming-update path (DESIGN.md §10) to reconstruct the
    forward-solve chunks beta = L^T alpha (and y = L beta) from posterior
    states that predate the live-state fields.  Batched: (B, T, m, m) x
    (B, M, m) -> (B, M, m).
    """
    batched = lpacked.ndim == 4
    m_tiles = chunks.shape[-2]
    if tiling.num_packed_tiles(m_tiles) != lpacked.shape[-3]:
        raise ValueError(
            f"chunk rows {m_tiles} inconsistent with packed store {lpacked.shape}"
        )
    rows, cols = tiling._packed_coords(m_tiles)
    m = lpacked.shape[-1]
    dense = jnp.zeros(
        lpacked.shape[:-3] + (m_tiles, m_tiles, m, m), lpacked.dtype
    )
    if batched:
        dense = dense.at[:, rows, cols].set(lpacked)
        ein = "zjiba,zjb->zia" if transpose else "zijab,zjb->zia"
    else:
        dense = dense.at[rows, cols].set(lpacked)
        ein = "jiba,jb->ia" if transpose else "ijab,jb->ia"
    return jnp.einsum(ein, dense, chunks.astype(lpacked.dtype))


def identity_tiles(m_tiles: int, m: int, dtype=jnp.float32) -> jax.Array:
    """Identity matrix as an (M, M, m, m) tile grid (matrix-solve RHS layout)."""
    eye = jnp.eye(m, dtype=dtype)
    block_diag = jnp.eye(m_tiles, dtype=dtype)[:, :, None, None]
    return block_diag * eye[None, None]


def kinv_tiles_from_factor(
    lpacked: jax.Array, *, n_streams: Optional[int] = None
) -> jax.Array:
    """K^{-1} tile grid (M, M, m, m) from the packed Cholesky factor.

    Blocked reverse-mode building block (DESIGN.md §8): solve ``L Z = I``
    through the tiled matrix-solve executor (Z = L^{-1} as tile rows), then
    ``K^{-1} = Z^T Z`` via the tiled gram.  O(n^3) like the factorization
    itself — one triangular matrix solve + one gram, instead of autodiff
    back through every wavefront launch.  Identity padding makes the padded
    diagonal block of K^{-1} identity, which callers slice away.
    """
    m_tiles = executor.m_tiles_of_packed(lpacked)
    m = lpacked.shape[-1]
    eye = identity_tiles(m_tiles, m, lpacked.dtype)
    if lpacked.ndim == 4:  # problem-batched factor: one RHS per problem
        eye = jnp.broadcast_to(eye, (lpacked.shape[0],) + eye.shape)
    z = forward_substitution_matrix(lpacked, eye, n_streams=n_streams)
    return tiled_gram(z)


def logdet_from_factor(lpacked: jax.Array, m_tiles: int, n_valid=None) -> jax.Array:
    """log det K = 2 sum_i log diag(L)_i from the packed factor.

    When the factor came out of the masked assembly path its padding is
    exactly identity and contributes log(1) = 0 with no masking.  But a
    factor whose padded diagonal is anything else — a raw ``pack_lower`` of
    a dense matrix with junk past ``n_valid``, or a ragged-batch factor
    where each problem's frontier differs — would silently corrupt the
    log-determinant, so when ``n_valid`` is given the diagonal entries at
    global index >= n_valid are masked to 1 before the log.  ``n_valid``
    may be a scalar or, for batched factors (B, T, m, m), a (B,) array of
    per-problem frontiers.  Batched factors return per-problem
    log-determinants (B,).
    """
    slots = _diag_slots(m_tiles)
    tiles = lpacked[:, slots] if lpacked.ndim == 4 else lpacked[slots]
    diags = jnp.diagonal(tiles, axis1=-2, axis2=-1)  # (..., M, m)
    if n_valid is not None:
        m = lpacked.shape[-1]
        gi = jnp.arange(m_tiles, dtype=jnp.int32)[:, None] * m + jnp.arange(
            m, dtype=jnp.int32
        )[None, :]                                    # (M, m) global indices
        nv = jnp.asarray(n_valid)
        if nv.ndim > 0:                               # per-problem (B,)
            nv = nv[:, None, None]
        diags = jnp.where(gi < nv, diags, jnp.ones((), diags.dtype))
    return 2.0 * jnp.sum(jnp.log(diags), axis=(-2, -1))
