"""Tiled triangular solves and tiled matmul helpers for the GP pipeline.

Forward substitution   L b = y        (paper: L beta = y)
Backward substitution  L^T a = b      (paper: L^T alpha = beta)
Matrix forward solve   L V = B        (paper: L V = K_{X,X̂}, for uncertainty)

All operate on the packed symmetric-lower tile store for L (see tiling.py)
and tile stacks for vectors/matrices.  The outer recurrence over tile-rows is
inherently sequential (length-M dependency chain); the inner reduction over
previously solved chunks is a single batched matmul per row — this is the
level-batched execution the paper's stream pool approximates on GPU.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling


def _row_slots(i: int, m_tiles: int) -> np.ndarray:
    """Packed slots of tiles (i, 0..i-1) — the strictly-left row of tile-row i."""
    return np.array([tiling.packed_index(i, j, m_tiles) for j in range(i)], np.int32)


def _col_slots(i: int, m_tiles: int) -> np.ndarray:
    """Packed slots of tiles (i+1..M-1, i) — the strictly-below column i."""
    return np.array(
        [tiling.packed_index(k, i, m_tiles) for k in range(i + 1, m_tiles)], np.int32
    )


def _solve_lower(lii: jax.Array, rhs: jax.Array, *, transpose: bool = False) -> jax.Array:
    """Solve L x = rhs (or L^T x = rhs) for one diagonal tile; rhs (m,) or (m, k)."""
    vec = rhs.ndim == 1
    b = rhs[:, None] if vec else rhs
    x = jax.lax.linalg.triangular_solve(
        lii, b, left_side=True, lower=True, transpose_a=transpose
    )
    return x[:, 0] if vec else x


def forward_substitution(lpacked: jax.Array, y_chunks: jax.Array) -> jax.Array:
    """Solve L b = y.  lpacked: (T, m, m); y_chunks: (M, m) -> b chunks (M, m)."""
    t = lpacked.shape[0]
    m_tiles = y_chunks.shape[0]
    assert tiling.num_packed_tiles(m_tiles) == t
    out = []
    for i in range(m_tiles):
        acc = y_chunks[i]
        if i > 0:
            row = lpacked[_row_slots(i, m_tiles)]          # (i, m, m)
            prev = jnp.stack(out)                           # (i, m)
            acc = acc - jnp.einsum("jab,jb->a", row, prev)
        lii = lpacked[tiling.packed_index(i, i, m_tiles)]
        out.append(_solve_lower(lii, acc))
    return jnp.stack(out)


def backward_substitution(lpacked: jax.Array, b_chunks: jax.Array) -> jax.Array:
    """Solve L^T a = b.  Uses tiles (k, i) for k > i: (L^T)_{i,k} = L_{k,i}^T."""
    t = lpacked.shape[0]
    m_tiles = b_chunks.shape[0]
    assert tiling.num_packed_tiles(m_tiles) == t
    out = [None] * m_tiles
    for i in reversed(range(m_tiles)):
        acc = b_chunks[i]
        if i < m_tiles - 1:
            col = lpacked[_col_slots(i, m_tiles)]           # (M-1-i, m, m): L_{k,i}
            nxt = jnp.stack(out[i + 1 :])                   # (M-1-i, m)
            acc = acc - jnp.einsum("jba,jb->a", col, nxt)   # L_{k,i}^T x_k
        lii = lpacked[tiling.packed_index(i, i, m_tiles)]
        out[i] = _solve_lower(lii, acc, transpose=True)
    return jnp.stack(out)


def forward_substitution_matrix(lpacked: jax.Array, b_tiles: jax.Array) -> jax.Array:
    """Solve L V = B for a tiled matrix RHS.

    b_tiles: (M, Q, m, mq) tile grid of B (n × q).  Returns V tiles (M, Q, m, mq).
    """
    t = lpacked.shape[0]
    m_tiles = b_tiles.shape[0]
    assert tiling.num_packed_tiles(m_tiles) == t
    solve_cols = jax.vmap(_solve_lower, in_axes=(None, 0))
    out = []
    for i in range(m_tiles):
        acc = b_tiles[i]                                    # (Q, m, mq)
        if i > 0:
            row = lpacked[_row_slots(i, m_tiles)]           # (i, m, m)
            prev = jnp.stack(out)                            # (i, Q, m, mq)
            acc = acc - jnp.einsum("jab,jqbc->qac", row, prev)
        lii = lpacked[tiling.packed_index(i, i, m_tiles)]
        out.append(solve_cols(lii, acc))
    return jnp.stack(out)


def backward_substitution_matrix(lpacked: jax.Array, b_tiles: jax.Array) -> jax.Array:
    """Solve L^T X = B for a tiled matrix RHS (used by full posterior solve)."""
    t = lpacked.shape[0]
    m_tiles = b_tiles.shape[0]
    assert tiling.num_packed_tiles(m_tiles) == t
    solve_cols = jax.vmap(
        lambda a, b: _solve_lower(a, b, transpose=True), in_axes=(None, 0)
    )
    out = [None] * m_tiles
    for i in reversed(range(m_tiles)):
        acc = b_tiles[i]
        if i < m_tiles - 1:
            col = lpacked[_col_slots(i, m_tiles)]           # L_{k,i}, k > i
            nxt = jnp.stack(out[i + 1 :])                   # (K, Q, m, mq)
            acc = acc - jnp.einsum("jba,jqbc->qac", col, nxt)
        lii = lpacked[tiling.packed_index(i, i, m_tiles)]
        out[i] = solve_cols(lii, acc)
    return jnp.stack(out)


def tiled_matvec(a_tiles: jax.Array, x_chunks: jax.Array) -> jax.Array:
    """(P, Q, m, mq) tile grid times (Q, mq) chunked vector -> (P, m)."""
    return jnp.einsum("pqab,qb->pa", a_tiles, x_chunks)


def tiled_gram(v_tiles: jax.Array) -> jax.Array:
    """W = V^T V for V tiles (M, Q, m, mq) -> W tiles (Q, Q, mq, mq)."""
    return jnp.einsum("ipab,iqac->pqbc", v_tiles, v_tiles)


def logdet_from_factor(lpacked: jax.Array, m_tiles: int, n_valid: Optional[int] = None) -> jax.Array:
    """log det K = 2 sum_i log diag(L)_i from the packed factor.

    Padded rows contribute log(1) = 0 by construction (identity padding), so
    no masking is required; n_valid is accepted for interface clarity.
    """
    del n_valid
    diag_slots = np.array(
        [tiling.packed_index(i, i, m_tiles) for i in range(m_tiles)], np.int32
    )
    diags = jax.vmap(jnp.diag)(lpacked[diag_slots])         # (M, m)
    return 2.0 * jnp.sum(jnp.log(diags))
