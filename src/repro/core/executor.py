"""Schedule-driven level-batched executor — the stream pool of the paper.

HPX executes the tiled Cholesky/solve DAG by firing each task as its future
operands resolve, round-robin over a pool of CUDA streams; kernels from
*different* columns overlap whenever the dataflow allows it.  On TPU the graph
must be static, so this module compiles a :class:`repro.core.scheduler.Schedule`
into the equivalent static program:

  for each level (ASAP antichain, or <= n_streams wavefront wave):
      group the level's tasks by op        # POTRF / TRSM / SYRK / GEMM / ...
      for each round-robin chunk of <= n_streams tasks:
          gather operand tiles (precomputed numpy index arrays)
          ONE batched kernel call (vmapped jnp op or Pallas kernel)
          scatter results back into the packed store

With ``n_streams=None`` every ASAP level becomes one batch per op — the
TPU-native maximum-batching limit.  With a finite ``n_streams`` the plan is
the *wavefront* schedule (scheduler.build_wavefront_schedule): waves of at
most ``n_streams`` simultaneously-ready tasks, critical-path first, so the
GEMM tail of column j co-batches with the TRSM panel of column j+1 — exactly
the cross-column overlap the paper's Fig. 5 timeline shows for the stream
pool.  ``n_streams=1`` is the fully sequential single-stream baseline.

Plans (the gather/scatter index arrays per level) are pure functions of
``(m_tiles, n_streams)`` and are lru-cached, so repeated traces pay no
schedule-construction cost.  See DESIGN.md §3.

**Problem batching (DESIGN.md §9).**  Every buffer may carry an optional
leading problem-batch dimension ``B`` — ``B`` independent GPs of identical
tile geometry executed by the *same* Plan (the DAG depends only on
``m_tiles``/``q_tiles``, never on ``B``, so plans stay shared and
lru-cached).  Gathers/scatters move from axis 0 to axis 1 and every batched
kernel launch covers ``B x G`` tiles instead of ``G``: either flattened into
the kernel's existing batch/grid axis (``batch_dispatch="flat"``, the
default — one launch whose Pallas grid absorbs B) or via one more
``jax.vmap`` level over the single-problem kernels
(``batch_dispatch="vmap"``).  ``benchmarks/fig9_batched_fleet.py`` measures
both.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.core import kernels_math as km
from repro.core import scheduler as sch
from repro.core import tiling
from repro.dist import sharding as dist_sharding


# ---------------------------------------------------------------------------
# Tile-level ops (jnp backend).  a/b are (m, m) tiles; batched via vmap.
# The Pallas backend (repro.kernels.ops) exposes the same signatures.
# ---------------------------------------------------------------------------


def _potrf_jnp(a: jax.Array) -> jax.Array:
    return jnp.linalg.cholesky(a)


def _trsm_jnp(ljj: jax.Array, b: jax.Array) -> jax.Array:
    # Solve X @ L_JJ^T = B  (right-looking panel update: L_IJ = K_IJ L_JJ^{-T})
    return jax.lax.linalg.triangular_solve(
        ljj, b, left_side=False, lower=True, transpose_a=True
    )


def _syrk_jnp(kii: jax.Array, lij: jax.Array, update_dtype=None) -> jax.Array:
    a = lij if update_dtype is None else lij.astype(update_dtype)
    upd = (a @ a.T).astype(kii.dtype)
    return kii - upd


def _gemm_jnp(kik: jax.Array, lij: jax.Array, lkj: jax.Array, update_dtype=None) -> jax.Array:
    a, b = lij, lkj
    if update_dtype is not None:
        a, b = a.astype(update_dtype), b.astype(update_dtype)
    upd = (a @ b.T).astype(kik.dtype)
    return kik - upd


def get_ops(backend: str):
    """(potrf, trsm, syrk, gemm) tile ops for a backend name."""
    if backend == "jnp":
        return _potrf_jnp, _trsm_jnp, _syrk_jnp, _gemm_jnp
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.potrf, kops.trsm, kops.syrk, kops.gemm
    raise ValueError(f"unknown backend: {backend}")


def get_lrgemm_op(backend: str):
    """The per-tile LRGEMM contraction op (DESIGN.md §14): (m, mb) @ (mb,)."""
    if backend == "jnp":
        return lambda a, v: a @ v
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.lrgemm
    raise ValueError(f"unknown backend: {backend}")


# ---------------------------------------------------------------------------
# Compiled plans: per level, per op, per stream-chunk gather/scatter indices.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Batch:
    """One batched kernel launch: gather operands, compute, scatter ``out``.

    Index semantics by op (all numpy int32, length = batch size):
      POTRF: a = diagonal slots;                       out = a
      TRSM:  a = L_JJ slots, b = panel slots;          out = b
      SYRK:  a = target (i,i) slots, b = panel slots;  out = a
      GEMM:  a = target slots, b/c = panel slots;      out = a
      TRSV:  a = diagonal slots;                       out = rhs tile-rows
      GEMV:  a = L tile slots, b = source tile-rows;   out = dest tile-rows
    """

    op: str
    tasks: Tuple[sch.Task, ...]
    out: np.ndarray
    a: np.ndarray
    b: Optional[np.ndarray] = None
    c: Optional[np.ndarray] = None

    @property
    def size(self) -> int:
        return len(self.tasks)


@dataclasses.dataclass(frozen=True)
class Plan:
    """A schedule compiled to batched gather/compute/scatter launches."""

    kind: str
    m_tiles: int
    n_streams: Optional[int]
    levels: Tuple[Tuple[Batch, ...], ...]

    @property
    def n_batches(self) -> int:
        return sum(len(l) for l in self.levels)

    def level_task_counts(self) -> List[int]:
        """Tasks per level — must match ``len(level)`` of the source Schedule."""
        return [sum(b.size for b in level) for level in self.levels]

    def flat_tasks(self) -> List[sch.Task]:
        """Tasks in issue order (level-major, batch order within a level)."""
        return [t for level in self.levels for b in level for t in b.tasks]


def _arr(xs: Sequence[int]) -> np.ndarray:
    return np.asarray(xs, np.int32)


# ---------------------------------------------------------------------------
# Wave-trace telemetry (DESIGN.md §15) — the live analogue of fig5: what one
# dispatch of a Plan launches, wave by wave, and how full the stream pool is.
# ---------------------------------------------------------------------------

# Plans are lru-cached and live for the process; keying the digest by id()
# makes the per-dispatch record a dict lookup, not a Plan walk.
_plan_stats_cache: dict = {}


def plan_wave_stats(plan: Plan) -> dict:
    """Static per-Plan wave digest: waves, launches, tasks by op family,
    bulk-op ride-alongs, and mean stream-pool occupancy.

    ``occupancy`` is pool tasks per pool-bearing wave over ``n_streams``
    (BULK_OPS ride along outside the pool budget — scheduler docstring);
    with ``n_streams=None`` the pool is unbounded and occupancy is 1.0 by
    definition.  Memoized per Plan object, so recording a dispatch costs a
    dict hit.
    """
    st = _plan_stats_cache.get(id(plan))
    if st is not None:
        return st
    by_op: dict = {}
    bulk_tasks = 0
    pool_tasks = 0
    pool_waves = 0
    for level in plan.levels:
        level_pool = 0
        for bt in level:
            by_op[bt.op] = by_op.get(bt.op, 0) + bt.size
            if bt.op in sch.BULK_OPS:
                bulk_tasks += bt.size
            else:
                level_pool += bt.size
        if level_pool:
            pool_waves += 1
            pool_tasks += level_pool
    if plan.n_streams and pool_waves:
        occupancy = pool_tasks / (pool_waves * plan.n_streams)
    else:
        occupancy = 1.0 if pool_tasks else 0.0
    st = {
        "plan": plan.kind,
        "waves": len(plan.levels),
        "launches": plan.n_batches,
        "tasks": bulk_tasks + pool_tasks,
        "bulk_tasks": bulk_tasks,
        "pool_tasks": pool_tasks,
        "n_streams": plan.n_streams,
        "occupancy": occupancy,
        "by_op": by_op,
    }
    _plan_stats_cache[id(plan)] = st
    return st


def record_dispatch(kind: str, plan: Plan, *, backend: str, batched: bool) -> None:
    """Count + log one host-side dispatch of ``plan`` (obs must be enabled;
    callers guard — and must never call this at trace time: under jit the
    program body runs once per trace, so an in-trace record would count
    compilations, not dispatches.  The eager run_* entry points check
    ``isinstance(operand, jax.core.Tracer)`` and log a retrace counter
    instead; the jitted fast paths record from their *callers* in
    predict/update, where operands are concrete)."""
    st = plan_wave_stats(plan)
    obs.inc(f"executor.dispatch.{kind}")
    obs.inc("executor.launches", st["launches"])
    for op, cnt in st["by_op"].items():
        obs.inc(f"executor.tasks.{op}", cnt)
    obs.event(
        "executor.wave",
        dispatch=kind,
        backend=backend,
        batched=bool(batched),
        **st,
    )


def _cholesky_batch(op: str, tasks: Sequence[sch.Task], m: int) -> Batch:
    slot = tiling.packed_index
    tasks = tuple(tasks)
    if op == sch.POTRF:
        d = _arr([slot(j, j, m) for _, _, j, _ in tasks])
        return Batch(op, tasks, out=d, a=d)
    if op == sch.TRSM:
        diag = _arr([slot(j, j, m) for _, _, j, _ in tasks])
        tgt = _arr([slot(i, j, m) for _, i, j, _ in tasks])
        return Batch(op, tasks, out=tgt, a=diag, b=tgt)
    if op == sch.SYRK:
        tgt = _arr([slot(i, i, m) for _, i, _, _ in tasks])
        panel = _arr([slot(i, j, m) for _, i, j, _ in tasks])
        return Batch(op, tasks, out=tgt, a=tgt, b=panel)
    if op == sch.GEMM:
        tgt = _arr([slot(i, k, m) for _, i, _, k in tasks])
        pa = _arr([slot(i, j, m) for _, i, j, _ in tasks])
        pb = _arr([slot(k, j, m) for _, _, j, k in tasks])
        return Batch(op, tasks, out=tgt, a=tgt, b=pa, c=pb)
    raise ValueError(op)


def _solve_batch(op: str, tasks: Sequence[sch.Task], m: int, lower: bool) -> Batch:
    slot = tiling.packed_index
    tasks = tuple(tasks)
    if op == sch.TRSV:
        rows = _arr([i for _, i, _, _ in tasks])
        diag = _arr([slot(i, i, m) for _, i, _, _ in tasks])
        return Batch(op, tasks, out=rows, a=diag)
    if op == sch.GEMV:
        dst = _arr([i for _, i, _, _ in tasks])
        src = _arr([j for _, _, j, _ in tasks])
        tiles = _arr(
            [slot(i, j, m) if lower else slot(j, i, m) for _, i, j, _ in tasks]
        )
        return Batch(op, tasks, out=dst, a=tiles, b=src)
    raise ValueError(op)


def _compile(schedule: sch.Schedule, n_streams: Optional[int], batch_fn) -> Plan:
    levels = []
    for level in schedule.levels:
        batches = []
        for op, tasks in sch.split_by_op(level).items():
            for chunk in sch.chunk_tasks(tasks, n_streams):
                batches.append(batch_fn(op, chunk, schedule.m_tiles))
        levels.append(tuple(batches))
    return Plan(schedule.kind, schedule.m_tiles, n_streams, tuple(levels))


@functools.lru_cache(maxsize=None)
def cholesky_plan(m_tiles: int, n_streams: Optional[int] = None) -> Plan:
    """``None``: whole-ASAP-level batches (TPU-native limit).  Finite: the
    wavefront schedule — waves of <= n_streams ready tasks, critical-path
    first, which co-batches trailing updates of column j with the panel of
    column j+1 exactly like the paper's round-robin stream pool."""
    if n_streams is None:
        schedule = sch.build_schedule(m_tiles)
    else:
        schedule = sch.build_wavefront_schedule(m_tiles, n_streams, kind="cholesky")
    return _compile(schedule, n_streams, _cholesky_batch)


@functools.lru_cache(maxsize=None)
def solve_plan(
    m_tiles: int, *, lower: bool = True, n_streams: Optional[int] = None
) -> Plan:
    kind = "forward" if lower else "backward"
    if n_streams is None:
        schedule = sch.build_solve_schedule(m_tiles, lower=lower)
    else:
        schedule = sch.build_wavefront_schedule(m_tiles, n_streams, kind=kind)
    return _compile(
        schedule, n_streams, functools.partial(_solve_batch, lower=lower)
    )


# ---------------------------------------------------------------------------
# Execution.
# ---------------------------------------------------------------------------


def m_tiles_of_packed(packed: jax.Array) -> int:
    """Tile count M of a packed (..., T, m, m) store, validating T = M(M+1)/2."""
    t = packed.shape[-3]
    m_tiles = int((np.sqrt(8 * t + 1) - 1) // 2)
    if tiling.num_packed_tiles(m_tiles) != t:
        raise ValueError(f"{t} is not a triangular number of tiles")
    return m_tiles


def _env_ops(batched: bool):
    """(take, put, add) buffer accessors for unbatched / problem-batched envs.

    Unbatched buffers gather/scatter on axis 0; batched buffers carry the
    problem axis B first and gather/scatter on axis 1 — same index arrays,
    same Plan.
    """
    if batched:
        return (
            lambda buf, idx: buf[:, idx],
            lambda buf, idx, val: buf.at[:, idx].set(val),
            lambda buf, idx, val: buf.at[:, idx].add(val),
        )
    return (
        lambda buf, idx: buf[idx],
        lambda buf, idx, val: buf.at[idx].set(val),
        lambda buf, idx, val: buf.at[idx].add(val),
    )


def _fleet_shard(mesh, batched: bool):
    """Layout pin for B-leading buffers: identity without a mesh (or for
    unbatched programs — a single problem has no axis to shard)."""
    if mesh is None or not batched:
        return lambda a: a
    return lambda a: dist_sharding.fleet_hint(a, mesh)


def _tile_dispatch(fn, batched: bool, mode: str = "flat"):
    """Lift a per-tile op to a (possibly problem-batched) batched launch.

    Unbatched: one ``jax.vmap`` over the gathered G tiles, as before.
    Batched (operands (B, G, ...)): ``mode="flat"`` reshapes to (B*G, ...)
    so the ONE launch's existing batch axis — the Pallas grid — absorbs B;
    ``mode="vmap"`` nests a second ``jax.vmap`` over the problem axis
    instead.  Both produce (B, G, ...) results; fig9 benchmarks the two.
    """
    f = jax.vmap(fn)
    if not batched:
        return f
    if mode == "vmap":
        return jax.vmap(f)
    if mode != "flat":
        raise ValueError(f"batch_dispatch must be 'flat' or 'vmap', got {mode!r}")

    def flat(*arrays):
        b, g = arrays[0].shape[:2]
        out = f(*[a.reshape((b * g,) + a.shape[2:]) for a in arrays])
        unflatten = lambda o: o.reshape((b, g) + o.shape[1:])
        return jax.tree_util.tree_map(unflatten, out)  # multi-output ops too

    return flat


def run_cholesky(
    packed: jax.Array,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    batch_dispatch: str = "flat",
) -> jax.Array:
    """Factor a packed store K -> L by walking the level schedule.

    Each Batch is one gather + one batched kernel + one scatter; tasks inside
    a level are mutually independent (ASAP antichain), so batches may contain
    tasks of *different* columns — the cross-column overlap that the paper
    obtains from HPX dataflow over the stream pool.

    packed: (T, m, m), or (B, T, m, m) for B independent problems driven by
    the same lru-cached Plan (every launch then covers B x chunk tiles).
    """
    batched = packed.ndim == 4
    take, put, _ = _env_ops(batched)
    plan = cholesky_plan(m_tiles_of_packed(packed), n_streams)
    potrf, trsm, syrk, gemm = get_ops(backend)
    potrf_b = _tile_dispatch(potrf, batched, batch_dispatch)
    trsm_b = _tile_dispatch(trsm, batched, batch_dispatch)
    syrk_b = _tile_dispatch(
        functools.partial(syrk, update_dtype=update_dtype), batched, batch_dispatch
    )
    gemm_b = _tile_dispatch(
        functools.partial(gemm, update_dtype=update_dtype), batched, batch_dispatch
    )
    for level in plan.levels:
        for bt in level:
            if bt.op == sch.POTRF:
                packed = put(packed, bt.out, potrf_b(take(packed, bt.a)))
            elif bt.op == sch.TRSM:
                packed = put(
                    packed, bt.out, trsm_b(take(packed, bt.a), take(packed, bt.b))
                )
            elif bt.op == sch.SYRK:
                packed = put(
                    packed, bt.out, syrk_b(take(packed, bt.a), take(packed, bt.b))
                )
            else:
                packed = put(
                    packed,
                    bt.out,
                    gemm_b(take(packed, bt.a), take(packed, bt.b), take(packed, bt.c)),
                )
    return packed


def _trsv_batch(lii: jax.Array, x: jax.Array, transpose: bool) -> jax.Array:
    """Batched diagonal-tile solve.

    lii (..., G, m, m); x (..., G, m) vector chunks or (..., G, Q, m, mq)
    matrix tile-rows, where ``...`` is the optional problem-batch axis —
    ``triangular_solve`` broadcasts over all leading axes.
    """
    if x.ndim == lii.ndim - 1:  # vector rhs chunks
        sol = jax.lax.linalg.triangular_solve(
            lii, x[..., None], left_side=True, lower=True, transpose_a=transpose
        )
        return sol[..., 0]
    liiq = jnp.broadcast_to(
        lii[..., None, :, :], x.shape[:-2] + lii.shape[-2:]
    )
    return jax.lax.linalg.triangular_solve(
        liiq, x, left_side=True, lower=True, transpose_a=transpose
    )


# ---------------------------------------------------------------------------
# Whole-pipeline program execution (DESIGN.md §7).
#
# The program plan generalizes the single packed operand to a named *buffer
# environment*:
#
#   "packed"  (T, m, m)       covariance tiles -> Cholesky factor (in place)
#   "y"       (M, m)          y chunks -> beta (forward substitution)
#   "alpha"   (M, m)          beta -> alpha (backward substitution)
#   "cross"   (Q*M, m, m)     cross-covariance tile grid K_{X̂,X} (flat)
#   "mean"    (Q, m)          predictive-mean chunks
#   "v"       (M, Q, m, m)    uncertainty workspace V = L^{-1} K_{X,X̂}
#   "prior"   (Q*Q, m, m)     prior test tiles -> posterior covariance tiles
#
# plus the read-only feature blocks xc (M, m, D) / xtc (Q, m, D).  One
# run_program walks the fused schedule issuing per-level multi-op batches;
# SYRK and GEMM tasks of a level are dispatched as a single fused
# trailing-update launch (TRAIL) since their batched kernel is identical
# (SYRK is GEMM with both panels equal).
# ---------------------------------------------------------------------------

TRAIL = sch.TRAIL_GROUP  # fused SYRK+GEMM dispatch group (program plans only)


def _program_batch(
    op: str, tasks: Sequence[sch.Task], m: int, q_tiles: int
) -> Batch:
    """Gather/scatter indices of one program batch (buffer roles fixed by op)."""
    slot = tiling.packed_index
    tasks = tuple(tasks)
    if op in (sch.POTRF, sch.TRSM):
        return _cholesky_batch(op, tasks, m)
    if op == TRAIL:
        tgt, pa, pb = [], [], []
        for t in tasks:
            _, i, j, k = t
            if t[0] == sch.SYRK:
                tgt.append(slot(i, i, m))
                pa.append(slot(i, j, m))
                pb.append(slot(i, j, m))
            else:
                tgt.append(slot(i, k, m))
                pa.append(slot(i, j, m))
                pb.append(slot(k, j, m))
        return Batch(op, tasks, out=_arr(tgt), a=_arr(tgt), b=_arr(pa), c=_arr(pb))
    if op in (sch.TRSV, sch.GEMV):
        return _solve_batch(op, tasks, m, lower=True)
    if op in (sch.TRSV_B, sch.GEMV_B):
        base = _solve_batch(
            sch.TRSV if op == sch.TRSV_B else sch.GEMV, tasks, m, lower=False
        )
        return dataclasses.replace(base, op=op, tasks=tasks)
    if op == sch.ASSEMBLE:
        rows = _arr([i for _, i, _, _ in tasks])
        cols = _arr([j for _, _, j, _ in tasks])
        slots = _arr([slot(i, j, m) for _, i, j, _ in tasks])
        return Batch(op, tasks, out=slots, a=rows, b=cols)
    if op == sch.CROSS:
        p = _arr([i for _, i, _, _ in tasks])
        q = _arr([j for _, _, j, _ in tasks])
        return Batch(op, tasks, out=_arr([i * m + j for _, i, j, _ in tasks]), a=p, b=q)
    if op == sch.PRIOR:
        p = _arr([i for _, i, _, _ in tasks])
        q = _arr([j for _, _, j, _ in tasks])
        return Batch(
            op, tasks, out=_arr([i * q_tiles + j for _, i, j, _ in tasks]), a=p, b=q
        )
    if op == sch.XGEMV:
        rows = _arr([i for _, i, _, _ in tasks])
        return Batch(op, tasks, out=rows, a=rows)
    if op == sch.VINIT:
        rows = _arr([i for _, i, _, _ in tasks])
        return Batch(op, tasks, out=rows, a=rows)
    if op in (sch.VTRSV, sch.VGEMV):
        # same row/tile indexing as the vector forward solve, on the v buffer
        base = _solve_batch(
            sch.TRSV if op == sch.VTRSV else sch.GEMV, tasks, m, lower=True
        )
        return dataclasses.replace(base, op=op, tasks=tasks)
    if op == sch.GRAM:
        return Batch(op, tasks, out=_arr([]), a=_arr([]))
    raise ValueError(op)


@functools.lru_cache(maxsize=None)
def program_plan(
    m_tiles: int,
    q_tiles: int,
    uncertainty: bool = False,
    n_streams: Optional[int] = None,
) -> Plan:
    """Compile the fused prediction program into batched launches.

    ``None``: ASAP levels of the whole-pipeline DAG (cross tiles at level 0
    alongside assembly, solve rows leveled against the columns that produce
    their tiles).  Finite: the cross-stage wavefront schedule — waves of
    <= n_streams simultaneously-ready tasks, critical-path first, so solve
    rows and cross assembly ride the tail of Cholesky columns (paper Fig. 5).
    """
    if n_streams is None:
        schedule = sch.build_program_schedule(
            m_tiles, q_tiles, uncertainty=uncertainty
        )
    else:
        schedule = sch.build_wavefront_schedule(
            m_tiles,
            n_streams,
            kind="program",
            q_tiles=q_tiles,
            uncertainty=uncertainty,
        )
    levels = []
    for level in schedule.levels:
        groups: dict = {}
        for t in level:
            groups.setdefault(sch.dispatch_group(t[0]), []).append(t)
        batches = []
        for gop, tasks in groups.items():
            # BULK ops are one batched custom-kernel launch regardless of the
            # pool size (see scheduler.BULK_OPS) — never chunk them.
            width = None if gop in sch.BULK_OPS else n_streams
            for chunk in sch.chunk_tasks(tasks, width):
                batches.append(_program_batch(gop, chunk, m_tiles, q_tiles))
        levels.append(tuple(batches))
    return Plan("program", m_tiles, n_streams, tuple(levels))


@functools.lru_cache(maxsize=None)
def lowrank_plan(
    mu_tiles: int, n_tiles: int, n_streams: Optional[int] = None
) -> Plan:
    """Compile the LRGEMM bulk family (DESIGN.md §14) into ONE batched launch.

    The lowrank schedule is a single level of ``mu_tiles * n_tiles``
    independent tile contractions over the K_un grid; like every BULK_OPS
    family it is never chunked by the stream pool.  The Plan depends only on
    the (mu_tiles, n_tiles) tile geometry — B-invariant, so every fleet
    width and every problem batch reuses the same cache entry.
    """
    tasks = tuple(sch.lowrank_tasks(mu_tiles, n_tiles))
    batch = Batch(
        sch.LRGEMM,
        tasks,
        out=_arr([p for _, p, _, _ in tasks]),           # c chunk rows
        a=_arr([p * n_tiles + j for _, p, j, _ in tasks]),  # flat K_un slots
        b=_arr([j for _, _, j, _ in tasks]),             # training chunks
    )
    return Plan("lowrank", mu_tiles, n_streams, ((batch,),))


def run_lowrank_contraction(
    kun: jax.Array,
    yc: jax.Array,
    *,
    backend: str = "jnp",
    batch_dispatch: str = "flat",
    n_streams: Optional[int] = None,
) -> jax.Array:
    """c = K_un y through the LRGEMM family: c_p = sum_j K_un[p, j] y_j.

    ``kun`` (MU, M, m, m) cross-covariance tile grid (rows = inducing
    points, cols = training points), ``yc`` (M, m) training chunks — or
    ``(B, ...)`` problem-batched operands driven by the SAME lru-cached
    Plan.  One gather + ONE batched tile matvec (jnp or the Pallas LRGEMM
    kernel through ``_tile_dispatch``) + one scatter-add; ragged problems
    need no masking here because padded K_un columns are assembled as zero.
    """
    batched = kun.ndim == 5
    take, _, add = _env_ops(batched)
    mu_tiles, n_tiles = kun.shape[-4], kun.shape[-3]
    plan = lowrank_plan(mu_tiles, n_tiles, n_streams)
    mv = _tile_dispatch(get_lrgemm_op(backend), batched, batch_dispatch)
    kflat = kun.reshape(kun.shape[:-4] + (mu_tiles * n_tiles,) + kun.shape[-2:])
    out = jnp.zeros(kun.shape[:-4] + (mu_tiles, kun.shape[-2]), kun.dtype)
    for level in plan.levels:
        for bt in level:
            out = add(out, bt.out, mv(take(kflat, bt.a), take(yc, bt.b)))
    return out


def staged_launch_count(
    m_tiles: int, *, uncertainty: bool = False, n_streams: Optional[int] = None
) -> int:
    """Batched launches the *staged* pipeline issues end-to-end.

    One covariance assembly + the factorization plan + both vector-solve
    plans + cross assembly + mean matvec; with uncertainty also the prior
    assembly, the B-tile transpose pack, the matrix forward-solve plan, the
    gram einsum and the prior - W subtraction.  The fused program plan must
    beat this strictly for M >= 8 (tests/test_executor.py).
    """
    n = 1 + cholesky_plan(m_tiles, n_streams).n_batches
    n += solve_plan(m_tiles, lower=True, n_streams=n_streams).n_batches
    n += solve_plan(m_tiles, lower=False, n_streams=n_streams).n_batches
    n += 1 + 1  # cross assembly, mean matvec
    if uncertainty:
        n += 1 + 1  # prior assembly, B-tile transpose pack
        n += solve_plan(m_tiles, lower=True, n_streams=n_streams).n_batches
        n += 1 + 1  # gram, prior - W subtraction
    return n


def _params_concrete(params) -> bool:
    """True iff the hyperparameters are concrete (not traced) leaves.

    The Pallas assembly kernels bake hyperparameters in as compile-time
    constants, which is impossible inside a gradient trace; callers use this
    to fall back to the differentiable jnp assembly tile (DESIGN.md §8).
    """
    return km.params_concrete(params)


def _cov_batch_fn(
    backend: str, params, nvr: int, nvc: int, symmetric: bool, kernel=None
):
    """Batched covariance-tile assembly: (G,m,D) x (G,m,D) -> (G,m,m).

    ``kernel`` picks the registered covariance family (None -> the paper's
    SE).  ``backend="pallas"`` requires concrete hyperparameters (they are
    baked into the kernel); under a gradient trace the params are tracers,
    so the differentiable jnp tile kernel is used instead — assembly is
    O(n^2), cheap relative to the O(n^3) tile BLAS which stays on Pallas.
    """
    kernel = km.resolve_kernel(kernel)
    if backend == "pallas" and _params_concrete(params):
        from repro.kernels import cov_assembly as cova
        from repro.kernels import ops as kops

        def pallas_fn(xa, xb, row0, col0):
            return cova.cov_tiles(
                xa,
                xb,
                row0,
                col0,
                kernel=kernel,
                params=params,
                n_valid_r=nvr,
                n_valid_c=nvc,
                symmetric=symmetric,
                interpret=kops._interpret(),
            )

        return pallas_fn

    def jnp_fn(xa, xb, row0, col0):
        f = lambda a, b, r, c: km.cov_tile(
            a, b, r, c, params, nvr, nvc, symmetric, kernel=kernel
        )
        return jax.vmap(f)(xa, xb, row0, col0)

    return jnp_fn


def _params_per_problem(params, kernel=None) -> bool:
    """True iff any hyperparameter leaf carries a problem-batch axis (B, ...)."""
    return km.params_per_problem(params, kernel)


def _cov_batch_fn_batched(
    backend: str, params, nvr, nvc, symmetric: bool, kernel=None
):
    """Problem-batched assembly: (B,G,m,D) x (B,G,m,D) -> (B,G,m,m).

    Shared hyperparameters (scalar leaves) flatten B into the single
    launch's batch axis and reuse :func:`_cov_batch_fn` (Pallas grid absorbs
    B).  Per-problem hyperparameters (leaves of shape (B,)) vmap the jnp
    tile kernel over the problem axis — the Pallas assembly kernel bakes
    hyperparameters in as compile-time constants, so it cannot vary them
    across the batch; assembly is O(n^2), cheap next to the tile BLAS.

    **Ragged batches (DESIGN.md §11):** ``nvr``/``nvc`` may be (B,) arrays
    of per-problem validity frontiers instead of one shared scalar.  On the
    jnp tile path the frontiers simply join the problem-axis vmap; on the
    Pallas path (concrete shared params) the (B,) frontiers expand to
    per-tile (B*G,) i32 operands and B problems of different valid sizes
    still share ONE flat kernel launch.
    """
    kernel = km.resolve_kernel(kernel)
    ragged = jnp.ndim(nvr) > 0 or jnp.ndim(nvc) > 0
    pallas_ok = backend == "pallas" and _params_concrete(params)
    if _params_per_problem(params, kernel) or (ragged and not pallas_ok):

        def per_problem(xa, xb, row0, col0):
            # mixed scalar/(B,) leaves are legal — normalize before the vmap
            b = xa.shape[0]
            pb = km.broadcast_params(params, b, kernel)
            nvr_b = jnp.broadcast_to(jnp.asarray(nvr), (b,))
            nvc_b = jnp.broadcast_to(jnp.asarray(nvc), (b,))

            def one(xa1, xb1, p, nr, nc):
                f = lambda a, b, r, c: km.cov_tile(
                    a, b, r, c, p, nr, nc, symmetric, kernel=kernel
                )
                return jax.vmap(f)(xa1, xb1, row0, col0)

            return jax.vmap(one, in_axes=(0, 0, 0, 0, 0))(xa, xb, pb, nvr_b, nvc_b)

        return per_problem

    if ragged:
        # concrete shared params on Pallas: per-problem frontiers become
        # per-tile (1,)-block operands of the ONE flattened launch.
        from repro.kernels import cov_assembly as cova
        from repro.kernels import ops as kops

        def flat_ragged(xa, xb, row0, col0):
            b, g = xa.shape[:2]
            nvr_t = jnp.repeat(jnp.broadcast_to(jnp.asarray(nvr), (b,)), g)
            nvc_t = jnp.repeat(jnp.broadcast_to(jnp.asarray(nvc), (b,)), g)
            out = cova.cov_tiles(
                xa.reshape((b * g,) + xa.shape[2:]),
                xb.reshape((b * g,) + xb.shape[2:]),
                jnp.tile(row0, b),
                jnp.tile(col0, b),
                kernel=kernel,
                params=params,
                n_valid_r=nvr_t,
                n_valid_c=nvc_t,
                symmetric=symmetric,
                interpret=kops._interpret(),
            )
            return out.reshape((b, g) + out.shape[1:])

        return flat_ragged

    single = _cov_batch_fn(backend, params, nvr, nvc, symmetric, kernel)

    def flat(xa, xb, row0, col0):
        b, g = xa.shape[:2]
        out = single(
            xa.reshape((b * g,) + xa.shape[2:]),
            xb.reshape((b * g,) + xb.shape[2:]),
            jnp.tile(row0, b),
            jnp.tile(col0, b),
        )
        return out.reshape((b, g) + out.shape[1:])

    return flat


def run_program(
    xc: jax.Array,
    yc: jax.Array,
    xtc: jax.Array,
    params,
    n_valid,
    nt_valid,
    *,
    uncertainty: bool = False,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    batch_dispatch: str = "flat",
    mesh=None,
    kernel=None,
):
    """Execute the fused prediction pipeline as one multi-stage program.

    xc (M, m, D) / yc (M, m) / xtc (Q, m, D) are the padded feature and
    target blocks; ``n_valid`` / ``nt_valid`` the unpadded row counts.
    Returns the final buffer environment (see module section docstring):
    ``env["mean"]`` holds the predictive-mean chunks, ``env["prior"]`` the
    posterior-covariance tiles (uncertainty only), and ``env["packed"]`` /
    ``env["alpha"]`` the factor/weights slices a PosteriorState caches.

    **Problem batching:** with xc (B, M, m, D) / yc (B, M, m) /
    xtc (B, Q, m, D) — B independent problems of identical tile geometry —
    every env buffer gains the leading B axis and the SAME lru-cached Plan
    drives all of them: identical launch count, each launch B times wider
    (DESIGN.md §9).  Hyperparameters may be shared (scalar leaves) or
    per-problem (leaves of shape (B,)).  ``batch_dispatch`` picks how the
    tile kernels absorb B: ``"flat"`` folds it into the launch's batch/grid
    axis, ``"vmap"`` nests one more vmap level.

    **Ragged batches:** ``n_valid``/``nt_valid`` may also be (B,) arrays of
    per-problem row counts (or traced scalars) — problems of *different*
    valid sizes share the bucket's tile geometry, the same Plan, and the
    same jit trace; only the masked assembly sees the frontiers
    (DESIGN.md §11).

    **Sharded batches (DESIGN.md §12):** with a ``mesh``, every B-leading
    buffer — the inputs and all named env buffers — is pinned to the fleet
    layout (B over the mesh's DP axes, tiles replicated per problem) via
    ``with_sharding_constraint``.  Problems are independent, so GSPMD
    partitions every launch along B with zero collectives.  The mesh never
    reaches :func:`program_plan` — Plans stay shard-invariant.

    **Kernel zoo (DESIGN.md §13):** ``kernel`` picks the covariance family
    (None -> the paper's SE).  Only the ASSEMBLE/CROSS/PRIOR op payloads
    change; the kernel never reaches :func:`program_plan` either — Plans
    stay kernel-invariant and are reused across kernels.
    """
    batched = xc.ndim == 4
    m_tiles, m = xc.shape[-3], xc.shape[-2]
    q_tiles = xtc.shape[-3]
    plan = program_plan(m_tiles, q_tiles, uncertainty, n_streams)
    if obs.enabled():
        if isinstance(xc, jax.core.Tracer):
            obs.inc("executor.traces.run_program")
        else:
            record_dispatch("run_program", plan, backend=backend, batched=batched)
    dtype = xc.dtype
    lead = (xc.shape[0],) if batched else ()
    take, put, add = _env_ops(batched)
    Z = "z" if batched else ""  # einsum prefix for the problem-batch axis
    shard = _fleet_shard(mesh, batched)
    xc, yc, xtc = shard(xc), shard(yc), shard(xtc)

    potrf, trsm, _, gemm = get_ops(backend)
    potrf_b = _tile_dispatch(potrf, batched, batch_dispatch)
    trsm_b = _tile_dispatch(trsm, batched, batch_dispatch)
    trail_b = _tile_dispatch(
        functools.partial(gemm, update_dtype=update_dtype), batched, batch_dispatch
    )
    cov_fn = _cov_batch_fn_batched if batched else _cov_batch_fn
    asm = cov_fn(backend, params, n_valid, n_valid, True, kernel)
    crossf = cov_fn(backend, params, nt_valid, n_valid, False, kernel)
    priorf = cov_fn(backend, params, nt_valid, nt_valid, False, kernel)

    env = {
        "packed": shard(
            jnp.zeros(lead + (tiling.num_packed_tiles(m_tiles), m, m), dtype)
        ),
        "y": yc,
        "alpha": shard(jnp.zeros_like(yc)),
        "cross": shard(jnp.zeros(lead + (q_tiles * m_tiles, m, m), dtype)),
        "mean": shard(jnp.zeros(lead + (q_tiles, m), dtype)),
    }
    if uncertainty:
        env["v"] = shard(jnp.zeros(lead + (m_tiles, q_tiles, m, m), dtype))
        env["prior"] = shard(jnp.zeros(lead + (q_tiles * q_tiles, m, m), dtype))

    def off(idx):  # tile index -> global row/col offset, i32 on device
        return jnp.asarray(idx * m, jnp.int32)

    def cross_grid():  # cross buffer viewed as the (..., Q, M, m, m) tile grid
        return env["cross"].reshape(lead + (q_tiles, m_tiles, m, m))

    for level in plan.levels:
        for bt in level:
            op, packed = bt.op, env["packed"]
            if op == sch.ASSEMBLE:
                tiles = asm(take(xc, bt.a), take(xc, bt.b), off(bt.a), off(bt.b))
                env["packed"] = put(packed, bt.out, tiles)
            elif op == sch.CROSS:
                tiles = crossf(take(xtc, bt.a), take(xc, bt.b), off(bt.a), off(bt.b))
                env["cross"] = put(env["cross"], bt.out, tiles)
            elif op == sch.PRIOR:
                tiles = priorf(take(xtc, bt.a), take(xtc, bt.b), off(bt.a), off(bt.b))
                env["prior"] = put(env["prior"], bt.out, tiles)
            elif op == sch.POTRF:
                env["packed"] = put(packed, bt.out, potrf_b(take(packed, bt.a)))
            elif op == sch.TRSM:
                env["packed"] = put(
                    packed, bt.out, trsm_b(take(packed, bt.a), take(packed, bt.b))
                )
            elif op == TRAIL:
                env["packed"] = put(
                    packed,
                    bt.out,
                    trail_b(take(packed, bt.a), take(packed, bt.b), take(packed, bt.c)),
                )
            elif op == sch.TRSV:
                sol = _trsv_batch(take(packed, bt.a), take(env["y"], bt.out), False)
                env["y"] = put(env["y"], bt.out, sol)
                # publish the solved row into the backward pass's buffer
                env["alpha"] = put(env["alpha"], bt.out, sol)
            elif op == sch.GEMV:
                upd = jnp.einsum(
                    f"{Z}gab,{Z}gb->{Z}ga", take(packed, bt.a), take(env["y"], bt.b)
                )
                env["y"] = add(env["y"], bt.out, -upd.astype(dtype))
            elif op == sch.TRSV_B:
                sol = _trsv_batch(take(packed, bt.a), take(env["alpha"], bt.out), True)
                env["alpha"] = put(env["alpha"], bt.out, sol)
            elif op == sch.GEMV_B:
                upd = jnp.einsum(
                    f"{Z}gba,{Z}gb->{Z}ga", take(packed, bt.a), take(env["alpha"], bt.b)
                )
                env["alpha"] = add(env["alpha"], bt.out, -upd.astype(dtype))
            elif op == sch.XGEMV:
                rows = take(cross_grid(), bt.out)
                env["mean"] = put(
                    env["mean"],
                    bt.out,
                    jnp.einsum(f"{Z}gqab,{Z}qb->{Z}ga", rows, env["alpha"]),
                )
            elif op == sch.VINIT:
                if batched:
                    cols = cross_grid()[:, :, bt.out]      # (B, Q, G, m, m)
                    vrows = cols.transpose(0, 2, 1, 4, 3)  # (B, G, Q, m, m)
                else:
                    cols = cross_grid()[:, bt.out]         # (Q, G, m, m)
                    vrows = cols.transpose(1, 0, 3, 2)     # (G, Q, m, m)
                env["v"] = put(env["v"], bt.out, vrows)
            elif op == sch.VTRSV:
                sol = _trsv_batch(take(packed, bt.a), take(env["v"], bt.out), False)
                env["v"] = put(env["v"], bt.out, sol)
            elif op == sch.VGEMV:
                upd = jnp.einsum(
                    f"{Z}gab,{Z}gqbc->{Z}gqac", take(packed, bt.a), take(env["v"], bt.b)
                )
                env["v"] = add(env["v"], bt.out, -upd.astype(dtype))
            elif op == sch.GRAM:
                w = jnp.einsum(f"{Z}ipab,{Z}iqac->{Z}pqbc", env["v"], env["v"])
                env["prior"] = env["prior"] - w.reshape(
                    lead + (q_tiles * q_tiles, m, m)
                )
            else:
                raise ValueError(op)
    return env


def run_solve(
    lpacked: jax.Array,
    rhs: jax.Array,
    *,
    lower: bool = True,
    n_streams: Optional[int] = None,
) -> jax.Array:
    """Level-batched triangular solve on the packed factor.

    rhs: (M, m) vector chunks or (M, Q, m, mq) matrix tile rows; solved in
    place (functionally).  ``lower=True`` solves L x = rhs, else L^T x = rhs
    (reading the stored lower tiles transposed).  Unlike the old per-row
    loops there is no O(M) restacking: the rhs stays one array and every
    level is a single gather/einsum/scatter.

    With lpacked (B, T, m, m) and rhs (B, M, m) / (B, M, Q, m, mq) the same
    Plan solves B independent systems at once (DESIGN.md §9).
    """
    batched = lpacked.ndim == 4
    take, put, add = _env_ops(batched)
    m_tiles = rhs.shape[1] if batched else rhs.shape[0]
    if tiling.num_packed_tiles(m_tiles) != lpacked.shape[-3]:
        raise ValueError(
            f"rhs rows {m_tiles} inconsistent with packed store {lpacked.shape}"
        )
    plan = solve_plan(m_tiles, lower=lower, n_streams=n_streams)
    transpose = not lower
    matrix = rhs.ndim == (5 if batched else 4)
    Z = "z" if batched else ""
    if matrix:
        ein = f"{Z}gba,{Z}gqbc->{Z}gqac" if transpose else f"{Z}gab,{Z}gqbc->{Z}gqac"
    else:
        ein = f"{Z}gba,{Z}gb->{Z}ga" if transpose else f"{Z}gab,{Z}gb->{Z}ga"
    for level in plan.levels:
        for bt in level:
            if bt.op == sch.TRSV:
                sol = _trsv_batch(take(lpacked, bt.a), take(rhs, bt.out), transpose)
                rhs = put(rhs, bt.out, sol)
            else:
                upd = jnp.einsum(ein, take(lpacked, bt.a), take(rhs, bt.b))
                rhs = add(rhs, bt.out, -upd.astype(rhs.dtype))
    return rhs


# ---------------------------------------------------------------------------
# Streaming updates (DESIGN.md §10): block Cholesky append / rank update.
#
# The append plan's buffer environment:
#   "packed" (T_store, m, m)  the frozen existing factor (read-only)
#   "row"    (R + 1, m, m)    the appended tile-row; slot R is the corner
# plus the read-only feature chunks xc and the new row chunk x_row.  The
# rank-update plan's environment:
#   "packed" (T', m, m)       the factor, rewritten column by column
#   "w"      (M', m, m)       the rank-b carry blocks
#   "xaux/yaux/caux" (M', m, m)  per-column X / Y / C auxiliaries
# All buffers accept the optional leading problem-batch axis B (§9).
# ---------------------------------------------------------------------------


def _append_batch(
    op: str, tasks: Sequence[sch.Task], r_tiles: int, m_store: int
) -> Batch:
    """Gather/scatter indices of one append batch.

    The packed store may hold ``m_store`` tile-rows with ``m_store >
    r_tiles`` (refilling a partially padded trailing row reads only the
    frozen prefix rows < R but indexes slots of the full store).
    """
    slot = tiling.packed_index
    tasks = tuple(tasks)
    if op in (sch.UASM, sch.UASMD):
        cols = _arr([i for _, i, _, _ in tasks])
        return Batch(op, tasks, out=cols, a=cols)
    if op == sch.UTRSM:
        rows = _arr([i for _, i, _, _ in tasks])
        diag = _arr([slot(i, i, m_store) for _, i, _, _ in tasks])
        return Batch(op, tasks, out=rows, a=diag, b=rows)
    if op == sch.UGEMM:  # row_i -= row_j L(i,j)^T
        tgt = _arr([i for _, i, _, _ in tasks])
        src = _arr([j for _, _, j, _ in tasks])
        til = _arr([slot(i, j, m_store) for _, i, j, _ in tasks])
        return Batch(op, tasks, out=tgt, a=tgt, b=src, c=til)
    if op == sch.USYRK:  # corner -= row_i row_i^T
        tgt = _arr([r_tiles] * len(tasks))
        panel = _arr([i for _, i, _, _ in tasks])
        return Batch(op, tasks, out=tgt, a=tgt, b=panel)
    if op == sch.UPOTRF:
        d = _arr([r_tiles])
        return Batch(op, tasks, out=d, a=d)
    raise ValueError(op)


@functools.lru_cache(maxsize=None)
def update_append_plan(
    r_tiles: int, m_store: int, n_streams: Optional[int] = None
) -> Plan:
    """Compile the one-tile-row append DAG into batched launches."""
    if n_streams is None:
        schedule = sch.build_update_schedule(r_tiles, kind="update_append")
    else:
        schedule = sch.build_wavefront_schedule(
            r_tiles, n_streams, kind="update_append"
        )
    levels = []
    for level in schedule.levels:
        batches = []
        for op, tasks in sch.split_by_op(level).items():
            width = None if op in sch.BULK_OPS else n_streams
            for chunk in sch.chunk_tasks(tasks, width):
                batches.append(_append_batch(op, chunk, r_tiles, m_store))
        levels.append(tuple(batches))
    return Plan("update_append", r_tiles, n_streams, tuple(levels))


def run_append(
    lpacked: jax.Array,
    xc: jax.Array,
    x_row: jax.Array,
    params,
    r_tiles: int,
    n_valid_new,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    batch_dispatch: str = "flat",
    mesh=None,
    kernel=None,
) -> jax.Array:
    """Solve one appended tile-row against the frozen factor (DESIGN.md §10).

    lpacked: the existing packed factor, (T_store, m, m) or (B, T_store,
    m, m); xc the matching padded feature chunks; x_row (m, D) / (B, m, D)
    the (padded) chunk of the appended row; ``r_tiles`` the number of frozen
    prefix rows the new row is solved against (``r_tiles == m_store`` grows
    the factor; ``r_tiles < m_store`` recomputes tile-row ``r_tiles`` of the
    store in place — the trailing partially padded row in the scalar case,
    or ANY interior row of a ragged batch's sweep, see
    ``update.extend_state_ragged``).  ``n_valid_new`` is the total valid
    observation count *after* the append — a scalar, a traced scalar, or a
    (B,) per-problem array for ragged batches.  For problems whose frontier
    lies at or below ``r_tiles * m`` the masked assembly degenerates to
    identity/zero tiles and the recomputed row reproduces the padding
    contract exactly (the refill is idempotent).

    Returns the row buffer (R + 1, m, m): the R solved off-diagonal tiles
    followed by the factored corner.  The caller scatters it into a grown
    or refilled packed store (tiling.grow_packed_indices /
    tiling.replace_row_indices).
    """
    batched = xc.ndim == 4
    m_store = xc.shape[-3]
    m = xc.shape[-2]
    if not 0 <= r_tiles <= m_store:
        raise ValueError(
            f"r_tiles must be in [0, m_store] = [0, {m_store}] "
            f"(m_store grows, less refills a row in place); got {r_tiles}"
        )
    if tiling.num_packed_tiles(m_store) != lpacked.shape[-3]:
        raise ValueError(
            f"feature chunks ({m_store} tiles) inconsistent with packed "
            f"store {lpacked.shape}"
        )
    plan = update_append_plan(r_tiles, m_store, n_streams)
    if obs.enabled():
        if isinstance(lpacked, jax.core.Tracer):
            obs.inc("executor.traces.run_append")
        else:
            record_dispatch("run_append", plan, backend=backend, batched=batched)
    take, put, _ = _env_ops(batched)
    lead = (xc.shape[0],) if batched else ()
    dtype = lpacked.dtype
    shard = _fleet_shard(mesh, batched)
    lpacked, xc, x_row = shard(lpacked), shard(xc), shard(x_row)

    potrf, trsm, syrk, gemm = get_ops(backend)
    potrf_b = _tile_dispatch(potrf, batched, batch_dispatch)
    trsm_b = _tile_dispatch(trsm, batched, batch_dispatch)
    syrk_b = _tile_dispatch(
        functools.partial(syrk, update_dtype=update_dtype), batched, batch_dispatch
    )
    gemm_b = _tile_dispatch(
        functools.partial(gemm, update_dtype=update_dtype), batched, batch_dispatch
    )
    cov_fn = _cov_batch_fn_batched if batched else _cov_batch_fn
    # both axes mask at n_valid_new: prefix columns past a problem's
    # frontier (possible only in the ragged sweep) zero out, and for the
    # scalar callers every prefix column < r_tiles*m <= n_valid_new is
    # valid anyway — identical to the old r_tiles*m column mask.
    crossf = cov_fn(backend, params, n_valid_new, n_valid_new, False, kernel)
    diagf = cov_fn(backend, params, n_valid_new, n_valid_new, True, kernel)

    row = shard(jnp.zeros(lead + (r_tiles + 1, m, m), dtype))
    row0 = r_tiles * m

    def bcast_row(g):  # the row chunk, repeated for each gathered tile
        if batched:
            return jnp.broadcast_to(x_row[:, None], lead + (g,) + x_row.shape[1:])
        return jnp.broadcast_to(x_row[None], (g,) + x_row.shape)

    def off(idx):
        return jnp.asarray(idx * m, jnp.int32)

    for level in plan.levels:
        for bt in level:
            if bt.op == sch.UASM:
                tiles = crossf(
                    bcast_row(bt.size), take(xc, bt.a),
                    jnp.full((bt.size,), row0, jnp.int32), off(bt.a),
                )
                row = put(row, bt.out, tiles)
            elif bt.op == sch.UASMD:
                tiles = diagf(
                    bcast_row(1), bcast_row(1),
                    jnp.full((1,), row0, jnp.int32),
                    jnp.full((1,), row0, jnp.int32),
                )
                row = put(row, bt.out, tiles)
            elif bt.op == sch.UTRSM:
                row = put(
                    row, bt.out, trsm_b(take(lpacked, bt.a), take(row, bt.b))
                )
            elif bt.op == sch.UGEMM:
                row = put(
                    row,
                    bt.out,
                    gemm_b(take(row, bt.a), take(row, bt.b), take(lpacked, bt.c)),
                )
            elif bt.op == sch.USYRK:
                row = put(
                    row, bt.out, syrk_b(take(row, bt.a), take(row, bt.b))
                )
            elif bt.op == sch.UPOTRF:
                row = put(row, bt.out, potrf_b(take(row, bt.a)))
            else:
                raise ValueError(bt.op)
    return row


# -- rank-b up/downdate ------------------------------------------------------


def _rank_batch(op: str, tasks: Sequence[sch.Task], m: int) -> Batch:
    """Gather/scatter indices of one rank-update batch."""
    slot = tiling.packed_index
    tasks = tuple(tasks)
    if op == sch.UPREP:
        rows = _arr([i for _, i, _, _ in tasks])
        diag = _arr([slot(i, i, m) for _, i, _, _ in tasks])
        return Batch(op, tasks, out=rows, a=diag)
    if op == sch.UPROW:  # L'(i,j) = L(i,j) X_j^T + s W_i Y_j^T
        tgt = _arr([slot(i, j, m) for _, i, j, _ in tasks])
        wrows = _arr([i for _, i, _, _ in tasks])
        cols = _arr([j for _, _, j, _ in tasks])
        return Batch(op, tasks, out=tgt, a=tgt, b=wrows, c=cols)
    if op == sch.UCARRY:  # W_i <- (W_i - L'(i,j) Y_j) C_j^{-T}
        wrows = _arr([i for _, i, _, _ in tasks])
        til = _arr([slot(i, j, m) for _, i, j, _ in tasks])
        cols = _arr([j for _, _, j, _ in tasks])
        return Batch(op, tasks, out=wrows, a=til, b=wrows, c=cols)
    raise ValueError(op)


@functools.lru_cache(maxsize=None)
def update_rank_plan(m_tiles: int, n_streams: Optional[int] = None) -> Plan:
    """Compile the blocked cholupdate sweep into batched launches."""
    if n_streams is None:
        schedule = sch.build_update_schedule(m_tiles, kind="update_rank")
    else:
        schedule = sch.build_wavefront_schedule(
            m_tiles, n_streams, kind="update_rank"
        )
    return _compile(schedule, n_streams, _rank_batch)


def get_update_ops(backend: str, sign: float):
    """(uprep, uprow, ucarry) per-tile ops of the rank-update sweep.

    ``sign=+1.0``: L' L'^T = L L^T + W W^T (eviction of a leading window is
    a *positive* update of the trailing factor).  ``sign=-1.0``: the true
    hyperbolic downdate L L^T - W W^T; its Cholesky heads go NaN when the
    downdated matrix is not positive definite — callers check and fall back
    to a full refactorization (repro.core.update).
    """
    if backend == "pallas":
        from repro.kernels import ops as kops

        potrf_tile = kops.potrf
        carry = kops.carry_update
    elif backend == "jnp":
        potrf_tile = _potrf_jnp

        def carry(wi, lij, yj, cj):
            b = wi - lij @ yj
            return jax.lax.linalg.triangular_solve(
                cj, b, left_side=False, lower=True, transpose_a=True
            )
    else:
        raise ValueError(f"unknown backend: {backend}")

    def uprep(ljj, wj):
        d = ljj @ ljj.T + sign * (wj @ wj.T)
        lnew = potrf_tile(d)
        x = jax.lax.linalg.triangular_solve(lnew, ljj, left_side=True, lower=True)
        y = jax.lax.linalg.triangular_solve(lnew, wj, left_side=True, lower=True)
        eye = jnp.eye(ljj.shape[-1], dtype=ljj.dtype)
        c = potrf_tile(eye - sign * (y.T @ y))
        return lnew, x, y, c

    def uprow(lij, wi, xj, yj):
        return (lij @ xj.T + sign * (wi @ yj.T)).astype(lij.dtype)

    return uprep, uprow, carry


def run_rank_update(
    lpacked: jax.Array,
    w: jax.Array,
    *,
    sign: float = 1.0,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    batch_dispatch: str = "flat",
    mesh=None,
) -> Tuple[jax.Array, jax.Array]:
    """Blocked rank-b up/downdate: L' L'^T = L L^T + sign * W W^T.

    lpacked (T, m, m) packed factor; w (M, m, m) carry blocks (one per
    tile-row; unused trailing columns of a rank-b < m carry must be zero —
    they propagate zeros through Y and keep C identity there).  Optional
    leading problem-batch axis B on both (§9).  Returns (new factor, final
    carry).  NaNs in the new factor signal a failed (non-PD) downdate.
    """
    batched = lpacked.ndim == 4
    take, put, _ = _env_ops(batched)
    m_tiles = w.shape[1] if batched else w.shape[0]
    if tiling.num_packed_tiles(m_tiles) != lpacked.shape[-3]:
        raise ValueError(
            f"carry rows {m_tiles} inconsistent with packed store {lpacked.shape}"
        )
    m = lpacked.shape[-1]
    lead = (lpacked.shape[0],) if batched else ()
    plan = update_rank_plan(m_tiles, n_streams)
    if obs.enabled():
        if isinstance(lpacked, jax.core.Tracer):
            obs.inc("executor.traces.run_rank_update")
        else:
            record_dispatch(
                "run_rank_update", plan, backend=backend, batched=batched
            )
    uprep, uprow, ucarry = get_update_ops(backend, sign)
    uprep_b = _tile_dispatch(uprep, batched, batch_dispatch)
    uprow_b = _tile_dispatch(uprow, batched, batch_dispatch)
    ucarry_b = _tile_dispatch(ucarry, batched, batch_dispatch)

    shard = _fleet_shard(mesh, batched)
    lpacked, w = shard(lpacked), shard(w)
    xaux = shard(jnp.zeros(lead + (m_tiles, m, m), lpacked.dtype))
    yaux = shard(jnp.zeros_like(xaux))
    caux = shard(jnp.zeros_like(xaux))
    for level in plan.levels:
        for bt in level:
            if bt.op == sch.UPREP:
                lnew, x, y, c = uprep_b(take(lpacked, bt.a), take(w, bt.out))
                lpacked = put(lpacked, bt.a, lnew)
                xaux = put(xaux, bt.out, x)
                yaux = put(yaux, bt.out, y)
                caux = put(caux, bt.out, c)
            elif bt.op == sch.UPROW:
                lpacked = put(
                    lpacked,
                    bt.out,
                    uprow_b(
                        take(lpacked, bt.a), take(w, bt.b),
                        take(xaux, bt.c), take(yaux, bt.c),
                    ),
                )
            elif bt.op == sch.UCARRY:
                w = put(
                    w,
                    bt.out,
                    ucarry_b(
                        take(w, bt.b), take(lpacked, bt.a),
                        take(yaux, bt.c), take(caux, bt.c),
                    ).astype(w.dtype),
                )
            else:
                raise ValueError(bt.op)
    return lpacked, w


# Expose every plan cache to obs.cache_stats() — plan-invariance regressions
# (a cache that grows per call instead of per geometry) become visible at
# runtime, not just in tests (DESIGN.md §15).
obs.register_cache("executor.cholesky_plan", cholesky_plan)
obs.register_cache("executor.solve_plan", solve_plan)
obs.register_cache("executor.program_plan", program_plan)
obs.register_cache("executor.lowrank_plan", lowrank_plan)
obs.register_cache("executor.update_append_plan", update_append_plan)
obs.register_cache("executor.update_rank_plan", update_rank_plan)
