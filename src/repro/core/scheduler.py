"""Tile-task DAG scheduler — the JAX-side analogue of HPX ``hpx::dataflow``.

The paper expresses the tiled Cholesky as a dataflow graph: each tile is
wrapped in an ``hpx::shared_future`` and POTRF/TRSM/SYRK/GEMM tasks fire as
their inputs become ready, spread round-robin over a pool of CUDA streams.

On TPU there is no runtime task graph — the graph must be *static*.  This
module builds the same DAG at trace time and derives:

* ``levels`` — an ASAP (as-soon-as-possible) level schedule: level k holds all
  tasks whose longest dependency chain has length k.  All tasks inside one
  level are independent, which is exactly the set HPX would have in flight
  concurrently with unlimited streams.
* ``chunk(level, n_streams)`` — splits a level into round-robin chunks of at
  most ``n_streams`` tasks; the executor issues one *batched* kernel per chunk.
  ``n_streams=1`` reproduces fully sequential per-task execution (the paper's
  single-stream case); ``n_streams=None`` batches the entire level (the
  TPU-native limit).

The schedule is consumed by :mod:`repro.core.cholesky`; it is also unit-tested
directly (task counts, dependency sanity, critical path length).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Task encodings: (op, i, j, k).  k is only used by GEMM.
POTRF = "potrf"
TRSM = "trsm"
SYRK = "syrk"
GEMM = "gemm"

Task = Tuple[str, int, int, int]


def _deps(task: Task, m_tiles: int) -> List[Task]:
    """Direct dependencies of a task in the right-looking tiled Cholesky.

    Matches the paper's Fig. 1 loop nest:
      POTRF(J,J)   needs SYRK(J,J) of step J-1            (last writer of (J,J))
      TRSM(I,J)    needs POTRF(J,J) and GEMM(I,J) of step J-1 (last writer of (I,J))
      SYRK(I,I)@J  needs TRSM(I,J) and SYRK(I,I) of step J-1
      GEMM(I,K)@J  needs TRSM(I,J), TRSM(K,J) and GEMM(I,K) of step J-1
    """
    op, i, j, k = task
    deps: List[Task] = []
    if op == POTRF:
        # last update of tile (j, j) was SYRK at step j-1
        if j > 0:
            deps.append((SYRK, j, j - 1, -1))
    elif op == TRSM:
        deps.append((POTRF, j, j, -1))
        if j > 0:
            deps.append((GEMM, i, j - 1, j))  # last writer of (i, j): GEMM(I=i, K=j) at step j-1
    elif op == SYRK:
        # SYRK at step j updates tile (i, i) using panel tile (i, j)
        deps.append((TRSM, i, j, -1))
        if j > 0:
            deps.append((SYRK, i, j - 1, -1))
    elif op == GEMM:
        # GEMM at step j updates tile (i, k) using panel tiles (i, j), (k, j)
        deps.append((TRSM, i, j, -1))
        deps.append((TRSM, k, j, -1))
        if j > 0:
            deps.append((GEMM, i, j - 1, k))
    else:
        raise ValueError(op)
    return deps


def all_tasks(m_tiles: int) -> List[Task]:
    """Every task of the factorization, in the paper's Fig. 1 program order."""
    tasks: List[Task] = []
    for j in range(m_tiles):
        tasks.append((POTRF, j, j, -1))
        for i in range(j + 1, m_tiles):
            tasks.append((TRSM, i, j, -1))
        for i in range(j + 1, m_tiles):
            tasks.append((SYRK, i, j, -1))
            for k in range(j + 1, i):
                tasks.append((GEMM, i, j, k))
    return tasks


@dataclasses.dataclass(frozen=True)
class Schedule:
    m_tiles: int
    levels: Tuple[Tuple[Task, ...], ...]

    @property
    def critical_path(self) -> int:
        return len(self.levels)

    @property
    def n_tasks(self) -> int:
        return sum(len(l) for l in self.levels)

    def max_width(self) -> int:
        return max(len(l) for l in self.levels)

    def op_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {POTRF: 0, TRSM: 0, SYRK: 0, GEMM: 0}
        for level in self.levels:
            for t in level:
                counts[t[0]] += 1
        return counts


def build_schedule(m_tiles: int) -> Schedule:
    """ASAP level schedule of the tiled Cholesky DAG."""
    tasks = all_tasks(m_tiles)
    level_of: Dict[Task, int] = {}
    for t in tasks:  # program order is a valid topological order
        deps = _deps(t, m_tiles)
        level_of[t] = 0 if not deps else 1 + max(level_of[d] for d in deps)
    n_levels = 1 + max(level_of.values()) if level_of else 0
    levels: List[List[Task]] = [[] for _ in range(n_levels)]
    for t in tasks:
        levels[level_of[t]].append(t)
    return Schedule(m_tiles=m_tiles, levels=tuple(tuple(l) for l in levels))


def chunk_tasks(
    tasks: Sequence[Task], n_streams: Optional[int]
) -> List[List[Task]]:
    """Round-robin chunking of one level into groups of <= n_streams tasks.

    The paper assigns tasks to a stream pool round-robin; a chunk here is the
    set of tasks that would be resident on the pool simultaneously, which we
    execute as a single batched kernel call.
    """
    tasks = list(tasks)
    if n_streams is None or n_streams >= len(tasks):
        return [tasks] if tasks else []
    return [tasks[i : i + n_streams] for i in range(0, len(tasks), n_streams)]


def split_by_op(tasks: Iterable[Task]) -> Dict[str, List[Task]]:
    out: Dict[str, List[Task]] = {}
    for t in tasks:
        out.setdefault(t[0], []).append(t)
    return out


def theoretical_task_counts(m_tiles: int) -> Dict[str, int]:
    m = m_tiles
    return {
        POTRF: m,
        TRSM: m * (m - 1) // 2,
        SYRK: m * (m - 1) // 2,
        GEMM: m * (m - 1) * (m - 2) // 6,
    }
