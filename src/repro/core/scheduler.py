"""Tile-task DAG scheduler — the JAX-side analogue of HPX ``hpx::dataflow``.

The paper expresses the tiled Cholesky as a dataflow graph: each tile is
wrapped in an ``hpx::shared_future`` and POTRF/TRSM/SYRK/GEMM tasks fire as
their inputs become ready, spread round-robin over a pool of CUDA streams.

On TPU there is no runtime task graph — the graph must be *static*.  This
module builds the same DAG at trace time and derives:

* ``levels`` — an ASAP (as-soon-as-possible) level schedule: level k holds all
  tasks whose longest dependency chain has length k.  All tasks inside one
  level are independent, which is exactly the set HPX would have in flight
  concurrently with unlimited streams.
* ``chunk(level, n_streams)`` — splits a level into round-robin chunks of at
  most ``n_streams`` tasks; the executor issues one *batched* kernel per chunk.
  ``n_streams=1`` reproduces fully sequential per-task execution (the paper's
  single-stream case); ``n_streams=None`` batches the entire level (the
  TPU-native limit).

The schedule is compiled into gather/compute/scatter batches by
:mod:`repro.core.executor` and consumed through :mod:`repro.core.cholesky`
(``tiled_cholesky``) and :mod:`repro.core.triangular`
(the solve DAGs below); it is also unit-tested directly (task counts,
dependency sanity, critical path length).  See DESIGN.md §3.

Besides the factorization DAG this module also builds the dataflow graphs of
the triangular solves (forward substitution ``L b = y``, backward
substitution ``L^T a = b`` and their tiled-matrix variants): ``TRSV`` tasks
solve one diagonal tile, ``GEMV`` tasks propagate a solved tile-row into a
pending one.  They level-schedule the same way the factorization does.

Finally, :func:`build_program_schedule` fuses the *entire* prediction
pipeline — covariance assembly, Cholesky, both substitutions, cross
covariance, predictive mean and (optionally) the full-covariance tail — into
one DAG with cross-stage edges, so e.g. ``TRSV(0)`` depends only on
``POTRF@col0`` and cross-covariance tiles are ready at level 0.  The
wavefront scheduler then co-batches solve rows and cross-assembly into the
tail of Cholesky columns exactly like the paper's Fig. 5 timeline (DESIGN.md
§7).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Task encodings: (op, i, j, k).  k is only used by GEMM.
POTRF = "potrf"
TRSM = "trsm"
SYRK = "syrk"
GEMM = "gemm"

# Triangular-solve ops: TRSV solves the diagonal tile of row i; GEMV updates
# pending row i with solved row j (tile (i, j) for forward, (j, i)^T backward).
TRSV = "trsv"
GEMV = "gemv"

# Whole-pipeline program ops (build_program): covariance assembly feeds the
# factorization, solves/cross-covariance feed the prediction heads.  Forward
# substitution reuses TRSV/GEMV; the backward pass gets distinct ops because
# both stages coexist in one DAG (and write a different buffer).
ASSEMBLE = "assemble"    # packed training-covariance tile (i, j)
CROSS = "cross"          # cross-covariance tile K_*[p, q] (test row p, train col q)
PRIOR = "prior"          # prior test-covariance tile K_{X̂,X̂}[p, q]
TRSV_B = "trsv_b"        # backward diagonal solve of row i (alpha buffer)
GEMV_B = "gemv_b"        # backward propagation a_i -= L_ji^T a_j
XGEMV = "xgemv"          # predictive-mean row p: mean_p = sum_q K_*[p,q] alpha_q
VINIT = "vinit"          # uncertainty workspace row i: V_i,q <- K_*[q,i]^T
VTRSV = "vtrsv"          # matrix forward solve, diagonal tile of row i
VGEMV = "vgemv"          # matrix forward propagation V_i -= L_ij V_j
GRAM = "gram"            # Sigma = prior - V^T V (single closing task)

# Streaming-update ops (DESIGN.md §10).  Two DAG families:
#
# *Append* ("update_append"): grow the factor by one tile-row R of new
# observations.  The new row's tiles obey the same recurrence as the TRSM
# row of a factorization step, solved against the frozen existing factor:
#
#   row_j = (K(R, j) - sum_{k<j} row_k L(j,k)^T) L(j,j)^{-T}      (j < R)
#   corner = chol(K(R, R) - sum_j row_j row_j^T)
#
# *Rank update* ("update_rank"): L' L'^T = L L^T + sigma W W^T for a
# tile-column carry W (sliding-window eviction uses sigma=+1 on the trailing
# factor; a true downdate is sigma=-1 via hyperbolic rotations) — the
# blocked cholupdate recurrence (per column j):
#
#   L'(j,j) = chol(L(j,j) L(j,j)^T + s W_j W_j^T)
#   X_j = L'(j,j)^{-1} L(j,j);  Y_j = L'(j,j)^{-1} W_j
#   C_j = chol(I - s Y_j^T Y_j)                 <- positivity check (s=-1)
#   L'(i,j) = L(i,j) X_j^T + s W_i Y_j^T                          (i > j)
#   W_i    <- (W_i - L'(i,j) Y_j) C_j^{-T}                        (i > j)
UASM = "uasm"            # assemble cross tile K(x_row, x_j) of the new row
UASMD = "uasmd"          # assemble the new diagonal (corner) tile
UTRSM = "utrsm"          # row_j <- row_j L(j,j)^{-T}
UGEMM = "ugemm"          # row_j -= row_k L(j,k)^T
USYRK = "usyrk"          # corner -= row_j row_j^T
UPOTRF = "upotrf"        # corner <- chol(corner)
UPREP = "uprep"          # column head: L'(j,j) + the X/Y/C auxiliaries
UPROW = "uprow"          # L'(i,j) = L(i,j) X_j^T + s W_i Y_j^T
UCARRY = "ucarry"        # W_i <- (W_i - L'(i,j) Y_j) C_j^{-T}

# Low-rank (Nyström) tier op (DESIGN.md §14): the n-side contraction of the
# inducing system.  One tile task per (inducing row p, training column j) of
# the K_un grid — every task is independent (the n axis is embarrassingly
# parallel), so the whole family is a single bulk launch in the executor.
LRGEMM = "lrgemm"        # c_p += K_un[p, j] @ y_j  /  G += K_un[:, j] K_un[:, j]^T

Task = Tuple[str, int, int, int]

# Ops that the wavefront scheduler does NOT count against the stream pool:
# the pool models the paper's per-stream cuBLAS/cuSOLVER handles (one tile
# BLAS kernel resident per stream), whereas these ops are single batched
# custom-kernel launches in the executor no matter how many tiles they cover
# (exactly how the staged pipeline issues them).  They still enter waves as
# soon as their dependencies resolve — riding along with whatever BLAS wave
# is current — so the cross-stage overlap is preserved without inflating the
# launch count.
BULK_OPS = frozenset(
    {ASSEMBLE, CROSS, PRIOR, VINIT, XGEMV, GRAM, UASM, UASMD, LRGEMM}
)

# Dispatch groups: tasks whose batched kernel is literally the same launch.
# SYRK is GEMM with both panels equal, so the executor fuses both into one
# trailing-update launch per level (executor.TRAIL).  The update-family bulk
# ops are the assembly of the appended row (single batched launch).
TRAIL_GROUP = "trail"


def dispatch_group(op: str) -> str:
    return TRAIL_GROUP if op in (SYRK, GEMM) else op


def _deps(task: Task, m_tiles: int) -> List[Task]:
    """Direct dependencies of a task in the right-looking tiled Cholesky.

    Matches the paper's Fig. 1 loop nest:
      POTRF(J,J)   needs SYRK(J,J) of step J-1            (last writer of (J,J))
      TRSM(I,J)    needs POTRF(J,J) and GEMM(I,J) of step J-1 (last writer of (I,J))
      SYRK(I,I)@J  needs TRSM(I,J) and SYRK(I,I) of step J-1
      GEMM(I,K)@J  needs TRSM(I,J), TRSM(K,J) and GEMM(I,K) of step J-1
    """
    op, i, j, k = task
    deps: List[Task] = []
    if op == POTRF:
        # last update of tile (j, j) was SYRK at step j-1
        if j > 0:
            deps.append((SYRK, j, j - 1, -1))
    elif op == TRSM:
        deps.append((POTRF, j, j, -1))
        if j > 0:
            deps.append((GEMM, i, j - 1, j))  # last writer of (i, j): GEMM(I=i, K=j) at step j-1
    elif op == SYRK:
        # SYRK at step j updates tile (i, i) using panel tile (i, j)
        deps.append((TRSM, i, j, -1))
        if j > 0:
            deps.append((SYRK, i, j - 1, -1))
    elif op == GEMM:
        # GEMM at step j updates tile (i, k) using panel tiles (i, j), (k, j)
        deps.append((TRSM, i, j, -1))
        deps.append((TRSM, k, j, -1))
        if j > 0:
            deps.append((GEMM, i, j - 1, k))
    else:
        raise ValueError(op)
    return deps


def all_tasks(m_tiles: int) -> List[Task]:
    """Every task of the factorization, in the paper's Fig. 1 program order."""
    tasks: List[Task] = []
    for j in range(m_tiles):
        tasks.append((POTRF, j, j, -1))
        for i in range(j + 1, m_tiles):
            tasks.append((TRSM, i, j, -1))
        for i in range(j + 1, m_tiles):
            tasks.append((SYRK, i, j, -1))
            for k in range(j + 1, i):
                tasks.append((GEMM, i, j, k))
    return tasks


@dataclasses.dataclass(frozen=True)
class Schedule:
    m_tiles: int
    levels: Tuple[Tuple[Task, ...], ...]
    kind: str = "cholesky"  # "cholesky" | "forward" | "backward" | "program"
    q_tiles: int = 0        # test tile count (program schedules only)
    uncertainty: bool = False  # program includes the full-covariance tail

    @property
    def critical_path(self) -> int:
        return len(self.levels)

    @property
    def n_tasks(self) -> int:
        return sum(len(l) for l in self.levels)

    def max_width(self) -> int:
        return max(len(l) for l in self.levels)

    def op_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for level in self.levels:
            for t in level:
                counts[t[0]] = counts.get(t[0], 0) + 1
        if self.kind == "cholesky":
            for op in (POTRF, TRSM, SYRK, GEMM):
                counts.setdefault(op, 0)
        return counts


def _asap_levels(tasks: Sequence[Task], deps_fn) -> Tuple[Tuple[Task, ...], ...]:
    """ASAP level assignment; ``tasks`` must be in topological order."""
    level_of: Dict[Task, int] = {}
    for t in tasks:
        deps = deps_fn(t)
        level_of[t] = 0 if not deps else 1 + max(level_of[d] for d in deps)
    n_levels = 1 + max(level_of.values()) if level_of else 0
    levels: List[List[Task]] = [[] for _ in range(n_levels)]
    for t in tasks:
        levels[level_of[t]].append(t)
    return tuple(tuple(l) for l in levels)


def build_schedule(m_tiles: int) -> Schedule:
    """ASAP level schedule of the tiled Cholesky DAG."""
    levels = _asap_levels(all_tasks(m_tiles), lambda t: _deps(t, m_tiles))
    return Schedule(m_tiles=m_tiles, levels=levels)


def solve_deps(task: Task, m_tiles: int, *, lower: bool = True) -> List[Task]:
    """Direct dependencies of a triangular-solve task.

    Forward (``L b = y``, right-looking): once row j is solved, every pending
    row i > j receives the update ``b_i -= L_ij b_j``:

      TRSV(i)      needs GEMV(i, i-1)             (last accumulation into row i)
      GEMV(i, j)   needs TRSV(j) and GEMV(i, j-1) (last writer of row i's acc)

    Backward (``L^T a = b``) mirrors this with the recurrence running from
    row M-1 down; GEMV(i, j) with j > i applies ``a_i -= L_ji^T a_j``.
    """
    op, i, j, _ = task
    deps: List[Task] = []
    if op == TRSV:
        if lower and i > 0:
            deps.append((GEMV, i, i - 1, -1))
        elif not lower and i < m_tiles - 1:
            deps.append((GEMV, i, i + 1, -1))
    elif op == GEMV:
        deps.append((TRSV, j, j, -1))
        if lower and j > 0:
            deps.append((GEMV, i, j - 1, -1))
        elif not lower and j < m_tiles - 1:
            deps.append((GEMV, i, j + 1, -1))
    else:
        raise ValueError(op)
    return deps


def solve_tasks(m_tiles: int, *, lower: bool = True) -> List[Task]:
    """Every task of a tiled triangular solve, in dataflow program order."""
    tasks: List[Task] = []
    cols = range(m_tiles) if lower else reversed(range(m_tiles))
    for j in cols:
        tasks.append((TRSV, j, j, -1))
        rows = range(j + 1, m_tiles) if lower else range(j)
        for i in rows:
            tasks.append((GEMV, i, j, -1))
    return tasks


def build_solve_schedule(m_tiles: int, *, lower: bool = True) -> Schedule:
    """ASAP level schedule of forward (lower) / backward substitution.

    The same schedule drives both the vector solves (``L b = y``) and the
    tiled-matrix solves (``L V = B``): the DAG over tile-rows is identical,
    only the per-task operand shapes differ (see executor.run_solve).
    Critical path is 2M - 1 levels: TRSV and batched-GEMV levels alternate.
    """
    levels = _asap_levels(
        solve_tasks(m_tiles, lower=lower),
        lambda t: solve_deps(t, m_tiles, lower=lower),
    )
    return Schedule(
        m_tiles=m_tiles, levels=levels, kind="forward" if lower else "backward"
    )


# ---------------------------------------------------------------------------
# The whole-pipeline program DAG (assembly -> factorization -> solves ->
# cross covariance -> mean / full covariance) with cross-stage edges.
# ---------------------------------------------------------------------------


def program_tasks(m_tiles: int, q_tiles: int, *, uncertainty: bool = False) -> List[Task]:
    """Every task of the fused prediction pipeline, in program order.

    The order is topological for :func:`program_deps` (assembly first, then
    factorization, forward substitution, backward substitution, prediction
    heads), which is what ``_asap_levels`` requires.
    """
    tasks: List[Task] = []
    for j in range(m_tiles):
        for i in range(j, m_tiles):
            tasks.append((ASSEMBLE, i, j, -1))
    for p in range(q_tiles):
        for q in range(m_tiles):
            tasks.append((CROSS, p, q, -1))
    if uncertainty:
        for p in range(q_tiles):
            for q in range(q_tiles):
                tasks.append((PRIOR, p, q, -1))
    tasks += all_tasks(m_tiles)
    tasks += solve_tasks(m_tiles, lower=True)  # forward: TRSV / GEMV
    for op, i, j, k in solve_tasks(m_tiles, lower=False):
        tasks.append((TRSV_B if op == TRSV else GEMV_B, i, j, k))
    for p in range(q_tiles):
        tasks.append((XGEMV, p, -1, -1))
    if uncertainty:
        for i in range(m_tiles):
            tasks.append((VINIT, i, -1, -1))
        for op, i, j, k in solve_tasks(m_tiles, lower=True):
            tasks.append((VTRSV if op == TRSV else VGEMV, i, j, k))
        tasks.append((GRAM, -1, -1, -1))
    return tasks


def program_deps(task: Task, m_tiles: int, q_tiles: int) -> List[Task]:
    """Direct dependencies of a task in the fused prediction program.

    These are the paper's cross-stage dataflow edges: a consumer waits only
    for the *tiles it reads*, never for a whole stage.  The last writer of an
    off-diagonal packed tile (i, j) is ``TRSM(i, j)``; of a diagonal tile
    (j, j) it is ``POTRF(j)``; of vector row i it is the forward/backward
    ``TRSV`` of that row.  Hence e.g. ``TRSV(0)`` depends on ``POTRF@col0``
    only — forward substitution starts while the factorization of later
    columns is still in flight (the paper's Fig. 5 overlap).

    Buffer hazards: forward substitution runs in the ``y`` buffer; its
    diagonal solve also publishes the row into the separate ``alpha`` buffer
    that the backward pass accumulates in, so backward writes can never
    clobber rows a forward GEMV still reads (no WAR anti-edges needed).
    """
    op, i, j, k = task
    m = m_tiles
    if op in (ASSEMBLE, CROSS, PRIOR):
        return []
    if op == POTRF:
        return [(SYRK, j, j - 1, -1) if j > 0 else (ASSEMBLE, j, j, -1)]
    if op == TRSM:
        return [
            (POTRF, j, j, -1),
            (GEMM, i, j - 1, j) if j > 0 else (ASSEMBLE, i, j, -1),
        ]
    if op == SYRK:
        return [
            (TRSM, i, j, -1),
            (SYRK, i, j - 1, -1) if j > 0 else (ASSEMBLE, i, i, -1),
        ]
    if op == GEMM:
        return [
            (TRSM, i, j, -1),
            (TRSM, k, j, -1),
            (GEMM, i, j - 1, k) if j > 0 else (ASSEMBLE, i, k, -1),
        ]
    if op == TRSV:  # forward; reads L(i,i), accumulations must be done
        deps = [(POTRF, i, i, -1)]
        if i > 0:
            deps.append((GEMV, i, i - 1, -1))
        return deps
    if op == GEMV:  # forward: b_i -= L(i,j) b_j; reads finalized tile (i, j)
        deps = [(TRSV, j, j, -1), (TRSM, i, j, -1)]
        if j > 0:
            deps.append((GEMV, i, j - 1, -1))
        return deps
    if op == TRSV_B:  # backward; row i seeded by the forward solve of row i
        deps = [(POTRF, i, i, -1), (TRSV, i, i, -1)]
        if i < m - 1:
            deps.append((GEMV_B, i, i + 1, -1))
        return deps
    if op == GEMV_B:  # a_i -= L(j,i)^T a_j; reads finalized tile (j, i)
        deps = [(TRSV_B, j, j, -1), (TRSV, i, i, -1), (TRSM, j, i, -1)]
        if j < m - 1:
            deps.append((GEMV_B, i, j + 1, -1))
        return deps
    if op == XGEMV:  # mean row p reads every cross tile of row p and all alpha
        return [(CROSS, i, q, -1) for q in range(m)] + [
            (TRSV_B, q, q, -1) for q in range(m)
        ]
    if op == VINIT:  # V row i is the transposed cross column i
        return [(CROSS, p, i, -1) for p in range(q_tiles)]
    if op == VTRSV:
        deps = [(VINIT, i, -1, -1), (POTRF, i, i, -1)]
        if i > 0:
            deps.append((VGEMV, i, i - 1, -1))
        return deps
    if op == VGEMV:  # V_i -= L(i,j) V_j
        deps = [(VTRSV, j, j, -1), (TRSM, i, j, -1), (VINIT, i, -1, -1)]
        if j > 0:
            deps.append((VGEMV, i, j - 1, -1))
        return deps
    if op == GRAM:
        return [(VTRSV, r, r, -1) for r in range(m)] + [
            (PRIOR, p, q, -1) for p in range(q_tiles) for q in range(q_tiles)
        ]
    raise ValueError(op)


def build_program_schedule(
    m_tiles: int, q_tiles: int, *, uncertainty: bool = False
) -> Schedule:
    """ASAP level schedule of the fused prediction program.

    Cross-stage overlap falls out of the DAG: e.g. ``TRSV(0)`` levels right
    next to the TRSM panel of column 0, and every ``CROSS`` tile sits at
    level 0 alongside the covariance assembly.
    """
    tasks = program_tasks(m_tiles, q_tiles, uncertainty=uncertainty)
    levels = _asap_levels(tasks, lambda t: program_deps(t, m_tiles, q_tiles))
    return Schedule(
        m_tiles=m_tiles,
        levels=levels,
        kind="program",
        q_tiles=q_tiles,
        uncertainty=uncertainty,
    )


def build_nlml_schedule(m_tiles: int) -> Schedule:
    """The trainable NLML prefix of the prediction program (DESIGN.md §8).

    ``q_tiles=0`` degenerates the program DAG to exactly the tasks the
    negative log marginal likelihood needs — ASSEMBLE, the factorization,
    and both substitutions (``alpha = K^{-1} y`` for the quadratic term; the
    log-determinant reads the factor's diagonal tiles, which is a reduction
    head in the executor, not a scheduled task).  No CROSS/PRIOR tiles, no
    prediction heads.  This is the forward program that
    :func:`repro.core.mll.nlml_tiled` differentiates.
    """
    return build_program_schedule(m_tiles, 0, uncertainty=False)


# ---------------------------------------------------------------------------
# Streaming-update DAGs (DESIGN.md §10): block Cholesky append / rank update.
# ---------------------------------------------------------------------------


def append_tasks(r_tiles: int) -> List[Task]:
    """Every task of a one-tile-row block-Cholesky append, in program order.

    ``r_tiles`` is the number of *existing* factor tile-rows the new row is
    solved against (the new row gets index R = r_tiles).  ``r_tiles=0``
    degenerates to assembling + factoring a single corner tile (the very
    first observations of a GP whose partial tile is being refilled).
    """
    r = r_tiles
    tasks: List[Task] = []
    for j in range(r):
        tasks.append((UASM, j, -1, -1))
    tasks.append((UASMD, r, -1, -1))
    for j in range(r):
        for k in range(j):
            tasks.append((UGEMM, j, k, -1))
        tasks.append((UTRSM, j, -1, -1))
        tasks.append((USYRK, j, -1, -1))
    tasks.append((UPOTRF, r, -1, -1))
    return tasks


def append_deps(task: Task, r_tiles: int) -> List[Task]:
    """Direct dependencies of an append task.

    The existing factor is a frozen *input* (its last writer completed in a
    previous program), so edges only run between the new row's own tasks:
    the TRSM-row recurrence chains UGEMM corrections before each diagonal
    solve, and the corner accumulates SYRK contributions in program order.
    """
    op, i, j, _ = task
    r = r_tiles
    if op in (UASM, UASMD):
        return []
    if op == UTRSM:  # row_i <- row_i L(i,i)^{-T} after all corrections
        return [(UGEMM, i, i - 1, -1) if i > 0 else (UASM, i, -1, -1)]
    if op == UGEMM:  # row_i -= row_j L(i,j)^T; reads solved row_j
        deps = [(UTRSM, j, -1, -1)]
        deps.append((UGEMM, i, j - 1, -1) if j > 0 else (UASM, i, -1, -1))
        return deps
    if op == USYRK:  # corner -= row_i row_i^T (accumulation chain)
        return [
            (UTRSM, i, -1, -1),
            (USYRK, i - 1, -1, -1) if i > 0 else (UASMD, r, -1, -1),
        ]
    if op == UPOTRF:
        return [(USYRK, r - 1, -1, -1) if r > 0 else (UASMD, r, -1, -1)]
    raise ValueError(op)


def rank_update_tasks(m_tiles: int) -> List[Task]:
    """Every task of a tiled rank-b up/downdate, in program order."""
    tasks: List[Task] = []
    for j in range(m_tiles):
        tasks.append((UPREP, j, -1, -1))
        for i in range(j + 1, m_tiles):
            tasks.append((UPROW, i, j, -1))
        for i in range(j + 1, m_tiles):
            tasks.append((UCARRY, i, j, -1))
    return tasks


def rank_update_deps(task: Task, m_tiles: int) -> List[Task]:
    """Direct dependencies of a rank-update task (blocked cholupdate).

    The recurrence sweeps columns left to right; row i's carry W_i evolves
    once per column, so every column-j task on row i waits for UCARRY(i,
    j-1) — the last writer of W_i.  UPREP(j) writes the new diagonal into
    the factor *and* the X/Y/C auxiliaries its column reads; UPROW(i,j)
    overwrites L(i,j) in place (no later task reads the old value).
    """
    op, i, j, _ = task
    if op == UPREP:  # reads L(j,j) and the settled carry W_j
        return [(UCARRY, i, i - 1, -1)] if i > 0 else []
    if op == UPROW:
        deps = [(UPREP, j, -1, -1)]
        if j > 0:
            deps.append((UCARRY, i, j - 1, -1))
        return deps
    if op == UCARRY:
        return [(UPROW, i, j, -1), (UPREP, j, -1, -1)]
    raise ValueError(op)


def build_update_schedule(
    m_tiles: int, *, kind: str = "update_append"
) -> Schedule:
    """ASAP level schedule of an update DAG.

    ``kind="update_append"``: ``m_tiles`` is the *existing* row count R the
    appended row solves against.  ``kind="update_rank"``: ``m_tiles`` is the
    size of the factor being up/downdated.
    """
    tasks, deps_fn = _dag(m_tiles, kind)
    levels = _asap_levels(tasks, deps_fn)
    return Schedule(m_tiles=m_tiles, levels=levels, kind=kind)


def lowrank_tasks(mu_tiles: int, n_tiles: int) -> List[Task]:
    """The LRGEMM bulk family over the (mu_tiles × n_tiles) K_un grid.

    Single level: every tile contraction is independent, so the whole
    family compiles to ONE batched launch (BULK_OPS) — the low-rank tier's
    n-dimensional work is embarrassingly tile-parallel by construction.
    """
    return [(LRGEMM, p, j, -1) for p in range(mu_tiles) for j in range(n_tiles)]


def lowrank_deps(task: Task) -> List[Task]:
    if task[0] != LRGEMM:
        raise ValueError(task[0])
    return []


def task_deps(task: Task, schedule: Schedule) -> List[Task]:
    """Dependencies of ``task`` under the DAG family of ``schedule.kind``."""
    if schedule.kind == "cholesky":
        return _deps(task, schedule.m_tiles)
    if schedule.kind == "program":
        return program_deps(task, schedule.m_tiles, schedule.q_tiles)
    if schedule.kind == "update_append":
        return append_deps(task, schedule.m_tiles)
    if schedule.kind == "update_rank":
        return rank_update_deps(task, schedule.m_tiles)
    if schedule.kind == "lowrank":
        return lowrank_deps(task)
    return solve_deps(task, schedule.m_tiles, lower=schedule.kind == "forward")


def _dag(m_tiles: int, kind: str, q_tiles: int = 0, uncertainty: bool = False):
    """(tasks in topological order, deps_fn) for a DAG family."""
    if kind == "cholesky":
        return all_tasks(m_tiles), lambda t: _deps(t, m_tiles)
    if kind in ("forward", "backward"):
        lower = kind == "forward"
        return (
            solve_tasks(m_tiles, lower=lower),
            lambda t: solve_deps(t, m_tiles, lower=lower),
        )
    if kind == "program":
        return (
            program_tasks(m_tiles, q_tiles, uncertainty=uncertainty),
            lambda t: program_deps(t, m_tiles, q_tiles),
        )
    if kind == "update_append":
        return append_tasks(m_tiles), lambda t: append_deps(t, m_tiles)
    if kind == "update_rank":
        return rank_update_tasks(m_tiles), lambda t: rank_update_deps(t, m_tiles)
    if kind == "lowrank":
        # q_tiles carries the n-side tile count of the K_un grid
        return lowrank_tasks(m_tiles, q_tiles), lambda t: lowrank_deps(t)
    raise ValueError(kind)


def _bottom_levels(tasks: Sequence[Task], deps_fn) -> Dict[Task, int]:
    """Longest path from each task to a sink (critical-path priority)."""
    bottom: Dict[Task, int] = {t: 0 for t in tasks}
    for t in reversed(tasks):  # reverse topological order
        for d in deps_fn(t):
            bottom[d] = max(bottom[d], bottom[t] + 1)
    return bottom


def build_wavefront_schedule(
    m_tiles: int,
    n_streams: int,
    *,
    kind: str = "cholesky",
    q_tiles: int = 0,
    uncertainty: bool = False,
) -> Schedule:
    """Finite-stream-pool list schedule: the paper's round-robin pool, static.

    ASAP levels of the right-looking Cholesky DAG are *column-phased* (level
    3j+{0,1,2} holds exactly the POTRF / TRSM panel / trailing update of
    column j), so plain level chunking can never co-batch tasks of different
    columns.  HPX with a finite stream pool does better: when the trailing
    update of column j does not fill the pool, panel tasks of column j+1 that
    are already ready ride along.  This function reproduces that statically:

      wave k = the <= n_streams ready tasks with the greatest bottom-level
               (longest path to a sink, i.e. critical-path-first priority)

    Program DAGs additionally carry BULK_OPS tasks (covariance assembly and
    the prediction heads); those are single batched custom-kernel launches in
    the executor, so they ride every wave as soon as they are ready without
    consuming pool slots (the pool models per-stream BLAS handles).

    Every wave is an antichain (all members were simultaneously ready), and
    accumulation chains (SYRK/GEMM onto one tile) stay in program order, so
    executing waves in sequence is exactly dependency-faithful — but a wave
    may now mix, say, GEMM(i,k)@j with TRSM@j+1, which the executor turns
    into co-issued batched kernels.  ``n_streams=1`` degenerates to the
    fully sequential priority order (the paper's single-stream baseline).
    """
    import heapq

    if n_streams < 1:
        raise ValueError(f"n_streams must be >= 1 or None, got {n_streams}")
    tasks, deps_fn = _dag(m_tiles, kind, q_tiles, uncertainty)
    bottom = _bottom_levels(tasks, deps_fn)
    order = {t: i for i, t in enumerate(tasks)}
    indeg = {t: len(deps_fn(t)) for t in tasks}
    succs: Dict[Task, List[Task]] = {}
    for t in tasks:
        for d in deps_fn(t):
            succs.setdefault(d, []).append(t)

    def push(h, t):
        heapq.heappush(h, (-bottom[t], order[t], t))

    heap: list = []       # pooled BLAS tile tasks (<= n_streams per wave)
    bulk_heap: list = []  # batched custom-kernel ops (ride along, see BULK_OPS)
    for t in tasks:
        if indeg[t] == 0:
            push(bulk_heap if t[0] in BULK_OPS else heap, t)
    waves: List[Tuple[Task, ...]] = []
    affinity = kind == "program"  # staged plans keep PR-1's pure priority order
    while heap or bulk_heap:
        wave = [heapq.heappop(bulk_heap)[2] for _ in range(len(bulk_heap))]
        if affinity and heap:
            # The wave leader is still chosen critical-path-first; remaining
            # pool slots prefer tasks of the leader's dispatch group so a wave
            # compiles to as few batched launches as possible.  Tasks are all
            # simultaneously ready, so this only reorders within the wave's
            # antichain — dependency-faithfulness is untouched.
            ready = [heapq.heappop(heap) for _ in range(len(heap))]
            leader = ready[0]
            grp = dispatch_group(leader[2][0])
            same = [e for e in ready[1:] if dispatch_group(e[2][0]) == grp]
            rest = [e for e in ready[1:] if dispatch_group(e[2][0]) != grp]
            picked = [leader] + (same + rest)[: n_streams - 1]
            wave += [e[2] for e in picked]
            for e in same[n_streams - 1 :] + rest[max(n_streams - 1 - len(same), 0) :]:
                heapq.heappush(heap, e)
        else:
            wave += [heapq.heappop(heap)[2] for _ in range(min(n_streams, len(heap)))]
        waves.append(tuple(wave))
        for t in wave:
            for s in succs.get(t, ()):
                indeg[s] -= 1
                if indeg[s] == 0:
                    push(bulk_heap if s[0] in BULK_OPS else heap, s)
    return Schedule(
        m_tiles=m_tiles,
        levels=tuple(waves),
        kind=kind,
        q_tiles=q_tiles,
        uncertainty=uncertainty,
    )


def chunk_tasks(
    tasks: Sequence[Task], n_streams: Optional[int]
) -> List[List[Task]]:
    """Round-robin chunking of one level into groups of <= n_streams tasks.

    The paper assigns tasks to a stream pool round-robin; a chunk here is the
    set of tasks that would be resident on the pool simultaneously, which we
    execute as a single batched kernel call.
    """
    tasks = list(tasks)
    if n_streams is None or n_streams >= len(tasks):
        return [tasks] if tasks else []
    return [tasks[i : i + n_streams] for i in range(0, len(tasks), n_streams)]


def split_by_op(tasks: Iterable[Task]) -> Dict[str, List[Task]]:
    out: Dict[str, List[Task]] = {}
    for t in tasks:
        out.setdefault(t[0], []).append(t)
    return out


def theoretical_task_counts(m_tiles: int) -> Dict[str, int]:
    m = m_tiles
    return {
        POTRF: m,
        TRSM: m * (m - 1) // 2,
        SYRK: m * (m - 1) // 2,
        GEMM: m * (m - 1) * (m - 2) // 6,
    }
