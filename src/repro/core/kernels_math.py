"""Gaussian-process covariance math (pure jnp reference implementations).

The paper (Eq. 1) uses the squared-exponential kernel

    k(x_i, x_j) = v * exp( -1/(2*l) * sum_d (x_i_d - x_j_d)^2 ) + delta_ij * sigma^2

with hyperparameters: lengthscale ``l``, vertical lengthscale ``v`` and noise
variance ``sigma^2``.  Note the paper's parameterization divides by ``2*l``
(not ``2*l**2``); we follow the paper exactly.

Everything here is dtype-parametric and shape-padding aware: covariance
assembly can generate *padded* matrices where rows/cols with global index
``>= n_valid`` are replaced by identity (diagonal blocks) or zero
(off-diagonal / cross blocks).  Padding with an identity block is exactly
equivalent to solving the unpadded system (the Cholesky factor of
``blockdiag(K, I)`` is ``blockdiag(L, I)``), which lets the tiled pipeline
require only ``n % m == 0`` internally while the public API accepts any n.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SEKernelParams:
    """Hyperparameters of the squared-exponential kernel (paper Eq. 1)."""

    lengthscale: jax.Array | float = 1.0
    vertical: jax.Array | float = 1.0
    noise: jax.Array | float = 0.1  # sigma^2 (variance, not std)

    @staticmethod
    def paper_defaults() -> "SEKernelParams":
        # Section 4.1: l = 1, v = 1, sigma^2 = 0.1.
        return SEKernelParams(1.0, 1.0, 0.1)


def broadcast_params(params: SEKernelParams, b: int) -> SEKernelParams:
    """Broadcast every hyperparameter leaf to per-problem shape (B,).

    Mixed leaves are legal inputs (e.g. per-problem lengthscales with a
    shared noise); this normalizes them for code that vmaps over the
    problem axis (DESIGN.md §9).
    """
    bcast = lambda leaf: jnp.broadcast_to(jnp.asarray(leaf), (b,))
    return SEKernelParams(
        lengthscale=bcast(params.lengthscale),
        vertical=bcast(params.vertical),
        noise=bcast(params.noise),
    )


def sq_dists(x1: jax.Array, x2: jax.Array) -> jax.Array:
    """Pairwise squared euclidean distances. x1: (n1, D), x2: (n2, D) -> (n1, n2).

    Uses the expanded form |a|^2 + |b|^2 - 2 a.b so the inner product hits the
    MXU on TPU; clamped at zero for numerical safety.
    """
    n1sq = jnp.sum(x1 * x1, axis=-1, keepdims=True)      # (n1, 1)
    n2sq = jnp.sum(x2 * x2, axis=-1, keepdims=True).T    # (1, n2)
    cross = x1 @ x2.T                                    # (n1, n2)
    return jnp.maximum(n1sq + n2sq - 2.0 * cross, 0.0)


def se_kernel(
    x1: jax.Array,
    x2: jax.Array,
    params: SEKernelParams,
    *,
    diag_offset: Optional[int] = None,
) -> jax.Array:
    """Dense SE covariance block between x1 (n1,D) and x2 (n2,D).

    If ``diag_offset`` is not None, the entry (i, j) with
    ``i + diag_offset == j`` receives the ``+ sigma^2`` noise term, i.e. the
    block lies on the global diagonal with the given column offset.  For the
    full training matrix use ``diag_offset=0``.
    """
    d2 = sq_dists(x1, x2)
    k = params.vertical * jnp.exp(-0.5 / params.lengthscale * d2)
    if diag_offset is not None:
        i = jnp.arange(x1.shape[0])[:, None]
        j = jnp.arange(x2.shape[0])[None, :]
        k = k + jnp.where(i + diag_offset == j, params.noise, 0.0).astype(k.dtype)
    return k


def cov_tile(
    xa: jax.Array,
    xb: jax.Array,
    row0,
    col0,
    params: SEKernelParams,
    n_valid_r,
    n_valid_c,
    symmetric: bool,
) -> jax.Array:
    """One covariance tile with global-index masking (vmap-friendly).

    xa: (m, D) rows, xb: (mb, D) cols; row0/col0 global offsets (traced or
    static scalars).  Padded region -> identity (symmetric) or zero (cross);
    symmetric tiles also receive the ``+ sigma^2`` noise on the global
    diagonal.  This is the jnp analogue of the Pallas cov-assembly kernel
    (repro.kernels.cov_assembly) and the per-task op behind the ASSEMBLE /
    CROSS / PRIOR program tasks.
    """
    k = se_kernel(xa, xb, params)
    gi = row0 + jnp.arange(xa.shape[0])[:, None]
    gj = col0 + jnp.arange(xb.shape[0])[None, :]
    on_diag = gi == gj
    valid = (gi < n_valid_r) & (gj < n_valid_c)
    if symmetric:
        k = k + jnp.where(on_diag, params.noise, 0.0).astype(k.dtype)
        return jnp.where(valid, k, on_diag.astype(k.dtype))
    return jnp.where(valid, k, jnp.zeros((), k.dtype))


def assemble_covariance(
    x: jax.Array,
    params: SEKernelParams,
    *,
    n_valid: Optional[int] = None,
    dtype=jnp.float32,
) -> jax.Array:
    """Full training covariance K = K_XX + sigma^2 I, optionally padded.

    x: (n_pad, D) where rows >= n_valid are padding (any values).  The padded
    region is overwritten: identity on the diagonal, zero elsewhere.
    """
    x = x.astype(dtype)
    k = se_kernel(x, x, params, diag_offset=0).astype(dtype)
    if n_valid is not None and n_valid != x.shape[0]:
        n_pad = x.shape[0]
        i = jnp.arange(n_pad)[:, None]
        j = jnp.arange(n_pad)[None, :]
        valid = (i < n_valid) & (j < n_valid)
        eye = (i == j).astype(dtype)
        k = jnp.where(valid, k, eye)
    return k


def assemble_cross_covariance(
    x_test: jax.Array,
    x_train: jax.Array,
    params: SEKernelParams,
    *,
    n_test_valid: Optional[int] = None,
    n_train_valid: Optional[int] = None,
    dtype=jnp.float32,
) -> jax.Array:
    """Cross covariance K_{X̂,X} (n̂_pad × n_pad), padded region zeroed."""
    k = se_kernel(x_test.astype(dtype), x_train.astype(dtype), params).astype(dtype)
    nt, ntr = k.shape
    if (n_test_valid is not None and n_test_valid != nt) or (
        n_train_valid is not None and n_train_valid != ntr
    ):
        i = jnp.arange(nt)[:, None]
        j = jnp.arange(ntr)[None, :]
        valid = jnp.ones((nt, ntr), dtype=bool)
        if n_test_valid is not None:
            valid &= i < n_test_valid
        if n_train_valid is not None:
            valid &= j < n_train_valid
        k = jnp.where(valid, k, 0.0)
    return k


def assemble_prior_covariance(
    x_test: jax.Array,
    params: SEKernelParams,
    *,
    n_valid: Optional[int] = None,
    include_noise: bool = False,
    dtype=jnp.float32,
) -> jax.Array:
    """Prior test covariance K_{X̂,X̂}; optionally with observation noise."""
    k = se_kernel(
        x_test.astype(dtype),
        x_test.astype(dtype),
        params,
        diag_offset=0 if include_noise else None,
    ).astype(dtype)
    if n_valid is not None and n_valid != k.shape[0]:
        n_pad = k.shape[0]
        i = jnp.arange(n_pad)[:, None]
        j = jnp.arange(n_pad)[None, :]
        valid = (i < n_valid) & (j < n_valid)
        k = jnp.where(valid, k, 0.0)
    return k
