"""Gaussian-process covariance math: the kernel registry + jnp references.

The paper (Eq. 1) uses the squared-exponential kernel

    k(x_i, x_j) = v * exp( -1/(2*l) * sum_d (x_i_d - x_j_d)^2 ) + delta_ij * sigma^2

with hyperparameters: lengthscale ``l``, vertical lengthscale ``v`` and noise
variance ``sigma^2``.  Note the paper's parameterization divides by ``2*l``
(not ``2*l**2``); we follow the paper exactly, and every other stationary
family in the registry keeps the same convention (``lengthscale`` scales
*squared* distances).

Beyond the paper's SE kernel this module hosts the **kernel registry**
(DESIGN.md §13): ``Kernel`` subclasses (SE, Matérn 1/2 · 3/2 · 5/2, rational
quadratic, per-dimension ARD, white noise) and ``Sum`` / ``Product`` /
``Scaled`` composition.  A kernel is a frozen, hashable dataclass — it joins
jit/posterior cache keys directly — and its hyperparameters live in a
separate params *pytree* so the same kernel object serves concrete params
(Pallas assembly with baked constants) and traced params (differentiable jnp
assembly under ``grad``).  The contract each kernel implements:

  * ``kfree(params, xa, xb)`` — the noise-free covariance block, pure jnp,
    valid both under tracing and inside a Pallas kernel body with host
    constants for params.
  * ``noise(params)`` — the variance added on the *global* diagonal of a
    training covariance (zero for kernels with no observation-noise role).
  * ``diag(params)`` — the exact value of ``kfree(x, x)`` for stationary
    kernels; assembly pins the global diagonal to ``diag + noise`` instead
    of trusting the cancellation-prone expanded distance form.
  * ``default_params()`` / ``base_ndims(params)`` — the params pytree and
    the per-leaf base rank (0 for scalars, 1 for ARD lengthscale vectors)
    that lets generic code detect/broadcast per-problem (B,)-batched leaves.

Everything here is dtype-parametric and shape-padding aware: covariance
assembly can generate *padded* matrices where rows/cols with global index
``>= n_valid`` are replaced by identity (diagonal blocks) or zero
(off-diagonal / cross blocks).  Padding with an identity block is exactly
equivalent to solving the unpadded system (the Cholesky factor of
``blockdiag(K, I)`` is ``blockdiag(L, I)``), which lets the tiled pipeline
require only ``n % m == 0`` internally while the public API accepts any n.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, ClassVar, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Hyperparameter pytrees
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SEKernelParams:
    """Hyperparameters of the paper's SE kernel (Eq. 1).

    Also the params pytree of every simple stationary family with the same
    three knobs (Matérn 1/2 · 3/2 · 5/2): lengthscale, vertical lengthscale
    and observation-noise variance.
    """

    lengthscale: jax.Array | float = 1.0
    vertical: jax.Array | float = 1.0
    noise: jax.Array | float = 0.1  # sigma^2 (variance, not std)

    @staticmethod
    def paper_defaults() -> "SEKernelParams":
        # Section 4.1: l = 1, v = 1, sigma^2 = 0.1.
        return SEKernelParams(1.0, 1.0, 0.1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RQKernelParams:
    """Rational-quadratic hyperparameters (SE mixture over lengthscales)."""

    lengthscale: jax.Array | float = 1.0
    vertical: jax.Array | float = 1.0
    noise: jax.Array | float = 0.1
    alpha: jax.Array | float = 1.0  # mixture concentration; RQ -> SE as alpha -> inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ARDKernelParams:
    """SE-ARD hyperparameters: one lengthscale per feature dimension."""

    lengthscales: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.ones((1,))
    )  # (D,) or per-problem (B, D)
    vertical: jax.Array | float = 1.0
    noise: jax.Array | float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WhiteKernelParams:
    """White-noise hyperparameter: the observation-noise variance."""

    noise: jax.Array | float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScaledParams:
    """Params of ``Scaled``: an output-scale knob wrapping the child's pytree."""

    scale: jax.Array | float = 1.0
    inner: Any = None


# ---------------------------------------------------------------------------
# Distance helpers
# ---------------------------------------------------------------------------


def sq_dists(x1: jax.Array, x2: jax.Array) -> jax.Array:
    """Pairwise squared euclidean distances. x1: (n1, D), x2: (n2, D) -> (n1, n2).

    Uses the expanded form |a|^2 + |b|^2 - 2 a.b so the inner product hits the
    MXU on TPU; clamped at zero for numerical safety.  The expanded form
    cancels catastrophically for large-magnitude inputs (the self-distance is
    not exactly zero in f32) — training-covariance assembly therefore never
    trusts it on the global diagonal and pins ``diag + noise`` exactly.
    """
    n1sq = jnp.sum(x1 * x1, axis=-1, keepdims=True)      # (n1, 1)
    n2sq = jnp.sum(x2 * x2, axis=-1, keepdims=True).T    # (1, n2)
    cross = jax.lax.dot_general(
        x1, x2, (((1,), (1,)), ((), ())), preferred_element_type=x1.dtype
    )
    return jnp.maximum(n1sq + n2sq - 2.0 * cross, 0.0)


def _safe_sqrt(d2: jax.Array) -> jax.Array:
    """sqrt with a zero (not NaN) gradient at d2 == 0 (double-where trick)."""
    pos = d2 > 0.0
    return jnp.where(pos, jnp.sqrt(jnp.where(pos, d2, 1.0)), 0.0)


# ---------------------------------------------------------------------------
# The kernel registry
# ---------------------------------------------------------------------------


class Kernel:
    """Base of the registry contract (see module docstring / DESIGN.md §13).

    Subclasses are frozen dataclasses: hashable with structural equality, so
    a kernel instance can join lru/jit cache keys directly.  ``analytic_vjp``
    marks kernels with hand-derived dK/dtheta in ``mll`` (only SE today);
    everything else trains through plain autodiff of the fused program.
    """

    name: ClassVar[str] = "kernel"
    analytic_vjp: ClassVar[bool] = False

    def default_params(self):
        raise NotImplementedError

    def kfree(self, params, xa: jax.Array, xb: jax.Array) -> jax.Array:
        """Noise-free covariance block (n1, n2); pure jnp, Pallas-body safe."""
        raise NotImplementedError

    def noise(self, params):
        return params.noise

    def diag(self, params):
        """Exact k(x, x) — constant for the stationary families hosted here."""
        return params.vertical

    def base_ndims(self, params):
        """Per-leaf base rank of the params pytree (before any (B,) batching)."""
        return jax.tree_util.tree_map(lambda _: 0, params)

    def kfree_vjp(self, params, xa, xb, g):
        """Hand-derived VJP of ``sum(g * kfree(params, xa, xb))``.

        Returns ``(g_params, g_xa, g_xb)`` where ``g_params`` matches the
        params pytree (the ``noise`` leaf is zero — kfree is noise-free; the
        caller folds its own noise cotangent in) and ``g_xa``/``g_xb`` match
        the input blocks.  Only kernels with ``analytic_vjp = True`` provide
        this; everything else trains through autodiff of the fused program.
        """
        raise NotImplementedError(
            f"{self.name} has no hand-derived kfree VJP (analytic_vjp is "
            f"{self.analytic_vjp})"
        )

    def kernel_id(self) -> str:
        return self.name


@dataclasses.dataclass(frozen=True)
class SquaredExponential(Kernel):
    """The paper's kernel: k = v * exp(-d2 / (2 l))."""

    name: ClassVar[str] = "se"
    analytic_vjp: ClassVar[bool] = True

    def default_params(self) -> SEKernelParams:
        return SEKernelParams.paper_defaults()

    def kfree(self, params, xa, xb):
        return params.vertical * jnp.exp(-0.5 / params.lengthscale * sq_dists(xa, xb))

    def kfree_vjp(self, params, xa, xb, g):
        l, v = params.lengthscale, params.vertical
        d2 = sq_dists(xa, xb)
        gk = g * (v * jnp.exp(-0.5 / l * d2))
        g_l = jnp.sum(gk * d2) / (2.0 * l * l)
        g_v = jnp.sum(gk) / v
        # dk/d(d2) = -k / (2 l); d(d2)/dxa = 2 (xa - xb) rowwise
        w = -gk / (2.0 * l)
        g_xa = 2.0 * (jnp.sum(w, axis=1, keepdims=True) * xa - w @ xb)
        g_xb = 2.0 * (jnp.sum(w, axis=0)[:, None] * xb - w.T @ xa)
        return SEKernelParams(g_l, g_v, jnp.zeros_like(params.noise)), g_xa, g_xb


@dataclasses.dataclass(frozen=True)
class Matern12(Kernel):
    """Matérn nu=1/2 (exponential): k = v * exp(-r), r^2 = d2 / l."""

    name: ClassVar[str] = "matern12"

    def default_params(self) -> SEKernelParams:
        return SEKernelParams.paper_defaults()

    def kfree(self, params, xa, xb):
        r = _safe_sqrt(sq_dists(xa, xb) / params.lengthscale)
        return params.vertical * jnp.exp(-r)


@dataclasses.dataclass(frozen=True)
class Matern32(Kernel):
    """Matérn nu=3/2: k = v * (1 + sqrt(3) r) exp(-sqrt(3) r)."""

    name: ClassVar[str] = "matern32"

    def default_params(self) -> SEKernelParams:
        return SEKernelParams.paper_defaults()

    def kfree(self, params, xa, xb):
        s = math.sqrt(3.0) * _safe_sqrt(sq_dists(xa, xb) / params.lengthscale)
        return params.vertical * (1.0 + s) * jnp.exp(-s)


@dataclasses.dataclass(frozen=True)
class Matern52(Kernel):
    """Matérn nu=5/2: k = v * (1 + sqrt(5) r + 5 r^2 / 3) exp(-sqrt(5) r)."""

    name: ClassVar[str] = "matern52"
    analytic_vjp: ClassVar[bool] = True

    def default_params(self) -> SEKernelParams:
        return SEKernelParams.paper_defaults()

    def kfree(self, params, xa, xb):
        s = math.sqrt(5.0) * _safe_sqrt(sq_dists(xa, xb) / params.lengthscale)
        return params.vertical * (1.0 + s + s * s / 3.0) * jnp.exp(-s)

    def kfree_vjp(self, params, xa, xb, g):
        l, v = params.lengthscale, params.vertical
        s = math.sqrt(5.0) * _safe_sqrt(sq_dists(xa, xb) / l)
        e = jnp.exp(-s)
        g_v = jnp.sum(g * (1.0 + s + s * s / 3.0) * e)
        # dk/dl = v s^2 (1 + s) e^{-s} / (6 l)   (via ds/dl = -s / (2 l))
        g_l = jnp.sum(g * s * s * (1.0 + s) * e) * v / (6.0 * l)
        # dk/d(d2) = -(5 v / (6 l)) (1 + s) e^{-s} — the 1/s of ds/d(d2)
        # cancels against dk/ds ∝ s, so this is finite at d2 == 0.
        w = g * (-(5.0 * v / (6.0 * l)) * (1.0 + s) * e)
        g_xa = 2.0 * (jnp.sum(w, axis=1, keepdims=True) * xa - w @ xb)
        g_xb = 2.0 * (jnp.sum(w, axis=0)[:, None] * xb - w.T @ xa)
        return SEKernelParams(g_l, g_v, jnp.zeros_like(params.noise)), g_xa, g_xb


@dataclasses.dataclass(frozen=True)
class RationalQuadratic(Kernel):
    """RQ: k = v * (1 + d2 / (2 alpha l))^-alpha — an SE lengthscale mixture."""

    name: ClassVar[str] = "rq"

    def default_params(self) -> RQKernelParams:
        return RQKernelParams()

    def kfree(self, params, xa, xb):
        base = 1.0 + sq_dists(xa, xb) / (2.0 * params.alpha * params.lengthscale)
        # base >= 1 so the log is safe under tracing and in a Pallas body.
        return params.vertical * jnp.exp(-params.alpha * jnp.log(base))


@dataclasses.dataclass(frozen=True)
class ARDSquaredExponential(Kernel):
    """SE with one lengthscale per feature dim: k = v * exp(-0.5 sum d_i^2/l_i)."""

    ndim: int = 1

    name: ClassVar[str] = "se_ard"

    def default_params(self) -> ARDKernelParams:
        return ARDKernelParams(lengthscales=jnp.ones((self.ndim,)))

    def kfree(self, params, xa, xb):
        ls = params.lengthscales
        if isinstance(ls, tuple):
            # host-baked Pallas body: a vector constant would be captured by
            # the kernel jaxpr (pallas_call rejects non-scalar consts), so
            # ``concrete_params`` hands lengthscales over as a float tuple
            # and the per-dim scalars inline as literals.
            if len(ls) == 1:  # shared lengthscale broadcasts over D dims
                ls = ls * xa.shape[1]
            d2 = None
            for d, l in enumerate(ls):
                diff = xa[:, d : d + 1] - jnp.transpose(xb[:, d : d + 1])
                term = diff * diff * (1.0 / l)
                d2 = term if d2 is None else d2 + term
            return params.vertical * jnp.exp(-0.5 * d2)
        ls = jnp.asarray(ls, dtype=xa.dtype)
        inv = 1.0 / jnp.sqrt(ls)  # scale features so sq_dists stays on the MXU
        return params.vertical * jnp.exp(-0.5 * sq_dists(xa * inv, xb * inv))

    def base_ndims(self, params) -> ARDKernelParams:
        return ARDKernelParams(lengthscales=1, vertical=0, noise=0)

    def kernel_id(self) -> str:
        return f"se_ard{self.ndim}"


@dataclasses.dataclass(frozen=True)
class White(Kernel):
    """White observation noise: zero off-diagonal, ``noise`` on the diagonal.

    Use inside ``Sum`` to give a composite an explicit noise term (the
    ARBO-style ``C * Matern52 + White`` residual model).
    """

    name: ClassVar[str] = "white"

    def default_params(self) -> WhiteKernelParams:
        return WhiteKernelParams()

    def kfree(self, params, xa, xb):
        return jnp.zeros((xa.shape[0], xb.shape[0]), xa.dtype)

    def diag(self, params):
        return 0.0


@dataclasses.dataclass(frozen=True, init=False)
class Sum(Kernel):
    """k = sum of children; params is the tuple of child params pytrees."""

    children: tuple

    name: ClassVar[str] = "sum"

    def __init__(self, *children: Kernel):
        object.__setattr__(self, "children", tuple(children))

    def default_params(self) -> tuple:
        return tuple(c.default_params() for c in self.children)

    def kfree(self, params, xa, xb):
        parts = [c.kfree(p, xa, xb) for c, p in zip(self.children, params)]
        return sum(parts[1:], parts[0])

    def noise(self, params):
        return sum(c.noise(p) for c, p in zip(self.children, params))

    def diag(self, params):
        return sum(c.diag(p) for c, p in zip(self.children, params))

    def base_ndims(self, params) -> tuple:
        return tuple(c.base_ndims(p) for c, p in zip(self.children, params))

    def kernel_id(self) -> str:
        return "sum(" + ",".join(c.kernel_id() for c in self.children) + ")"


@dataclasses.dataclass(frozen=True, init=False)
class Product(Kernel):
    """k = product of children's noise-free parts; params a tuple of pytrees.

    Child ``noise`` leaves are *ignored* (a product of observation noises
    has no meaning); give the composite noise via ``Sum(..., White())`` or
    the top-level leaf of a child under ``Sum``.
    """

    children: tuple

    name: ClassVar[str] = "product"

    def __init__(self, *children: Kernel):
        object.__setattr__(self, "children", tuple(children))

    def default_params(self) -> tuple:
        return tuple(c.default_params() for c in self.children)

    def kfree(self, params, xa, xb):
        out = self.children[0].kfree(params[0], xa, xb)
        for c, p in zip(self.children[1:], params[1:]):
            out = out * c.kfree(p, xa, xb)
        return out

    def noise(self, params):
        return 0.0

    def diag(self, params):
        out = self.children[0].diag(params[0])
        for c, p in zip(self.children[1:], params[1:]):
            out = out * c.diag(p)
        return out

    def base_ndims(self, params) -> tuple:
        return tuple(c.base_ndims(p) for c, p in zip(self.children, params))

    def kernel_id(self) -> str:
        return "prod(" + ",".join(c.kernel_id() for c in self.children) + ")"


@dataclasses.dataclass(frozen=True)
class Scaled(Kernel):
    """k = scale * child (scale multiplies kfree, diag AND the child's noise)."""

    inner: Kernel

    name: ClassVar[str] = "scaled"

    def default_params(self) -> ScaledParams:
        return ScaledParams(scale=1.0, inner=self.inner.default_params())

    def kfree(self, params, xa, xb):
        return params.scale * self.inner.kfree(params.inner, xa, xb)

    def noise(self, params):
        return params.scale * self.inner.noise(params.inner)

    def diag(self, params):
        return params.scale * self.inner.diag(params.inner)

    def base_ndims(self, params) -> ScaledParams:
        return ScaledParams(scale=0, inner=self.inner.base_ndims(params.inner))

    def kernel_id(self) -> str:
        return f"scaled({self.inner.kernel_id()})"


SQUARED_EXPONENTIAL = SquaredExponential()  # the default kernel everywhere

KERNEL_REGISTRY: dict[str, Callable[..., Kernel]] = {}


def register_kernel(name: str, factory: Callable[..., Kernel]) -> None:
    """Register a kernel factory under ``name`` (``get_kernel`` resolves it)."""
    KERNEL_REGISTRY[name] = factory


def get_kernel(name: str, **kwargs) -> Kernel:
    """Instantiate a registered kernel by name (e.g. ``get_kernel("matern32")``)."""
    try:
        factory = KERNEL_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(KERNEL_REGISTRY)}"
        ) from None
    return factory(**kwargs)


for _cls in (
    SquaredExponential,
    Matern12,
    Matern32,
    Matern52,
    RationalQuadratic,
    ARDSquaredExponential,
    White,
):
    register_kernel(_cls.name, _cls)


def resolve_kernel(kernel) -> Kernel:
    """None -> the SE default; a registry name -> its instance; else as-is."""
    if kernel is None:
        return SQUARED_EXPONENTIAL
    if isinstance(kernel, str):
        return get_kernel(kernel)
    return kernel


# ---------------------------------------------------------------------------
# Params-pytree utilities (concreteness, batching, bucketing)
# ---------------------------------------------------------------------------


def params_concrete(params) -> bool:
    """True iff every hyperparameter leaf is concrete (not traced).

    The Pallas assembly kernels bake hyperparameters in as compile-time
    constants, which is impossible inside a gradient trace; callers use this
    to fall back to the differentiable jnp assembly tile (DESIGN.md §8).
    """
    try:
        for leaf in jax.tree_util.tree_leaves(params):
            np.asarray(leaf)
        return True
    except (TypeError, jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return False


def concrete_params(params):
    """Params pytree as host constants for Pallas baking.

    Scalars become Python floats (inlined as jaxpr literals); vector leaves
    (ARD lengthscales) become float *tuples* — a np/jnp array constant inside
    a Pallas kernel body would be captured by its jaxpr, which ``pallas_call``
    rejects, so vector-aware kernels (``ARDSquaredExponential.kfree``) unroll
    tuple leaves dimension by dimension with scalar literals instead.
    """
    def conv(leaf):
        a = np.asarray(leaf)
        return float(a) if a.ndim == 0 else tuple(float(v) for v in a.ravel())
    return jax.tree_util.tree_map(conv, params)


def _base_ndims_of(params, kernel: Optional[Kernel]):
    if kernel is None:
        return jax.tree_util.tree_map(lambda _: 0, params)
    return resolve_kernel(kernel).base_ndims(params)


def params_per_problem(params, kernel: Optional[Kernel] = None) -> bool:
    """True iff any hyperparameter leaf carries a problem-batch axis (B, ...)."""
    base = _base_ndims_of(params, kernel)
    flags = jax.tree_util.tree_map(
        lambda leaf, nd: jnp.ndim(leaf) > nd, params, base
    )
    return any(jax.tree_util.tree_leaves(flags))


def broadcast_params(params, b: int, kernel: Optional[Kernel] = None):
    """Broadcast every hyperparameter leaf to per-problem shape (B, ...).

    Mixed leaves are legal inputs (e.g. per-problem lengthscales with a
    shared noise); this normalizes them for code that vmaps over the
    problem axis (DESIGN.md §9).  A ``tree_map`` over the params pytree, so
    it works for every registered kernel — ARD vectors gain a leading (B,)
    axis on top of their (D,) base shape.
    """
    base = _base_ndims_of(params, kernel)

    def bcast(leaf, nd):
        leaf = jnp.asarray(leaf)
        if leaf.ndim == nd:
            return jnp.broadcast_to(leaf, (b,) + leaf.shape)
        if leaf.ndim == nd + 1:
            return jnp.broadcast_to(leaf, (b,) + leaf.shape[1:])
        raise ValueError(
            f"hyperparameter leaf of rank {leaf.ndim} is neither shared "
            f"(rank {nd}) nor per-problem (rank {nd + 1})"
        )

    return jax.tree_util.tree_map(bcast, params, base)


def gather_params(params, idx, kernel: Optional[Kernel] = None):
    """Gather per-problem leaves at ``idx``; shared leaves pass through.

    The fleet-bucketing primitive (GPFleet): shared hyperparameters stay
    scalars (one trace serves every bucket) while per-problem leaves are
    gathered into the bucket's (B_bucket, ...) rows.
    """
    base = _base_ndims_of(params, kernel)
    idx = jnp.asarray(idx)

    def gather(leaf, nd):
        leaf = jnp.asarray(leaf)
        return leaf if leaf.ndim == nd else leaf[idx]

    return jax.tree_util.tree_map(gather, params, base)


# ---------------------------------------------------------------------------
# Dense reference assembly (monolithic; the tiled pipeline's ground truth)
# ---------------------------------------------------------------------------


def se_kernel(
    x1: jax.Array,
    x2: jax.Array,
    params: SEKernelParams,
    *,
    diag_offset: Optional[int] = None,
) -> jax.Array:
    """Dense SE covariance block between x1 (n1,D) and x2 (n2,D).

    If ``diag_offset`` is not None, the entry (i, j) with
    ``i + diag_offset == j`` receives the ``+ sigma^2`` noise term, i.e. the
    block lies on the global diagonal with the given column offset.  For the
    full training matrix use ``diag_offset=0``.
    """
    d2 = sq_dists(x1, x2)
    k = params.vertical * jnp.exp(-0.5 / params.lengthscale * d2)
    if diag_offset is not None:
        i = jnp.arange(x1.shape[0])[:, None]
        j = jnp.arange(x2.shape[0])[None, :]
        k = k + jnp.where(i + diag_offset == j, params.noise, 0.0).astype(k.dtype)
    return k


def cov_tile(
    xa: jax.Array,
    xb: jax.Array,
    row0,
    col0,
    params,
    n_valid_r,
    n_valid_c,
    symmetric: bool,
    kernel: Optional[Kernel] = None,
) -> jax.Array:
    """One covariance tile with global-index masking (vmap-friendly).

    xa: (m, D) rows, xb: (mb, D) cols; row0/col0 global offsets (traced or
    static scalars).  Padded region -> identity (symmetric) or zero (cross).
    Symmetric tiles pin the global diagonal to the *exact*
    ``kernel.diag + kernel.noise`` — the expanded-form squared distances
    cancel catastrophically in f32 for large-magnitude inputs, so the
    diagonal is never computed through them.  This is the jnp analogue of
    the Pallas cov-assembly kernel (repro.kernels.cov_assembly) and the
    per-task op behind the ASSEMBLE / CROSS / PRIOR program tasks.
    """
    kernel = resolve_kernel(kernel)
    k = kernel.kfree(params, xa, xb)
    gi = row0 + jnp.arange(xa.shape[0])[:, None]
    gj = col0 + jnp.arange(xb.shape[0])[None, :]
    on_diag = gi == gj
    valid = (gi < n_valid_r) & (gj < n_valid_c)
    if symmetric:
        diag_val = jnp.asarray(kernel.diag(params) + kernel.noise(params))
        k = jnp.where(on_diag, diag_val.astype(k.dtype), k)
        return jnp.where(valid, k, on_diag.astype(k.dtype))
    return jnp.where(valid, k, jnp.zeros((), k.dtype))


def assemble_covariance(
    x: jax.Array,
    params,
    *,
    kernel: Optional[Kernel] = None,
    n_valid: Optional[int] = None,
    dtype=jnp.float32,
) -> jax.Array:
    """Full training covariance K = K_XX + sigma^2 I, optionally padded.

    x: (n_pad, D) where rows >= n_valid are padding (any values).  The padded
    region is overwritten: identity on the diagonal, zero elsewhere.  The
    valid diagonal is pinned to the exact ``diag + noise`` (same contract as
    the tiled assembly — see :func:`cov_tile`).
    """
    kernel = resolve_kernel(kernel)
    x = x.astype(dtype)
    k = kernel.kfree(params, x, x).astype(dtype)
    n_pad = x.shape[0]
    i = jnp.arange(n_pad)[:, None]
    j = jnp.arange(n_pad)[None, :]
    diag_val = jnp.asarray(kernel.diag(params) + kernel.noise(params))
    k = jnp.where(i == j, diag_val.astype(dtype), k)
    if n_valid is not None and n_valid != n_pad:
        valid = (i < n_valid) & (j < n_valid)
        eye = (i == j).astype(dtype)
        k = jnp.where(valid, k, eye)
    return k


def assemble_cross_covariance(
    x_test: jax.Array,
    x_train: jax.Array,
    params,
    *,
    kernel: Optional[Kernel] = None,
    n_test_valid: Optional[int] = None,
    n_train_valid: Optional[int] = None,
    dtype=jnp.float32,
) -> jax.Array:
    """Cross covariance K_{X̂,X} (n̂_pad × n_pad), padded region zeroed."""
    kernel = resolve_kernel(kernel)
    k = kernel.kfree(
        params, x_test.astype(dtype), x_train.astype(dtype)
    ).astype(dtype)
    nt, ntr = k.shape
    if (n_test_valid is not None and n_test_valid != nt) or (
        n_train_valid is not None and n_train_valid != ntr
    ):
        i = jnp.arange(nt)[:, None]
        j = jnp.arange(ntr)[None, :]
        valid = jnp.ones((nt, ntr), dtype=bool)
        if n_test_valid is not None:
            valid &= i < n_test_valid
        if n_train_valid is not None:
            valid &= j < n_train_valid
        k = jnp.where(valid, k, 0.0)
    return k


def assemble_prior_covariance(
    x_test: jax.Array,
    params,
    *,
    kernel: Optional[Kernel] = None,
    n_valid: Optional[int] = None,
    include_noise: bool = False,
    dtype=jnp.float32,
) -> jax.Array:
    """Prior test covariance K_{X̂,X̂}; optionally with observation noise."""
    kernel = resolve_kernel(kernel)
    xt = x_test.astype(dtype)
    k = kernel.kfree(params, xt, xt).astype(dtype)
    n_pad = k.shape[0]
    i = jnp.arange(n_pad)[:, None]
    j = jnp.arange(n_pad)[None, :]
    if include_noise:
        k = k + jnp.where(
            i == j, jnp.asarray(kernel.noise(params)), 0.0
        ).astype(dtype)
    if n_valid is not None and n_valid != n_pad:
        valid = (i < n_valid) & (j < n_valid)
        k = jnp.where(valid, k, 0.0)
    return k
