"""Streaming posterior maintenance: block Cholesky append / evict (DESIGN.md §10).

The serving story of the paper (and GPRat's) assumes a fixed training set:
absorbing one new observation forces a full O(n^3) re-factorization.  This
module turns the cached :class:`repro.core.predict.PosteriorState` into a
*live* artifact:

* :func:`extend_state` absorbs b new observations in O(n^2 b) by growing
  the packed factor one tile-row at a time (the append DAG of
  ``scheduler.append_tasks``, executed by ``executor.run_append``).  A
  partially padded trailing tile is refilled in place first — padding
  always stays at the very end, which is what keeps the scalar ``n_valid``
  masking of the assembly kernels exact.
* :func:`shrink_state` evicts the k *oldest* observations (sliding-window
  semantics) in O(n^2 k) — dropping the leading tile-column of a factor is
  a *positive* rank-m update of the trailing block
  (K22 = L21 L21^T + L22 L22^T), run as the blocked cholupdate sweep of
  ``executor.run_rank_update``.  ``sign=-1`` of the same sweep is the true
  hyperbolic downdate; both share the positivity guardrail below.

Posterior maintenance rides along: the forward-solve chunks beta are
extended incrementally (prefix rows of a grown triangular system never
change), and alpha is re-solved with ONE O(n^2) backward substitution —
``predict`` after an update never re-runs the O(n^3) program.

Numerical stability: every public entry point validates the refreshed
factor/weights for NaNs (a failed Cholesky head — e.g. a non-PD downdate —
surfaces as NaN) and raises :class:`CholeskyUpdateError`; callers
(``GaussianProcess.update`` / ``forget``) catch it and fall back to a full
refactorization.  The f64 path flows through unchanged via the state dtype.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.core import executor, tiling, triangular


def _record_step(kind: str, plan, backend: str, batched: bool, operand) -> None:
    """Dispatch-boundary record for the jitted step fns (DESIGN.md §15).

    The jnp backend jits each step, so executor.run_append/run_rank_update
    only execute at trace time there — the per-dispatch record happens
    here, where ``operand`` is concrete.  The Pallas backend runs the steps
    unjitted and records inside the executor entry points instead.
    """
    if obs.enabled() and backend == "jnp" \
            and not isinstance(operand, jax.core.Tracer):
        executor.record_dispatch(kind, plan, backend=backend, batched=batched)


class CholeskyUpdateError(RuntimeError):
    """The incremental factor update went numerically bad (NaN heads).

    Raised after the fact — the returned state would be poisoned — so
    callers can fall back to a full refactorization of the grown/shrunk
    dataset (the established O(n^3) path)."""


# ---------------------------------------------------------------------------
# jitted step functions (lru-cached per static geometry/config, like
# predict._fused_program_fn; the Pallas backend runs unjitted since its
# assembly bakes hyperparameters and n_valid in as compile-time constants).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _append_step_fn(
    r_tiles: int,
    m_store: int,
    grow: bool,
    n_streams: Optional[int],
    backend: str,
    update_dtype,
    batched: bool,
    batch_dispatch: str,
    mesh=None,
    kernel=None,
):
    """One tile-row append: solve the row, repack the store, extend beta.

    Returns ``fn(lpacked, xc, yc, beta, x_row, y_row, params, n_valid_new)
    -> (lpacked', xc', yc', beta')`` where the primed buffers hold the
    grown (or refilled-in-place) factor and chunk stacks.  ``kernel`` is
    the state's covariance family (hashable — part of the lru key).
    """

    def fn(lpacked, xc, yc, beta, x_row, y_row, params, n_valid_new):
        row = executor.run_append(
            lpacked,
            xc,
            x_row,
            params,
            r_tiles,
            n_valid_new,
            n_streams=n_streams,
            backend=backend,
            update_dtype=update_dtype,
            batch_dispatch=batch_dispatch,
            mesh=mesh,
            kernel=kernel,
        )
        # beta_R = corner^{-1} (y_row - sum_{j<R} row_j beta_j): the prefix
        # of a grown forward-triangular system never changes.
        z = "z" if batched else ""
        off = (slice(None),) if batched else ()
        s = jnp.einsum(
            f"{z}jab,{z}jb->{z}a", row[off + (slice(0, r_tiles),)],
            beta[off + (slice(0, r_tiles),)],
        )
        corner = row[off + (r_tiles,)]
        rhs = (y_row - s).astype(corner.dtype)[..., None]
        beta_new = jax.lax.linalg.triangular_solve(
            corner, rhs, left_side=True, lower=True
        )[..., 0]
        if grow:
            idx = tiling.grow_packed_indices(m_store)
            store = jnp.concatenate([lpacked, row], axis=-3)
            lpacked = store[:, idx] if batched else store[idx]
            xc = jnp.concatenate(
                [xc, x_row[:, None] if batched else x_row[None]], axis=-3
            )
            yc = jnp.concatenate(
                [yc, y_row[:, None] if batched else y_row[None]], axis=-2
            )
            beta = jnp.concatenate(
                [beta, beta_new[:, None] if batched else beta_new[None]], axis=-2
            )
        else:
            slots = tiling.replace_row_indices(r_tiles, m_store)
            lpacked = (
                lpacked.at[:, slots].set(row) if batched
                else lpacked.at[slots].set(row)
            )
            xc = xc.at[off + (r_tiles,)].set(x_row)
            yc = yc.at[off + (r_tiles,)].set(y_row)
            beta = beta.at[off + (r_tiles,)].set(beta_new)
        return lpacked, xc, yc, beta

    return jax.jit(fn) if backend == "jnp" else fn


@functools.lru_cache(maxsize=None)
def _evict_step_fn(
    m_tiles: int, n_streams: Optional[int], backend: str, batch_dispatch: str,
    mesh=None,
):
    """Drop the leading tile-column: positive rank-m update of the trailing
    factor (K22 = L21 L21^T + L22 L22^T)."""
    trailing, evicted = tiling.shrink_packed_indices(m_tiles)

    def fn(lpacked):
        batched = lpacked.ndim == 4
        w = lpacked[:, evicted] if batched else lpacked[evicted]
        sub = lpacked[:, trailing] if batched else lpacked[trailing]
        new_packed, _ = executor.run_rank_update(
            sub,
            w,
            sign=1.0,
            n_streams=n_streams,
            backend=backend,
            batch_dispatch=batch_dispatch,
            mesh=mesh,
        )
        return new_packed

    return jax.jit(fn) if backend == "jnp" else fn


@functools.lru_cache(maxsize=None)
def _resolve_fn(n_streams: Optional[int], forward: bool):
    """Jitted O(n^2) re-solve of the weight chunks off a fresh factor.

    ``forward=False``: beta is given, return alpha only (append path).
    ``forward=True``: solve beta from y chunks too (evict path)."""

    def fn(lpacked, chunks):
        beta = (
            triangular.forward_substitution(lpacked, chunks, n_streams=n_streams)
            if forward
            else chunks
        )
        alpha = triangular.backward_substitution(lpacked, beta, n_streams=n_streams)
        return beta, alpha

    return jax.jit(fn)


def _check(state_arrays, what: str) -> None:
    flat = jnp.concatenate([jnp.ravel(a) for a in state_arrays])
    if bool(jnp.any(jnp.isnan(flat))):
        obs.health_event("nan_guard_trip", what=what)
        raise CholeskyUpdateError(
            f"incremental {what} produced NaNs (non-positive-definite head); "
            "fall back to a full refactorization"
        )


def _live_chunks(state) -> Tuple[jax.Array, jax.Array]:
    """(beta, y_chunks), reconstructing pre-§10 states from the factor:
    beta = L^T alpha and y = L beta are two O(n^2) packed matvecs."""
    beta = state.beta
    if beta is None:
        beta = triangular.packed_matvec(state.lpacked, state.alpha, transpose=True)
    yc = state.y_chunks
    if yc is None:
        yc = triangular.packed_matvec(state.lpacked, beta, transpose=False)
    return beta, yc


# ---------------------------------------------------------------------------
# Public entry points.
# ---------------------------------------------------------------------------


def extend_state(
    state,
    x_new: jax.Array,
    y_new: jax.Array,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    batch_dispatch: str = "flat",
    check_finite: bool = True,
    mesh=None,
):
    """Absorb new observations into a cached posterior in O(n^2 b).

    x_new (b, D) / y_new (b,) — or stacked (B, b, D) / (B, b) for a fleet
    state (every problem absorbs the same count b, keeping the shared tile
    geometry that makes the fleet one program).  Returns a new
    :class:`~repro.core.predict.PosteriorState`; the input state is
    unchanged (jax arrays are immutable — states are cheap snapshots).

    The append walks tile-row by tile-row: a partially padded trailing tile
    is refilled first (recomputing only that row), then whole new rows are
    appended, each one O(n^2 m) — never a full refactorization.  beta grows
    incrementally; alpha is re-solved with one O(n^2) backward substitution
    at the end.
    """
    from repro.core import predict as pred  # cycle: predict imports update

    batched = state.x_chunks.ndim == 4
    m = state.m
    dtype = state.x_chunks.dtype
    x_new = jnp.asarray(x_new, dtype)
    y_new = jnp.asarray(y_new, dtype)
    if x_new.ndim == (2 if batched else 1):  # 1-D problem convenience
        x_new = x_new[..., None]
    want = 3 if batched else 2
    d = state.x_chunks.shape[-1]
    if (
        x_new.ndim != want
        or x_new.shape[-1] != d
        or y_new.shape != x_new.shape[:-1]
    ):
        raise ValueError(
            f"x_new must be {'(B, b, D)' if batched else '(b, D)'} with "
            f"D == {d} and matching y_new; got x {tuple(x_new.shape)}, "
            f"y {tuple(y_new.shape)}"
        )
    b_total = x_new.shape[-2]
    if b_total == 0:
        return state

    lpacked, xc, yc = state.lpacked, state.x_chunks, state.y_chunks
    beta, yc_live = _live_chunks(state)
    yc = yc_live
    n = state.n
    consumed = 0
    off = (slice(None),) if batched else ()
    while consumed < b_total:
        r = n % m
        grow = r == 0
        r_tiles = n // m  # row index R being appended / refilled
        m_store = xc.shape[-3]
        take = min(m - r, b_total - consumed)
        xs = x_new[off + (slice(consumed, consumed + take),)]
        ys = y_new[off + (slice(consumed, consumed + take),)]
        if grow:
            x_row = jnp.zeros(xs.shape[:-2] + (m, xs.shape[-1]), dtype)
            y_row = jnp.zeros(ys.shape[:-1] + (m,), dtype)
        else:
            x_row = xc[off + (r_tiles,)]
            y_row = yc[off + (r_tiles,)]
        x_row = x_row.at[off + (slice(r, r + take),)].set(xs)
        y_row = y_row.at[off + (slice(r, r + take),)].set(ys)
        n_valid_new = n + take
        step = _append_step_fn(
            r_tiles, m_store, grow, n_streams, backend, update_dtype,
            batched, batch_dispatch, mesh if batched else None,
            getattr(state, "kernel", None),
        )
        _record_step(
            "run_append", executor.update_append_plan(r_tiles, m_store, n_streams),
            backend, batched, lpacked,
        )
        lpacked, xc, yc, beta = step(
            lpacked, xc, yc, beta, x_row, y_row, state.params,
            n_valid_new if backend == "pallas" else jnp.asarray(n_valid_new),
        )
        n = n_valid_new
        consumed += take

    _, alpha = _resolve_fn(n_streams, False)(lpacked, beta)
    if check_finite:
        _check((alpha,), "append")
    return pred.PosteriorState(
        lpacked=lpacked, alpha=alpha, x_chunks=xc, n=n, m=m,
        params=state.params, beta=beta, y_chunks=yc, kernel=state.kernel,
    )


def extend_state_ragged(
    state,
    x_new: jax.Array,
    y_new: jax.Array,
    counts,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    batch_dispatch: str = "flat",
    check_finite: bool = True,
    mesh=None,
):
    """Absorb per-problem arrival counts b_i into a ragged fleet state.

    ``state`` is a stacked bucket state (B problems sharing one tile
    geometry, per-problem frontiers in ``state.n_valid``); ``x_new`` is
    (B, b_max, D) with each problem's arrivals in its leading ``counts[i]``
    rows (rows past the count are ignored), ``y_new`` (B, b_max), and
    ``counts`` a host-side (B,) int vector.  Every problem must stay within
    the bucket capacity — crossing a boundary is a *migration*, handled one
    level up by ``gp.GPFleet`` (re-embed via ``tiling.embed_packed``, then
    extend in the destination bucket).

    The sweep (DESIGN.md §11): first scatter all arrivals into the feature /
    target chunks at each problem's own frontier, then refill tile-rows
    R = min_i floor(n_i/m) .. max_i ceil(n_i'/m)-1 in increasing order for
    the WHOLE batch with the final per-problem ``n_valid`` masking both
    axes.  Row refill is idempotent: problems untouched at row R reproduce
    their row (same masked assembly, same frozen prefix) and problems whose
    frontier lies below R reproduce identity padding — so one shared
    B-invariant append plan per row serves every ragged arrival mix.
    """
    from repro.core import predict as pred  # cycle: predict imports update

    if state.x_chunks.ndim != 4:
        raise ValueError("extend_state_ragged needs a stacked (B, ...) state")
    if getattr(state, "n_valid", None) is None:
        raise ValueError("extend_state_ragged needs a state with n_valid")
    m = state.m
    dtype = state.x_chunks.dtype
    bsz, m_store, _, d = state.x_chunks.shape
    capacity = m_store * m
    x_new = jnp.asarray(x_new, dtype)
    y_new = jnp.asarray(y_new, dtype)
    if x_new.ndim == 2:  # 1-D problem convenience
        x_new = x_new[..., None]
    counts = np.asarray(counts, np.int64).reshape(-1)
    if (
        x_new.ndim != 3
        or x_new.shape[0] != bsz
        or x_new.shape[-1] != d
        or y_new.shape != x_new.shape[:-1]
        or counts.shape != (bsz,)
    ):
        raise ValueError(
            f"need x_new (B, b_max, D={d}), matching y_new and counts (B,); "
            f"got x {tuple(x_new.shape)}, y {tuple(y_new.shape)}, "
            f"counts {counts.shape}"
        )
    b_max = x_new.shape[1]
    if np.any(counts < 0) or np.any(counts > b_max):
        raise ValueError(f"counts must lie in [0, b_max={b_max}]: {counts}")
    n_old = np.asarray(state.n_valid, np.int64)
    n_new = n_old + counts
    if np.any(n_new > capacity):
        over = np.nonzero(n_new > capacity)[0].tolist()
        raise ValueError(
            f"problems {over} would outgrow the bucket capacity {capacity}; "
            "migrate them to a larger geometry first (gp.GPFleet does)"
        )
    if not np.any(counts > 0):
        return state

    beta, yc = _live_chunks(state)
    lpacked, xc = state.lpacked, state.x_chunks

    # 1) scatter arrivals at each problem's frontier (out-of-bounds rows —
    #    the per-problem tail past counts[i] — drop).
    nv_dev = jnp.asarray(n_old, jnp.int32)
    cnt_dev = jnp.asarray(counts, jnp.int32)

    def scatter_one(xf, yf, xs, ys, n0, cnt):
        ar = jnp.arange(b_max, dtype=jnp.int32)
        pos = jnp.where(ar < cnt, n0 + ar, capacity)
        return (
            xf.at[pos].set(xs, mode="drop"),
            yf.at[pos].set(ys, mode="drop"),
        )

    xc_flat, yc_flat = jax.vmap(scatter_one)(
        xc.reshape(bsz, capacity, d), yc.reshape(bsz, capacity),
        x_new, y_new, nv_dev, cnt_dev,
    )
    xc = xc_flat.reshape(bsz, m_store, m, d)
    yc = yc_flat.reshape(bsz, m_store, m)

    # 2) refill the affected tile-rows, lowest first, whole batch at once.
    growing = counts > 0
    r_lo = int(np.min(n_old[growing]) // m)
    r_hi = int(np.max(n_new[growing] - 1) // m)
    nv_new_dev = jnp.asarray(n_new, jnp.int32)
    for r in range(r_lo, r_hi + 1):
        step = _append_step_fn(
            r, m_store, False, n_streams, backend, update_dtype,
            True, batch_dispatch, mesh, getattr(state, "kernel", None),
        )
        _record_step(
            "run_append", executor.update_append_plan(r, m_store, n_streams),
            backend, True, lpacked,
        )
        lpacked, xc, yc, beta = step(
            lpacked, xc, yc, beta, xc[:, r], yc[:, r], state.params, nv_new_dev
        )

    _, alpha = _resolve_fn(n_streams, False)(lpacked, beta)
    if check_finite:
        _check((alpha,), "ragged append")
    return pred.PosteriorState(
        lpacked=lpacked, alpha=alpha, x_chunks=xc, n=state.n, m=m,
        params=state.params, beta=beta, y_chunks=yc, n_valid=nv_new_dev,
        kernel=state.kernel,
    )


def shrink_state(
    state,
    k: int,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    batch_dispatch: str = "flat",
    check_finite: bool = True,
    mesh=None,
):
    """Evict the k oldest observations from a cached posterior in O(n^2 k).

    ``k`` must be a multiple of the tile size (whole leading tile-columns —
    the sliding-window serving shape; ``GaussianProcess.forget`` falls back
    to refactorization for unaligned k) and must leave at least one valid
    observation.  Each evicted column is a positive rank-m update of the
    trailing factor; beta/alpha are re-solved with one O(n^2) forward +
    backward substitution pass at the end.
    """
    from repro.core import predict as pred

    m = state.m
    if k == 0:
        return state
    if k % m != 0:
        raise ValueError(
            f"shrink_state evicts whole leading tiles: k={k} is not a "
            f"multiple of the tile size {m} (refactorize instead)"
        )
    t = k // m
    m_tiles = state.x_chunks.shape[-3]
    if t >= m_tiles or k >= state.n:
        raise ValueError(
            f"cannot evict {k} of {state.n} observations ({m_tiles} tiles)"
        )
    batched = state.x_chunks.ndim == 4
    off = (slice(None),) if batched else ()
    _, yc = _live_chunks(state)
    lpacked = state.lpacked
    for step in range(t):
        _record_step(
            "run_rank_update",
            executor.update_rank_plan(m_tiles - step - 1, n_streams),
            backend, batched, lpacked,
        )
        lpacked = _evict_step_fn(
            m_tiles - step, n_streams, backend, batch_dispatch,
            mesh if batched else None,
        )(lpacked)
    xc = state.x_chunks[off + (slice(t, None),)]
    yc = yc[off + (slice(t, None),)]
    beta, alpha = _resolve_fn(n_streams, True)(lpacked, yc)
    if check_finite:
        _check((alpha,), "evict")
    return pred.PosteriorState(
        lpacked=lpacked, alpha=alpha, x_chunks=xc, n=state.n - k, m=m,
        params=state.params, beta=beta, y_chunks=yc, kernel=state.kernel,
    )


def downdate_factor(
    lpacked: jax.Array,
    w: jax.Array,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    check_finite: bool = True,
) -> jax.Array:
    """True rank-b downdate: chol(L L^T - W W^T) via hyperbolic rotations.

    w: (M, m, m) carry blocks (zero-padded beyond the rank).  Raises
    :class:`CholeskyUpdateError` when L L^T - W W^T is not positive
    definite (the Cholesky heads go NaN) — the guardrail the sliding-window
    path shares.  The inverse of :func:`update_factor`.
    """
    new_packed, _ = executor.run_rank_update(
        lpacked, w, sign=-1.0, n_streams=n_streams, backend=backend
    )
    if check_finite:
        _check((new_packed,), "downdate")
    return new_packed


def update_factor(
    lpacked: jax.Array,
    w: jax.Array,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    check_finite: bool = True,
) -> jax.Array:
    """Positive rank-b update: chol(L L^T + W W^T) (always PD in exact
    arithmetic; NaN-checked for numerical failures)."""
    new_packed, _ = executor.run_rank_update(
        lpacked, w, sign=1.0, n_streams=n_streams, backend=backend
    )
    if check_finite:
        _check((new_packed,), "update")
    return new_packed


obs.register_cache("update.append_step_fn", _append_step_fn)
obs.register_cache("update.evict_step_fn", _evict_step_fn)
obs.register_cache("update.resolve_fn", _resolve_fn)
