"""Tiled right-looking Cholesky decomposition (paper Fig. 1) on packed tiles.

The factorization runs on the packed symmetric-lower store of
:mod:`repro.core.tiling` through the level-batched executor (DESIGN.md
§2–3): the ASAP level schedule from :mod:`repro.core.scheduler` is compiled
by :mod:`repro.core.executor` into one batched kernel per (level, op,
stream-chunk).  Independent tasks from *different* columns batch together
(e.g. the GEMM tail of column j with the TRSM panel of column j+1) — the
cross-column overlap HPX dataflow achieves with its stream pool.  (A legacy
per-column loop baseline was removed once the executor covered every
caller; ``monolithic_cholesky`` remains the reference baseline.)

``n_streams`` is the CUDA-stream-pool analogue:

* ``n_streams=None``  — whole-level (resp. whole-panel) batching: the
  TPU-native limit (maximum exposed concurrency).
* ``n_streams=s``     — round-robin chunks of at most ``s`` batched tasks,
  reproducing the paper's stream-pool sweep.
* ``n_streams=1``     — fully sequential tile-by-tile tasks (paper's single
  stream / pure dataflow-ordered baseline).

Because XLA schedules on data dependencies (like HPX dataflow), chunks with no
mutual dependencies may still overlap; ``n_streams`` controls the *batching
granularity* the compiler sees, which is the knob that matters on TPU.

Backends: ``jnp`` (XLA ops) or ``pallas`` (explicit VMEM-tiled kernels from
:mod:`repro.kernels`).  ``update_dtype`` enables the paper's future-work mixed
precision: trailing SYRK/GEMM updates accumulate through a lower-precision
matmul while panels stay in the storage dtype.

Differentiability (DESIGN.md §8): both backends are traceable under
``jax.grad`` — the jnp tile ops natively, the Pallas tile ops through their
reference VJP hooks (repro.kernels.ops).  The trainable NLML
(``mll.nlml_tiled``) nevertheless defaults to a blocked reverse-mode
``custom_vjp`` that never differentiates back through the factorization's
wavefront launches: its backward pass re-uses this factor to build K^{-1}
with one tiled matrix solve + gram (triangular.kinv_tiles_from_factor).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import executor, tiling

# Tile-op definitions live in the executor; re-exported here for backwards
# compatibility.
from repro.core.executor import (  # noqa: F401
    _gemm_jnp,
    _potrf_jnp,
    _syrk_jnp,
    _trsm_jnp,
    get_ops as _get_ops,
)


# ---------------------------------------------------------------------------
# The tiled factorization.
# ---------------------------------------------------------------------------


def tiled_cholesky(
    packed: jax.Array,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
) -> jax.Array:
    """Factor a packed symmetric-lower tile store in place: K -> L.

    packed: (T, m, m) with T = M(M+1)/2 (see tiling.pack_lower).
    Returns the packed Cholesky factor (diagonal tiles lower-triangular),
    computed through the level-batched executor (the Schedule is the
    execution plan).
    """
    return executor.run_cholesky(
        packed, n_streams=n_streams, backend=backend, update_dtype=update_dtype
    )


# ---------------------------------------------------------------------------
# Convenience wrappers.
# ---------------------------------------------------------------------------


def cholesky_dense_via_tiles(
    a: jax.Array,
    m: int,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
) -> jax.Array:
    """Dense (n,n) SPD -> dense lower Cholesky factor, via the tiled path."""
    packed = tiling.pack_lower(a, m)
    lpacked = tiled_cholesky(
        packed,
        n_streams=n_streams,
        backend=backend,
        update_dtype=update_dtype,
    )
    return tiling.unpack_lower(lpacked, fill="lower")


def monolithic_cholesky(a: jax.Array) -> jax.Array:
    """The cuSOLVER-reference analogue: XLA's single-call Cholesky."""
    return jnp.linalg.cholesky(a)
