"""Tiled right-looking Cholesky decomposition (paper Fig. 1) on packed tiles.

The factorization runs on the packed symmetric-lower store of
:mod:`repro.core.tiling`.  Two execution strategies exist (DESIGN.md §2–3):

* ``schedule=True`` (default) — the level-batched executor: the ASAP level
  schedule from :mod:`repro.core.scheduler` is compiled by
  :mod:`repro.core.executor` into one batched kernel per (level, op,
  stream-chunk).  Independent tasks from *different* columns batch together
  (e.g. the GEMM tail of column j with the TRSM panel of column j+1) —
  the cross-column overlap HPX dataflow achieves with its stream pool.
* ``schedule=False`` — the legacy per-column loop, kept as a benchmark
  baseline: TRSM -> SYRK -> GEMM serialized within each column.

``n_streams`` is the CUDA-stream-pool analogue in both modes:

* ``n_streams=None``  — whole-level (resp. whole-panel) batching: the
  TPU-native limit (maximum exposed concurrency).
* ``n_streams=s``     — round-robin chunks of at most ``s`` batched tasks,
  reproducing the paper's stream-pool sweep.
* ``n_streams=1``     — fully sequential tile-by-tile tasks (paper's single
  stream / pure dataflow-ordered baseline).

Because XLA schedules on data dependencies (like HPX dataflow), chunks with no
mutual dependencies may still overlap; ``n_streams`` controls the *batching
granularity* the compiler sees, which is the knob that matters on TPU.

Backends: ``jnp`` (XLA ops) or ``pallas`` (explicit VMEM-tiled kernels from
:mod:`repro.kernels`).  ``update_dtype`` enables the paper's future-work mixed
precision: trailing SYRK/GEMM updates accumulate through a lower-precision
matmul while panels stay in the storage dtype.

Differentiability (DESIGN.md §8): both backends are traceable under
``jax.grad`` — the jnp tile ops natively, the Pallas tile ops through their
reference VJP hooks (repro.kernels.ops).  The trainable NLML
(``mll.nlml_tiled``) nevertheless defaults to a blocked reverse-mode
``custom_vjp`` that never differentiates back through the factorization's
wavefront launches: its backward pass re-uses this factor to build K^{-1}
with one tiled matrix solve + gram (triangular.kinv_tiles_from_factor).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor, tiling

# Tile-op definitions live in the executor (shared by both strategies);
# re-exported here for backwards compatibility.
from repro.core.executor import (  # noqa: F401
    _gemm_jnp,
    _potrf_jnp,
    _syrk_jnp,
    _trsm_jnp,
    get_ops as _get_ops,
)


# ---------------------------------------------------------------------------
# The tiled factorization.
# ---------------------------------------------------------------------------


def tiled_cholesky(
    packed: jax.Array,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    schedule: bool = True,
) -> jax.Array:
    """Factor a packed symmetric-lower tile store in place: K -> L.

    packed: (T, m, m) with T = M(M+1)/2 (see tiling.pack_lower).
    Returns the packed Cholesky factor (diagonal tiles lower-triangular).

    ``schedule=True`` runs the level-batched executor (the Schedule is the
    execution plan); ``schedule=False`` runs the legacy per-column loop.
    """
    if schedule:
        return executor.run_cholesky(
            packed, n_streams=n_streams, backend=backend, update_dtype=update_dtype
        )
    return _column_loop_cholesky(
        packed, n_streams=n_streams, backend=backend, update_dtype=update_dtype
    )


def _column_loop_cholesky(
    packed: jax.Array,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
) -> jax.Array:
    """Legacy baseline: serialize TRSM -> SYRK -> GEMM within each column."""
    m_tiles = executor.m_tiles_of_packed(packed)
    potrf, trsm, syrk, gemm = _get_ops(backend)
    trsm_b = jax.vmap(trsm, in_axes=(None, 0))
    syrk_b = jax.vmap(functools.partial(syrk, update_dtype=update_dtype))
    gemm_b = jax.vmap(functools.partial(gemm, update_dtype=update_dtype))

    for j in range(m_tiles):
        dslot = tiling.packed_index(j, j, m_tiles)
        ljj = potrf(packed[dslot])
        packed = packed.at[dslot].set(ljj)
        n_below = m_tiles - j - 1
        if n_below == 0:
            continue

        # --- TRSM panel: tiles (j+1..M-1, j), contiguous slots ------------
        lo, hi = dslot + 1, dslot + 1 + n_below
        for c0, c1 in _chunks(n_below, n_streams):
            sol = trsm_b(ljj, jax.lax.dynamic_slice_in_dim(packed, lo + c0, c1 - c0))
            packed = jax.lax.dynamic_update_slice_in_dim(packed, sol, lo + c0, axis=0)
        panel = packed[lo:hi]  # (n_below, m, m), rows j+1..M-1

        # --- trailing update: SYRK on diagonals, GEMM off-diagonal --------
        # SYRK: tile (i, i) -= L(i,j) L(i,j)^T      for i in j+1..M-1
        syrk_slots = np.array(
            [tiling.packed_index(i, i, m_tiles) for i in range(j + 1, m_tiles)]
        )
        for c0, c1 in _chunks(n_below, n_streams):
            sl = syrk_slots[c0:c1]
            packed = packed.at[sl].set(syrk_b(packed[sl], panel[c0:c1]))

        # GEMM: tile (i, k) -= L(i,j) L(k,j)^T      for j < k < i < M
        gi, gk, gslots = _gemm_indices(j, m_tiles)
        for c0, c1 in _chunks(len(gslots), n_streams):
            sl = gslots[c0:c1]
            a = panel[gi[c0:c1] - (j + 1)]
            b = panel[gk[c0:c1] - (j + 1)]
            packed = packed.at[sl].set(gemm_b(packed[sl], a, b))
    return packed


@functools.lru_cache(maxsize=None)
def _gemm_indices_cached(j: int, m_tiles: int):
    gi, gk, gslots = [], [], []
    for i in range(j + 1, m_tiles):
        for k in range(j + 1, i):
            gi.append(i)
            gk.append(k)
            gslots.append(tiling.packed_index(i, k, m_tiles))
    return (np.array(gi, np.int32), np.array(gk, np.int32), np.array(gslots, np.int32))


def _gemm_indices(j: int, m_tiles: int):
    return _gemm_indices_cached(j, m_tiles)


def _chunks(n: int, n_streams: Optional[int]):
    """(start, stop) chunk bounds covering range(n) with width n_streams."""
    if n <= 0:
        return []
    if n_streams is None or n_streams >= n:
        return [(0, n)]
    return [(i, min(i + n_streams, n)) for i in range(0, n, n_streams)]


# ---------------------------------------------------------------------------
# Convenience wrappers.
# ---------------------------------------------------------------------------


def cholesky_dense_via_tiles(
    a: jax.Array,
    m: int,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    schedule: bool = True,
) -> jax.Array:
    """Dense (n,n) SPD -> dense lower Cholesky factor, via the tiled path."""
    packed = tiling.pack_lower(a, m)
    lpacked = tiled_cholesky(
        packed,
        n_streams=n_streams,
        backend=backend,
        update_dtype=update_dtype,
        schedule=schedule,
    )
    return tiling.unpack_lower(lpacked, fill="lower")


def monolithic_cholesky(a: jax.Array) -> jax.Array:
    """The cuSOLVER-reference analogue: XLA's single-call Cholesky."""
    return jnp.linalg.cholesky(a)
