"""Tiled right-looking Cholesky decomposition (paper Fig. 1) on packed tiles.

The factorization runs on the packed symmetric-lower store of
:mod:`repro.core.tiling` and emits, per step J:

    POTRF(J,J);  TRSM(I,J) for I>J;  SYRK(I,I) & GEMM(I,K) for J<K<I

Execution strategies (the CUDA-stream analogue, see DESIGN.md §2):

* ``n_streams=None``  — whole-panel batching: all TRSMs of the column are one
  batched triangular solve, the whole trailing update is one batched matmul.
  This is the TPU-native limit (maximum exposed concurrency).
* ``n_streams=s``     — each panel/update is issued in round-robin chunks of
  at most ``s`` batched tasks, reproducing the paper's stream-pool sweep.
* ``n_streams=1``     — fully sequential tile-by-tile tasks (paper's single
  stream / pure dataflow-ordered baseline).

Because XLA schedules on data dependencies (like HPX dataflow), chunks with no
mutual dependencies may still overlap; ``n_streams`` controls the *batching
granularity* the compiler sees, which is the knob that matters on TPU.

Backends: ``jnp`` (XLA ops) or ``pallas`` (explicit VMEM-tiled kernels from
:mod:`repro.kernels`).  ``update_dtype`` enables the paper's future-work mixed
precision: trailing SYRK/GEMM updates accumulate through a lower-precision
matmul while panels stay in the storage dtype.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling


# ---------------------------------------------------------------------------
# Tile-level ops (jnp backend).  a/b are (m, m) tiles; batched via vmap.
# ---------------------------------------------------------------------------


def _potrf_jnp(a: jax.Array) -> jax.Array:
    return jnp.linalg.cholesky(a)


def _trsm_jnp(ljj: jax.Array, b: jax.Array) -> jax.Array:
    # Solve X @ L_JJ^T = B  (right-looking panel update: L_IJ = K_IJ L_JJ^{-T})
    return jax.lax.linalg.triangular_solve(
        ljj, b, left_side=False, lower=True, transpose_a=True
    )


def _syrk_jnp(kii: jax.Array, lij: jax.Array, update_dtype=None) -> jax.Array:
    a = lij if update_dtype is None else lij.astype(update_dtype)
    upd = (a @ a.T).astype(kii.dtype)
    return kii - upd


def _gemm_jnp(kik: jax.Array, lij: jax.Array, lkj: jax.Array, update_dtype=None) -> jax.Array:
    a, b = lij, lkj
    if update_dtype is not None:
        a, b = a.astype(update_dtype), b.astype(update_dtype)
    upd = (a @ b.T).astype(kik.dtype)
    return kik - upd


def _get_ops(backend: str):
    if backend == "jnp":
        return _potrf_jnp, _trsm_jnp, _syrk_jnp, _gemm_jnp
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.potrf, kops.trsm, kops.syrk, kops.gemm
    raise ValueError(f"unknown backend: {backend}")


# ---------------------------------------------------------------------------
# The tiled factorization.
# ---------------------------------------------------------------------------


def tiled_cholesky(
    packed: jax.Array,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
) -> jax.Array:
    """Factor a packed symmetric-lower tile store in place: K -> L.

    packed: (T, m, m) with T = M(M+1)/2 (see tiling.pack_lower).
    Returns the packed Cholesky factor (diagonal tiles lower-triangular).
    """
    t, m, _ = packed.shape
    m_tiles = int((np.sqrt(8 * t + 1) - 1) // 2)
    if tiling.num_packed_tiles(m_tiles) != t:
        raise ValueError(f"{t} is not a triangular number of tiles")
    potrf, trsm, syrk, gemm = _get_ops(backend)
    trsm_b = jax.vmap(trsm, in_axes=(None, 0))
    syrk_b = jax.vmap(functools.partial(syrk, update_dtype=update_dtype))
    gemm_b = jax.vmap(functools.partial(gemm, update_dtype=update_dtype))

    for j in range(m_tiles):
        dslot = tiling.packed_index(j, j, m_tiles)
        ljj = potrf(packed[dslot])
        packed = packed.at[dslot].set(ljj)
        n_below = m_tiles - j - 1
        if n_below == 0:
            continue

        # --- TRSM panel: tiles (j+1..M-1, j), contiguous slots ------------
        lo, hi = dslot + 1, dslot + 1 + n_below
        for c0, c1 in _chunks(n_below, n_streams):
            sol = trsm_b(ljj, jax.lax.dynamic_slice_in_dim(packed, lo + c0, c1 - c0))
            packed = jax.lax.dynamic_update_slice_in_dim(packed, sol, lo + c0, axis=0)
        panel = packed[lo:hi]  # (n_below, m, m), rows j+1..M-1

        # --- trailing update: SYRK on diagonals, GEMM off-diagonal --------
        # SYRK: tile (i, i) -= L(i,j) L(i,j)^T      for i in j+1..M-1
        syrk_slots = np.array(
            [tiling.packed_index(i, i, m_tiles) for i in range(j + 1, m_tiles)]
        )
        for c0, c1 in _chunks(n_below, n_streams):
            sl = syrk_slots[c0:c1]
            packed = packed.at[sl].set(syrk_b(packed[sl], panel[c0:c1]))

        # GEMM: tile (i, k) -= L(i,j) L(k,j)^T      for j < k < i < M
        gi, gk, gslots = _gemm_indices(j, m_tiles)
        for c0, c1 in _chunks(len(gslots), n_streams):
            sl = gslots[c0:c1]
            a = panel[gi[c0:c1] - (j + 1)]
            b = panel[gk[c0:c1] - (j + 1)]
            packed = packed.at[sl].set(gemm_b(packed[sl], a, b))
    return packed


@functools.lru_cache(maxsize=None)
def _gemm_indices_cached(j: int, m_tiles: int):
    gi, gk, gslots = [], [], []
    for i in range(j + 1, m_tiles):
        for k in range(j + 1, i):
            gi.append(i)
            gk.append(k)
            gslots.append(tiling.packed_index(i, k, m_tiles))
    return (np.array(gi, np.int32), np.array(gk, np.int32), np.array(gslots, np.int32))


def _gemm_indices(j: int, m_tiles: int):
    return _gemm_indices_cached(j, m_tiles)


def _chunks(n: int, n_streams: Optional[int]):
    """(start, stop) chunk bounds covering range(n) with width n_streams."""
    if n <= 0:
        return []
    if n_streams is None or n_streams >= n:
        return [(0, n)]
    return [(i, min(i + n_streams, n)) for i in range(0, n, n_streams)]


# ---------------------------------------------------------------------------
# Convenience wrappers.
# ---------------------------------------------------------------------------


def cholesky_dense_via_tiles(
    a: jax.Array,
    m: int,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
) -> jax.Array:
    """Dense (n,n) SPD -> dense lower Cholesky factor, via the tiled path."""
    packed = tiling.pack_lower(a, m)
    lpacked = tiled_cholesky(
        packed, n_streams=n_streams, backend=backend, update_dtype=update_dtype
    )
    return tiling.unpack_lower(lpacked, fill="lower")


def monolithic_cholesky(a: jax.Array) -> jax.Array:
    """The cuSOLVER-reference analogue: XLA's single-call Cholesky."""
    return jnp.linalg.cholesky(a)
