"""Tiled Nyström low-rank tier: O(n m²) approximate GP regression (DESIGN.md §14).

The exact tier factorizes the n×n covariance; this tier factorizes only the
m×m *inner system* of the DTC/Nyström approximation (m = number of inducing
points, m ≪ n):

    A  = K_uu + σ⁻² K_un K_nu                     (m × m)
    μ* = σ⁻² K_*u A⁻¹ K_un y
    Σ* = K_** − K_*u K_uu⁻¹ K_u* + K_*u A⁻¹ K_u*

Everything n-sized goes through the same tiled bulk-op machinery as the
exact tier: K_un is a (MU × M) tile grid assembled by the CROSS family, the
contraction c = K_un y is the LRGEMM bulk-op family
(``executor.run_lowrank_contraction``), and the m×m factorizations reuse
the fused POTRF/TRSM/SYRK pipeline — the Plans are method-invariant, so
the Plan cache is shared with the exact tier.

Numerically the inner system is held in *whitened* (SGPR) form: with
W = L_uu⁻¹ K_un,

    B  = I + σ⁻² W Wᵀ        so that        A = L_uu B L_uuᵀ.

A itself is badly conditioned in float32 (its scale grows like σ⁻² n while
its smallest eigenvalue is the K_uu jitter), but B's eigenvalues are ≥ 1 by
construction, so chol(B) never goes indefinite.  All A⁻¹ applications
become L_uu/L_B triangular-solve sandwiches, and
log det A − log det K_uu = log det B falls out of L_B's diagonal directly.

The NLML uses the Woodbury identity + matrix determinant lemma (see
``mll.nlml_lowrank``), so training is O(n m²) per step too.

Inducing-point selection (``select_inducing``) supports a strided subset of
the training inputs, a few Lloyd iterations of k-means ("kmeans-lite"), or
an explicit user-supplied set.  The selected inducing inputs always pass
through ``jax.lax.stop_gradient`` — hyperparameter gradients treat u as
fixed (standard sparse-GP practice), which also keeps the hand-derived
custom VJP in ``mll`` consistent with autodiff of this builder.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor
from repro.core import kernels_math as km
from repro.core import predict as pred
from repro.core import tiling, triangular

# K_uu is regularized with a small jitter (NOT the noise variance) so the
# approximation converges to the exact GP as m -> n.  1e-4 is the float32
# floor: SE Gram matrices are numerically rank-deficient and chol(K_uu)
# needs the jitter to dominate the ~eps * m roundoff in the factorization;
# pass a smaller value explicitly when building float64 states.
DEFAULT_JITTER = 1e-4


# ---------------------------------------------------------------------------
# Inducing-point selection.
# ---------------------------------------------------------------------------


def _subset_indices(mu: int, nv) -> jax.Array:
    """Strided subset indices, ragged-safe: distinct for the first min(mu, nv)
    rows even when nv < mu (the tail repeats the last valid point)."""
    nv = jnp.asarray(nv, jnp.int32)
    step = jnp.maximum(jnp.minimum(mu, nv), 1)
    idx = (jnp.arange(mu, dtype=jnp.int32) * nv) // step
    return jnp.clip(idx, 0, jnp.maximum(nv - 1, 0))


def _kmeans_lite(x: jax.Array, mu: int, nv, iters: int) -> jax.Array:
    """A few Lloyd iterations, pure jnp; rows >= nv are masked out."""
    n = x.shape[0]
    centers = x[_subset_indices(mu, nv)]
    valid = (jnp.arange(n) < nv)[:, None]  # (n, 1)
    for _ in range(iters):
        d2 = km.sq_dists(x, centers)  # (n, mu)
        assign = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(assign, mu, dtype=x.dtype) * valid
        counts = jnp.sum(onehot, axis=0)  # (mu,)
        sums = onehot.T @ x  # (mu, D)
        centers = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], centers
        )
    return centers


def _select_one(x, m_inducing, strategy, nv, kmeans_iters):
    if strategy == "subset":
        return x[_subset_indices(m_inducing, nv)]
    if strategy == "kmeans-lite":
        return _kmeans_lite(x, m_inducing, nv, kmeans_iters)
    raise ValueError(f"unknown inducing strategy: {strategy!r}")


def select_inducing(
    x: jax.Array,
    m_inducing: int,
    *,
    strategy: str = "subset",
    inducing: Optional[jax.Array] = None,
    n_valid=None,
    kmeans_iters: int = 4,
) -> Tuple[jax.Array, object]:
    """Pick inducing inputs u from training inputs x.

    Returns ``(u, mu_valid)`` where u is (m_inducing, D) — or (B, m_inducing,
    D) for batched x — and ``mu_valid`` is the per-problem count of distinct
    inducing points (None when every problem fills all m_inducing slots).
    u is wrapped in ``stop_gradient``: hyperparameter training treats the
    inducing set as fixed.
    """
    if inducing is not None:
        u = jnp.asarray(inducing)
        if u.shape[-2] != m_inducing:
            raise ValueError(
                f"explicit inducing set has {u.shape[-2]} points, expected "
                f"m_inducing={m_inducing}"
            )
        return jax.lax.stop_gradient(u), None
    batched = x.ndim == 3
    if n_valid is None:
        nv = x.shape[-2]
        nv = jnp.full((x.shape[0],), nv, jnp.int32) if batched else nv
    else:
        nv = jnp.asarray(n_valid, jnp.int32)
    if batched:
        u = jax.vmap(
            lambda xi, nvi: _select_one(xi, m_inducing, strategy, nvi, kmeans_iters)
        )(x, nv)
    else:
        u = _select_one(x, m_inducing, strategy, nv, kmeans_iters)
    mu_valid = jnp.minimum(m_inducing, nv)
    if not batched and isinstance(nv, int):
        mu_valid = min(m_inducing, nv)
        if mu_valid == m_inducing:
            mu_valid = None
    return jax.lax.stop_gradient(u), mu_valid


# ---------------------------------------------------------------------------
# Low-rank posterior state.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LowRankState:
    """Cached Nyström pieces — everything needed for O(m²)-per-test-point
    prediction and O(m³) streaming absorption of new data.

    Shapes are written single-problem; every array field grows a leading
    (B,) axis under problem batching.
    """

    u_chunks: jax.Array  # (MU, m, D) padded inducing chunks
    luu_packed: jax.Array  # packed lower tiles of chol(K_uu + jitter I)
    b_packed: jax.Array  # packed lower tiles of B = I + s^-2 W W^T (unfactored)
    lb_packed: jax.Array  # packed lower tiles of chol(B)
    c_chunks: jax.Array  # (MU, m) tiled  c = K_un y
    gamma: jax.Array  # (MU, m) tiled  A^{-1} c  (A = L_uu B L_uu^T)
    yty: jax.Array  # scalar (or (B,))  yᵀy
    n: int  # padded training-point count
    m: int  # tile size
    m_inducing: int
    params: object
    jitter: float
    mu_valid: Optional[jax.Array] = None  # (B,) or None
    n_valid: Optional[jax.Array] = None  # (B,) or None
    kernel: object = km.SQUARED_EXPONENTIAL


# ---------------------------------------------------------------------------
# Assembly helpers.
# ---------------------------------------------------------------------------


def _retune_diag(packed, mu_tiles, m, delta, mu_valid, batched):
    """Shift the *valid* diagonal of packed symmetric tiles by ``delta``.

    Symmetric assembly pins the diagonal to kernel.diag + noise; the inner
    matrices here want jitter instead, so post-correct by
    delta = jitter - noise on rows < mu_valid (padding rows keep their
    identity pinning).  Works uniformly for every kernel family.
    """
    idx = np.array([tiling.packed_index(p, p, mu_tiles) for p in range(mu_tiles)])
    take, put, _ = executor._env_ops(batched)
    diag = take(packed, idx)  # (..., MU, m, m)
    row = jnp.arange(mu_tiles * m).reshape(mu_tiles, m)
    if mu_valid is None:
        mask = jnp.ones((mu_tiles, m), bool)
    elif batched:
        mask = row[None] < jnp.asarray(mu_valid, jnp.int32)[:, None, None]
    else:
        mask = row < jnp.asarray(mu_valid, jnp.int32)
    eye = jnp.eye(m, dtype=packed.dtype)
    # delta may be a scalar or per-problem (B,); align it under the (MU, m) mask
    delta = jnp.asarray(delta)[..., None, None]
    shift = jnp.where(mask, delta, 0.0)[..., :, :, None] * eye
    return put(packed, idx, diag + shift.astype(packed.dtype))


def _assemble_kuu(u_chunks, params, mu_valid, *, backend, kernel, batched):
    """Packed lower tiles of K_uu (diag pinned to k(0,0) + noise; identity
    padding past mu_valid)."""
    if batched:
        b = u_chunks.shape[0]
        mu = u_chunks.shape[1] * u_chunks.shape[2]
        mv = (
            jnp.full((b,), mu, jnp.int32)
            if mu_valid is None
            else jnp.broadcast_to(jnp.asarray(mu_valid, jnp.int32), (b,))
        )
        bp = pred._broadcast_params(params, b, kernel)
        return jax.vmap(
            lambda uc, p, v: pred.assemble_packed_covariance(uc, p, v, kernel=kernel)
        )(u_chunks, bp, mv)
    mv = u_chunks.shape[0] * u_chunks.shape[1] if mu_valid is None else mu_valid
    use_pallas = backend == "pallas" and km.params_concrete(params)
    return pred.assemble_packed_covariance(
        u_chunks, params, mv,
        backend="pallas" if use_pallas else "jnp", kernel=kernel,
    )


def _assemble_cross(u_chunks, x_chunks, params, mu_valid, n_valid, *, backend, kernel, batched):
    """K_un tile grid (MU, M, m, m) — rows = inducing, cols = training."""
    if batched:
        return pred.assemble_cross_tiles_batched(
            u_chunks, x_chunks, params, mu_valid, n_valid, kernel=kernel
        )
    use_pallas = backend == "pallas" and km.params_concrete(params)
    mu = u_chunks.shape[0] * u_chunks.shape[1]
    n = x_chunks.shape[0] * x_chunks.shape[1]
    return pred.assemble_cross_tiles(
        u_chunks,
        x_chunks,
        params,
        mu if mu_valid is None else mu_valid,
        n if n_valid is None else n_valid,
        backend="pallas" if use_pallas else "jnp",
        kernel=kernel,
    )


def _packed_from_grid(grid, mu_tiles, batched):
    """Gather the lower-triangle tiles of a symmetric (MU, MU, m, m) grid
    into packed order."""
    rows, cols = tiling._packed_coords(mu_tiles)
    if batched:
        return grid[:, rows, cols]
    return grid[rows, cols]


def _packed_eye(mu_tiles, m, dtype):
    """Packed lower tiles of the (MU*m × MU*m) identity."""
    rows, cols = tiling._packed_coords(mu_tiles)
    base = np.zeros((len(rows), m, m), np.float64)
    base[rows == cols] = np.eye(m)
    return jnp.asarray(base, dtype)


def _inner_solve(luu, lb, rhs, n_streams):
    """gamma = A^{-1} rhs via the whitened sandwich
    L_uu^-T L_B^-T L_B^-1 L_uu^-1 rhs (four triangular sweeps)."""
    z = executor.run_solve(luu, rhs, lower=True, n_streams=n_streams)
    z = executor.run_solve(lb, z, lower=True, n_streams=n_streams)
    z = executor.run_solve(lb, z, lower=False, n_streams=n_streams)
    return executor.run_solve(luu, z, lower=False, n_streams=n_streams)


# ---------------------------------------------------------------------------
# State construction.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_fn(cfg):
    (n_streams, backend, update_dtype, batch_dispatch, kernel, jitter, _dt, batched) = cfg
    z = "z" if batched else ""

    def build(u_chunks, x_chunks, y_chunks, params, mu_valid, n_valid):
        kuu = _assemble_kuu(
            u_chunks, params, mu_valid, backend=backend, kernel=kernel, batched=batched
        )
        mu_tiles, m = (u_chunks.shape[-3], u_chunks.shape[-2])
        noise = jnp.asarray(kernel.noise(params))
        inv_noise = 1.0 / noise
        kuu = _retune_diag(
            kuu, mu_tiles, m, jnp.asarray(jitter) - noise, mu_valid, batched
        )
        kun = _assemble_cross(
            u_chunks, x_chunks, params, mu_valid, n_valid,
            backend=backend, kernel=kernel, batched=batched,
        )
        c = executor.run_lowrank_contraction(
            kun, y_chunks, backend=backend,
            batch_dispatch=batch_dispatch, n_streams=n_streams,
        )
        luu = executor.run_cholesky(
            kuu, backend=backend, n_streams=n_streams,
            update_dtype=update_dtype, batch_dispatch=batch_dispatch,
        )
        # whitened cross grid W = L_uu^-1 K_un, then B = I + s^-2 W W^T
        w = executor.run_solve(luu, kun, lower=True, n_streams=n_streams)
        gram = jnp.einsum(f"{z}pjac,{z}qjbc->{z}pqab", w, w)
        b_packed = _packed_eye(mu_tiles, m, kuu.dtype) + inv_noise[
            ..., None, None, None
        ] * _packed_from_grid(gram, mu_tiles, batched)
        lb = executor.run_cholesky(
            b_packed, backend=backend, n_streams=n_streams,
            update_dtype=update_dtype, batch_dispatch=batch_dispatch,
        )
        gamma = _inner_solve(luu, lb, c, n_streams)
        # rows past the validity frontier may hold caller padding, not zeros
        row = jnp.arange(y_chunks.shape[-2] * y_chunks.shape[-1]).reshape(
            y_chunks.shape[-2:]
        )
        ymask = row[None] < n_valid[:, None, None] if batched else row < n_valid
        yty = jnp.sum(jnp.where(ymask, y_chunks * y_chunks, 0.0), axis=(-2, -1))
        return dict(
            luu_packed=luu, b_packed=b_packed, lb_packed=lb,
            c_chunks=c, gamma=gamma, yty=yty,
        )

    if backend == "jnp":
        return jax.jit(build)
    return build


def lowrank_state(
    x: jax.Array,
    y: jax.Array,
    params,
    m_inducing: int,
    tile_size: int,
    *,
    strategy: str = "subset",
    inducing: Optional[jax.Array] = None,
    jitter: float = DEFAULT_JITTER,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    dtype=jnp.float32,
    batch_dispatch: str = "flat",
    n_valid=None,
    kernel=None,
) -> LowRankState:
    """Build the Nyström low-rank posterior state.

    x: (n, D) or (B, n, D); y: (n,) or (B, n).  ``n_valid`` (None, int, or
    (B,) array) marks ragged problems — rows past it are padding.
    """
    kernel = km.resolve_kernel(kernel)
    x = jnp.asarray(x, dtype)
    y = jnp.asarray(y, dtype)
    batched = x.ndim == 3
    u, mu_valid = select_inducing(
        x, m_inducing, strategy=strategy, inducing=inducing, n_valid=n_valid
    )
    uc = tiling.pad_features(u, tile_size)
    xc = tiling.pad_features(x, tile_size)
    yc = tiling.pad_vector(y, tile_size)
    if batched:
        nv = (
            jnp.full((x.shape[0],), x.shape[1], jnp.int32)
            if n_valid is None
            else jnp.asarray(n_valid, jnp.int32)
        )
        mv = (
            jnp.full((x.shape[0],), m_inducing, jnp.int32)
            if mu_valid is None
            else jnp.asarray(mu_valid, jnp.int32)
        )
    else:
        nv = x.shape[0] if n_valid is None else n_valid
        mv = m_inducing if mu_valid is None else mu_valid
    cfg = (
        n_streams, backend, update_dtype, batch_dispatch, kernel,
        float(jitter), jnp.dtype(dtype).name, batched,
    )
    out = _build_fn(cfg)(uc, xc, yc, params, mv, nv)
    if mu_valid is None:
        keep_mv = None
    elif not batched:
        keep_mv = mu_valid  # ragged single problem: fewer points than slots
    elif n_valid is not None or m_inducing > x.shape[1]:
        keep_mv = mu_valid
    else:
        keep_mv = None
    return LowRankState(
        u_chunks=uc,
        luu_packed=out["luu_packed"],
        b_packed=out["b_packed"],
        lb_packed=out["lb_packed"],
        c_chunks=out["c_chunks"],
        gamma=out["gamma"],
        yty=out["yty"],
        n=x.shape[-2],
        m=tile_size,
        m_inducing=m_inducing,
        params=params,
        jitter=float(jitter),
        mu_valid=None if keep_mv is None else jnp.asarray(keep_mv, jnp.int32),
        n_valid=None if n_valid is None else jnp.asarray(nv, jnp.int32),
        kernel=kernel,
    )


# ---------------------------------------------------------------------------
# Streaming absorption (rank-m update; O(b m² + m³), never O(n³)).
# ---------------------------------------------------------------------------


def absorb(
    state: LowRankState,
    x_new: jax.Array,
    y_new: jax.Array,
    counts=None,
    *,
    sign: int = 1,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    batch_dispatch: str = "flat",
) -> LowRankState:
    """Absorb (sign=+1) or forget (sign=-1) a block of training data.

    The inducing set stays fixed; only the m×m inner system A, the
    projection c = K_un y, and the counters change.  ``counts`` masks a
    ragged batch block (scalar or (B,)); None means every row is valid.
    Raises :class:`repro.core.update.CholeskyUpdateError` when the refreshed
    factor goes non-finite (sign=-1 can remove more information than the
    inner system holds) — callers should cold-rebuild.
    """
    from repro.core import update as upd

    kernel = state.kernel
    dtype = state.c_chunks.dtype
    x_new = jnp.asarray(x_new, dtype)
    y_new = jnp.asarray(y_new, dtype)
    batched = state.c_chunks.ndim == 3
    b = x_new.shape[-2]
    if counts is None:
        cnt = jnp.full((x_new.shape[0],), b, jnp.int32) if batched else b
    else:
        cnt = jnp.asarray(counts, jnp.int32)
    xbc = tiling.pad_features(x_new, state.m)
    ybc = tiling.pad_vector(y_new, state.m)
    mv = state.mu_valid
    if mv is None:
        mv = (
            jnp.full((x_new.shape[0],), state.m_inducing, jnp.int32)
            if batched
            else state.m_inducing
        )
    kub = _assemble_cross(
        state.u_chunks, xbc, state.params, mv, cnt,
        backend=backend, kernel=kernel, batched=batched,
    )
    dc = executor.run_lowrank_contraction(
        kub, ybc, backend=backend,
        batch_dispatch=batch_dispatch, n_streams=n_streams,
    )
    z = "z" if batched else ""
    mu_tiles = state.u_chunks.shape[-3]
    # whitened block W_b = L_uu^-1 K_ub; the inducing factor never changes
    wb = executor.run_solve(state.luu_packed, kub, lower=True, n_streams=n_streams)
    dgram = jnp.einsum(f"{z}pjac,{z}qjbc->{z}pqab", wb, wb)
    dgram_p = _packed_from_grid(dgram, mu_tiles, batched)
    inv_noise = 1.0 / jnp.asarray(kernel.noise(state.params))
    s = jnp.asarray(sign, dtype)
    b_packed = state.b_packed + s * inv_noise[..., None, None, None] * dgram_p
    c = state.c_chunks + s * dc
    lb = executor.run_cholesky(
        b_packed, backend=backend, n_streams=n_streams,
        update_dtype=update_dtype, batch_dispatch=batch_dispatch,
    )
    if bool(jnp.any(~jnp.isfinite(lb))):
        raise upd.CholeskyUpdateError(
            "low-rank inner-system refactorization went non-finite"
        )
    gamma = _inner_solve(state.luu_packed, lb, c, n_streams)
    row = jnp.arange(ybc.shape[-2] * ybc.shape[-1]).reshape(ybc.shape[-2:])
    if batched:
        ymask = row[None] < cnt[:, None, None]
    else:
        ymask = row < cnt
    dyty = jnp.sum(jnp.where(ymask, ybc * ybc, 0.0), axis=(-2, -1))
    nv = state.n_valid
    if nv is not None:
        nv = nv + sign * cnt
    return dataclasses.replace(
        state,
        b_packed=b_packed,
        lb_packed=lb,
        c_chunks=c,
        gamma=gamma,
        yty=state.yty + s * dyty,
        n=state.n + sign * b,
        n_valid=nv,
    )


# ---------------------------------------------------------------------------
# Prediction heads.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _head_fn(cfg):
    (full_cov, n_streams, backend, _dt, kernel, batched, batch_dispatch) = cfg
    z = "z" if batched else ""

    def head(xtc, u_chunks, luu, lb, gamma, params, ntv, mv):
        if batched:
            kstar = pred.assemble_cross_tiles_batched(
                xtc, u_chunks, params, ntv, mv, kernel=kernel
            )
        else:
            use_pallas = backend == "pallas" and km.params_concrete(params)
            kstar = pred.assemble_cross_tiles(
                xtc, u_chunks, params, ntv, mv,
                backend="pallas" if use_pallas else "jnp", kernel=kernel,
            )
        inv_noise = 1.0 / jnp.asarray(kernel.noise(params))
        mean_c = inv_noise[..., None, None] * jnp.einsum(
            f"{z}pqab,{z}qb->{z}pa", kstar, gamma
        )
        mean = mean_c.reshape(mean_c.shape[:-2] + (-1,))
        if not full_cov:
            return mean, None
        # tile rows of K_u* : (..., MU, Q, m, m)
        kut = jnp.swapaxes(jnp.swapaxes(kstar, -4, -3), -2, -1)
        v1 = executor.run_solve(luu, kut, lower=True, n_streams=n_streams)
        v2 = executor.run_solve(lb, v1, lower=True, n_streams=n_streams)
        if batched:
            prior = pred.assemble_prior_tiles_batched(xtc, params, ntv, kernel=kernel)
        else:
            prior = pred.assemble_prior_tiles(xtc, params, ntv, kernel=kernel)
        covt = (
            prior
            - jnp.einsum(f"{z}ipab,{z}iqac->{z}pqbc", v1, v1)
            + jnp.einsum(f"{z}ipab,{z}iqac->{z}pqbc", v2, v2)
        )
        cov = tiling.untile_dense(covt)
        nt_pad = cov.shape[-1]
        eye = jnp.eye(nt_pad, dtype=bool)
        cov = jnp.where(eye, jnp.clip(cov, 0.0, None), cov)
        return mean, cov

    if backend == "jnp":
        return jax.jit(head)
    return head


def predict_from_lowrank_state(
    state: LowRankState,
    x_test: jax.Array,
    *,
    full_cov: bool = False,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    dtype=None,
    nt_valid=None,
    batch_dispatch: str = "flat",
):
    """Posterior mean (and optionally covariance) from a cached low-rank
    state.  x_test: (n*, D) or (B, n*, D)."""
    dtype = state.c_chunks.dtype if dtype is None else jnp.dtype(dtype)
    x_test = jnp.asarray(x_test, dtype)
    batched = state.c_chunks.ndim == 3
    nt = x_test.shape[-2]
    xtc = tiling.pad_features(x_test, state.m)
    if batched:
        B = x_test.shape[0]
        ntv = (
            jnp.full((B,), nt, jnp.int32)
            if nt_valid is None
            else jnp.asarray(nt_valid, jnp.int32)
        )
        mv = (
            jnp.full((B,), state.m_inducing, jnp.int32)
            if state.mu_valid is None
            else state.mu_valid
        )
    else:
        ntv = nt if nt_valid is None else nt_valid
        mv = state.m_inducing if state.mu_valid is None else state.mu_valid
    cfg = (
        bool(full_cov), n_streams, backend, jnp.dtype(dtype).name,
        state.kernel, batched, batch_dispatch,
    )
    mean, cov = _head_fn(cfg)(
        xtc, state.u_chunks, state.luu_packed, state.lb_packed,
        state.gamma, state.params, ntv, mv,
    )
    mean = mean[..., :nt]
    if not full_cov:
        return mean
    return mean, cov[..., :nt, :nt]


# ---------------------------------------------------------------------------
# NLML pieces (consumed by mll.nlml_lowrank).
# ---------------------------------------------------------------------------


def nlml_from_lowrank_state(state: LowRankState, *, dtype=None):
    """Woodbury / matrix-determinant-lemma NLML from the cached pieces:

        0.5 [ σ⁻² yᵀy − σ⁻⁴ cᵀ A⁻¹ c + n log σ²
              + log det B + n log 2π ]

    (log det A − log det K_uu = log det B in the whitened form.)
    """
    dtype = state.c_chunks.dtype if dtype is None else jnp.dtype(dtype)
    mu_tiles = state.u_chunks.shape[-3]
    noise = jnp.asarray(state.kernel.noise(state.params))
    inv = 1.0 / noise
    quad = inv * state.yty - inv * inv * jnp.sum(
        state.c_chunks * state.gamma, axis=(-2, -1)
    )
    logdet_b = triangular.logdet_from_factor(state.lb_packed, mu_tiles)
    nv = jnp.asarray(state.n if state.n_valid is None else state.n_valid, dtype)
    return 0.5 * (
        quad + nv * jnp.log(noise) + logdet_b + nv * jnp.log(2.0 * jnp.pi)
    ).astype(dtype)


# ---------------------------------------------------------------------------
# End-to-end traceable predict (benchmarks/fig14; jit covers selection,
# assembly, factorization, and the prediction head in one program).
# ---------------------------------------------------------------------------


def predict_lowrank(
    x: jax.Array,
    y: jax.Array,
    x_test: jax.Array,
    params,
    m_inducing: int,
    tile_size: int,
    *,
    strategy: str = "subset",
    inducing: Optional[jax.Array] = None,
    jitter: float = DEFAULT_JITTER,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    dtype=jnp.float32,
    batch_dispatch: str = "flat",
    kernel=None,
) -> jax.Array:
    """Cold-path low-rank predictive mean: state build + head, arrays in,
    arrays out (traceable end to end for benchmarking)."""
    state = lowrank_state(
        x, y, params, m_inducing, tile_size,
        strategy=strategy, inducing=inducing, jitter=jitter,
        n_streams=n_streams, backend=backend, update_dtype=update_dtype,
        dtype=dtype, batch_dispatch=batch_dispatch, kernel=kernel,
    )
    return predict_from_lowrank_state(
        state, x_test, n_streams=n_streams, backend=backend,
        dtype=dtype, batch_dispatch=batch_dispatch,
    )
