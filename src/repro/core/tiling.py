"""Tile layouts for the tiled GP pipeline.

Two layouts are used:

* **Dense tile grid** ``(M_rows, M_cols, m, m)`` — used for rectangular
  operands (cross covariance, solve workspaces).
* **Packed symmetric-lower store** ``(T, m, m)`` with ``T = M (M+1) / 2`` —
  only the lower-triangular tiles of a symmetric matrix are stored, packed
  column-by-column.  This realizes the paper's 50–75 % memory saving claim
  (Section 4.2): a dense n×n float needs ``M^2`` tiles, the packed store
  ``M(M+1)/2``; ratio = (M+1)/(2M) ∈ (0.5, 0.75] for M >= 2.

Packing order (column-major over tile columns):

    col J occupies flat slots  off(J) .. off(J) + (M - J - 1)
    off(J) = J*M - J*(J-1)//2
    tile (I, J) with I >= J lives at  off(J) + (I - J)

The per-column contiguity is exactly what the level-batched Cholesky wants:
the TRSM panel of step J — tiles (J+1..M-1, J) — is one contiguous slice.
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def num_packed_tiles(m_tiles: int) -> int:
    return m_tiles * (m_tiles + 1) // 2


def packed_index(i: int, j: int, m_tiles: int) -> int:
    """Flat slot of lower tile (i, j), i >= j, in the packed store."""
    if i < j:
        raise ValueError(f"packed_index requires i >= j, got ({i}, {j})")
    off = j * m_tiles - (j * (j - 1)) // 2
    return off + (i - j)


def column_slice(j: int, m_tiles: int) -> Tuple[int, int]:
    """(start, stop) flat range of packed column j (diagonal tile first)."""
    off = j * m_tiles - (j * (j - 1)) // 2
    return off, off + (m_tiles - j)


def pad_amount(n: int, m: int) -> int:
    """Padding needed to round n up to a multiple of the tile size m."""
    return (-n) % m


def pad_features(x: jax.Array, m: int, *, dtype=None) -> jax.Array:
    """(n, D) -> (M, m, D) or (B, n, D) -> (B, M, m, D) zero-padded chunks.

    The problem-batch axis B is optional and preserved (DESIGN.md §9).
    ``dtype=None`` keeps the input dtype — callers pass an explicit dtype
    when they want a cast, instead of relying on an implicit float32.
    """
    x = jnp.asarray(x, dtype)  # device array even when no padding happens
    pad = pad_amount(x.shape[-2], m)
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)])
    return x.reshape(x.shape[:-2] + (-1, m, x.shape[-1]))


def pad_vector(y: jax.Array, m: int, *, dtype=None) -> jax.Array:
    """(n,) -> (M, m) or (B, n) -> (B, M, m) zero-padded chunks."""
    y = jnp.asarray(y, dtype)  # device array even when no padding happens
    pad = pad_amount(y.shape[-1], m)
    if pad:
        y = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)])
    return y.reshape(y.shape[:-1] + (-1, m))


def tile_dense(a: jax.Array, m: int) -> jax.Array:
    """(R, C) -> (R/m, C/m, m, m) tile grid.  R, C must divide by m."""
    r, c = a.shape
    if r % m or c % m:
        raise ValueError(f"shape {a.shape} not divisible by tile size {m}")
    return a.reshape(r // m, m, c // m, m).transpose(0, 2, 1, 3)


def untile_dense(tiles: jax.Array) -> jax.Array:
    """(Mr, Mc, m, m) -> (Mr*m, Mc*m); leading batch axes are preserved."""
    mr, mc, m, mc2 = tiles.shape[-4:]
    return tiles.swapaxes(-3, -2).reshape(tiles.shape[:-4] + (mr * m, mc * mc2))


def tile_vector(v: jax.Array, m: int) -> jax.Array:
    """(n,) -> (M, m) stack of vector chunks."""
    if v.shape[0] % m:
        raise ValueError(f"length {v.shape[0]} not divisible by {m}")
    return v.reshape(-1, m)


def untile_vector(chunks: jax.Array) -> jax.Array:
    return chunks.reshape(-1)


def _packed_coords(m_tiles: int) -> Tuple[np.ndarray, np.ndarray]:
    """Row/col tile indices of every packed slot, as numpy int arrays."""
    rows, cols = [], []
    for j in range(m_tiles):
        for i in range(j, m_tiles):
            rows.append(i)
            cols.append(j)
    return np.asarray(rows), np.asarray(cols)


def pack_lower(a: jax.Array, m: int) -> jax.Array:
    """Dense symmetric (n, n) -> packed lower tile store (T, m, m)."""
    tiles = tile_dense(a, m)
    m_tiles = tiles.shape[0]
    rows, cols = _packed_coords(m_tiles)
    return tiles[rows, cols]


def unpack_lower(packed: jax.Array, *, fill: str = "lower") -> jax.Array:
    """Packed (T, m, m) -> dense (n, n).

    fill: 'lower'      — upper tiles zero (Cholesky factor output)
          'symmetric'  — upper tiles mirrored (covariance matrix)
    """
    t, m, _ = packed.shape
    m_tiles = int((math.isqrt(8 * t + 1) - 1) // 2)
    if num_packed_tiles(m_tiles) != t:
        raise ValueError(f"{t} is not a triangular tile count")
    rows, cols = _packed_coords(m_tiles)
    dense = jnp.zeros((m_tiles, m_tiles, m, m), packed.dtype)
    dense = dense.at[rows, cols].set(packed)
    if fill == "symmetric":
        off = rows != cols
        dense = dense.at[cols[off], rows[off]].set(
            jnp.swapaxes(packed[np.nonzero(off)[0]], -1, -2)
        )
    elif fill != "lower":
        raise ValueError(f"unknown fill: {fill}")
    full = untile_dense(dense)
    if fill == "lower":
        full = jnp.tril(full)  # zero the upper triangle inside diagonal tiles
    return full


@functools.lru_cache(maxsize=None)
def grow_packed_indices(m_tiles_old: int) -> np.ndarray:
    """Gather indices that append one tile-row to a packed store.

    Let ``cat = concat(old_packed (T_old), row_buffer (M_old + 1))`` where
    the row buffer holds the new row's tiles (R, 0..R-1) plus the corner
    (R, R), R = M_old.  Then ``cat[grow_packed_indices(M_old)]`` is the
    packed store of the grown (M_old + 1)-tile factor: the column-major
    packing interleaves the new row's tile at the end of every column
    (DESIGN.md §10 env growth).
    """
    m_old, m_new = m_tiles_old, m_tiles_old + 1
    t_old = num_packed_tiles(m_old)
    idx = np.empty(num_packed_tiles(m_new), np.int32)
    for j in range(m_new):
        for i in range(j, m_new):
            idx[packed_index(i, j, m_new)] = (
                t_old + j if i == m_old else packed_index(i, j, m_old)
            )
    return idx


@functools.lru_cache(maxsize=None)
def replace_row_indices(row: int, m_tiles: int) -> np.ndarray:
    """Packed slots of tile-row ``row``: (row, 0..row), corner last.

    Scattering a row buffer (row + 1 tiles, corner last) into these slots
    overwrites one tile-row of an existing packed store in place — the
    append path that refills a partially padded trailing tile, and the
    ragged batch sweep that refills interior rows (DESIGN.md §11).
    """
    return np.array(
        [packed_index(row, j, m_tiles) for j in range(row + 1)], np.int32
    )


def replace_last_row_indices(m_tiles: int) -> np.ndarray:
    """Packed slots of the last tile-row (R, 0..R), R = m_tiles - 1."""
    return replace_row_indices(m_tiles - 1, m_tiles)


@functools.lru_cache(maxsize=None)
def shrink_packed_indices(m_tiles_old: int) -> Tuple[np.ndarray, np.ndarray]:
    """(trailing, evicted) gather indices that drop the leading tile-column.

    ``old_packed[trailing]`` is the packed store of the trailing
    (M_old - 1)-tile block (tiles (i, j) with i, j >= 1);
    ``old_packed[evicted]`` is the evicted column's sub-diagonal panel
    (tiles (1.., 0)) — the rank-m carry W of the eviction update.
    """
    m_old, m_new = m_tiles_old, m_tiles_old - 1
    trailing = np.empty(num_packed_tiles(m_new), np.int32)
    for j in range(m_new):
        for i in range(j, m_new):
            trailing[packed_index(i, j, m_new)] = packed_index(i + 1, j + 1, m_old)
    evicted = np.array(
        [packed_index(i, 0, m_old) for i in range(1, m_old)], np.int32
    )
    return trailing, evicted


@functools.lru_cache(maxsize=None)
def embed_packed_indices(m_tiles_old: int, m_tiles_new: int) -> Tuple[np.ndarray, np.ndarray]:
    """Gather map embedding a packed factor into a larger tile geometry.

    Because padding is identity by construction (DESIGN.md §1), the factor
    of the same problem at a larger store is exactly
    ``blockdiag(L_old, I)`` — growing a factor from ``m_tiles_old`` to
    ``m_tiles_new`` tile-rows is a pure gather, no FLOPs.  Returns
    ``(src, kind)`` of length ``num_packed_tiles(m_tiles_new)``: ``kind``
    0 copies ``old_packed[src]``, 1 is an identity tile, 2 a zero tile.
    This is what makes bucket migration cheap in ``gp.GPFleet``: a problem
    crossing a bucket boundary re-embeds its live factor into the next
    bucket's geometry instead of refactorizing (DESIGN.md §11).
    """
    if m_tiles_new < m_tiles_old:
        raise ValueError(f"cannot shrink: {m_tiles_old} -> {m_tiles_new}")
    t_new = num_packed_tiles(m_tiles_new)
    src = np.zeros(t_new, np.int32)
    kind = np.full(t_new, 2, np.int32)
    for j in range(m_tiles_new):
        for i in range(j, m_tiles_new):
            slot = packed_index(i, j, m_tiles_new)
            if i < m_tiles_old and j < m_tiles_old:
                src[slot] = packed_index(i, j, m_tiles_old)
                kind[slot] = 0
            elif i == j:
                kind[slot] = 1
    return src, kind


def embed_packed(packed: jax.Array, m_tiles_old: int, m_tiles_new: int) -> jax.Array:
    """Embed packed factor tiles (..., T_old, m, m) into (..., T_new, m, m)."""
    src, kind = embed_packed_indices(m_tiles_old, m_tiles_new)
    m = packed.shape[-1]
    tiles = jnp.take(packed, jnp.asarray(src), axis=-3)
    kindb = jnp.asarray(kind)[:, None, None]
    eye = jnp.eye(m, dtype=packed.dtype)
    tiles = jnp.where(kindb == 0, tiles, jnp.where(kindb == 1, eye, 0.0))
    return tiles


DEFAULT_BUCKETS = "pow2"


def bucket_boundaries(m_tiles_max: int, boundaries=DEFAULT_BUCKETS) -> Tuple[int, ...]:
    """Normalize a bucket-boundary spec to a sorted tuple of tile-count caps.

    ``"pow2"`` — powers of two up to (and covering) ``m_tiles_max``;
    an int k — k geometrically spaced caps from 1 to ``m_tiles_max``;
    an iterable — explicit caps, extended with ``m_tiles_max`` if they do
    not cover it.  Every spec is guaranteed to cover ``m_tiles_max``.
    """
    m_tiles_max = max(int(m_tiles_max), 1)
    if boundaries == "pow2":
        caps = []
        c = 1
        while c < m_tiles_max:
            caps.append(c)
            c *= 2
        caps.append(c)
        return tuple(caps)
    if isinstance(boundaries, int):
        k = max(boundaries, 1)
        caps = sorted(
            {
                max(1, int(round(m_tiles_max ** (i / (k - 1)))) if k > 1 else m_tiles_max)
                for i in range(k)
            }
        )
        if caps[-1] != m_tiles_max:
            caps[-1] = m_tiles_max
        return tuple(dict.fromkeys(caps))
    caps = sorted({int(c) for c in boundaries if int(c) >= 1})
    if not caps or caps[-1] < m_tiles_max:
        caps.append(m_tiles_max)
    return tuple(caps)


def bucket_problems(ns, m: int, boundaries=DEFAULT_BUCKETS):
    """Assign ragged problems to tile-geometry buckets (DESIGN.md §11).

    ``ns`` are per-problem observation counts, ``m`` the tile size.  Each
    problem needs ``ceil(n / m)`` tile-rows; that count rounds UP to the
    smallest boundary cap that fits, so problems of nearby sizes share one
    bucket — one fused program, one lru-cached B-invariant Plan — and the
    per-problem ``n_valid`` mask absorbs the (at most one-boundary-step)
    padding.  Returns ``{cap_tiles: [problem indices]}``, caps ascending,
    preserving submission order within a bucket.
    """
    ns = [int(n) for n in ns]
    if any(n < 1 for n in ns):
        raise ValueError(f"every problem needs at least one observation: {ns}")
    need = [max(-(-n // m), 1) for n in ns]
    caps = bucket_boundaries(max(need), boundaries)
    out: dict = {}
    for i, nd in enumerate(need):
        cap = next(c for c in caps if c >= nd)
        out.setdefault(cap, []).append(i)
    return dict(sorted(out.items()))


def packed_bytes(m_tiles: int, m: int, dtype=jnp.float32) -> int:
    return num_packed_tiles(m_tiles) * m * m * jnp.dtype(dtype).itemsize


def dense_bytes(n: int, dtype=jnp.float32) -> int:
    return n * n * jnp.dtype(dtype).itemsize
