"""GP hyperparameter training through the negative log marginal likelihood.

Beyond the paper's scope (it fixes l=1, v=1, sigma^2=0.1) but part of the
GPRat library proper; DESIGN.md §7–§8 cover how the training path relates to
the fused program IR.

    nlml = 0.5 * ( y^T alpha + log det K + n log 2 pi )

Three evaluation paths:

* :func:`negative_log_marginal_likelihood` — the monolithic dense reference
  (one-call Cholesky, differentiated by JAX autodiff).
* :func:`nlml_from_state` — evaluation at fixed hyperparameters from a
  cached tiled :class:`repro.core.predict.PosteriorState` (quadratic term
  from the alpha chunks, log-determinant from the packed factor's diagonal
  tiles) — no re-factorization, exact for any n thanks to identity padding.
* :func:`nlml_tiled` — the *trainable* tiled NLML (DESIGN.md §8): the fused
  program with ``q_tiles=0`` (assembly → tiled Cholesky → both
  substitutions) plus the quad/logdet heads.  Differentiable w.r.t.
  ``(x, y, params)`` either through a blocked reverse-mode ``custom_vjp``
  (default — one tiled triangular matrix solve + gram for K^{-1}, instead
  of autodiff back through every wavefront launch) or by plain autodiff
  through the program (``vjp="autodiff"``; Pallas tile ops carry reference
  VJPs, see repro.kernels.ops).

:func:`optimize_hyperparameters` runs Adam on either path as ONE jitted
``lax.scan`` — the whole optimization is a single compiled program, not a
Python loop that re-enters jit every step.  Hyperparameters live in
unconstrained log-space (softplus).

Problem-batched variants (DESIGN.md §9): :func:`nlml_tiled_batched`
evaluates B stacked GPs' NLMLs through ONE problem-batched fused program
(per-problem losses (B,), per-problem hyperparameter leaves (B,)), and
:func:`optimize_hyperparameters_batched` trains all B GPs in one jitted
``lax.scan`` with independent elementwise Adam states
(:func:`adam_scan_batched`).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import cholesky as chol
from repro.core import kernels_math as km
from repro.core import tiling, triangular


def negative_log_marginal_likelihood(
    x: jax.Array,
    y: jax.Array,
    params,
    *,
    dtype=jnp.float32,
    kernel=None,
) -> jax.Array:
    """Exact NLML through the monolithic Cholesky (differentiable)."""
    x = x.astype(dtype)
    y = y.astype(dtype)
    n = y.shape[0]
    k = km.assemble_covariance(x, params, kernel=kernel, dtype=dtype)
    l = chol.monolithic_cholesky(k)
    beta = jax.lax.linalg.triangular_solve(l, y[:, None], left_side=True, lower=True)
    quad = jnp.sum(beta * beta)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))
    return 0.5 * (quad + logdet + n * math.log(2.0 * math.pi))


def nlml_from_state(state, y: jax.Array, *, dtype=jnp.float32, n_valid=None) -> jax.Array:
    """NLML from a cached tiled posterior (no re-factorization).

    quad   = y^T alpha            (alpha = K^{-1} y, cached chunks; padded
                                   rows contribute 0 because y pads with 0)
    logdet = 2 sum log diag(L)    (packed factor's diagonal tiles; padded
                                   rows contribute log 1 = 0)

    Batch-aware: a stacked state (leading B axis) with y (B, n) returns the
    per-problem NLML vector (B,).

    Ragged states (DESIGN.md §11): per-problem frontiers from ``n_valid``
    (or ``state.n_valid``) replace the shared n in the constant term and
    mask the factor diagonal — per-problem NLMLs stay exact even though
    every problem in the bucket shares the padded stack shape.
    """
    y = y.astype(dtype)
    yc = tiling.pad_vector(y, state.m)
    quad = jnp.sum(yc * state.alpha, axis=(-2, -1))
    m_tiles = state.alpha.shape[-2]
    nv = getattr(state, "n_valid", None) if n_valid is None else n_valid
    n = y.shape[-1] if nv is None else jnp.asarray(nv, yc.dtype)
    logdet = triangular.logdet_from_factor(state.lpacked, m_tiles, n_valid=nv)
    return 0.5 * (quad + logdet + n * math.log(2.0 * math.pi))


# ---------------------------------------------------------------------------
# The trainable tiled NLML (DESIGN.md §8).
#
# Forward: the fused program with q_tiles=0 (scheduler.build_nlml_schedule)
# — the NLML program IS the prediction program minus the test-point stages,
# sharing its plan/jit caches.  Heads: quad = sum(yc * alpha) and logdet
# from the factor's diagonal tiles.
#
# Backward (vjp="custom", default): blocked reverse-mode from the closed
# form  dNLML/dK = 0.5 (K^{-1} - alpha alpha^T) =: S.  The O(n^3) piece is
# K^{-1} = L^{-T} L^{-1}, computed with the *tiled* machinery (one matrix
# forward solve on identity tiles + one tiled gram —
# triangular.kinv_tiles_from_factor); the O(n^2) contractions with dK/dtheta
# are dense:
#
#   dNLML/dl      = sum(S ∘ K_se ∘ D2) / (2 l^2)     (K_se = v exp(-D2/2l))
#   dNLML/dv      = sum(S ∘ K_se) / v
#   dNLML/dsigma2 = tr(S)
#   dNLML/dy      = alpha
#   dNLML/dx_i    = -(2/l) sum_j S_ij K_se_ij (x_i - x_j)
#
# Padding never enters: the padded block of K is a constant identity, so its
# derivative is zero and everything is computed on the unpadded n×n region.
# ---------------------------------------------------------------------------


def _nlml_cfg(
    tile_size,
    n_streams,
    backend,
    update_dtype,
    dtype,
    batch_dispatch="flat",
    kernel=None,
):
    """Hashable static config for the custom-vjp / jit caches.

    ``kernel`` instances are frozen dataclasses (hashable, structural
    equality) so they slot straight into this tuple."""
    return (
        int(tile_size),
        n_streams,
        backend,
        update_dtype,
        jnp.dtype(dtype).name,
        batch_dispatch,
        km.resolve_kernel(kernel),
    )


def _nlml_forward(cfg, x, y, params):
    """Run the tiled NLML program; returns (value, residuals for the vjp).

    Batch-aware: with x (B, n, D) / y (B, n) the program env is
    problem-batched and the value is the per-problem loss vector (B,).
    """
    from repro.core import predict as pred

    tile_size, n_streams, backend, update_dtype, dtype_name, batch_dispatch, kernel = cfg
    dtype = jnp.dtype(dtype_name)
    n = y.shape[-1]
    env, yc = pred.nlml_program_env(
        x,
        y,
        params,
        tile_size,
        n_streams=n_streams,
        backend=backend,
        update_dtype=update_dtype,
        dtype=dtype,
        batch_dispatch=batch_dispatch,
        kernel=kernel,
    )
    quad = jnp.sum(yc * env["alpha"], axis=(-2, -1))
    logdet = triangular.logdet_from_factor(env["packed"], env["alpha"].shape[-2])
    val = 0.5 * (quad + logdet + n * math.log(2.0 * math.pi))
    return val, (env["packed"], env["alpha"])


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _nlml_tiled_cv(cfg, x, y, params):
    val, _ = _nlml_forward(cfg, x, y, params)
    return val


def _nlml_cv_fwd(cfg, x, y, params):
    val, (lpacked, alpha_c) = _nlml_forward(cfg, x, y, params)
    return val, (x, y, params, lpacked, alpha_c)


def _nlml_dense_grads(kernel, params, xd, alpha, kinv):
    """O(n^2) dense contraction of S = 0.5(K^{-1} - aa^T) with dK/dtheta.

    One problem: xd (n, D), alpha (n,), kinv (n, n), scalar params leaves.
    Returns (g_x, g_y, g_params) with g_params matching the params pytree:
    the kernel's hand-derived ``kfree_vjp`` supplies every noise-free
    derivative (and the x cotangents), and dK/dsigma2 = I adds tr(S) onto
    the noise leaf.  The batched backward pass vmaps this over the problem
    axis.
    """
    s = 0.5 * (kinv - jnp.outer(alpha, alpha))
    g_params, g_xa, g_xb = kernel.kfree_vjp(params, xd, xd, s)
    g_params = dataclasses.replace(
        g_params, noise=g_params.noise + jnp.trace(s)
    )
    return g_xa + g_xb, alpha, g_params


def _nlml_cv_bwd(cfg, res, ct):
    # analytic-vjp kernels only (SE, Matérn 5/2): nlml_tiled routes every
    # other family to vjp="autodiff" before this rule can be installed.
    _, n_streams, _, _, dtype_name, _, kernel = cfg
    dtype = jnp.dtype(dtype_name)
    x, y, params, lpacked, alpha_c = res
    n = y.shape[0]
    # O(n^3): K^{-1} through the tiled solve executor (blocked reverse-mode).
    kinv_t = triangular.kinv_tiles_from_factor(lpacked, n_streams=n_streams)
    kinv = tiling.untile_dense(kinv_t)[:n, :n]
    alpha = alpha_c.reshape(-1)[:n]
    # O(n^2): contract S with the analytic kernel derivatives.
    params_d = jax.tree_util.tree_map(
        lambda p: jnp.asarray(p, dtype), params
    )
    g_x, g_y, g_params = _nlml_dense_grads(
        kernel, params_d, x.astype(dtype), alpha, kinv
    )
    ct = jnp.asarray(ct, dtype)
    return (
        ct * g_x,
        ct * g_y,
        jax.tree_util.tree_map(lambda g: ct * g, g_params),
    )


_nlml_tiled_cv.defvjp(_nlml_cv_fwd, _nlml_cv_bwd)


# -- problem-batched trainable NLML (DESIGN.md §9) --------------------------
#
# Forward: ONE problem-batched program (q_tiles=0) evaluates B independent
# NLMLs; the per-problem losses come back as a vector (B,).  Backward: the
# blocked reverse-mode rule per problem — K^{-1} for all B factors through
# ONE batched tiled matrix solve + gram, then the O(n^2) dense contraction
# vmapped over the problem axis.  Hyperparameter leaves are (B,) throughout
# (callers broadcast shared scalars up front).


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _nlml_tiled_batched_cv(cfg, x, y, params):
    val, _ = _nlml_forward(cfg, x, y, params)
    return val


def _nlml_batched_cv_fwd(cfg, x, y, params):
    val, (lpacked, alpha_c) = _nlml_forward(cfg, x, y, params)
    return val, (x, y, params, lpacked, alpha_c)


def _nlml_batched_cv_bwd(cfg, res, ct):
    _, n_streams, _, _, dtype_name, _, kernel = cfg
    dtype = jnp.dtype(dtype_name)
    x, y, params, lpacked, alpha_c = res
    b, n = y.shape
    # O(n^3): B inverses through ONE problem-batched tiled solve + gram.
    kinv_t = triangular.kinv_tiles_from_factor(lpacked, n_streams=n_streams)
    kinv = tiling.untile_dense(kinv_t)[:, :n, :n]
    alpha = alpha_c.reshape(b, -1)[:, :n]
    # per-problem leaves (B,) — callers broadcast shared scalars up front
    params_b = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(jnp.asarray(p, dtype), (b,)), params
    )
    g_x, g_y, g_params = jax.vmap(
        lambda p, xd, a, ki: _nlml_dense_grads(kernel, p, xd, a, ki)
    )(params_b, x.astype(dtype), alpha, kinv)
    ct = jnp.asarray(ct, dtype)  # (B,) — one cotangent per problem loss
    return (
        ct[:, None, None] * g_x,
        ct[:, None] * g_y,
        jax.tree_util.tree_map(lambda g: ct * g, g_params),
    )


_nlml_tiled_batched_cv.defvjp(_nlml_batched_cv_fwd, _nlml_batched_cv_bwd)


def nlml_tiled_batched(
    x: jax.Array,
    y: jax.Array,
    params,
    *,
    tile_size: int = 256,
    n_streams=None,
    op_backend: str = "jnp",
    update_dtype=None,
    dtype=jnp.float32,
    vjp: str = "custom",
    batch_dispatch: str = "flat",
    kernel=None,
) -> jax.Array:
    """Per-problem NLML vector (B,) for B stacked GPs, in ONE batched program.

    x (B, n, D) / y (B, n); hyperparameter leaves scalar (shared) or (B,)
    (per-problem) — scalars are broadcast so the gradient contract is always
    per-problem leaves (B,).  Differentiable like :func:`nlml_tiled`:
    ``vjp="custom"`` (default) runs the blocked reverse-mode rule batched,
    ``vjp="autodiff"`` differentiates straight through the program.  Kernels
    without a hand-derived dK/dtheta (``kernel.analytic_vjp`` False) fall
    back to autodiff automatically.
    """
    x = jnp.asarray(x, dtype)
    if x.ndim == 2:
        x = x[..., None]
    y = jnp.asarray(y, dtype)
    if x.ndim != 3 or y.ndim != 2 or x.shape[:2] != y.shape:
        raise ValueError(
            f"batched NLML needs x (B, n, D) and y (B, n); got {x.shape}, {y.shape}"
        )
    kernel = km.resolve_kernel(kernel)
    params = km.broadcast_params(params, x.shape[0], kernel)
    cfg = _nlml_cfg(
        tile_size, n_streams, op_backend, update_dtype, dtype, batch_dispatch, kernel
    )
    if vjp == "custom" and not kernel.analytic_vjp:
        vjp = "autodiff"
    if vjp == "custom":
        return _nlml_tiled_batched_cv(cfg, x, y, params)
    if vjp == "autodiff":
        val, _ = _nlml_forward(cfg, x, y, params)
        return val
    raise ValueError(f"vjp must be 'custom' or 'autodiff', got {vjp!r}")


def nlml_tiled(
    x: jax.Array,
    y: jax.Array,
    params,
    *,
    tile_size: int = 256,
    n_streams=None,
    op_backend: str = "jnp",
    update_dtype=None,
    dtype=jnp.float32,
    vjp: str = "custom",
    kernel=None,
) -> jax.Array:
    """NLML through the tiled fused program — differentiable (DESIGN.md §8).

    Value-equivalent to :func:`negative_log_marginal_likelihood` for any n
    (identity padding).  ``vjp="custom"`` (default) installs the blocked
    reverse-mode backward pass; ``vjp="autodiff"`` differentiates straight
    through the program's wavefront launches (the jnp ops natively, the
    Pallas tile ops via their reference VJPs) — kept as the correctness
    baseline the custom rule is tested against.

    The blocked reverse-mode rule contracts hand-derived kernel
    derivatives, so only kernels with ``analytic_vjp`` (SE, Matérn-5/2)
    use it; any other registered ``kernel`` silently falls back to
    ``vjp="autodiff"``.
    """
    x = jnp.asarray(x, dtype)
    if x.ndim == 1:
        x = x[:, None]
    y = jnp.asarray(y, dtype).reshape(-1)
    kernel = km.resolve_kernel(kernel)
    cfg = _nlml_cfg(
        tile_size, n_streams, op_backend, update_dtype, dtype, kernel=kernel
    )
    if vjp == "custom" and not kernel.analytic_vjp:
        vjp = "autodiff"
    if vjp == "custom":
        return _nlml_tiled_cv(cfg, x, y, params)
    if vjp == "autodiff":
        val, _ = _nlml_forward(cfg, x, y, params)
        return val
    raise ValueError(f"vjp must be 'custom' or 'autodiff', got {vjp!r}")


# ---------------------------------------------------------------------------
# Low-rank (Nyström / DTC) NLML — O(n m^2) per evaluation (DESIGN.md §14).
#
# Forward: the whitened inner system from repro.core.lowrank (K_un through
# the CROSS family, c = K_un y through LRGEMM, chol(K_uu)/chol(B) through
# the fused POTRF/TRSM/SYRK plans).  Backward (vjp="custom"): the blocked
# reverse-mode rule below — all cotangents contract against *dense* m×m /
# m×n quantities, so the backward pass is O(n m^2) like the forward.  With
#   A = K_uu + s^-2 K_un K_nu,   b = A^{-1} K_un y,
# the NLML derivatives are
#   G_A    = 0.5 A^{-1} + 0.5 s^-4 b b^T
#   G_Kuu  = G_A - 0.5 K_uu^{-1}
#   G_Kun  = 2 s^-2 G_A K_un - s^-4 b y^T
#   g_s2   = -0.5 s^-4 y^T y + s^-6 c^T b + 0.5 n s^-2
#            - s^-4 tr(G_A K_un K_nu)
#   g_y    = s^-2 (y - s^-2 K_nu b)
# and the kernel-level cotangents route through kernel.kfree_vjp exactly
# like the exact tier's rule.  The inducing inputs are stop_gradient'ed in
# the forward builder, so their cotangent is zero by construction.
# ---------------------------------------------------------------------------


def _dense_from_packed(packed):
    """Packed lower tiles (T, m, m) -> dense lower-triangular (M*m, M*m)."""
    t, m, _ = packed.shape[-3:]
    m_tiles = int((math.isqrt(8 * t + 1) - 1) // 2)
    rows, cols = tiling._packed_coords(m_tiles)
    grid = jnp.zeros((m_tiles, m_tiles, m, m), packed.dtype)
    grid = grid.at[rows, cols].set(packed)
    return tiling.untile_dense(grid)


def _lr_state(cfg, x, y, u, params):
    from repro.core import lowrank

    (mu, tile_size, jitter, n_streams, backend, update_dtype, dtype_name,
     kernel) = cfg
    return lowrank.lowrank_state(
        x, y, params, mu, tile_size,
        inducing=u, jitter=jitter, n_streams=n_streams, backend=backend,
        update_dtype=update_dtype, dtype=jnp.dtype(dtype_name), kernel=kernel,
    )


def _nlml_lr_value(cfg, x, y, u, params):
    from repro.core import lowrank

    state = _lr_state(cfg, x, y, u, params)
    return lowrank.nlml_from_lowrank_state(state), state


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _nlml_lr_cv(cfg, x, y, u, params):
    val, _ = _nlml_lr_value(cfg, x, y, u, params)
    return val


def _nlml_lr_fwd(cfg, x, y, u, params):
    val, state = _nlml_lr_value(cfg, x, y, u, params)
    return val, (x, y, u, params, state.luu_packed, state.lb_packed, state.gamma)


def _nlml_lr_bwd(cfg, res, ct):
    mu, _, _, _, _, _, dtype_name, kernel = cfg
    dtype = jnp.dtype(dtype_name)
    x, y, u, params, luu_packed, lb_packed, gamma = res
    n = y.shape[0]
    params_d = jax.tree_util.tree_map(lambda p: jnp.asarray(p, dtype), params)
    xd, yd, ud = x.astype(dtype), y.astype(dtype), u.astype(dtype)
    # O(m^3) dense sandwich for A^{-1} / K_uu^{-1} from the saved factors
    luu_d = _dense_from_packed(luu_packed)[:mu, :mu]
    lb_d = _dense_from_packed(lb_packed)[:mu, :mu]
    eye = jnp.eye(mu, dtype=dtype)
    linv = jax.scipy.linalg.solve_triangular(luu_d, eye, lower=True)
    t = jax.scipy.linalg.solve_triangular(lb_d, linv, lower=True)
    ainv = t.T @ t
    kuuinv = linv.T @ linv
    kun = kernel.kfree(params_d, ud, xd)  # (m, n)
    c = kun @ yd
    b = gamma.reshape(-1)[:mu]  # A^{-1} c, solved stably in the forward
    inv = 1.0 / jnp.asarray(kernel.noise(params_d))
    ga = 0.5 * ainv + 0.5 * inv * inv * jnp.outer(b, b)
    g_kuu = ga - 0.5 * kuuinv
    ga_kun = ga @ kun
    g_kun = 2.0 * inv * ga_kun - inv * inv * jnp.outer(b, yd)
    g_noise = (
        -0.5 * inv * inv * jnp.sum(yd * yd)
        + inv * inv * inv * jnp.dot(c, b)
        + 0.5 * n * inv
        - inv * inv * jnp.sum(ga_kun * kun)
    )
    g_y = inv * yd - inv * inv * (kun.T @ b)
    gp_uu, _, _ = kernel.kfree_vjp(params_d, ud, ud, g_kuu)
    gp_un, _, g_x = kernel.kfree_vjp(params_d, ud, xd, g_kun)
    g_params = jax.tree_util.tree_map(jnp.add, gp_uu, gp_un)
    g_params = dataclasses.replace(
        g_params, noise=g_params.noise + g_noise
    )
    ct = jnp.asarray(ct, dtype)
    return (
        ct * g_x,
        ct * g_y,
        jnp.zeros_like(u),  # inducing inputs are stop_gradient'ed
        jax.tree_util.tree_map(lambda g: ct * g, g_params),
    )


_nlml_lr_cv.defvjp(_nlml_lr_fwd, _nlml_lr_bwd)


def nlml_lowrank(
    x: jax.Array,
    y: jax.Array,
    params,
    *,
    m_inducing: int,
    tile_size: int = 256,
    strategy: str = "subset",
    inducing=None,
    jitter=None,
    n_streams=None,
    op_backend: str = "jnp",
    update_dtype=None,
    dtype=jnp.float32,
    vjp: str = "custom",
    kernel=None,
) -> jax.Array:
    """Nyström low-rank NLML — O(n m^2), differentiable (DESIGN.md §14).

    Same contract as :func:`nlml_tiled` but through the low-rank tier:
    ``vjp="custom"`` installs the blocked O(n m^2) reverse-mode rule above
    (analytic-vjp kernels only — others fall back to autodiff through the
    builder, which works on both backends via the tile ops' reference
    VJPs).  The inducing set is selected once per call from the *primal*
    inputs and carries no gradient.
    """
    from repro.core import lowrank

    x = jnp.asarray(x, dtype)
    if x.ndim == 1:
        x = x[:, None]
    y = jnp.asarray(y, dtype).reshape(-1)
    kernel = km.resolve_kernel(kernel)
    jitter = lowrank.DEFAULT_JITTER if jitter is None else float(jitter)
    u, _ = lowrank.select_inducing(
        x, m_inducing, strategy=strategy, inducing=inducing
    )
    cfg = (
        int(m_inducing), int(tile_size), jitter, n_streams, op_backend,
        update_dtype, jnp.dtype(dtype).name, kernel,
    )
    if vjp == "custom" and not kernel.analytic_vjp:
        vjp = "autodiff"
    if vjp == "custom":
        return _nlml_lr_cv(cfg, x, y, u, params)
    if vjp == "autodiff":
        val, _ = _nlml_lr_value(cfg, x, y, u, params)
        return val
    raise ValueError(f"vjp must be 'custom' or 'autodiff', got {vjp!r}")


def nlml_lowrank_batched(
    x: jax.Array,
    y: jax.Array,
    params,
    *,
    m_inducing: int,
    tile_size: int = 256,
    strategy: str = "subset",
    inducing=None,
    jitter=None,
    n_streams=None,
    op_backend: str = "jnp",
    update_dtype=None,
    dtype=jnp.float32,
    batch_dispatch: str = "flat",
    n_valid=None,
    kernel=None,
) -> jax.Array:
    """Per-problem low-rank NLML vector (B,) in one batched build.

    Differentiates through the builder (autodiff; the custom rule is
    single-problem).  Hyperparameter leaves scalar or (B,) as usual.
    """
    from repro.core import lowrank

    x = jnp.asarray(x, dtype)
    if x.ndim == 2:
        x = x[..., None]
    y = jnp.asarray(y, dtype)
    if x.ndim != 3 or y.ndim != 2 or x.shape[:2] != y.shape:
        raise ValueError(
            f"batched NLML needs x (B, n, D) and y (B, n); got {x.shape}, {y.shape}"
        )
    kernel = km.resolve_kernel(kernel)
    state = lowrank.lowrank_state(
        x, y, params, m_inducing, tile_size,
        strategy=strategy, inducing=inducing,
        jitter=lowrank.DEFAULT_JITTER if jitter is None else float(jitter),
        n_streams=n_streams, backend=op_backend, update_dtype=update_dtype,
        dtype=dtype, batch_dispatch=batch_dispatch, n_valid=n_valid,
        kernel=kernel,
    )
    return lowrank.nlml_from_lowrank_state(state, dtype=dtype)


# ---------------------------------------------------------------------------
# Unconstrained-space packing and the jitted lax.scan Adam optimizer.
# ---------------------------------------------------------------------------


def _softplus(z: jax.Array) -> jax.Array:
    # softplus keeps hyperparameters positive; logaddexp is overflow-safe
    return jnp.logaddexp(z, 0.0)


def _inv_softplus(p: jax.Array) -> jax.Array:
    """Numerically stable softplus inverse, exact from tiny up to f32 max.

    The naive ``log(expm1(p))`` overflows expm1 for p ≳ 88 in float32 (and
    ≳ 709 in float64), turning any large hyperparameter into inf at pack
    time; the algebraically identical ``p + log1p(-exp(-p))`` never forms
    e^p but loses to ``exp(-p) == 1`` rounding below p ≈ 1e-7.  So: branch
    at 20 (each arm clamped into its own safe range — the classic
    double-where against NaN gradients from the untaken branch), and floor
    p at the dtype's tiny (where log(expm1(p)) ≈ log(p) stays finite)
    instead of the old lossy 1e-6 clamp that collapsed every smaller
    hyperparameter onto the same raw value.
    """
    p = jnp.maximum(p, jnp.finfo(jnp.result_type(p)).tiny)
    small = jnp.log(jnp.expm1(jnp.minimum(p, 20.0)))
    big = p + jnp.log1p(-jnp.exp(-jnp.maximum(p, 20.0)))
    return jnp.where(p > 20.0, big, small)


def unpack_params(raw):
    """Softplus every leaf of an unconstrained kernel-params pytree."""
    return jax.tree_util.tree_map(_softplus, raw)


def pack_params(params, dtype=None):
    """Inverse-softplus every leaf of a kernel-params pytree (generic
    counterpart of :func:`_pack` for the kernel zoo — every registered
    family keeps all its hyperparameter leaves positive, so one
    unconstrained map serves the whole registry)."""
    if dtype is None:
        dtype = jnp.result_type(*jax.tree_util.tree_leaves(params))
    return jax.tree_util.tree_map(
        lambda p: _inv_softplus(jnp.asarray(p).astype(dtype)), params
    )


def _unpack(raw: jax.Array) -> km.SEKernelParams:
    # raw is in R^3 — or (B, 3) for B problems (the SE hyperparameter triple
    # always lives on the last axis)
    return km.SEKernelParams(
        lengthscale=_softplus(raw[..., 0]),
        vertical=_softplus(raw[..., 1]),
        noise=_softplus(raw[..., 2]),
    )


def _pack(params: km.SEKernelParams, dtype=None) -> jax.Array:
    """Inverse softplus into R^3 (or (B, 3) for per-problem leaves (B,)).
    ``dtype=None`` keeps the leaves' common dtype (float64 params no longer
    silently round-trip through float32)."""
    leaves = [
        jnp.asarray(p) for p in (params.lengthscale, params.vertical, params.noise)
    ]
    if dtype is None:
        dtype = jnp.result_type(*leaves)
    return jnp.stack([_inv_softplus(p.astype(dtype)) for p in leaves], axis=-1)


def _raw_codec(kernel):
    """(pack, unpack) pair for a kernel's unconstrained parameterization.

    SE keeps the legacy stacked (…, 3) raw layout (the optimizer-state shape
    tests and benchmarks rely on); every other family round-trips its whole
    params pytree leaf-by-leaf.
    """
    if isinstance(kernel, km.SquaredExponential):
        return _pack, _unpack
    return pack_params, unpack_params


def nlml_loss_fn(
    x: jax.Array,
    y: jax.Array,
    *,
    method: str = "monolithic",
    dtype=jnp.float32,
    tile_size: int = 256,
    n_streams=None,
    op_backend: str = "jnp",
    update_dtype=None,
    vjp: str = "custom",
    kernel=None,
    m_inducing=None,
    strategy: str = "subset",
    inducing=None,
    jitter=None,
):
    """loss(raw) over unconstrained hyperparameters, for any NLML path."""
    kernel = km.resolve_kernel(kernel)
    _, unpack = _raw_codec(kernel)
    if method == "monolithic":
        return lambda raw: negative_log_marginal_likelihood(
            x, y, unpack(raw), dtype=dtype, kernel=kernel
        )
    if method == "tiled":
        return lambda raw: nlml_tiled(
            x,
            y,
            unpack(raw),
            tile_size=tile_size,
            n_streams=n_streams,
            op_backend=op_backend,
            update_dtype=update_dtype,
            dtype=dtype,
            vjp=vjp,
            kernel=kernel,
        )
    if method == "lowrank":
        if m_inducing is None:
            raise ValueError("method='lowrank' needs m_inducing")
        return lambda raw: nlml_lowrank(
            x,
            y,
            unpack(raw),
            m_inducing=m_inducing,
            tile_size=tile_size,
            strategy=strategy,
            inducing=inducing,
            jitter=jitter,
            n_streams=n_streams,
            op_backend=op_backend,
            update_dtype=update_dtype,
            dtype=dtype,
            vjp=vjp,
            kernel=kernel,
        )
    raise ValueError(
        f"method must be 'monolithic', 'tiled' or 'lowrank', got {method!r}"
    )


def _adam_scan_impl(vg, steps: int, lr: float):
    """Shared Adam core: ``vg(raw) -> ((objective, report), grad)``.

    The scan records ``report`` (the loss value(s) *before* update t) and
    updates elementwise — the same code serves one problem (scalar
    objective == report) and B independent problems (objective = sum of
    per-problem losses, report = the (B,) loss vector; independence makes
    the summed gradient the stacked per-problem gradients, and elementwise
    moments on (B, 3) raws ARE B independent optimizers).

    ``raw`` may be any pytree (the SE stacked (…, 3) array, or a full
    kernel-params pytree from :func:`pack_params`) — the update is a
    ``tree_map`` so arbitrary registered kernels train through the same
    compiled scan.
    """
    b1, b2, eps = 0.9, 0.999, 1e-8
    tmap = jax.tree_util.tree_map

    def step(carry, t):
        raw, m, v = carry
        (_, report), g = vg(raw)
        m = tmap(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
        v = tmap(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_, v, g)
        raw = tmap(
            lambda r_, m_, v_: r_
            - lr * (m_ / (1 - b1**t)) / (jnp.sqrt(v_ / (1 - b2**t)) + eps),
            raw,
            m,
            v,
        )
        return (raw, m, v), report

    def run(raw0):
        z = tmap(jnp.zeros_like, raw0)
        ts = jnp.arange(
            1, steps + 1, dtype=jax.tree_util.tree_leaves(raw0)[0].dtype
        )
        (raw, _, _), losses = jax.lax.scan(step, (raw0, z, z), ts)
        return raw, losses

    return jax.jit(run)


def adam_scan(loss, steps: int, lr: float):
    """The whole Adam run as ONE jitted ``lax.scan`` over optimizer steps.

    Returns a compiled function ``raw0 -> (raw_final, losses)`` where
    ``losses[t]`` is the loss *before* update t (``losses[0]`` is the loss
    at the initial point, matching the old Python-loop semantics).  One
    trace, one compile, zero per-step dispatch from Python — the paper's
    "recurring O(n^3) cost per optimizer step" runs entirely on device.
    """

    def total(raw):
        val = loss(raw)
        return val, val

    return _adam_scan_impl(jax.value_and_grad(total, has_aux=True), steps, lr)


def adam_scan_batched(loss, steps: int, lr: float):
    """B independent Adam runs in ONE jitted ``lax.scan`` (DESIGN.md §9).

    ``loss`` maps raw (B, 3) -> per-problem losses (B,).  Differentiating the
    *sum* of independent per-problem losses yields exactly the stacked
    per-problem gradients (zero cross-terms), and Adam's update is
    elementwise, so one (B, 3) moment pair IS B independent optimizers.
    Returns ``raw0 (B, 3) -> (raw_final (B, 3), losses (steps, B))`` with
    the same loss-before-update-t semantics as :func:`adam_scan`.
    """

    def total(raw):
        losses = loss(raw)
        return jnp.sum(losses), losses

    return _adam_scan_impl(jax.value_and_grad(total, has_aux=True), steps, lr)


def optimize_hyperparameters(
    x: jax.Array,
    y: jax.Array,
    init,
    *,
    steps: int = 100,
    lr: float = 0.05,
    dtype=jnp.float32,
    method: str = "monolithic",
    tile_size: int = 256,
    n_streams=None,
    op_backend: str = "jnp",
    update_dtype=None,
    vjp: str = "custom",
    kernel=None,
    m_inducing=None,
    strategy: str = "subset",
    inducing=None,
    jitter=None,
) -> Tuple:
    """Adam on the NLML in unconstrained space.  Returns (params, loss curve).

    ``method="monolithic"`` differentiates the dense reference NLML;
    ``method="tiled"`` trains through the tiled fused program
    (:func:`nlml_tiled` — no monolithic Cholesky anywhere in the loop);
    ``method="lowrank"`` trains the O(n m^2) Nyström NLML
    (:func:`nlml_lowrank`, requires ``m_inducing``).
    Either way the optimizer is one jitted ``lax.scan`` (:func:`adam_scan`).
    Any registered ``kernel`` trains: ``init`` is that kernel's params
    pytree, optimized leaf-by-leaf through softplus space (SE keeps its
    analytic backward pass; other families autodiff through the program).
    """
    x = jnp.asarray(x, dtype)
    if x.ndim == 1:
        x = x[:, None]
    y = jnp.asarray(y, dtype).reshape(-1)
    kernel = km.resolve_kernel(kernel)
    pack, unpack = _raw_codec(kernel)
    loss = nlml_loss_fn(
        x,
        y,
        method=method,
        dtype=dtype,
        tile_size=tile_size,
        n_streams=n_streams,
        op_backend=op_backend,
        update_dtype=update_dtype,
        vjp=vjp,
        kernel=kernel,
        m_inducing=m_inducing,
        strategy=strategy,
        inducing=inducing,
        jitter=jitter,
    )
    raw, losses = adam_scan(loss, steps, lr)(pack(init, dtype=dtype))
    return unpack(raw), losses


def optimize_hyperparameters_batched(
    x: jax.Array,
    y: jax.Array,
    init,
    *,
    steps: int = 100,
    lr: float = 0.05,
    dtype=jnp.float32,
    method: str = "tiled",
    tile_size: int = 256,
    n_streams=None,
    op_backend: str = "jnp",
    update_dtype=None,
    vjp: str = "custom",
    batch_dispatch: str = "flat",
    kernel=None,
    m_inducing=None,
    strategy: str = "subset",
    inducing=None,
    jitter=None,
    n_valid=None,
) -> Tuple:
    """Train B GPs' hyperparameters in ONE jitted Adam scan (DESIGN.md §9).

    x (B, n, D) / y (B, n); ``init`` leaves scalar (shared start) or (B,)
    (per-problem starts).  Returns (params with (B,) leaves, loss curves
    (steps, B)).  ``method="tiled"`` (default) evaluates all B NLMLs through
    one problem-batched fused program per optimizer step;
    ``method="monolithic"`` vmaps the dense reference NLML — the
    equivalence baseline; ``method="lowrank"`` evaluates the Nyström NLML
    (:func:`nlml_lowrank_batched`, requires ``m_inducing``; trains by
    autodiff through the builder).
    """
    x = jnp.asarray(x, dtype)
    if x.ndim == 2:
        x = x[..., None]
    y = jnp.asarray(y, dtype)
    if x.ndim != 3 or y.ndim != 2 or x.shape[:2] != y.shape:
        raise ValueError(
            f"batched optimize needs x (B, n, D) and y (B, n); got "
            f"{tuple(x.shape)}, {tuple(y.shape)}"
        )
    b = x.shape[0]
    kernel = km.resolve_kernel(kernel)
    pack, unpack = _raw_codec(kernel)
    init = km.broadcast_params(init, b, kernel)
    if method == "tiled":
        loss = lambda raw: nlml_tiled_batched(
            x,
            y,
            unpack(raw),
            tile_size=tile_size,
            n_streams=n_streams,
            op_backend=op_backend,
            update_dtype=update_dtype,
            dtype=dtype,
            vjp=vjp,
            batch_dispatch=batch_dispatch,
            kernel=kernel,
        )
    elif method == "lowrank":
        if m_inducing is None:
            raise ValueError("method='lowrank' needs m_inducing")
        loss = lambda raw: nlml_lowrank_batched(
            x,
            y,
            unpack(raw),
            m_inducing=m_inducing,
            tile_size=tile_size,
            strategy=strategy,
            inducing=inducing,
            jitter=jitter,
            n_streams=n_streams,
            op_backend=op_backend,
            update_dtype=update_dtype,
            dtype=dtype,
            batch_dispatch=batch_dispatch,
            n_valid=n_valid,
            kernel=kernel,
        )
    elif method == "monolithic":
        mono = jax.vmap(
            lambda x1, y1, raw1: negative_log_marginal_likelihood(
                x1, y1, unpack(raw1), dtype=dtype, kernel=kernel
            ),
            in_axes=(0, 0, 0),
        )
        loss = lambda raw: mono(x, y, raw)
    else:
        raise ValueError(
            f"method must be 'monolithic', 'tiled' or 'lowrank', got {method!r}"
        )
    raw, losses = adam_scan_batched(loss, steps, lr)(pack(init, dtype=dtype))
    return unpack(raw), losses
