"""GP hyperparameter optimization via the log marginal likelihood.

Beyond the paper's scope (it fixes l=1, v=1, sigma^2=0.1) but part of the
GPRat library proper; included for completeness (DESIGN.md §7, which also
covers how the optimize path relates to the fused program IR).  The NLML is
computed through the monolithic Cholesky and differentiated with JAX;
hyperparameters are optimized in unconstrained log-space with Adam.

    nlml = 0.5 * ( y^T alpha + log det K + n log 2 pi )

For *evaluating* the NLML at fixed hyperparameters, :func:`nlml_from_state`
reuses a tiled :class:`repro.core.predict.PosteriorState` instead (quadratic
term from the cached alpha chunks, log-determinant from the packed factor's
diagonal tiles) — no re-factorization, exact for any n thanks to identity
padding.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import cholesky as chol
from repro.core import kernels_math as km
from repro.core import triangular


def negative_log_marginal_likelihood(
    x: jax.Array,
    y: jax.Array,
    params: km.SEKernelParams,
    *,
    dtype=jnp.float32,
) -> jax.Array:
    """Exact NLML through the monolithic Cholesky (differentiable)."""
    x = x.astype(dtype)
    y = y.astype(dtype)
    n = y.shape[0]
    k = km.assemble_covariance(x, params, dtype=dtype)
    l = chol.monolithic_cholesky(k)
    beta = jax.lax.linalg.triangular_solve(l, y[:, None], left_side=True, lower=True)
    quad = jnp.sum(beta * beta)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))
    return 0.5 * (quad + logdet + n * math.log(2.0 * math.pi))


def nlml_from_state(state, y: jax.Array, *, dtype=jnp.float32) -> jax.Array:
    """NLML from a cached tiled posterior (no re-factorization).

    quad   = y^T alpha            (alpha = K^{-1} y, cached chunks; padded
                                   rows contribute 0 because y pads with 0)
    logdet = 2 sum log diag(L)    (packed factor's diagonal tiles; padded
                                   rows contribute log 1 = 0)
    """
    from repro.core import predict as pred

    y = y.astype(dtype)
    n = y.shape[0]
    yc = pred.pad_vector(y, state.m)
    quad = jnp.sum(yc * state.alpha)
    m_tiles = state.alpha.shape[0]
    logdet = triangular.logdet_from_factor(state.lpacked, m_tiles)
    return 0.5 * (quad + logdet + n * math.log(2.0 * math.pi))


def _unpack(raw: jax.Array) -> km.SEKernelParams:
    # softplus keeps hyperparameters positive; raw is in R^3
    sp = lambda z: jnp.logaddexp(z, 0.0)
    return km.SEKernelParams(lengthscale=sp(raw[0]), vertical=sp(raw[1]), noise=sp(raw[2]))


def _pack(params: km.SEKernelParams) -> jax.Array:
    inv_sp = lambda p: jnp.log(jnp.expm1(jnp.maximum(jnp.asarray(p, jnp.float32), 1e-6)))
    return jnp.stack(
        [inv_sp(params.lengthscale), inv_sp(params.vertical), inv_sp(params.noise)]
    )


def optimize_hyperparameters(
    x: jax.Array,
    y: jax.Array,
    init: km.SEKernelParams,
    *,
    steps: int = 100,
    lr: float = 0.05,
    dtype=jnp.float32,
) -> Tuple[km.SEKernelParams, jax.Array]:
    """Adam on the NLML in unconstrained space.  Returns (params, loss curve)."""

    def loss(raw):
        return negative_log_marginal_likelihood(x, y, _unpack(raw), dtype=dtype)

    grad_fn = jax.jit(jax.value_and_grad(loss))
    raw = _pack(init)
    m = jnp.zeros_like(raw)
    v = jnp.zeros_like(raw)
    b1, b2, eps = 0.9, 0.999, 1e-8
    losses = []

    @jax.jit
    def update(raw, m, v, t):
        val, g = grad_fn(raw)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        raw = raw - lr * mhat / (jnp.sqrt(vhat) + eps)
        return raw, m, v, val

    for t in range(1, steps + 1):
        raw, m, v, val = update(raw, m, v, jnp.asarray(t, jnp.float32))
        losses.append(val)
    return _unpack(raw), jnp.stack(losses)
