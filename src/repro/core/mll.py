"""GP hyperparameter optimization via the log marginal likelihood.

Beyond the paper's scope (it fixes l=1, v=1, sigma^2=0.1) but part of the
GPRat library proper; included for completeness (DESIGN.md §7).  The NLML is
computed through the same Cholesky machinery and differentiated with JAX;
hyperparameters are optimized in unconstrained log-space with Adam.

    nlml = 0.5 * ( y^T alpha + log det K + n log 2 pi )
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import cholesky as chol
from repro.core import kernels_math as km


def negative_log_marginal_likelihood(
    x: jax.Array,
    y: jax.Array,
    params: km.SEKernelParams,
    *,
    dtype=jnp.float32,
) -> jax.Array:
    """Exact NLML through the monolithic Cholesky (differentiable)."""
    x = x.astype(dtype)
    y = y.astype(dtype)
    n = y.shape[0]
    k = km.assemble_covariance(x, params, dtype=dtype)
    l = chol.monolithic_cholesky(k)
    beta = jax.lax.linalg.triangular_solve(l, y[:, None], left_side=True, lower=True)
    quad = jnp.sum(beta * beta)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))
    return 0.5 * (quad + logdet + n * math.log(2.0 * math.pi))


def _unpack(raw: jax.Array) -> km.SEKernelParams:
    # softplus keeps hyperparameters positive; raw is in R^3
    sp = lambda z: jnp.logaddexp(z, 0.0)
    return km.SEKernelParams(lengthscale=sp(raw[0]), vertical=sp(raw[1]), noise=sp(raw[2]))


def _pack(params: km.SEKernelParams) -> jax.Array:
    inv_sp = lambda p: jnp.log(jnp.expm1(jnp.maximum(jnp.asarray(p, jnp.float32), 1e-6)))
    return jnp.stack(
        [inv_sp(params.lengthscale), inv_sp(params.vertical), inv_sp(params.noise)]
    )


def optimize_hyperparameters(
    x: jax.Array,
    y: jax.Array,
    init: km.SEKernelParams,
    *,
    steps: int = 100,
    lr: float = 0.05,
    dtype=jnp.float32,
) -> Tuple[km.SEKernelParams, jax.Array]:
    """Adam on the NLML in unconstrained space.  Returns (params, loss curve)."""

    def loss(raw):
        return negative_log_marginal_likelihood(x, y, _unpack(raw), dtype=dtype)

    grad_fn = jax.jit(jax.value_and_grad(loss))
    raw = _pack(init)
    m = jnp.zeros_like(raw)
    v = jnp.zeros_like(raw)
    b1, b2, eps = 0.9, 0.999, 1e-8
    losses = []

    @jax.jit
    def update(raw, m, v, t):
        val, g = grad_fn(raw)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        raw = raw - lr * mhat / (jnp.sqrt(vhat) + eps)
        return raw, m, v, val

    for t in range(1, steps + 1):
        raw, m, v, val = update(raw, m, v, jnp.asarray(t, jnp.float32))
        losses.append(val)
    return _unpack(raw), jnp.stack(losses)
