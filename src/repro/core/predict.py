"""Fully device-resident tiled GP prediction pipeline (paper Section 4).

Pipeline (all stages jit-compiled, data stays on device end-to-end):

  1. assemble packed training covariance  K = K_XX + sigma^2 I   (tiled)
  2. tiled Cholesky                       K = L L^T
  3. forward / backward substitution      L beta = y;  L^T alpha = beta
  4. cross covariance                     K_* = K_{X̂,X}          (tiled)
  5. predictive mean                      ŷ = K_* alpha
  6. (uncertainty) solve L V = K_{X,X̂};  W = V^T V;  Σ = K_{X̂,X̂} - W

Two execution strategies (DESIGN.md §7):

* :func:`predict` (the default path) — the whole pipeline is ONE
  multi-stage program: :func:`repro.core.scheduler.build_program_schedule`
  emits a single DAG with cross-stage edges and
  :func:`repro.core.executor.run_program` walks it over a named buffer
  environment, under one ``jax.jit``.  Substitution rows and
  cross-covariance tiles fire the moment their factor tiles resolve — the
  paper's headline cross-stage overlap.
* :func:`predict_staged` — the staged baseline: the six stages run as
  separate executor invocations with a barrier between each (kept for
  equivalence testing and as the paper's per-stage reference).

Padding: inputs of arbitrary n / n̂ are padded to tile multiples; the padded
covariance region is identity/zero which leaves all results for the first n
(resp. n̂) entries exactly unchanged (see kernels_math docstring).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.core import cholesky as chol
from repro.core import executor
from repro.core import kernels_math as km
from repro.core import tiling, triangular
from repro.dist import sharding as dist_sharding

# Dispatch-boundary trace spans (DESIGN.md §15).  The jnp fast paths run
# the program under jit, so executor.run_program only executes at trace
# time there — the per-dispatch record must happen HERE, at the host call
# into the cached jitted fn, where operands are concrete.
_tracer = obs.Tracer("repro.predict")


def _record_program(kind, xc, q_tiles, uncertainty, n_streams, backend):
    """Record one jitted fused-program dispatch (no-op unless obs is on).

    Skipped at trace time (``xc`` a tracer — when this caller is itself
    under an outer jit/grad the dispatch belongs to whoever runs that
    trace) and for the Pallas backend, whose unjitted eager path records
    inside executor.run_program — so no dispatch is ever counted twice.
    """
    if obs.enabled() and backend == "jnp" and not isinstance(xc, jax.core.Tracer):
        executor.record_dispatch(
            kind,
            executor.program_plan(xc.shape[-3], q_tiles, uncertainty, n_streams),
            backend=backend,
            batched=xc.ndim == 4,
        )


# ---------------------------------------------------------------------------
# Tiled covariance assembly (jnp path; Pallas path lives in repro.kernels).
# ---------------------------------------------------------------------------


def _tile_kernel(
    xa, xb, row0, col0, params, n_valid_r, n_valid_c, symmetric, kernel=None
):
    """One covariance tile with global index masking (see kernels_math.cov_tile)."""
    return km.cov_tile(
        xa, xb, row0, col0, params, n_valid_r, n_valid_c, symmetric, kernel=kernel
    )


def assemble_packed_covariance(
    x_chunks: jax.Array,
    params,
    n_valid: int,
    *,
    backend: str = "jnp",
    kernel: Optional[km.Kernel] = None,
) -> jax.Array:
    """x_chunks: (M, m, D) padded feature chunks -> packed lower tiles (T, m, m).

    Only the M(M+1)/2 lower tiles are evaluated — the paper's observation that
    the tiled structure reduces assembly work (Fig. 4 discussion).
    ``kernel`` picks the registered covariance family (None -> SE).
    """
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.assemble_packed_covariance(x_chunks, params, n_valid, kernel)
    m_tiles, m, _ = x_chunks.shape
    rows, cols = tiling._packed_coords(m_tiles)
    row0 = jnp.asarray(rows * m)
    col0 = jnp.asarray(cols * m)
    fn = jax.vmap(
        functools.partial(
            _tile_kernel, params=params, n_valid_r=n_valid, n_valid_c=n_valid,
            symmetric=True, kernel=kernel,
        )
    )
    return fn(x_chunks[rows], x_chunks[cols], row0, col0)


def assemble_cross_tiles(
    xt_chunks: jax.Array,
    x_chunks: jax.Array,
    params,
    nt_valid: int,
    n_valid: int,
    *,
    backend: str = "jnp",
    kernel: Optional[km.Kernel] = None,
) -> jax.Array:
    """K_{X̂,X} tile grid: (Mhat, M, m, m) from (Mhat, m, D) × (M, m, D)."""
    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.assemble_cross_tiles(
            xt_chunks, x_chunks, params, nt_valid, n_valid, kernel
        )
    mh, m, _ = xt_chunks.shape
    mt = x_chunks.shape[0]

    def one(xa, row0):
        return jax.vmap(
            lambda xb, col0: _tile_kernel(
                xa, xb, row0, col0, params, nt_valid, n_valid, symmetric=False,
                kernel=kernel,
            )
        )(x_chunks, jnp.arange(mt) * m)

    return jax.vmap(one)(xt_chunks, jnp.arange(mh) * m)


def assemble_prior_tiles(
    xt_chunks: jax.Array,
    params,
    nt_valid: int,
    *,
    backend: str = "jnp",
    kernel: Optional[km.Kernel] = None,
) -> jax.Array:
    """Prior K_{X̂,X̂} tile grid (Mhat, Mhat, m, m), no noise, padded region 0."""
    del backend  # cheap relative to cross/solves; jnp path always used
    mh, m, _ = xt_chunks.shape

    def one(xa, row0):
        return jax.vmap(
            lambda xb, col0: _tile_kernel(
                xa, xb, row0, col0, params, nt_valid, nt_valid, symmetric=False,
                kernel=kernel,
            )
        )(xt_chunks, jnp.arange(mh) * m)

    return jax.vmap(one)(xt_chunks, jnp.arange(mh) * m)


# per-problem (B,) leaf normalization — canonical impl in kernels_math
_broadcast_params = km.broadcast_params


def assemble_cross_tiles_batched(
    xt_chunks: jax.Array,
    x_chunks: jax.Array,
    params,
    nt_valid,
    n_valid,
    kernel: Optional[km.Kernel] = None,
) -> jax.Array:
    """Problem-batched K_{X̂,X} grid: (B, Mhat, M, m, m) with per-problem params.

    Always the jnp tile kernel: the Pallas assembly kernel bakes
    hyperparameters in as compile-time constants and cannot vary them across
    the problem axis (see executor._cov_batch_fn_batched).

    ``nt_valid``/``n_valid`` may be shared scalars or (B,) per-problem
    validity frontiers (the ragged-fleet path, DESIGN.md §11) — either way
    they join the problem-axis vmap.
    """
    b = xt_chunks.shape[0]
    params = _broadcast_params(params, b, kernel)
    ntb = jnp.broadcast_to(jnp.asarray(nt_valid), (b,))
    nb = jnp.broadcast_to(jnp.asarray(n_valid), (b,))
    return jax.vmap(
        lambda xt1, x1, p, nt1, n1: assemble_cross_tiles(
            xt1, x1, p, nt1, n1, kernel=kernel
        )
    )(xt_chunks, x_chunks, params, ntb, nb)


def assemble_prior_tiles_batched(
    xt_chunks: jax.Array, params, nt_valid, kernel: Optional[km.Kernel] = None
) -> jax.Array:
    """Problem-batched prior K_{X̂,X̂} grid (B, Mhat, Mhat, m, m)."""
    b = xt_chunks.shape[0]
    params = _broadcast_params(params, b, kernel)
    ntb = jnp.broadcast_to(jnp.asarray(nt_valid), (b,))
    return jax.vmap(
        lambda xt1, p, nt1: assemble_prior_tiles(xt1, p, nt1, kernel=kernel)
    )(xt_chunks, params, ntb)


def _resolve_dtype(dtype, *arrays):
    """``dtype=None`` means "preserve the (canonicalized) input dtype" —
    the explicit alternative to the old implicit float32 default."""
    if dtype is not None:
        return jnp.dtype(dtype)
    return jnp.result_type(*(jnp.asarray(a).dtype for a in arrays))


# ---------------------------------------------------------------------------
# End-to-end tiled prediction.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PosteriorState:
    """Cached per-training-set state: the packed factor and the weight vector.

    Everything a repeated ``predict`` needs that does not depend on x_test:
    re-using this skips covariance assembly, the factorization, and both
    substitutions — the O(n^3) part of the pipeline.

    This is a *live* state (DESIGN.md §10): :meth:`extend` absorbs new
    observations in O(n^2 b) via a block Cholesky append and :meth:`shrink`
    evicts the oldest ones via tiled rank updates — no re-factorization.
    The optional ``beta``/``y_chunks`` fields carry the forward-solve chunks
    and padded targets the incremental maintenance needs; states built
    before §10 (``None``) are reconstructed from the factor on demand
    (two O(n^2) packed matvecs).
    """

    lpacked: jax.Array     # (T, m, m) packed Cholesky factor of K
    alpha: jax.Array       # (M, m) chunks of K^{-1} y
    x_chunks: jax.Array    # (M, m, D) padded training features
    n: int                 # valid training rows (bucket capacity when ragged)
    m: int                 # tile size
    params: object         # hyperparameter pytree the factor was built with
    beta: Optional[jax.Array] = None      # (M, m) forward-solve chunks L^{-1} y
    y_chunks: Optional[jax.Array] = None  # (M, m) padded training targets
    # ragged stacked states only (DESIGN.md §11): per-problem validity
    # frontiers (B,) — each problem's factor is identity past its frontier
    # and the prediction/NLML heads mask with these instead of ``n``.
    n_valid: Optional[jax.Array] = None
    # the covariance family the factor was assembled with (DESIGN.md §13);
    # like ``params``, it travels with the state so warm predictions and
    # streaming updates can never silently mix kernels.
    kernel: km.Kernel = km.SQUARED_EXPONENTIAL

    def extend(self, x_new: jax.Array, y_new: jax.Array, **kwargs) -> "PosteriorState":
        """Absorb new observations in O(n^2 b) (block Cholesky append).

        Keyword arguments are forwarded to
        :func:`repro.core.update.extend_state` (``n_streams``, ``backend``,
        ``update_dtype``, ``check_finite``).  Raises
        :class:`repro.core.update.CholeskyUpdateError` on numerical failure
        — callers fall back to a fresh :func:`posterior_state`.
        """
        from repro.core import update as upd

        return upd.extend_state(self, x_new, y_new, **kwargs)

    def shrink(self, k: int, **kwargs) -> "PosteriorState":
        """Evict the k oldest observations in O(n^2 k) (tiled rank update).

        ``k`` must be a multiple of the tile size (whole leading
        tile-columns); see :func:`repro.core.update.shrink_state`.
        """
        from repro.core import update as upd

        return upd.shrink_state(self, k, **kwargs)


def posterior_state(
    x_train: jax.Array,
    y_train: jax.Array,
    params,
    m: int,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    dtype=None,
    kernel: Optional[km.Kernel] = None,
) -> PosteriorState:
    """Assemble + factor K and solve for alpha = K^{-1} y (the cacheable part)."""
    kernel = km.resolve_kernel(kernel)
    n = x_train.shape[0]
    dtype = _resolve_dtype(dtype, x_train)
    xc = tiling.pad_features(x_train, m, dtype=dtype)
    yc = tiling.pad_vector(y_train, m, dtype=dtype)
    packed = assemble_packed_covariance(xc, params, n, backend=backend, kernel=kernel)
    lpacked = chol.tiled_cholesky(
        packed, n_streams=n_streams, backend=backend, update_dtype=update_dtype
    )
    beta = triangular.forward_substitution(lpacked, yc, n_streams=n_streams)
    alpha = triangular.backward_substitution(lpacked, beta, n_streams=n_streams)
    return PosteriorState(
        lpacked=lpacked, alpha=alpha, x_chunks=xc, n=n, m=m, params=params,
        beta=beta, y_chunks=yc, kernel=kernel,
    )


def predict_from_state(
    state: PosteriorState,
    x_test: jax.Array,
    *,
    full_cov: bool = False,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    dtype=None,
):
    """Prediction given a (possibly cached) :class:`PosteriorState`.

    The kernel hyperparameters come from the state itself — alpha and the
    factor are only valid for the params K was assembled with, so accepting
    them separately would invite a silent mismatch.  ``dtype=None`` follows
    the state's storage dtype.
    """
    params = state.params
    kernel = state.kernel
    nh = x_test.shape[0]
    if obs.enabled() and not isinstance(x_test, jax.core.Tracer):
        obs.inc("predict.warm_tail")
    dtype = state.x_chunks.dtype if dtype is None else jnp.dtype(dtype)
    xtc = tiling.pad_features(x_test, state.m, dtype=dtype)
    kstar = assemble_cross_tiles(
        xtc, state.x_chunks, params, nh, state.n, backend=backend, kernel=kernel
    )
    mean = triangular.tiled_matvec(kstar, state.alpha).reshape(-1)[:nh]
    if not full_cov:
        return mean

    # L V = K_{X,X̂}:  B tiles are the transpose grid of K_* tiles.
    b_tiles = jnp.einsum("qiab->iqba", kstar)
    v = triangular.forward_substitution_matrix(state.lpacked, b_tiles, n_streams=n_streams)
    w = triangular.tiled_gram(v)                               # (Q, Q, mq, mq)
    prior = assemble_prior_tiles(xtc, params, nh, backend=backend, kernel=kernel)
    sigma_tiles = prior - w
    sigma = tiling.untile_dense(sigma_tiles)[:nh, :nh]
    return mean, sigma


# ---------------------------------------------------------------------------
# Fused whole-pipeline prediction (one program, one jit — DESIGN.md §7).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _fused_program_fn(
    uncertainty: bool,
    n_streams: Optional[int],
    backend: str,
    update_dtype,
    n_valid: Optional[int],
    nt_valid: Optional[int],
    batch_dispatch: str = "flat",
    mesh=None,
    kernel: Optional[km.Kernel] = None,
):
    """The ONE jit of the fused pipeline, cached per static configuration.

    Shapes are implied by the traced operands; the program plan itself is
    lru-cached inside :func:`repro.core.executor.program_plan`.  The cache
    is shared by the single-problem and problem-batched paths — B enters
    only through the traced operand shapes (jit re-specializes per B), never
    through the plan.  The Pallas backend bakes hyperparameters into its
    assembly kernels as compile-time constants, so it runs unjitted at this
    level (each Pallas call is its own compiled kernel).

    **Ragged variant:** keyed with ``n_valid=None`` the returned function
    takes the validity frontiers as two extra *traced* operands
    ``fn(xc, yc, xtc, params, n_valid, nt_valid)`` — (B,) arrays or
    scalars.  One jit trace (and one executor Plan) then serves every
    per-problem size mix of a bucket geometry: frontier values never force
    a retrace (DESIGN.md §11).

    **Sharded variant (DESIGN.md §12):** ``mesh`` pins every B-leading
    buffer to the fleet layout inside the jit.  The mesh changes the traced
    jaxpr (sharding constraints are ops), so it joins the lru key — but it
    never reaches the executor's Plan caches, which stay shard-invariant.

    **Kernel zoo (DESIGN.md §13):** the (hashable) ``kernel`` instance joins
    the lru key too — each covariance family gets its own jit — while the
    executor's Plan caches stay kernel-invariant (only ASSEMBLE/CROSS/PRIOR
    payloads differ).
    """
    if n_valid is None:

        def ragged_fn(xc, yc, xtc, params, nv, ntv):
            return executor.run_program(
                xc,
                yc,
                xtc,
                params,
                nv,
                ntv,
                uncertainty=uncertainty,
                n_streams=n_streams,
                backend=backend,
                update_dtype=update_dtype,
                batch_dispatch=batch_dispatch,
                mesh=mesh,
                kernel=kernel,
            )

        return jax.jit(ragged_fn) if backend == "jnp" else ragged_fn

    def fn(xc, yc, xtc, params):
        return executor.run_program(
            xc,
            yc,
            xtc,
            params,
            n_valid,
            nt_valid,
            uncertainty=uncertainty,
            n_streams=n_streams,
            backend=backend,
            update_dtype=update_dtype,
            batch_dispatch=batch_dispatch,
            mesh=mesh,
            kernel=kernel,
        )

    return jax.jit(fn) if backend == "jnp" else fn


def predict_fused(
    x_train: jax.Array,
    y_train: jax.Array,
    x_test: jax.Array,
    params,
    m: int,
    *,
    full_cov: bool = False,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    dtype=None,
    with_state: bool = False,
    kernel: Optional[km.Kernel] = None,
):
    """Whole-pipeline fused prediction: one program, one jit, one plan cache.

    Runs assembly, factorization, both substitutions, cross covariance and
    the prediction heads as a single multi-stage program with cross-stage
    wavefronts (executor.run_program).  Returns mean (or ``(mean, sigma)``
    with ``full_cov``); with ``with_state=True`` also the
    :class:`PosteriorState` sliced out of the program's buffer environment,
    so callers can reuse the factor for later staged predictions.
    """
    kernel = km.resolve_kernel(kernel)
    n = x_train.shape[0]
    nh = x_test.shape[0]
    dtype = _resolve_dtype(dtype, x_train)
    xc = tiling.pad_features(x_train, m, dtype=dtype)
    yc = tiling.pad_vector(y_train, m, dtype=dtype)
    xtc = tiling.pad_features(x_test, m, dtype=dtype)
    fn = _fused_program_fn(
        full_cov, n_streams, backend, update_dtype, n, nh, kernel=kernel
    )
    _record_program("run_program", xc, xtc.shape[-3], full_cov, n_streams, backend)
    with _tracer.span("fused"):
        env = fn(xc, yc, xtc, params)
    mean = env["mean"].reshape(-1)[:nh]
    if full_cov:
        q_tiles = xtc.shape[0]
        sigma_tiles = env["prior"].reshape(q_tiles, q_tiles, m, m)
        result = (mean, tiling.untile_dense(sigma_tiles)[:nh, :nh])
    else:
        result = mean
    if not with_state:
        return result
    # env["y"] holds beta after the in-place forward substitution (§7)
    state = PosteriorState(
        lpacked=env["packed"], alpha=env["alpha"], x_chunks=xc, n=n, m=m,
        params=params, beta=env["y"], y_chunks=yc, kernel=kernel,
    )
    return result, state


def predict_fused_batched(
    x_train: jax.Array,
    y_train: jax.Array,
    x_test: jax.Array,
    params,
    m: int,
    *,
    full_cov: bool = False,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    dtype=None,
    with_state: bool = False,
    batch_dispatch: str = "flat",
    n_valid=None,
    nt_valid=None,
    mesh=None,
    kernel: Optional[km.Kernel] = None,
):
    """Fused prediction for B independent GPs in ONE batched program.

    x_train (B, n, D) / y_train (B, n) / x_test (B, n̂, D) stacked problems
    of identical shape; ``params`` leaves may be scalars (shared) or (B,)
    (per-problem).  The same lru-cached Plan as the single-problem program
    drives all B problems — identical launch count, every launch B times
    wider (DESIGN.md §9).  Shares :func:`_fused_program_fn`'s jit cache with
    the unbatched path (jit re-specializes on the leading B axis).

    **Ragged batches (DESIGN.md §11):** pass ``n_valid`` — a (B,) vector of
    per-problem valid training counts — when the stacked problems are
    zero-padded to a shared bucket capacity; rows past each frontier must
    be zero.  ``nt_valid`` optionally masks per-problem test counts the
    same way (mean/sigma rows past a problem's own count come back zero).
    The frontiers are traced operands: every size mix of the same stacked
    shape shares one jit trace and one executor Plan.

    **Sharded fleets (DESIGN.md §12):** ``mesh`` commits the stacked inputs
    to the fleet layout (B over the mesh's DP axes) and pins every env
    buffer to it inside the program — pure data parallelism, zero
    collectives, one Plan regardless of device count.

    Returns mean (B, n̂), or ``(mean, sigma)`` with sigma (B, n̂, n̂) when
    ``full_cov``; with ``with_state=True`` also the stacked
    :class:`PosteriorState` (leading B axis on lpacked/alpha/x_chunks).
    """
    kernel = km.resolve_kernel(kernel)
    b, n = x_train.shape[0], x_train.shape[1]
    nh = x_test.shape[1]
    dtype = _resolve_dtype(dtype, x_train)
    xc = tiling.pad_features(x_train, m, dtype=dtype)    # (B, M, m, D)
    yc = tiling.pad_vector(y_train, m, dtype=dtype)      # (B, M, m)
    xtc = tiling.pad_features(x_test, m, dtype=dtype)    # (B, Q, m, D)
    if mesh is not None:
        xc = dist_sharding.device_put_fleet(xc, mesh)
        yc = dist_sharding.device_put_fleet(yc, mesh)
        xtc = dist_sharding.device_put_fleet(xtc, mesh)
    ragged = n_valid is not None
    if ragged:
        nv = jnp.asarray(n_valid, jnp.int32)
        ntv = jnp.asarray(nh if nt_valid is None else nt_valid, jnp.int32)
        fn = _fused_program_fn(
            full_cov, n_streams, backend, update_dtype, None, None,
            batch_dispatch, mesh, kernel,
        )
        _record_program(
            "run_program", xc, xtc.shape[-3], full_cov, n_streams, backend
        )
        with _tracer.span("fused_batched"):
            env = fn(xc, yc, xtc, params, nv, ntv)
    else:
        fn = _fused_program_fn(
            full_cov, n_streams, backend, update_dtype, n, nh, batch_dispatch,
            mesh, kernel,
        )
        _record_program(
            "run_program", xc, xtc.shape[-3], full_cov, n_streams, backend
        )
        with _tracer.span("fused_batched"):
            env = fn(xc, yc, xtc, params)
    mean = env["mean"].reshape(b, -1)[:, :nh]
    if full_cov:
        q_tiles = xtc.shape[1]
        sigma_tiles = env["prior"].reshape(b, q_tiles, q_tiles, m, m)
        result = (mean, tiling.untile_dense(sigma_tiles)[:, :nh, :nh])
    else:
        result = mean
    if not with_state:
        return result
    state = PosteriorState(
        lpacked=env["packed"], alpha=env["alpha"], x_chunks=xc, n=n, m=m,
        params=params, beta=env["y"], y_chunks=yc,
        n_valid=nv if ragged else None, kernel=kernel,
    )
    return result, state


def predict_from_state_batched(
    state: PosteriorState,
    x_test: jax.Array,
    *,
    full_cov: bool = False,
    n_streams: Optional[int] = None,
    dtype=None,
    nt_valid=None,
    mesh=None,
):
    """Warm batched prediction from a stacked :class:`PosteriorState`.

    The state holds B factors/weights (leading B axis); x_test (B, n̂, D).
    Reuses the cached O(n^3) work and runs only the cross-covariance / mean
    (and optionally the matrix-solve tail) — all through the batched
    executor plans.  Assembly uses the jnp tile kernel (per-problem params).

    Ragged states (``state.n_valid`` set) mask the cross covariance at each
    problem's own frontier — required for correctness, not just economy:
    the padded feature rows are zeros, so an unmasked K_* column against
    them would be k(x̂, 0) ≠ 0 and corrupt the solve tail (the masked
    factor is identity there).  ``nt_valid`` (scalar or (B,)) optionally
    masks per-problem test counts; rows past a problem's count come back 0.
    """
    params = state.params
    kernel = state.kernel
    b, nh = x_test.shape[0], x_test.shape[1]
    if obs.enabled() and not isinstance(x_test, jax.core.Tracer):
        obs.inc("predict.warm_tail_batched")
    dtype = state.x_chunks.dtype if dtype is None else jnp.dtype(dtype)
    xtc = tiling.pad_features(x_test, state.m, dtype=dtype)
    # the warm tail runs op-by-op (no enclosing jit): committing the test
    # block to the fleet layout is enough — the cached state buffers carry
    # their sharding out of the fused program and propagate it through the
    # assembly/matvec ops.
    xtc = dist_sharding.device_put_fleet(xtc, mesh)
    nv = state.n if state.n_valid is None else state.n_valid
    ntv = nh if nt_valid is None else nt_valid
    kstar = assemble_cross_tiles_batched(
        xtc, state.x_chunks, params, ntv, nv, kernel
    )
    mean = triangular.tiled_matvec(kstar, state.alpha).reshape(b, -1)[:, :nh]
    if not full_cov:
        return mean

    # L V = K_{X,X̂}:  B tiles are the per-problem transpose grids of K_*.
    b_tiles = jnp.einsum("zqiab->ziqba", kstar)
    v = triangular.forward_substitution_matrix(
        state.lpacked, b_tiles, n_streams=n_streams
    )
    w = triangular.tiled_gram(v)                         # (B, Q, Q, mq, mq)
    prior = assemble_prior_tiles_batched(xtc, params, ntv, kernel)
    sigma = tiling.untile_dense(prior - w)[:, :nh, :nh]
    return mean, sigma


def nlml_program_env(
    x_train: jax.Array,
    y_train: jax.Array,
    params,
    m: int,
    *,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    dtype=None,
    batch_dispatch: str = "flat",
    n_valid=None,
    mesh=None,
    kernel: Optional[km.Kernel] = None,
):
    """Run the NLML prefix of the fused program (DESIGN.md §8).

    ``q_tiles=0`` reduces the whole-pipeline DAG to assembly → factorization
    → both substitutions; the returned buffer environment's ``packed`` slice
    is the factor (log-determinant head) and ``alpha`` the weight chunks
    (quadratic-term head).  Shares the jit/plan caches with
    :func:`predict_fused` — the NLML program *is* the prediction program with
    zero test tiles.  Returns ``(env, yc)`` with ``yc`` the padded target
    chunks (the quadratic term is ``sum(yc * env['alpha'])``).

    Fully traceable under ``jax.grad``: jnp ops differentiate natively and
    the Pallas tile ops carry reference VJPs; assembly falls back to the jnp
    tile kernel when the hyperparameters are traced (executor._cov_batch_fn).

    Problem-batched with x_train (B, n, D) / y_train (B, n): the env buffers
    gain the leading B axis and ``env["alpha"]`` / ``env["packed"]`` hold B
    independent weight chunks / factors (DESIGN.md §9).  Ragged batches
    pass ``n_valid`` (B,) per-problem counts — stacks zero-padded to a
    bucket capacity factor through ONE traced program (DESIGN.md §11).
    """
    kernel = km.resolve_kernel(kernel)
    n = x_train.shape[-2]
    dtype = _resolve_dtype(dtype, x_train)
    xc = tiling.pad_features(x_train, m, dtype=dtype)
    yc = tiling.pad_vector(y_train, m, dtype=dtype)
    xtc = jnp.zeros(xc.shape[:-3] + (0, m, xc.shape[-1]), dtype)
    if mesh is not None and xc.ndim == 4:
        xc = dist_sharding.device_put_fleet(xc, mesh)
        yc = dist_sharding.device_put_fleet(yc, mesh)
    else:
        mesh = None  # unbatched programs have no problem axis to shard
    if n_valid is not None:
        fn = _fused_program_fn(
            False, n_streams, backend, update_dtype, None, None,
            batch_dispatch, mesh, kernel,
        )
        nv = jnp.asarray(n_valid, jnp.int32)
        _record_program("run_program", xc, 0, False, n_streams, backend)
        with _tracer.span("nlml_program"):
            return fn(xc, yc, xtc, params, nv, jnp.asarray(0, jnp.int32)), yc
    fn = _fused_program_fn(
        False, n_streams, backend, update_dtype, n, 0, batch_dispatch, mesh,
        kernel,
    )
    _record_program("run_program", xc, 0, False, n_streams, backend)
    with _tracer.span("nlml_program"):
        return fn(xc, yc, xtc, params), yc


def predict(
    x_train: jax.Array,
    y_train: jax.Array,
    x_test: jax.Array,
    params,
    m: int,
    *,
    full_cov: bool = False,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    dtype=None,
    kernel: Optional[km.Kernel] = None,
):
    """Tiled GP prediction — the fused whole-pipeline program.

    Returns mean (n̂,), or (mean, var) with ``full_cov=False`` semantics of
    the paper's *Predict with Full Covariance* operation when ``full_cov``:
    (mean (n̂,), posterior covariance (n̂, n̂)).

    The old ``fused=False`` wrapper branch is gone: the staged per-stage
    baseline lives behind :func:`predict_staged` (explicitly, for the
    fused-vs-staged benchmarks) and behind the warm
    :func:`posterior_state` / :func:`predict_from_state` pair everywhere
    else.
    """
    return predict_fused(
        x_train,
        y_train,
        x_test,
        params,
        m,
        full_cov=full_cov,
        n_streams=n_streams,
        backend=backend,
        update_dtype=update_dtype,
        dtype=dtype,
        kernel=kernel,
    )


def predict_staged(
    x_train: jax.Array,
    y_train: jax.Array,
    x_test: jax.Array,
    params,
    m: int,
    *,
    full_cov: bool = False,
    n_streams: Optional[int] = None,
    backend: str = "jnp",
    update_dtype=None,
    dtype=None,
    kernel: Optional[km.Kernel] = None,
):
    """The staged per-stage baseline: six executor invocations with a
    barrier between each — the paper's per-stage reference that the fused
    program is benchmarked against (DESIGN.md §7)."""
    state = posterior_state(
        x_train,
        y_train,
        params,
        m,
        n_streams=n_streams,
        backend=backend,
        update_dtype=update_dtype,
        dtype=dtype,
        kernel=kernel,
    )
    return predict_from_state(
        state,
        x_test,
        full_cov=full_cov,
        n_streams=n_streams,
        backend=backend,
        dtype=dtype,
    )


def predict_monolithic(
    x_train: jax.Array,
    y_train: jax.Array,
    x_test: jax.Array,
    params,
    *,
    full_cov: bool = False,
    dtype=None,
    kernel: Optional[km.Kernel] = None,
):
    """Reference (cuSOLVER-analogue) dense pipeline: one-call Cholesky."""
    dtype = _resolve_dtype(dtype, x_train)
    x = x_train.astype(dtype)
    y = y_train.astype(dtype)
    xt = x_test.astype(dtype)
    k = km.assemble_covariance(x, params, kernel=kernel, dtype=dtype)
    l = chol.monolithic_cholesky(k)
    beta = jax.lax.linalg.triangular_solve(
        l, y[:, None], left_side=True, lower=True
    )
    alpha = jax.lax.linalg.triangular_solve(
        l, beta, left_side=True, lower=True, transpose_a=True
    )[:, 0]
    kstar = km.assemble_cross_covariance(xt, x, params, kernel=kernel, dtype=dtype)
    mean = kstar @ alpha
    if not full_cov:
        return mean
    v = jax.lax.linalg.triangular_solve(l, kstar.T, left_side=True, lower=True)
    prior = km.assemble_prior_covariance(xt, params, kernel=kernel, dtype=dtype)
    sigma = prior - v.T @ v
    return mean, sigma


obs.register_cache("predict.fused_program_fn", _fused_program_fn)
