"""Core: the paper's contribution — tiled, device-resident GP regression."""

from repro.core.gp import GaussianProcess, GPBatch, GPFleet
from repro.core.kernels_math import SEKernelParams
from repro.core.update import CholeskyUpdateError

__all__ = [
    "GaussianProcess",
    "GPBatch",
    "GPFleet",
    "SEKernelParams",
    "CholeskyUpdateError",
]
