"""Core: the paper's contribution — tiled, device-resident GP regression."""

from repro.core.gp import GaussianProcess, GPBatch
from repro.core.kernels_math import SEKernelParams

__all__ = ["GaussianProcess", "GPBatch", "SEKernelParams"]
