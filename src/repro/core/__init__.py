"""Core: the paper's contribution — tiled, device-resident GP regression."""

from repro.core.gp import GaussianProcess, GPBatch, GPFleet
from repro.core.kernels_math import (
    ARDKernelParams,
    ARDSquaredExponential,
    Kernel,
    Matern12,
    Matern32,
    Matern52,
    Product,
    RationalQuadratic,
    RQKernelParams,
    Scaled,
    ScaledParams,
    SEKernelParams,
    SquaredExponential,
    Sum,
    White,
    WhiteKernelParams,
    get_kernel,
    register_kernel,
    resolve_kernel,
)
from repro.core.update import CholeskyUpdateError

__all__ = [
    "GaussianProcess",
    "GPBatch",
    "GPFleet",
    "CholeskyUpdateError",
    # kernel zoo (DESIGN.md §13)
    "Kernel",
    "SquaredExponential",
    "Matern12",
    "Matern32",
    "Matern52",
    "RationalQuadratic",
    "ARDSquaredExponential",
    "White",
    "Sum",
    "Product",
    "Scaled",
    "get_kernel",
    "register_kernel",
    "resolve_kernel",
    # params pytrees
    "SEKernelParams",
    "RQKernelParams",
    "ARDKernelParams",
    "WhiteKernelParams",
    "ScaledParams",
]
