"""Core: the paper's contribution — tiled, device-resident GP regression."""

from repro.core.gp import GaussianProcess
from repro.core.kernels_math import SEKernelParams

__all__ = ["GaussianProcess", "SEKernelParams"]
