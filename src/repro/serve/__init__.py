"""Continuous-batching GP serving (DESIGN.md §11).

The LLM-serving idea transplanted to GP fleets: requests against many
independent, differently-sized GPs are drained in *waves*; each wave is
executed through :class:`repro.core.gp.GPFleet`'s bucketed ragged programs
(one fused launch per occupied bucket, per-problem frontiers masked), and
buckets are re-formed between waves as observations land and problems
migrate across geometry boundaries.
"""

from repro.serve.loop import ContinuousBatcher, Request, WaveStats

__all__ = ["ContinuousBatcher", "Request", "WaveStats"]
