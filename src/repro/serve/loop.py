"""Wave-based continuous batching over a :class:`repro.core.gp.GPFleet`.

The serving loop is deliberately synchronous — a single driver thread drains
the request queue in waves, mirroring the paper's host-side task graph (the
asynchrony lives in the fused program's wavefront schedule, not in Python
threads).  One wave:

1. drain everything queued so far;
2. apply ALL observation requests as one ragged ``fleet.update`` — this is
   the continuous-batching step: bucket membership is recomputed, problems
   that outgrew their geometry migrate (``blockdiag(L, I)`` re-embed, zero
   FLOPs) and every stable bucket absorbs its arrivals through one shared
   append sweep;
3. *dispatch* ALL prediction requests via ``fleet.predict_each`` — one warm
   batched launch per occupied bucket, per-problem test counts masked with
   ``nt_valid``;
4. record per-request latencies (submit → results materialized).

Requests against the same problem within one wave are served against the
state at the *start* of the wave (observations land before predictions, so
a wave's predictions do see its own wave's observations — the queue order
inside a wave is observe-then-predict by construction, matching how a
replica would batch its inbox).

**Dispatch overlap.**  ``step`` never blocks on device results: JAX
dispatch is asynchronous, so the wave's prediction launches are enqueued
and the host immediately returns to assembling the next wave while the
devices execute.  Results are materialized ONE WAVE LATE — at the start of
the next ``step`` call — or on demand by :meth:`flush` / :meth:`result` /
the tail of :meth:`run_until_idle`.  Because fleet states are immutable
jax arrays, a later wave's ``fleet.update`` never clobbers the buffers an
in-flight prediction reads.  Ordering contract: wave N's predictions see
exactly waves 0..N's observations regardless of when their results are
fetched, and ``result(rid)`` always returns the value computed against
that snapshot.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

import repro.obs as obs
from repro.core.gp import GPFleet

PREDICT = "predict"
OBSERVE = "observe"


@dataclasses.dataclass
class Request:
    """One queued unit of work against a single fleet problem."""

    rid: int
    kind: str                  # PREDICT | OBSERVE
    problem: int
    x: np.ndarray              # test points (predict) or features (observe)
    y: Optional[np.ndarray]    # targets (observe only)
    t_submit: float
    uncertainty: bool = False  # predict only: also return the variance diag
    t_done: Optional[float] = None
    result: object = None


@dataclasses.dataclass
class WaveStats:
    """What one call to :meth:`ContinuousBatcher.step` did."""

    wave: int
    n_predict: int             # predictions DISPATCHED this wave (not fetched)
    n_observe: int
    points_absorbed: int
    buckets: Tuple[int, ...]   # occupied cap_tiles AFTER the wave
    migrations: int            # problems whose bucket capacity changed
    duration_s: float          # host dispatch time (excludes device wait)
    reoptimized: bool = False  # drift monitor fired -> fleet.optimize() ran


@dataclasses.dataclass
class _InflightWave:
    """One dispatched-but-unfetched prediction wave."""

    per_problem: Dict[int, List[Request]]
    outs: List[object]         # fleet.predict_each results (device futures)
    want_unc: bool
    d: int


class ContinuousBatcher:
    """Drains request waves through a bucketed ragged GP fleet.

    ``clock`` is injectable for deterministic tests; it must be monotonic.
    Results are kept until :meth:`result` pops them.

    **Accounting.**  The batcher keeps a private, always-on
    :class:`repro.obs.Registry` for its own wave/latency accounting —
    :meth:`summary` reads from it, so it works whether or not global
    telemetry is enabled.  With ``obs.enable()`` each wave additionally
    emits a ``serve.wave`` event (queue depth, bucket occupancy,
    padded-FLOP waste, ...) to the process-global registry/JSONL sink.

    **Drift-triggered re-optimize (DESIGN.md §15).**  Pass a
    :class:`repro.obs.DriftMonitor` as ``drift_monitor`` and the batcher
    feeds it the fleet's NLML-per-point after every wave that absorbed
    observations.  When the monitor fires, ``reoptimize`` (default
    ``fleet.optimize()``) runs at the END of the wave — after the wave's
    predictions are already dispatched and overlapping their device
    execution, so the hot dispatch path never waits on training — and the
    monitor is reset against the new hyperparameter level.
    """

    def __init__(
        self,
        fleet: GPFleet,
        *,
        clock: Callable[[], float] = time.perf_counter,
        drift_monitor: Optional[obs.DriftMonitor] = None,
        reoptimize: Optional[Callable[[], None]] = None,
    ):
        self.fleet = fleet
        self.clock = clock
        self.drift_monitor = drift_monitor
        self._reoptimize = reoptimize
        self._queue: List[Request] = []
        self._inflight: Optional[_InflightWave] = None
        self._done: Dict[int, Request] = {}
        self._next_rid = 0
        self._wave = 0
        self._t0 = clock()
        self._served = 0
        self._metrics = obs.Registry()  # private, always on (summary reads it)

    # -- submission ---------------------------------------------------------

    def submit_predict(self, problem: int, x_test, *, uncertainty: bool = False) -> int:
        """Queue a prediction request; returns its request id."""
        return self._push(PREDICT, problem, np.asarray(x_test), None, uncertainty)

    def submit_observe(self, problem: int, x_new, y_new) -> int:
        """Queue new observations for one problem; returns its request id."""
        x_new = np.asarray(x_new)
        y_new = np.asarray(y_new).reshape(-1)
        return self._push(OBSERVE, problem, x_new, y_new, False)

    def _push(self, kind, problem, x, y, uncertainty) -> int:
        if not 0 <= problem < self.fleet.batch_size:
            raise ValueError(
                f"problem must be in [0, {self.fleet.batch_size}); got {problem}"
            )
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            Request(rid, kind, problem, x, y, self.clock(), uncertainty)
        )
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- the wave loop ------------------------------------------------------

    def step(self) -> WaveStats:
        """Run one wave: materialize the PREVIOUS wave's dispatched
        predictions, absorb every queued observation, and dispatch every
        queued prediction (fetched one wave late — see the module
        docstring), re-forming buckets in between."""
        t0 = self.clock()
        self._metrics.histogram("serve.queue_depth", obs.COUNT_EDGES).observe(
            len(self._queue)
        )
        self._metrics.histogram("serve.inflight_depth", obs.COUNT_EDGES).observe(
            0 if self._inflight is None else 1
        )
        self.flush()  # previous wave's device work is done (or nearly) by now
        wave, self._queue = self._queue, []
        observes = [r for r in wave if r.kind == OBSERVE]
        predicts = [r for r in wave if r.kind == PREDICT]
        before = self._capacity_map()

        absorbed = 0
        if observes:
            b = self.fleet.batch_size
            d = self.fleet._xs[0].shape[-1]
            xs: List[List[np.ndarray]] = [[] for _ in range(b)]
            ys: List[List[np.ndarray]] = [[] for _ in range(b)]
            for r in observes:
                xs[r.problem].append(r.x.reshape(-1, d))
                ys[r.problem].append(r.y)
                absorbed += r.y.shape[0]
            xcat = [
                np.concatenate(px) if px else np.zeros((0, d), np.float32)
                for px in xs
            ]
            ycat = [
                np.concatenate(py) if py else np.zeros((0,), np.float32)
                for py in ys
            ]
            self.fleet.update(xcat, ycat)

        if predicts:
            d = self.fleet._xs[0].shape[-1]
            per_problem: Dict[int, List[Request]] = {}
            for r in predicts:
                per_problem.setdefault(r.problem, []).append(r)
            tests = []
            want_unc = any(r.uncertainty for r in predicts)
            for i in range(self.fleet.batch_size):
                reqs = per_problem.get(i, ())
                tests.append(
                    np.concatenate([r.x.reshape(-1, d) for r in reqs])
                    if reqs else np.zeros((0, d), np.float32)
                )
            # async dispatch: predict_each returns device futures — do NOT
            # block here.  The launches run while the host assembles the
            # next wave; flush() (next step / result / run_until_idle tail)
            # materializes them.
            outs = self.fleet.predict_each(tests, full_cov=want_unc)
            self._inflight = _InflightWave(per_problem, outs, want_unc, d)

        t1 = self.clock()
        for r in observes:
            r.result = r.y.shape[0]
            self._finish(r, t1)
        after = self._capacity_map()
        migrations = sum(
            1 for i, c in after.items() if before.get(i) not in (None, c)
        )
        self._wave += 1

        # off-hot-path training: predictions are already in flight, so a
        # triggered re-optimize overlaps their device execution and only
        # delays the NEXT wave's (cold) dispatch
        reoptimized = False
        if self.drift_monitor is not None and observes:
            nlml_pp = float(np.sum(np.asarray(self.fleet.nlml()))) \
                / max(sum(self.fleet.sizes), 1)
            if self.drift_monitor.observe(nlml_pp):
                (self._reoptimize or self.fleet.optimize)()
                self.drift_monitor.reset()
                self._metrics.counter("serve.reoptimizations").inc()
                obs.health_event("serve_reoptimize", wave=self._wave - 1,
                                 nlml_per_point=nlml_pp)
                reoptimized = True

        m = self.fleet.tile_size
        sizes = self.fleet.sizes
        caps = self._capacity_map()
        cap_n = {i: caps[i] * m for i in caps}
        occupancy = sum(sizes) / max(sum(cap_n.values()), 1)
        # quadratic measure: fraction of the warm tail's cross-covariance
        # FLOPs spent on padding rows (each problem's tail is O(cap_n^2))
        waste = 1.0 - sum(n * n for n in sizes) \
            / max(sum(c * c for c in cap_n.values()), 1)
        self._metrics.histogram("serve.wave_latency_ms").observe((t1 - t0) * 1e3)
        self._metrics.histogram(
            "serve.bucket_occupancy", obs.FRACTION_EDGES
        ).observe(occupancy)
        self._metrics.histogram(
            "serve.padded_flop_waste", obs.FRACTION_EDGES
        ).observe(waste)
        self._metrics.counter("serve.waves").inc()
        self._metrics.counter("serve.points_absorbed").inc(absorbed)
        self._metrics.counter("serve.migrations").inc(migrations)
        if obs.enabled():
            obs.event(
                "serve.wave",
                wave=self._wave - 1,
                n_predict=len(predicts),
                n_observe=len(observes),
                points_absorbed=absorbed,
                migrations=migrations,
                duration_ms=(t1 - t0) * 1e3,
                queue_depth=len(wave),
                bucket_occupancy=occupancy,
                padded_flop_waste=waste,
                buckets=sorted({c for c in after.values()}),
                reoptimized=reoptimized,
            )

        return WaveStats(
            wave=self._wave - 1,
            n_predict=len(predicts),
            n_observe=len(observes),
            points_absorbed=absorbed,
            buckets=tuple(sorted({c for c in after.values()})),
            migrations=migrations,
            duration_s=t1 - t0,
            reoptimized=reoptimized,
        )

    def flush(self) -> int:
        """Materialize the in-flight prediction wave, finishing its
        requests; returns how many were finished (0 when none in flight).
        Idempotent — safe to call at any point between waves."""
        fl, self._inflight = self._inflight, None
        if fl is None:
            return 0
        jax.block_until_ready(fl.outs)
        t_done = self.clock()
        finished = 0
        for i, reqs in fl.per_problem.items():
            if fl.want_unc:
                mean = np.asarray(fl.outs[i][0])
                var = np.diagonal(np.asarray(fl.outs[i][1]))
            else:
                mean = np.asarray(fl.outs[i])
                var = None
            off = 0
            for r in reqs:
                k = r.x.reshape(-1, fl.d).shape[0]
                sl = slice(off, off + k)
                r.result = (
                    (mean[sl], var[sl]) if r.uncertainty else mean[sl]
                )
                off += k
                self._finish(r, t_done)
                finished += 1
        return finished

    def run_until_idle(self, max_waves: int = 1000) -> List[WaveStats]:
        """Step until the queue drains (new work may be enqueued by callers
        between waves; this only loops over what is already queued).  The
        final wave's dispatched predictions are flushed before returning,
        so every request queued on entry is finished on exit."""
        stats = []
        while self._queue and len(stats) < max_waves:
            stats.append(self.step())
        self.flush()
        return stats

    # -- results / accounting -----------------------------------------------

    def result(self, rid: int):
        """Pop a finished request's result; raises KeyError if unknown or
        still queued.  A request whose wave is dispatched but not yet
        fetched is flushed transparently first."""
        if rid not in self._done and self._inflight is not None:
            self.flush()
        return self._done.pop(rid).result

    def summary(self) -> Dict[str, float]:
        """Throughput / latency digest over every finished request.

        Percentiles come from the private registry's request-latency
        histogram — exact-[min, max]-clamped bucket interpolation, so tiny
        sample sets behave: zero requests yields 0.0 (not NaN, not a
        percentile of garbage), one request yields that request's latency
        for every percentile, and p99 >= p50 always.
        """
        h = self._metrics.histogram("serve.request_latency_ms")
        empty = h.count == 0
        elapsed = max(self.clock() - self._t0, 1e-9)
        return {
            "requests": float(self._served),
            "waves": float(self._wave),
            "req_per_s": self._served / elapsed,
            "p50_ms": 0.0 if empty else h.percentile(50),
            "p99_ms": 0.0 if empty else h.percentile(99),
            "max_ms": 0.0 if empty else h.max,
            "reoptimizations": self._metrics.counter(
                "serve.reoptimizations"
            ).value,
        }

    def metrics_snapshot(self) -> dict:
        """The private wave-accounting registry's full snapshot."""
        return self._metrics.snapshot()

    def _finish(self, r: Request, t: float) -> None:
        r.t_done = t
        self._metrics.histogram("serve.request_latency_ms").observe(
            (t - r.t_submit) * 1e3
        )
        self._metrics.counter("serve.requests").inc()
        self._done[r.rid] = r
        self._served += 1

    def _capacity_map(self) -> Dict[int, int]:
        return {
            i: cap
            for cap, idx in self.fleet.bucket_assignment().items()
            for i in idx
        }
