"""GP serve/train step factories: fleet-aware, mesh-aware.

Mirrors the transformer factories in :mod:`repro.train.serve_step` /
:mod:`repro.train.train_step` for the Gaussian-process front-ends: a factory
takes a GP object plus an optional device mesh and returns ``(step_fn,
shardings)``.  Unlike the transformer path, GP steps close over a *stateful*
front-end (the posterior cache lives on the object), so the factory's job is
to (a) install the mesh on the front-end — fleets shard their problem axis B
over the mesh's DP axes (DESIGN.md §12) — and (b) normalize the three GP
front-ends behind one callable signature:

* :class:`repro.core.gp.GaussianProcess` — single problem; a mesh has no
  problem axis to shard, so it is ignored (documented, not an error — the
  same launch script can drive one GP or a fleet).
* :class:`repro.core.gp.GPBatch` — stacked (B, n, D) fleet; training inputs
  are committed to the fleet sharding up front so every downstream launch
  (including the jitted Adam scan) inherits the layout via GSPMD
  propagation.
* :class:`repro.core.gp.GPFleet` — ragged bucketed fleet; each bucket's
  stacked problem axis is sharded when it divides the mesh, replicated
  otherwise (never an error).
"""

from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

from repro.core.gp import GaussianProcess, GPBatch, GPFleet
from repro.dist import sharding as shard_rules


def attach_mesh(gp, mesh: Optional[Mesh]):
    """Install ``mesh`` on a GP front-end; returns the front-end.

    For :class:`GPBatch` the stacked training arrays are also committed to
    the fleet sharding (``device_put_fleet``) so eager warm-tail launches and
    jitted programs alike see sharded operands.  The mesh participates in
    the posterior cache key, so switching meshes soundly invalidates any
    cached factors.  A plain :class:`GaussianProcess` has no problem axis:
    the mesh is ignored.
    """
    if mesh is None or isinstance(gp, GaussianProcess):
        return gp
    if isinstance(gp, GPBatch):
        gp.x_train = shard_rules.device_put_fleet(gp.x_train, mesh)
        gp.y_train = shard_rules.device_put_fleet(gp.y_train, mesh)
        gp.mesh = mesh
    elif isinstance(gp, GPFleet):
        gp.mesh = mesh  # buckets stack + shard lazily per geometry
    else:
        raise TypeError(
            f"attach_mesh expects GaussianProcess/GPBatch/GPFleet; got "
            f"{type(gp).__name__}"
        )
    return gp


def _gp_shardings(gp, mesh: Optional[Mesh]):
    if mesh is None or isinstance(gp, GaussianProcess):
        return None
    if isinstance(gp, GPBatch):
        b = gp.batch_size
        return {
            "x_test": shard_rules.fleet_sharding(mesh, b, 3),
            "batch_axes": shard_rules.fleet_axes(mesh, b),
        }
    # GPFleet: widths vary per bucket; the effective spec is per-geometry
    return {"mesh": mesh}


def make_gp_serve_step(gp, mesh: Optional[Mesh] = None, *,
                       uncertainty: bool = False):
    """Build ``serve(x_test)`` for any GP front-end.

    ``x_test`` follows the front-end's own convention: an (n̂, D) block for
    :class:`GaussianProcess`, shared-or-stacked for :class:`GPBatch`, and —
    for :class:`GPFleet` — either one shared (n̂, D) block or a length-B
    list of per-problem test sets (routed to ``predict_each``).  With
    ``uncertainty`` the step returns ``(mean, variance_diagonal)`` per the
    front-end's ``predict_with_uncertainty``.

    Returns ``(serve_fn, shardings)`` like the transformer factories; the
    shardings entry describes how stacked test blocks land on the mesh
    (``None`` without a mesh).
    """
    attach_mesh(gp, mesh)

    def serve(x_test):
        if isinstance(gp, GPFleet) and isinstance(x_test, (list, tuple)):
            return gp.predict_each(x_test, full_cov=uncertainty)
        if uncertainty:
            return gp.predict_with_uncertainty(x_test)
        return gp.predict(x_test)

    return serve, _gp_shardings(gp, mesh)


def make_gp_train_step(gp, mesh: Optional[Mesh] = None, *, lr: float = 0.05):
    """Build ``train(steps=1) -> nlml`` for any GP front-end.

    One call runs ``steps`` Adam iterations on the negative log marginal
    likelihood via the front-end's ``optimize`` (one jitted ``lax.scan``)
    and returns the post-step NLML — scalar for a single GP, per-problem
    (B,) vector for fleets.  The posterior cache is invalidated by
    ``optimize`` itself, so a following serve step re-factorizes under the
    new hyperparameters (sharded, when a mesh is installed).

    :class:`GPFleet` has no batched optimizer (buckets have heterogeneous
    geometries); its train step raises ``NotImplementedError`` with the
    supported alternative spelled out.
    """
    attach_mesh(gp, mesh)
    if isinstance(gp, GPFleet):
        def train(steps: int = 1):
            raise NotImplementedError(
                "GPFleet has no batched hyperparameter optimizer; train each "
                "bucket as a GPBatch (shared geometry) or per-problem "
                "GaussianProcess.optimize instead"
            )
        return train, _gp_shardings(gp, mesh)

    def train(steps: int = 1):
        gp.optimize(steps=steps, lr=lr)
        return gp.nlml()

    return train, _gp_shardings(gp, mesh)
