"""Train-step factories: GSPMD (pjit) primary path + manual-DP variant with
gradient compression on the cross-pod hop.

The pjit path is the production path: parameters carry FSDP/TP/EP shardings
(repro.dist.sharding), the batch is DP-sharded, and GSPMD inserts/overlaps
the collectives (XLA latency-hiding scheduler flags in launch/mesh.py).

The shard_map variant demonstrates the distributed-optimization trick the
pjit path can't express: int8-compressed gradient averaging with error
feedback on the slowest axis (cross-pod DCI).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax

from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding as shard_rules
from repro.models import transformer as tf
from repro.optim.compression import compressed_psum


def make_train_step(
    cfg: ModelConfig,
    optimizer,
    mesh: Optional[Mesh] = None,
    shape: Optional[ShapeConfig] = None,
    donate: bool = True,
):
    """Returns (step_fn, shardings) — step(params, opt, inputs, labels)."""

    def step(params, opt_state, inputs, labels):
        loss, grads = jax.value_and_grad(tf.loss_fn)(params, cfg, inputs, labels)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ()), None

    compat.set_mesh(mesh)  # mesh context for activation sharding constraints
    params_shape = jax.eval_shape(lambda k: tf.init_model(k, cfg), jax.random.PRNGKey(0))
    p_sh = shard_rules.param_shardings(params_shape, mesh)
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    o_sh = shard_rules.opt_state_shardings(opt_shape, params_shape, mesh)
    assert shape is not None
    in_sh, lab_sh = shard_rules.input_shardings(cfg, shape, mesh)
    out_sh = (p_sh, o_sh, NamedSharding(mesh, P()))
    fn = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, in_sh, lab_sh),
        out_shardings=out_sh,
        donate_argnums=(0, 1) if donate else (),
    )
    return fn, {"params": p_sh, "opt": o_sh, "inputs": in_sh, "labels": lab_sh}


def make_compressed_dp_step(
    cfg: ModelConfig,
    optimizer,
    mesh: Mesh,
    *,
    compress_axis: str = "pod",
    chunk: int = 4096,
):
    """Manual-DP train step: per-shard grads, int8+error-feedback mean over
    ``compress_axis``, plain psum over remaining DP axes, then optimizer.

    Parameters are replicated across DP axes in this variant (classic data
    parallelism); intended for the cross-pod axis where wire bytes dominate.
    Returns (step_fn, init_err_fn).
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    other_axes = tuple(a for a in dp_axes if a != compress_axis)

    def spmd_step(params, opt_state, err, inputs, labels):
        loss, grads = jax.value_and_grad(tf.loss_fn)(params, cfg, inputs, labels)
        if other_axes:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, other_axes), grads)
            loss = jax.lax.pmean(loss, other_axes)
        if compress_axis in mesh.shape:
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = tdef.flatten_up_to(err)
            outs = [
                compressed_psum(g, e, compress_axis, chunk)
                for g, e in zip(flat_g, flat_e)
            ]
            grads = tdef.unflatten([o[0] for o in outs])
            err = tdef.unflatten([o[1] for o in outs])
            loss = jax.lax.pmean(loss, compress_axis)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, err, loss

    batch_spec = P(dp_axes)
    rep = P()
    fn = jax.jit(
        compat.shard_map(
            spmd_step,
            mesh=mesh,
            in_specs=(rep, rep, rep, batch_spec, batch_spec),
            out_specs=(rep, rep, rep, rep),
            check_vma=False,
        )
    )

    def init_err(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    return fn, init_err
