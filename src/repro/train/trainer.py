"""Fault-tolerant training loop.

Production posture implemented single-host (mechanisms, not mocks):

* **checkpoint/restart** — atomic CheckpointManager saves every
  ``ckpt_every`` steps (async by default); on construction the trainer
  auto-resumes from the newest valid checkpoint, so a killed process
  relaunched with the same command continues exactly where it stopped
  (validated by tests/test_trainer_fault.py which SIGKILLs mid-run).
* **elastic restart** — checkpoints are host-complete, so a restart may use
  a different mesh/device count; shardings are re-derived from the new mesh.
* **straggler mitigation** — per-step wall times are tracked; steps slower
  than ``straggler_factor ×`` the running median are counted and logged.  At
  multi-pod scale this signal drives the re-shard/evict decision; here it
  feeds the step-time report (and is unit-tested via an injected delay).
* **data determinism across restarts** — the synthetic pipeline is seeded by
  step index, so a resumed run sees the identical batch stream.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

import jax
import numpy as np

from repro.ckpt import CheckpointManager


@dataclasses.dataclass
class TrainerReport:
    steps: int = 0
    last_loss: float = float("nan")
    losses: list = dataclasses.field(default_factory=list)
    step_times: list = dataclasses.field(default_factory=list)
    stragglers: int = 0
    resumed_from: Optional[int] = None

    def median_step_time(self) -> float:
        return float(np.median(self.step_times)) if self.step_times else float("nan")


class Trainer:
    def __init__(
        self,
        step_fn: Callable,                 # (params, opt, inputs, labels) -> (params, opt, loss)
        params,
        opt_state,
        data_fn: Callable[[int], tuple],   # step index -> (inputs, labels)
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 50,
        ckpt_async: bool = True,
        keep: int = 3,
        straggler_factor: float = 3.0,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
    ):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data_fn = data_fn
        self.ckpt_every = ckpt_every
        self.ckpt_async = ckpt_async
        self.straggler_factor = straggler_factor
        self.log_every = log_every
        self.log = log_fn
        self.report = TrainerReport()
        self.start_step = 0
        self.mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
        if self.mgr is not None and self.mgr.latest_step() is not None:
            state_tmpl = {"params": self.params, "opt": self.opt_state}
            step, state = self.mgr.restore(state_tmpl)
            self.params, self.opt_state = state["params"], state["opt"]
            self.start_step = step
            self.report.resumed_from = step
            self.log(f"[trainer] resumed from checkpoint step {step}")

    def run(self, num_steps: int) -> TrainerReport:
        end = self.start_step + num_steps
        for step in range(self.start_step, end):
            inputs, labels = self.data_fn(step)
            t0 = time.perf_counter()
            self.params, self.opt_state, loss = self.step_fn(
                self.params, self.opt_state, inputs, labels
            )
            loss = jax.block_until_ready(loss)
            dt = time.perf_counter() - t0
            self.report.step_times.append(dt)
            self.report.steps = step + 1
            self.report.last_loss = float(loss)
            self.report.losses.append(float(loss))
            med = self.report.median_step_time()
            if len(self.report.step_times) > 5 and dt > self.straggler_factor * med:
                self.report.stragglers += 1
                self.log(
                    f"[trainer] straggler at step {step}: {dt*1e3:.1f} ms vs "
                    f"median {med*1e3:.1f} ms"
                )
            if self.log_every and (step + 1) % self.log_every == 0:
                self.log(
                    f"[trainer] step {step+1}/{end} loss={float(loss):.4f} "
                    f"({dt*1e3:.1f} ms/step)"
                )
            if self.mgr is not None and (step + 1) % self.ckpt_every == 0:
                self.mgr.save(
                    step + 1,
                    {"params": self.params, "opt": self.opt_state},
                    blocking=not self.ckpt_async,
                )
        if self.mgr is not None:
            self.mgr.save(end, {"params": self.params, "opt": self.opt_state})
            self.mgr.wait()
        return self.report
