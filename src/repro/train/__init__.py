"""Training / serving step factories and the fault-tolerant trainer loop."""
