"""Training / serving step factories and the fault-tolerant trainer loop.

Transformer steps live in :mod:`repro.train.train_step` /
:mod:`repro.train.serve_step`; the GP front-ends (single GP, stacked
:class:`~repro.core.gp.GPBatch`, ragged :class:`~repro.core.gp.GPFleet`)
get the same factory treatment in :mod:`repro.train.gp_step`.
"""

from repro.train.gp_step import (  # noqa: F401
    attach_mesh,
    make_gp_serve_step,
    make_gp_train_step,
)
