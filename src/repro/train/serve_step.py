"""Serving step factories: prefill and decode, mesh-aware.

``make_prefill_step``: full-context forward producing last-token logits and
the decode caches.  ``make_decode_step``: one token for every sequence in
the batch against the caches (KV ring buffers / recurrent states).  These are
the programs the decode_32k / long_500k dry-run cells lower.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding as shard_rules
from repro.models import transformer as tf


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                      shape: Optional[ShapeConfig] = None):
    def prefill(params, inputs):
        return tf.prefill_fn(params, cfg, inputs)

    if mesh is None:
        return jax.jit(prefill), None
    compat.set_mesh(mesh)  # mesh context for activation sharding constraints
    params_shape = jax.eval_shape(lambda k: tf.init_model(k, cfg), jax.random.PRNGKey(0))
    p_sh = shard_rules.param_shardings(params_shape, mesh)
    in_sh, _ = shard_rules.input_shardings(cfg, shape, mesh)
    fn = jax.jit(prefill, in_shardings=(p_sh, in_sh))
    return fn, {"params": p_sh, "inputs": in_sh}


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                     shape: Optional[ShapeConfig] = None, donate_cache: bool = True):
    def decode(params, token, pos, caches):
        return tf.decode_fn(params, cfg, token, pos, caches)

    if mesh is None:
        return jax.jit(decode, donate_argnums=(3,) if donate_cache else ()), None
    assert shape is not None
    compat.set_mesh(mesh)  # mesh context for activation sharding constraints
    b = shape.global_batch
    params_shape = jax.eval_shape(lambda k: tf.init_model(k, cfg), jax.random.PRNGKey(0))
    p_sh = shard_rules.param_shardings(params_shape, mesh)
    caches_shape = jax.eval_shape(lambda: tf.init_caches(cfg, b, shape.seq_len))
    c_sh = shard_rules.cache_shardings(cfg, b, mesh, caches_shape)
    tok_sh = NamedSharding(mesh, shard_rules.batch_spec(mesh, b, None))
    pos_sh = NamedSharding(mesh, P())
    fn = jax.jit(
        decode,
        in_shardings=(p_sh, tok_sh, pos_sh, c_sh),
        donate_argnums=(3,) if donate_cache else (),
    )
    return fn, {"params": p_sh, "token": tok_sh, "caches": c_sh}
