"""Launch layer: production meshes, dry-run, roofline, drivers."""
