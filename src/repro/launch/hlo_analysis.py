"""Compiled-artifact analysis: collective parsing + cost accounting.

``cost_analysis()`` on this backend reports *per-device* FLOPs/bytes, and —
crucially — counts each ``while`` body (lax.scan / fori_loop) exactly ONCE
(verified empirically; see EXPERIMENTS.md §Methodology).  The same holds for
collectives found by text-parsing the partitioned HLO.  The dry-run therefore
uses structured accounting: the full program provides memory analysis and the
"outside-loop" costs, and separate *probe* lowerings of the loop bodies
(one layer cycle, the loss head) are scaled by their known trip counts.

Collective wire model (per device, group size g):
  all-gather       result_bytes · (g−1)/g          (received payload)
  reduce-scatter   result_bytes · (g−1)            (operand = result·g)
  all-reduce       2 · result_bytes · (g−1)/g      (ring: reduce-scatter+AG)
  all-to-all       result_bytes · (g−1)/g
  collective-permute  result_bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(\(?[\w\[\],{}\s]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([\d,]+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(token: str) -> int:
    """Bytes of a shape token like ``f32[16,256]{1,0}`` or a tuple of them."""
    total = 0
    for m in _SHAPE_RE.finditer(token):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        # [n_groups, size...] reshape: group size = product of trailing dims
        if len(dims) == 1:
            return dims[0]
        size = 1
        for d in dims[1:]:
            size *= d
        return size
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    ops: Dict[str, int]
    operand_bytes: Dict[str, float]       # per-device operand-volume view
    wire_bytes: Dict[str, float]          # per-device wire-traffic view

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())

    def merged(self, other: "CollectiveStats", scale: float = 1.0) -> "CollectiveStats":
        out = CollectiveStats(dict(self.ops), dict(self.operand_bytes), dict(self.wire_bytes))
        for k in other.ops:
            out.ops[k] = out.ops.get(k, 0) + int(other.ops[k] * scale)
            out.operand_bytes[k] = out.operand_bytes.get(k, 0.0) + other.operand_bytes[k] * scale
            out.wire_bytes[k] = out.wire_bytes.get(k, 0.0) + other.wire_bytes[k] * scale
        return out


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    ops: Dict[str, int] = {}
    operand: Dict[str, float] = {}
    wire: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_tok, kind = m.group(1), m.group(2)
        b = float(_shape_bytes(shape_tok))
        g = _group_size(line, total_devices)
        if g <= 1:
            continue
        if kind == "all-gather":
            op_b, wire_b = b / g, b * (g - 1) / g
        elif kind == "reduce-scatter":
            op_b, wire_b = b * g, b * (g - 1)
        elif kind == "all-reduce":
            op_b, wire_b = b, 2 * b * (g - 1) / g
        elif kind == "all-to-all":
            op_b, wire_b = b, b * (g - 1) / g
        else:  # collective-permute
            op_b, wire_b = b, b
        ops[kind] = ops.get(kind, 0) + 1
        operand[kind] = operand.get(kind, 0.0) + op_b
        wire[kind] = wire.get(kind, 0.0) + wire_b
    return CollectiveStats(ops, operand, wire)


def cost_summary(compiled) -> Dict[str, float]:
    from repro import compat

    ca = compat.cost_analysis(compiled)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    ms = compiled.memory_analysis()
    return {
        "argument_bytes": float(ms.argument_size_in_bytes),
        "output_bytes": float(ms.output_size_in_bytes),
        "temp_bytes": float(ms.temp_size_in_bytes),
        "alias_bytes": float(ms.alias_size_in_bytes),
        "peak_bytes": float(
            ms.argument_size_in_bytes
            + ms.output_size_in_bytes
            + ms.temp_size_in_bytes
            - ms.alias_size_in_bytes
        ),
    }
