"""Production mesh construction + TPU v5e hardware model.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before first jax init, and smoke
tests / benches must keep seeing the single real device.

Recommended real-TPU flags (documented here; harmless on CPU):
  --xla_tpu_enable_latency_hiding_scheduler=true   overlap collectives/compute
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_enable_async_all_gather=true
These are what "overlap compute/comm" resolves to on the XLA/TPU stack: the
scheduler hoists collective-starts above independent compute and sinks the
dones below it — the pjit programs in this repo are written so the relevant
collectives are hoistable (no false dependencies through donated buffers).
"""

from __future__ import annotations

import dataclasses

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for the 8-device subprocess tests."""
    return compat.make_mesh(shape, axes)


def make_fleet_mesh(n_devices=None):
    """1-D data-parallel mesh for sharding a fleet's problem-batch axis.

    Fleet problems are independent, so the only useful axis is ``data``
    (``repro.dist.sharding.fleet_spec`` shards B over it; everything else is
    replicated).  ``n_devices=None`` takes every visible device; a smaller
    count takes a prefix — handy for crossover sweeps on a forced host mesh.
    """
    avail = len(jax.devices())
    if n_devices is None:
        n_devices = avail
    if not 1 <= n_devices <= avail:
        raise ValueError(
            f"n_devices must be in [1, {avail}] (visible devices); got {n_devices}"
        )
    return compat.make_mesh((n_devices,), ("data",))


@dataclasses.dataclass(frozen=True)
class Hardware:
    """TPU v5e chip model used for the roofline terms."""

    peak_flops_bf16: float = 197e12       # FLOP/s per chip
    hbm_bandwidth: float = 819e9          # B/s per chip
    ici_link_bandwidth: float = 50e9      # B/s per link (per-chip wire rate)
    hbm_bytes: float = 16e9               # capacity per chip

    def compute_seconds(self, flops_per_device: float) -> float:
        return flops_per_device / self.peak_flops_bf16

    def memory_seconds(self, bytes_per_device: float) -> float:
        return bytes_per_device / self.hbm_bandwidth

    def collective_seconds(self, wire_bytes_per_device: float) -> float:
        return wire_bytes_per_device / self.ici_link_bandwidth


V5E = Hardware()
