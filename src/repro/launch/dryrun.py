import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell.

The FIRST TWO LINES above must stay first: jax locks the device count on
first init, and the dry-run needs 512 placeholder CPU devices to build the
production meshes.  (Never set that flag globally — smoke tests and benches
must see the single real device.)

Per cell this driver:
  1. builds the production mesh ((16,16) or (2,16,16) with a ``pod`` axis),
  2. builds the jitted step with the full sharding rules,
  3. ``lower(**input_specs()).compile()`` — ShapeDtypeStructs only, nothing
     is allocated,
  4. prints ``compiled.memory_analysis()`` (proves the cell fits) and
     ``compiled.cost_analysis()``,
  5. parses the collective schedule from the compiled HLO,
  6. (single-pod) lowers the loop-body probes and emits trip-count-corrected
     FLOP/byte/collective totals (see launch/hlo_analysis.py for why),
  7. appends a JSON record under --out for the roofline stage.

Usage:
  python -m repro.launch.dryrun                      # all LM cells, both meshes
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --gp                 # the paper's GP cells
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import compat
import jax.numpy as jnp


def _record_path(out_dir, name):
    return os.path.join(out_dir, f"{name}.json")


def _analyze(compiled, devices):
    from repro.launch import hlo_analysis as ha

    coll = ha.parse_collectives(compiled.as_text(), devices)
    return {
        "memory": ha.memory_summary(compiled),
        "cost": ha.cost_summary(compiled),
        "collectives": {
            "ops": coll.ops,
            "operand_bytes": coll.operand_bytes,
            "wire_bytes": coll.wire_bytes,
            "total_wire_bytes": coll.total_wire_bytes,
        },
    }


def _lower_compile(fn, *args, label=""):
    t0 = time.time()
    lowered = fn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    print(f"    [{label}] lower {t1-t0:.1f}s compile {t2-t1:.1f}s")
    return compiled, {"lower_s": t1 - t0, "compile_s": t2 - t1}


def pick_optimizer(cfg):
    from repro.optim import Adafactor, Adam

    if cfg.param_count() > 2e10:
        return Adafactor(learning_rate=1e-3), "adafactor"
    return Adam(learning_rate=1e-4), "adam"


def run_lm_cell(arch, shape, multi_pod, out_dir, probes=True, force=False):
    from repro import configs
    from repro.launch import specs as sp
    from repro.launch.mesh import make_production_mesh
    from repro.train.serve_step import make_decode_step, make_prefill_step
    from repro.train.train_step import make_train_step

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}__{shape.name}__{mesh_name}"
    path = _record_path(out_dir, name)
    if os.path.exists(path) and not force:
        print(f"  [skip] {name} (cached)")
        return json.load(open(path))
    print(f"  [cell] {name}")
    cfg = configs.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    compat.set_mesh(mesh)  # mesh context for activation sharding constraints
    devices = int(len(mesh.devices.reshape(-1)))
    rec = {
        "kind": "lm",
        "arch": arch,
        "shape": dataclasses.asdict(shape),
        "mesh": mesh_name,
        "devices": devices,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "ok": False,
    }
    try:
        ins = sp.input_specs(cfg, shape)
        if shape.kind == "train":
            opt, opt_name = pick_optimizer(cfg)
            rec["optimizer"] = opt_name
            fn, _ = make_train_step(cfg, opt, mesh, shape, donate=False)
            ps = sp.params_shape(cfg)
            os_shape = jax.eval_shape(opt.init, ps)
            compiled, times = _lower_compile(
                fn, ps, os_shape, ins["inputs"], ins["labels"], label="full"
            )
            rec["model_flops"] = 6.0 * cfg.active_param_count() * shape.tokens
        elif shape.kind == "prefill":
            fn, _ = make_prefill_step(cfg, mesh, shape)
            compiled, times = _lower_compile(fn, sp.params_shape(cfg), ins["inputs"], label="full")
            rec["model_flops"] = 2.0 * cfg.active_param_count() * shape.tokens
        else:  # decode
            fn, _ = make_decode_step(cfg, mesh, shape)
            compiled, times = _lower_compile(
                fn, sp.params_shape(cfg), ins["token"], ins["pos"], ins["caches"],
                label="full",
            )
            rec["model_flops"] = 2.0 * cfg.active_param_count() * shape.global_batch
        rec["times"] = times
        rec["full"] = _analyze(compiled, devices)
        ms = compiled.memory_analysis()
        print(f"    memory_analysis: {ms}")
        ca = compat.cost_analysis(compiled)
        print(
            "    cost_analysis: flops/device=%.3e bytes/device=%.3e"
            % (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0))
        )
        rec["fits_16gb"] = rec["full"]["memory"]["peak_bytes"] < 16e9

        if probes and not multi_pod:
            rec["probes"] = _run_probes(cfg, shape, mesh, devices)
            rec["corrected"] = _corrected_costs(rec)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record per-cell failures, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"    [FAIL] {rec['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _run_probes(cfg, shape, mesh, devices):
    from repro.launch import specs as sp

    out = {}
    # layer-cycle probe
    fn, args, shardings, trips = sp.cycle_probe(cfg, shape, mesh)
    jfn = jax.jit(fn, in_shardings=shardings)
    compiled, times = _lower_compile(jfn, *args, label="cycle")
    out["cycle"] = {**_analyze(compiled, devices), "trips": trips, "times": times}
    # head probe
    fn, args, shardings, trips = sp.head_probe(cfg, shape, mesh)
    jfn = jax.jit(fn, in_shardings=shardings)
    compiled, times = _lower_compile(jfn, *args, label="head")
    out["head"] = {**_analyze(compiled, devices), "trips": trips, "times": times}
    # optimizer probe (train only)
    if shape.kind == "train":
        opt, _ = pick_optimizer(cfg)
        fn, args, shardings, trips = sp.optimizer_probe(cfg, opt, mesh)
        jfn = jax.jit(fn, in_shardings=shardings)
        compiled, times = _lower_compile(jfn, *args, label="opt")
        out["optimizer"] = {**_analyze(compiled, devices), "trips": trips, "times": times}
    return out


def _corrected_costs(rec):
    """Trip-count-corrected per-device totals from the probes."""
    probes = rec["probes"]
    tot = {"flops": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
    for p in probes.values():
        t = p["trips"]
        tot["flops"] += p["cost"]["flops"] * t
        tot["bytes"] += p["cost"]["bytes"] * t
        tot["wire_bytes"] += p["collectives"]["total_wire_bytes"] * t
    return tot


def run_gp_cell(gp_shape, multi_pod, out_dir, probes=True, force=False):
    from repro.configs.base import GPShapeConfig
    from repro.core import distributed as dist
    from repro.core.kernels_math import SEKernelParams
    from repro.launch.mesh import make_production_mesh

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"gp__{gp_shape.name}__{mesh_name}"
    path = _record_path(out_dir, name)
    if os.path.exists(path) and not force:
        print(f"  [skip] {name} (cached)")
        return json.load(open(path))
    print(f"  [cell] {name}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    devices = int(len(mesh.devices.reshape(-1)))
    row_axes = ("pod", "data") if multi_pod else ("data",)
    col_axes = ("model",)
    n, m = gp_shape.n_train, gp_shape.tile_size
    m_tiles = n // m
    nt = gp_shape.n_test
    d_feat = 16  # msd NFIR regressors
    rec = {
        "kind": "gp",
        "arch": "gp-tiled-cholesky",
        "shape": dataclasses.asdict(gp_shape),
        "mesh": mesh_name,
        "devices": devices,
        "m_tiles": m_tiles,
        "ok": False,
        # cholesky n^3/3 + solves 2n^2 + V-solve n^2*nt + gram nt*... mean 2*n*nt
        "model_flops": n**3 / 3.0 + 2.0 * n * n + float(n) * n * nt + 2.0 * n * nt,
    }
    try:
        params = SEKernelParams.paper_defaults()
        fn = dist.distributed_gp_predict_fn(
            mesh,
            m_tiles=m_tiles,
            tile_size=m,
            n_valid=n,
            n_test_valid=nt,
            params=params,
            row_axes=row_axes,
            col_axes=col_axes,
        )
        xc = jax.ShapeDtypeStruct((m_tiles, m, d_feat), jnp.float32)
        yc = jax.ShapeDtypeStruct((m_tiles, m), jnp.float32)
        xtc = jax.ShapeDtypeStruct((nt // m, m, d_feat), jnp.float32)
        compiled, times = _lower_compile(jax.jit(fn), xc, yc, xtc, label="full")
        rec["times"] = times
        rec["full"] = _analyze(compiled, devices)
        print(f"    memory_analysis: {compiled.memory_analysis()}")
        ca = compat.cost_analysis(compiled)
        print(
            "    cost_analysis: flops/device=%.3e bytes/device=%.3e"
            % (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0))
        )
        rec["fits_16gb"] = rec["full"]["memory"]["peak_bytes"] < 16e9
        if probes:
            p, q = dist.grid_shape(mesh, row_axes, col_axes)
            local_sds = jax.ShapeDtypeStruct(
                (m_tiles, m_tiles, m, m), jnp.float32
            )
            j_sds = jax.ShapeDtypeStruct((), jnp.int32)
            cf = dist.cholesky_step_probe_fn(
                mesh, m_tiles=m_tiles, row_axes=row_axes, col_axes=col_axes
            )
            c_comp, _ = _lower_compile(jax.jit(cf), local_sds, j_sds, label="chol-step")
            vf = dist.variance_step_probe_fn(
                mesh, m_tiles=m_tiles, row_axes=row_axes, col_axes=col_axes
            )
            b_sds = jax.ShapeDtypeStruct((m_tiles, nt // m // q, m, m), jnp.float32)
            v_comp, _ = _lower_compile(jax.jit(vf), local_sds, b_sds, j_sds, label="var-step")
            rec["probes"] = {
                "chol_step": {**_analyze(c_comp, devices), "trips": m_tiles},
                "var_step": {**_analyze(v_comp, devices), "trips": m_tiles},
            }
            rec["corrected"] = _corrected_costs(rec)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"    [FAIL] {rec['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    from repro import configs
    from repro.configs import gp_msd

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None, choices=list(configs.ARCH_IDS))
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--gp", action="store_true", help="run the paper's GP cells")
    ap.add_argument("--gp-shape", action="append", default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    if args.gp:
        shapes = [
            s for s in gp_msd.ALL_GP_SHAPES
            if args.gp_shape is None or s.name in args.gp_shape
        ]
        for multi in meshes:
            for s in shapes:
                results.append(run_gp_cell(s, multi, args.out, not args.no_probes, args.force))
    else:
        archs = args.arch or list(configs.ARCH_IDS)
        for multi in meshes:
            for arch in archs:
                for shape in configs.shapes_for(arch):
                    if args.shape and shape.name not in args.shape:
                        continue
                    results.append(
                        run_lm_cell(arch, shape, multi, args.out, not args.no_probes, args.force)
                    )
    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n== dry-run: {ok}/{len(results)} cells OK ==")
    for r in results:
        if not r.get("ok"):
            print(f"  FAILED: {r.get('arch')}/{r['shape'].get('name')}/{r['mesh']}: {r.get('error')}")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
