"""ShapeDtypeStruct input specs + probe programs for the dry-run.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable stand-ins
for every input of the lowered step — nothing is ever allocated.

Probe builders return (jitted_fn, arg_specs, trip_count_weight) for the
structured cost accounting described in launch/hlo_analysis.py: the loop
bodies (layer cycle, loss head, optimizer) are lowered standalone and their
costs scaled by known trip counts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding as shard_rules
from repro.models import transformer as tf

SDS = jax.ShapeDtypeStruct


def _act_dtype(cfg: ModelConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.activation_dtype]


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(lambda k: tf.init_model(k, cfg), jax.random.PRNGKey(0))


def caches_shape(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: tf.init_caches(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, object]:
    """Model-input stand-ins for one (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_mode == "embeddings":
            inputs = SDS((b, s, cfg.d_model), _act_dtype(cfg))
        else:
            inputs = SDS((b, s), jnp.int32)
        return {"inputs": inputs, "labels": SDS((b, s), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.input_mode == "embeddings":
            return {"inputs": SDS((b, s, cfg.d_model), _act_dtype(cfg))}
        return {"inputs": SDS((b, s), jnp.int32)}
    if shape.kind == "decode":
        return {
            "token": SDS((b, 1), jnp.int32),
            "pos": SDS((), jnp.int32),
            "caches": caches_shape(cfg, b, s),
        }
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Probe programs (loop bodies lowered standalone).
# ---------------------------------------------------------------------------


def _cycle_slice_shape(cfg: ModelConfig):
    ps = params_shape(cfg)
    return [jax.tree.map(lambda a: SDS(a.shape[1:], a.dtype), g) for g in ps["groups"]]


def _nofold(cfg: ModelConfig) -> ModelConfig:
    """Loop-free variant for probes: full attention, unchunked loss (same
    FLOPs/collectives as the chunked production program; memory is taken from
    the full compile, not from probes)."""
    return dataclasses.replace(cfg, attn_chunk=0, loss_chunk=0)


def cycle_probe(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """One pattern-cycle body (fwd for serve, fwd+bwd for train).

    Returns (fn, args_specs, in_shardings, trips).
    """
    pcfg = _nofold(cfg)
    b, s = shape.global_batch, shape.seq_len
    dt = _act_dtype(cfg)
    cyc = _cycle_slice_shape(cfg)
    trips = cfg.n_layers / len(cfg.pattern)

    if shape.kind in ("train", "prefill"):
        x_sds = SDS((b, s, cfg.d_model), dt)
        pos_sds = SDS((b, s), jnp.int32)

        def fwd(cycle_params, x, positions):
            for i, kind in enumerate(pcfg.pattern):
                x, _ = tf.apply_block(
                    cycle_params[i], kind, x, positions, pcfg, mode="train"
                )
            return x

        if shape.kind == "train":
            def fn(cycle_params, x, positions):
                out, grads = jax.value_and_grad(
                    lambda cp, xx: jnp.sum(fwd(cp, xx, positions).astype(jnp.float32) ** 2),
                    argnums=(0, 1),
                )(cycle_params, x)
                return grads
        else:
            fn = fwd
        args = (cyc, x_sds, pos_sds)
        shardings = (
            [shard_rules.param_shardings(c, mesh) for c in cyc],
            NamedSharding(mesh, shard_rules.batch_spec(mesh, b, None, None)),
            NamedSharding(mesh, shard_rules.batch_spec(mesh, b, None)),
        )
        # tuple-ify: param_shardings returns list matching cyc list
        return fn, args, shardings, trips

    # decode: one cycle step with a cache slice
    full_caches = caches_shape(cfg, b, s)
    cache_slice = [
        jax.tree.map(lambda a: SDS(a.shape[1:], a.dtype), g) for g in full_caches["groups"]
    ]
    x_sds = SDS((b, 1, cfg.d_model), dt)
    pos_sds = SDS((), jnp.int32)

    def fn(cycle_params, x, pos, cache):
        positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
        new_caches = []
        for i, kind in enumerate(pcfg.pattern):
            x, nc = tf.apply_block(
                cycle_params[i], kind, x, positions, pcfg,
                mode="step", cache=cache[i], pos=pos,
            )
            new_caches.append(nc)
        return x, new_caches

    args = (cyc, x_sds, pos_sds, cache_slice)
    shardings = (
        [shard_rules.param_shardings(c, mesh) for c in cyc],
        NamedSharding(mesh, shard_rules.batch_spec(mesh, b, None, None)),
        NamedSharding(mesh, P()),
        [shard_rules.cache_shardings(cfg, b, mesh, c) for c in cache_slice],
    )
    return fn, args, shardings, trips


def head_probe(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Embedding + final head (+ full-vocab CE loss and backward for train)."""
    pcfg = _nofold(cfg)
    b, s = shape.global_batch, shape.seq_len
    dt = _act_dtype(cfg)
    ps = params_shape(cfg)
    head_params = {"embed": ps["embed"]}
    if not cfg.tie_embeddings:
        head_params["lm_head"] = ps["lm_head"]
    hp_sh = shard_rules.param_shardings(head_params, mesh)

    if shape.kind == "train":
        x_sds = SDS((b, s, cfg.d_model), dt)
        lab_sds = SDS((b, s), jnp.int32)

        def loss_head(hp, x, labels):
            logits = tf._logits(hp, pcfg, x).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
            return jnp.mean(lse - gold)

        def fn(hp, x, labels):
            return jax.value_and_grad(loss_head, argnums=(0, 1))(hp, x, labels)

        args = (head_params, x_sds, lab_sds)
        shardings = (
            hp_sh,
            NamedSharding(mesh, shard_rules.batch_spec(mesh, b, None, None)),
            NamedSharding(mesh, shard_rules.batch_spec(mesh, b, None)),
        )
        return fn, args, shardings, 1.0

    # serving: last-position (prefill) or single-token (decode) logits
    def fn(hp, x):
        return tf._logits(hp, pcfg, x)

    x_sds = SDS((b, cfg.d_model), dt)
    args = (head_params, x_sds)
    shardings = (hp_sh, NamedSharding(mesh, shard_rules.batch_spec(mesh, b, None)))
    return fn, args, shardings, 1.0


def optimizer_probe(cfg: ModelConfig, optimizer, mesh: Mesh):
    """The optimizer update on full parameter shapes (no loops inside)."""
    ps = params_shape(cfg)
    p_sh = shard_rules.param_shardings(ps, mesh)
    opt_shape = jax.eval_shape(optimizer.init, ps)
    o_sh = shard_rules.opt_state_shardings(opt_shape, ps, mesh)

    def fn(grads, opt_state, params):
        return optimizer.update(grads, opt_state, params)

    args = (ps, opt_shape, ps)
    shardings = (p_sh, o_sh, p_sh)
    return fn, args, shardings, 1.0
