"""Roofline report: three terms per (arch × shape × mesh) from the dry-run.

Reads the JSON records written by launch/dryrun.py and emits the
EXPERIMENTS.md tables:

  compute_s    = corrected FLOPs/device   / 197 TFLOP/s
  memory_s     = corrected bytes/device   / 819 GB/s
  collective_s = corrected wire bytes/dev / 50 GB/s per link

``corrected`` = probe-cost × trip-count accounting (launch/hlo_analysis.py);
cells without probes (multi-pod) fall back to the raw once-counted numbers,
flagged in the table.  MODEL_FLOPS = 6·N(_active)·D for train, 2·N·D for
serving; the useful-compute ratio MODEL/HLO exposes remat & routing waste.

Usage:  python -m repro.launch.roofline [--dir experiments/dryrun] [--csv out.csv]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.launch.mesh import V5E


def load_records(d: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def derive(rec: Dict) -> Dict:
    dev = rec["devices"]
    if rec.get("corrected"):
        flops = rec["corrected"]["flops"]
        bytes_ = rec["corrected"]["bytes"]
        wire = rec["corrected"]["wire_bytes"]
        basis = "probes"
    else:
        flops = rec["full"]["cost"]["flops"]
        bytes_ = rec["full"]["cost"]["bytes"]
        wire = rec["full"]["collectives"]["total_wire_bytes"]
        basis = "raw(once)"
    compute_s = V5E.compute_seconds(flops)
    memory_s = V5E.memory_seconds(bytes_)
    coll_s = V5E.collective_seconds(wire)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model = rec.get("model_flops", 0.0) / dev
    useful = model / flops if flops else 0.0
    # roofline fraction: useful model-compute time over the binding term
    frac = (model / V5E.peak_flops_bf16) / bound if bound else 0.0
    return {
        "cell": f"{rec['arch']}×{rec['shape']['name']}",
        "mesh": rec["mesh"],
        "ok": rec.get("ok", False),
        "basis": basis,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_dev": model,
        "useful_ratio": useful,
        "roofline_frac": frac,
        "peak_gb": rec["full"]["memory"]["peak_bytes"] / 1e9 if rec.get("full") else None,
        "fits_16gb": rec.get("fits_16gb"),
        "error": rec.get("error"),
    }


def markdown_table(rows: List[Dict]) -> str:
    hdr = (
        "| cell | mesh | compute_s | memory_s | collective_s | dominant | "
        "useful MODEL/HLO | roofline frac | peak GB/dev | fits | basis |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if not r["ok"]:
            lines.append(f"| {r['cell']} | {r['mesh']} | — | — | — | FAILED: {r['error']} | | | | | |")
            continue
        lines.append(
            f"| {r['cell']} | {r['mesh']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** | {r['useful_ratio']:.3f} "
            f"| {r['roofline_frac']:.3f} | {r['peak_gb']:.2f} | "
            f"{'✓' if r['fits_16gb'] else '✗'} | {r['basis']} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--mesh", default=None, help="filter: pod16x16 | pod2x16x16")
    args = ap.parse_args()
    recs = load_records(args.dir)
    rows = [derive(r) for r in recs]
    if args.mesh:
        rows = [r for r in rows if r["mesh"] == args.mesh]
    rows.sort(key=lambda r: (r["mesh"], r["cell"]))
    print(markdown_table(rows))
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
