"""Fig. 10 (ours): online update vs full re-factorization latency.

The serving question of DESIGN.md §10: a live GP absorbs b new observations
— how much cheaper is the tiled block Cholesky append (O(n^2 b),
``PosteriorState.extend``) than the full O(n^3) refit the paper's fixed
training set implies?  This sweeps n and the append size b and reports both
latencies plus the speedup; the eviction sweep (``shrink``, the
sliding-window downdate) is timed at one tile per eviction.

The acceptance bar (ISSUE 5): the append beats the full re-factorization
for n >= 256 with b <= tile_size.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench, row
from repro.core import predict as pred
from repro.core.kernels_math import SEKernelParams


def run(ns=(256, 512, 1024), bs=(1, 16, 64), d=8, out=print, backend="jnp"):
    rng = np.random.default_rng(0)
    params = SEKernelParams.paper_defaults()
    results = []
    for n in ns:
        m = max(n // 8, 16)
        x = jnp.asarray(rng.standard_normal((n + max(bs), d)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal(n + max(bs)).astype(np.float32))
        state = pred.posterior_state(x[:n], y[:n], params, m, backend=backend)
        for b in bs:
            xb, yb = x[n : n + b], y[n : n + b]

            def extend(xb, yb):
                s = state.extend(xb, yb, backend=backend, check_finite=False)
                return s.lpacked, s.alpha

            def refit(xb, yb):
                # the honest O(n^3) baseline: the jitted fused q_tiles=0
                # program (assembly -> factorization -> both solves)
                env, _ = pred.nlml_program_env(
                    jnp.concatenate([x[:n], xb]),
                    jnp.concatenate([y[:n], yb]),
                    params,
                    m,
                    backend=backend,
                )
                return env["packed"], env["alpha"]

            t_up, _ = bench(extend, xb, yb)
            t_full, _ = bench(refit, xb, yb)
            speed = t_full / t_up
            out(row(
                f"fig10/update/n{n}/b{b}/m{m}", t_up,
                f"refactor_us={t_full * 1e6:.0f} speedup={speed:.2f}",
            ))
            results.append({
                "kind": "append", "n": n, "b": b, "m": m, "backend": backend,
                "us_update": t_up * 1e6, "us_refactor": t_full * 1e6,
                "speedup": speed,
            })

        # sliding-window eviction: one leading tile out
        def evict():
            s = state.shrink(m, backend=backend, check_finite=False)
            return s.lpacked

        t_ev, _ = bench(evict)
        out(row(f"fig10/evict/n{n}/k{m}", t_ev, f"tile_size={m}"))
        results.append({
            "kind": "evict", "n": n, "b": m, "m": m, "backend": backend,
            "us_update": t_ev * 1e6, "us_refactor": None, "speedup": None,
        })
    return results


if __name__ == "__main__":
    run()
