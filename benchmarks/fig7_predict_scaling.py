"""Fig. 7 analogue: Predict-with-Full-Covariance problem-size scaling.

n_test = n_train as in the paper; tiled pipeline vs monolithic reference.
"""

from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.common import bench, row
from repro.core import predict as pred
from repro.core.kernels_math import SEKernelParams


def run(sizes=(128, 256, 512, 1024), out=print):
    rng = np.random.default_rng(0)
    params = SEKernelParams.paper_defaults()
    d = 16
    for n in sizes:
        x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        xt = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
        mono = jax.jit(
            lambda a, b, c: pred.predict_monolithic(a, b, c, params, full_cov=True)
        )
        t_m, _ = bench(mono, x, y, xt)
        out(row(f"fig7/monolithic/n{n}", t_m))
        m = max(n // 8, 64)
        for label, impl in (("fused", pred.predict), ("staged", pred.predict_staged)):
            tiled = jax.jit(
                lambda a, b, c, m=m, impl=impl: impl(
                    a, b, c, params, m, full_cov=True
                )
            )
            t_t, _ = bench(tiled, x, y, xt)
            out(row(
                f"fig7/tiled_{label}/n{n}/m{m}", t_t, f"speedup={t_m/t_t:.3f}"
            ))


if __name__ == "__main__":
    run()
