"""Fig. 6 analogue: Cholesky problem-size scaling, tiled vs monolithic.

The paper compares CPU vs GPU over n; on this host the comparison is the
schedule-driven executor vs the monolithic single call (cuSOLVER analogue),
plus the crossover behaviour at small n (paper: n < 128 favors the untiled
path because task scheduling overhead dominates).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench, row
from repro.core import cholesky as chol


def run(sizes=(128, 256, 512, 1024, 2048), out=print):
    rng = np.random.default_rng(0)
    for n in sizes:
        a = rng.standard_normal((n, n)).astype(np.float32)
        k = jnp.asarray(a @ a.T + n * np.eye(n, dtype=np.float32))
        mono = jax.jit(chol.monolithic_cholesky)
        t_m, _ = bench(mono, k)
        out(row(f"fig6/monolithic/n{n}", t_m, f"gflops={(n**3/3)/t_m/1e9:.2f}"))
        m = max(n // 8, 64)
        fn = jax.jit(
            lambda kk, m=m: chol.cholesky_dense_via_tiles(kk, m)
        )
        t_t, _ = bench(fn, k)
        out(row(
            f"fig6/executor/n{n}/m{m}", t_t,
            f"gflops={(n**3/3)/t_t/1e9:.2f};speedup={t_m/t_t:.3f}",
        ))


if __name__ == "__main__":
    run()
