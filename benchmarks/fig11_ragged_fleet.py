"""Fig. 11 (ours): ragged fleets — bucketed batching vs pad-to-max vs loop.

B independent GPs with a *skewed* size mix (log-uniform: many small, a heavy
tail) can be served three ways:

* ``loop``       — a Python loop of single-problem fused programs: no
  padding waste, but one underfilled launch sequence per problem;
* ``pad-to-max`` — one GPBatch-style stacked program padded to the largest
  problem: one launch sequence, but every small problem pays the largest
  problem's O(n^3);
* ``bucketed``   — :class:`repro.core.gp.GPFleet` with k geometric bucket
  boundaries (DESIGN.md §11): problems share a fused program per bucket,
  per-problem ``n_valid`` frontiers mask the padding inside it.

``pad-to-max`` is exactly ``bucketed`` with one bucket, so the figure sweeps
the bucket count k and reports, per k: cold factor+predict wall time, the
padded-FLOP proxy sum((cap_i)^3) against the loop's no-waste floor, and —
through :class:`repro.serve.ContinuousBatcher` — served req/s and p99
latency for a mixed predict/observe request stream.  More buckets cut the
padding waste but split the fleet into thinner launches; the sweet spot is
the figure's point.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench, row
from repro.core import predict as pred
from repro.core import tiling
from repro.core.gp import GPFleet
from repro.core.kernels_math import SEKernelParams
from repro.serve import ContinuousBatcher


def skewed_sizes(b, lo, hi, rng):
    """Log-uniform sizes in [lo, hi] — many small problems, a heavy tail."""
    ns = np.exp(rng.uniform(np.log(lo), np.log(hi), b)).astype(int)
    ns[ns < lo] = lo
    # pin the extremes so every mix actually spans the range
    ns[0], ns[-1] = lo, hi
    return np.sort(ns)


def _flop_proxy(ns, m, boundaries):
    """sum(cap_i^3) over the bucket assignment — the padded-work proxy."""
    assign = tiling.bucket_problems([int(n) for n in ns], m, boundaries)
    return float(sum(float(cap * m) ** 3 * len(idx) for cap, idx in assign.items()))


def run(
    b=16,
    n_max=512,
    tile=32,
    bucket_counts=(1, 2, 3, 4),
    waves=4,
    batch=32,
    arrive=8,
    d=4,
    out=print,
    backend="jnp",
    seed=0,
):
    rng = np.random.default_rng(seed)
    params = SEKernelParams.paper_defaults()
    ns = skewed_sizes(b, tile, n_max, rng)
    xs = [rng.standard_normal((int(n), d)).astype(np.float32) for n in ns]
    ys = [rng.standard_normal(int(n)).astype(np.float32) for n in ns]
    nh = max(batch, 8)
    xt = rng.standard_normal((nh, d)).astype(np.float32)

    # -- loop baseline: per-problem fused programs, zero padding waste ------
    def loop():
        return [
            pred.predict_fused(x, y, xt, params, tile, backend=backend)
            for x, y in zip(xs, ys)
        ]

    t_loop, _ = bench(loop, reps=3)
    proxy_floor = float(sum((np.ceil(ns / tile) * tile) ** 3))
    out(row(f"fig11/loop/B{b}", t_loop, f"flop_proxy={proxy_floor:.3g}"))

    results = []
    proxy_pad = _flop_proxy(ns, tile, 1)
    t_pad = None
    for k in bucket_counts:
        fleet = GPFleet(
            xs, ys, params=params, tile_size=tile,
            op_backend=backend, boundaries=int(k),
        )
        n_buckets = len(fleet.bucket_assignment())
        proxy = _flop_proxy(ns, tile, int(k))

        def cold(fleet=fleet):
            fleet.invalidate_cache()
            return fleet.predict(xt)

        t_cold, _ = bench(cold, reps=3)
        if k == 1:
            t_pad = t_cold
        label = "pad_to_max" if k == 1 else f"bucketed_k{k}"

        # -- serving: mixed predict/observe waves over warm buckets ---------
        fleet.invalidate_cache()
        fleet.predict(xt)                      # warm every bucket
        srv = ContinuousBatcher(fleet)
        wrng = np.random.default_rng(seed + 1)
        for w in range(waves):
            rows = np.array_split(np.arange(nh), b)
            for i, rr in enumerate(rows):
                if rr.size:
                    srv.submit_predict(i, xt[rr])
            for i in wrng.choice(b, size=max(b // 4, 1), replace=False):
                xo = wrng.standard_normal((arrive, d)).astype(np.float32)
                yo = wrng.standard_normal(arrive).astype(np.float32)
                srv.submit_observe(int(i), xo, yo)
            srv.step()
        s = srv.summary()

        out(row(
            f"fig11/{label}/B{b}",
            t_cold,
            f"buckets={n_buckets} flop_proxy={proxy:.3g} "
            f"waste_vs_floor={proxy / proxy_floor:.2f} "
            f"speedup_vs_loop={t_loop / t_cold:.3f} "
            f"req_per_s={s['req_per_s']:.1f} p99_ms={s['p99_ms']:.1f}",
        ))
        results.append({
            "B": b,
            "n_max": n_max,
            "tile": tile,
            "k": int(k),
            "buckets": n_buckets,
            "strategy": label,
            "us_cold": t_cold * 1e6,
            "us_loop": t_loop * 1e6,
            "flop_proxy": proxy,
            "flop_proxy_floor": proxy_floor,
            "flop_ratio_vs_pad": proxy_pad / proxy,
            "speedup_vs_loop": t_loop / t_cold,
            "speedup_vs_pad": (t_pad / t_cold) if t_pad else 1.0,
            "req_per_s": s["req_per_s"],
            "p99_ms": s["p99_ms"],
            "migrations_seen": int(s["waves"]),
        })
    return results


if __name__ == "__main__":
    run()
