"""fig15: telemetry overhead — the zero-cost-when-off contract, measured.

Times the two instrumented hot paths with ``repro.obs`` disabled vs
enabled (DESIGN.md §15):

* ``fused_predict`` — one cold fused predict per iteration (cache
  invalidated each time), the path that records an ``executor.wave``
  dispatch event and opens a profiler span;
* ``serve_wave`` — one ContinuousBatcher wave per iteration (mixed
  predict + observe queue), the path that feeds both the batcher's
  private registry and, when enabled, the global ``serve.wave`` events.

Recording happens only at host dispatch boundaries (never inside jitted
code, never by materializing async results), so the enabled overhead is a
few dict hits per *launch sequence*, not per tile task — the acceptance
bar is <= 2% on the median.  The fused-predict means are also compared
bitwise across the two modes: telemetry must never perturb numerics.
"""

from __future__ import annotations

import time

import numpy as np

import repro.obs as obs


def _median_us(fn, reps: int, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _overhead(us_off: float, us_on: float) -> float:
    return (us_on / us_off - 1.0) * 100.0


def _bench_fused(n, tile, d, reps):
    import jax

    from repro.core.gp import GaussianProcess

    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((max(n // 8, 8), d)).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=tile)

    def cold_predict():
        gp.invalidate_cache()
        out = gp.predict(xt)
        jax.block_until_ready(out)
        return out

    results = {}
    for mode in ("off", "on"):
        (obs.enable if mode == "on" else obs.disable)()
        results[mode] = (np.asarray(cold_predict()), _median_us(cold_predict, reps))
    obs.disable()
    obs.reset()
    mean_off, us_off = results["off"]
    mean_on, us_on = results["on"]
    return us_off, us_on, bool(np.array_equal(mean_off, mean_on))


def _bench_serve(b, n_max, tile, batch, reps):
    from repro.core.gp import GPFleet
    from repro.serve import ContinuousBatcher

    def scenario():
        rng = np.random.default_rng(3)
        ns = rng.integers(max(tile // 2, 8), n_max, size=b)
        xs = [rng.uniform(size=(int(n), 1)).astype(np.float32) for n in ns]
        ys = [np.sin(6 * x[:, 0]).astype(np.float32) for x in xs]
        srv = ContinuousBatcher(GPFleet(xs, ys, tile_size=tile))
        rng_req = np.random.default_rng(4)

        def wave():
            for i in range(b):
                srv.submit_predict(i, rng_req.uniform(size=(batch // b + 1, 1)))
            srv.submit_observe(
                int(rng_req.integers(b)),
                rng_req.uniform(size=(2, 1)),
                rng_req.normal(size=2),
            )
            srv.step()

        return wave, srv

    # pre-pass: the schedule GROWS problems, so later waves hit new bucket
    # geometries — run it once untimed so every jit trace/Plan the timed
    # passes will touch is already compiled (else the first mode measured
    # pays all the compiles and the comparison is meaningless)
    obs.disable()
    wave, srv = scenario()
    for _ in range(reps + 2):
        wave()
    srv.flush()

    # one batcher per mode, waves INTERLEAVED (off, on, off, on, ...): the
    # two fleets follow identical request schedules in lockstep, so slow
    # machine drift lands on both modes instead of biasing whichever block
    # was measured first
    waves, srvs = {}, {}
    for mode in ("off", "on"):
        waves[mode], srvs[mode] = scenario()
    ts = {"off": [], "on": []}
    for rep in range(reps + 2):
        for mode in ("off", "on"):
            (obs.enable if mode == "on" else obs.disable)()
            t0 = time.perf_counter()
            waves[mode]()
            if rep >= 2:
                ts[mode].append(time.perf_counter() - t0)
    obs.disable()
    for mode in ("off", "on"):
        srvs[mode].flush()
    obs.reset()
    return (
        float(np.median(ts["off"]) * 1e6),
        float(np.median(ts["on"]) * 1e6),
    )


def run(n=512, tile=64, d=8, b=6, n_max=128, batch=24, reps=10, out=print):
    from benchmarks.common import row

    prev = obs.enabled()  # restore the caller's telemetry state on exit
    rows = []

    us_off, us_on, bitwise = _bench_fused(n, tile, d, reps)
    out(row(f"fig15/fused_predict/off/n{n}", us_off / 1e6))
    out(row(
        f"fig15/fused_predict/on/n{n}", us_on / 1e6,
        f"overhead_pct={_overhead(us_off, us_on):.2f} bitwise_identical={bitwise}",
    ))
    rows.append({
        "path": "fused_predict", "n": n, "us_off": us_off, "us_on": us_on,
        "overhead_pct": _overhead(us_off, us_on), "bitwise_identical": bitwise,
    })

    us_off, us_on = _bench_serve(b, n_max, tile, batch, reps)
    out(row(f"fig15/serve_wave/off/b{b}", us_off / 1e6))
    out(row(
        f"fig15/serve_wave/on/b{b}", us_on / 1e6,
        f"overhead_pct={_overhead(us_off, us_on):.2f}",
    ))
    rows.append({
        "path": "serve_wave", "b": b, "us_off": us_off, "us_on": us_on,
        "overhead_pct": _overhead(us_off, us_on),
    })

    if prev:
        obs.enable()
    return rows


if __name__ == "__main__":
    run()
