"""Fig. 8 (new): hyperparameter-training step time, tiled vs monolithic.

The O(n^3) assemble/factor/solve cost recurs on *every* optimizer step, so
training is where the tiled pipeline's launch fusion pays repeatedly.  This
sweep times one Adam step — value_and_grad of the NLML, the dominant cost of
`mll.adam_scan`'s scan body — for the differentiable tiled program
(`mll.nlml_tiled`, blocked reverse-mode VJP) against the monolithic dense
reference, over problem size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench, row
from repro.core import mll
from repro.core.kernels_math import SEKernelParams


def run(sizes=(128, 256, 512, 1024, 2048), out=print):
    rng = np.random.default_rng(0)
    for n in sizes:
        x = jnp.asarray(rng.uniform(-3, 3, (n, 8)).astype(np.float32))
        y = jnp.asarray(
            (np.sin(np.asarray(x)[:, 0]) + 0.1 * rng.standard_normal(n)).astype(
                np.float32
            )
        )
        m = max(n // 8, 16)
        raw = mll._pack(SEKernelParams.paper_defaults())
        mono = jax.jit(
            jax.value_and_grad(mll.nlml_loss_fn(x, y, method="monolithic"))
        )
        t_m, _ = bench(mono, raw)
        out(row(f"fig8/monolithic/n{n}", t_m))
        tiled = jax.jit(
            jax.value_and_grad(
                mll.nlml_loss_fn(x, y, method="tiled", tile_size=m)
            )
        )
        t_t, _ = bench(tiled, raw)
        out(row(
            f"fig8/tiled/n{n}/m{m}", t_t,
            f"step_ratio_vs_monolithic={t_t / t_m:.3f}",
        ))
