"""Fig. 3 analogue: tiled Cholesky runtime vs stream count and tile count.

The paper sweeps CUDA streams × tiles at n=32768 on an A30.  Here the same
sweep runs on the host CPU (single XLA device) and compares two execution
strategies (DESIGN.md §2–3):

* ``monolithic``  — single-call Cholesky (the cuSOLVER reference analogue)
* ``executor``    — the schedule-driven level-batched executor
  (wavefront plan for finite ``n_streams``)

``n_streams`` is the batching-granularity knob and tiles per dimension
sweeps M.  Sizes are scaled to CPU (default n=1024; use --n).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench, row
from repro.core import cholesky as chol


def run(n: int = 1024, tile_counts=(4, 8, 16), streams=(1, 4, 16, None), out=print):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n)).astype(np.float32)
    k = jnp.asarray(a @ a.T + n * np.eye(n, dtype=np.float32))

    mono = jax.jit(chol.monolithic_cholesky)
    t, ci = bench(mono, k)
    out(row(f"fig3/monolithic/n{n}", t, f"ci={ci:.2e}"))
    base = t

    for m_tiles in tile_counts:
        m = n // m_tiles
        for ns in streams:
            tag = "inf" if ns is None else str(ns)
            fn = jax.jit(
                lambda kk, m=m, ns=ns: chol.cholesky_dense_via_tiles(
                    kk, m, n_streams=ns
                )
            )
            t, ci = bench(fn, k)
            out(row(
                f"fig3/executor/n{n}/tiles{m_tiles}/streams{tag}",
                t,
                f"speedup_vs_monolithic={base/t:.3f}",
            ))


if __name__ == "__main__":
    run()
