"""Fig. 3 analogue: tiled Cholesky runtime vs stream count and tile count.

The paper sweeps CUDA streams × tiles at n=32768 on an A30.  Here the same
sweep runs the level-batched schedule on the host CPU (single XLA device):
``n_streams`` is the batching-granularity knob (DESIGN.md §2) and tiles per
dimension sweeps M.  The monolithic single-call Cholesky is the cuSOLVER
reference analogue.  Sizes are scaled to CPU (default n=1024; use --n).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench, row
from repro.core import cholesky as chol


def run(n: int = 1024, out=print):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n)).astype(np.float32)
    k = jnp.asarray(a @ a.T + n * np.eye(n, dtype=np.float32))

    mono = jax.jit(chol.monolithic_cholesky)
    t, ci = bench(mono, k)
    out(row(f"fig3/monolithic/n{n}", t, f"ci={ci:.2e}"))
    base = t

    for m_tiles in (4, 8, 16, 32):
        m = n // m_tiles
        for ns in (1, 4, 16, None):
            fn = jax.jit(
                lambda kk, m=m, ns=ns: chol.cholesky_dense_via_tiles(kk, m, n_streams=ns)
            )
            t, ci = bench(fn, k)
            tag = "inf" if ns is None else str(ns)
            out(row(
                f"fig3/tiled/n{n}/tiles{m_tiles}/streams{tag}",
                t,
                f"speedup_vs_monolithic={base/t:.3f}",
            ))


if __name__ == "__main__":
    run()
