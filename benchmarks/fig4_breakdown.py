"""Fig. 4 analogue: runtime breakdown of the GP pipeline per step.

Paper stages at n=32768 / 32 streams, varying tiles: covariance assembly,
Cholesky, triangular solves, prediction.  Same decomposition on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench, row
from repro.core import cholesky as chol
from repro.core import predict as pred
from repro.core import tiling, triangular
from repro.core.kernels_math import SEKernelParams


def run(n: int = 1024, n_test: int = 1024, out=print):
    rng = np.random.default_rng(0)
    d = 16
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    xt = jnp.asarray(rng.standard_normal((n_test, d)).astype(np.float32))
    params = SEKernelParams.paper_defaults()

    for m_tiles in (4, 16):
        m = n // m_tiles
        xc = tiling.pad_features(x, m)
        yc = tiling.pad_vector(y, m)
        xtc = tiling.pad_features(xt, m)

        assemble = jax.jit(lambda xc: pred.assemble_packed_covariance(xc, params, n))
        t, _ = bench(assemble, xc)
        out(row(f"fig4/assembly/n{n}/tiles{m_tiles}", t))
        packed = assemble(xc)

        factor = jax.jit(chol.tiled_cholesky)
        t, _ = bench(factor, packed)
        out(row(f"fig4/cholesky/n{n}/tiles{m_tiles}", t))
        lp = factor(packed)

        solves = jax.jit(
            lambda lp, yc: triangular.backward_substitution(
                lp, triangular.forward_substitution(lp, yc)
            )
        )
        t, _ = bench(solves, lp, yc)
        out(row(f"fig4/solves/n{n}/tiles{m_tiles}", t))
        alpha = solves(lp, yc)

        cross = jax.jit(lambda xtc, xc: pred.assemble_cross_tiles(xtc, xc, params, n_test, n))
        t, _ = bench(cross, xtc, xc)
        out(row(f"fig4/cross_assembly/n{n}/tiles{m_tiles}", t))
        kstar = cross(xtc, xc)

        mean = jax.jit(triangular.tiled_matvec)
        t, _ = bench(mean, kstar, alpha)
        out(row(f"fig4/mean/n{n}/tiles{m_tiles}", t))

        def variance_stage(lp, kstar, xtc):
            b_tiles = jnp.einsum("qiab->iqba", kstar)
            v = triangular.forward_substitution_matrix(lp, b_tiles)
            w = triangular.tiled_gram(v)
            prior = pred.assemble_prior_tiles(xtc, params, n_test)
            return prior - w

        var = jax.jit(variance_stage)
        t, _ = bench(var, lp, kstar, xtc)
        out(row(f"fig4/uncertainty/n{n}/tiles{m_tiles}", t))

        # the same pipeline as ONE fused program (DESIGN.md §7): no stage
        # barriers, cross-stage wavefronts, one jit
        fused = jax.jit(
            lambda a, b, c: pred.predict(a, b, c, params, m, full_cov=True)
        )
        t, _ = bench(fused, x, y, xt)
        out(row(f"fig4/fused_total/n{n}/tiles{m_tiles}", t))


if __name__ == "__main__":
    run()
