"""Fig. 9 (ours): batched multi-GP throughput vs a Python loop of single GPs.

The paper's wavefront width limits utilization for small n — one GP per
launch underfills every executor batch.  Batching B independent problems
through ONE fused program (DESIGN.md §9) multiplies every batch width by B
without changing the DAG.  This figure sweeps B at fixed n and reports
end-to-end problems/second for:

* ``batched``  — one problem-batched fused program (GPBatch cold path),
* ``loop``     — the same B problems as a Python loop over the
  single-problem fused program (same jit cache, B dispatches),
* ``autobatch`` — ``jax.vmap`` of the whole single-problem ``predict_fused``
  (ROADMAP bench hygiene): XLA autobatching with no shared executor plan —
  quantifies what the *explicit* executor batching buys beyond vmap,

plus the two Pallas/tile batch-dispatch strategies (``flat`` folds B into
the kernel's batch/grid axis, ``vmap`` nests one more vmap level) so the
tradeoff the tentpole calls out is measured, not guessed.
"""

from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.common import bench, row
from repro.core import predict as pred
from repro.core.kernels_math import SEKernelParams


def run(n=256, bs=(1, 2, 4, 8), d=8, out=print, backend="jnp"):
    rng = np.random.default_rng(0)
    params = SEKernelParams.paper_defaults()
    m = max(n // 8, 16)
    nh = max(n // 4, 8)
    results = []
    for b in bs:
        x = jnp.asarray(rng.standard_normal((b, n, d)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal((b, n)).astype(np.float32))
        xt = jnp.asarray(rng.standard_normal((b, nh, d)).astype(np.float32))

        def loop(x, y, xt):
            return [
                pred.predict_fused(x[i], y[i], xt[i], params, m, backend=backend)
                for i in range(b)
            ]

        t_loop, _ = bench(loop, x, y, xt)
        out(row(f"fig9/loop/B{b}/n{n}", t_loop, f"problems_per_s={b / t_loop:.1f}"))

        autob = jax.jit(jax.vmap(
            lambda x1, y1, xt1: pred.predict_fused(x1, y1, xt1, params, m, backend=backend)
        ))
        t_auto, _ = bench(autob, x, y, xt)
        out(row(
            f"fig9/autobatch/B{b}/n{n}",
            t_auto,
            f"problems_per_s={b / t_auto:.1f} speedup_vs_loop={t_loop / t_auto:.3f}",
        ))
        results.append({
            "B": b, "n": n, "m": m, "dispatch": "autobatch",
            "us_batched": t_auto * 1e6, "us_loop": t_loop * 1e6,
            "speedup_vs_loop": t_loop / t_auto,
        })

        for mode in ("flat", "vmap"):
            fn = lambda x, y, xt, mode=mode: pred.predict_fused_batched(
                x, y, xt, params, m, backend=backend, batch_dispatch=mode
            )
            t_b, _ = bench(fn, x, y, xt)
            out(row(
                f"fig9/batched_{mode}/B{b}/n{n}",
                t_b,
                f"problems_per_s={b / t_b:.1f} speedup_vs_loop={t_loop / t_b:.3f}",
            ))
            results.append({
                "B": b,
                "n": n,
                "m": m,
                "dispatch": mode,
                "us_batched": t_b * 1e6,
                "us_loop": t_loop * 1e6,
                "speedup_vs_loop": t_loop / t_b,
            })
    return results


if __name__ == "__main__":
    run()
