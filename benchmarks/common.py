"""Benchmark timing helpers (paper methodology: averaged repeats, CI)."""

from __future__ import annotations

import time

import jax
import numpy as np


def bench(fn, *args, reps: int = 5, warmup: int = 1):
    """Median + 95% CI wall time of jax fn (blocks on completion)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts = np.asarray(ts)
    ci = 1.96 * ts.std() / max(np.sqrt(len(ts)), 1)
    return float(np.median(ts)), float(ci)


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds*1e6:.1f},{derived}"
