# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--n N] [--json PATH]

Emits ``name,us_per_call,derived`` CSV rows on stdout AND writes a
machine-readable ``BENCH_pipeline.json`` (per-figure timings, executor
batch counts, fused-vs-staged pipeline timings) so the perf trajectory is
tracked across PRs.
"""

from __future__ import annotations

import argparse
import json


def _env_header() -> dict:
    """Execution environment stamped into every figure's row header."""
    import jax

    nd = jax.device_count()
    return {
        "devices": nd,
        "backend": jax.default_backend(),
        "mesh_shape": [nd],
        "mesh_axes": ["data"],
    }


class _Collector:
    """Print benchmark rows and keep them for the JSON artifact.

    The execution environment (device count, backend, fleet mesh shape) is
    stamped once at the payload's top level; figure groups carry only their
    ``rows`` — one run means one environment, so per-figure copies would be
    pure duplication."""

    def __init__(self) -> None:
        self.figures: dict = {}
        self._env: dict = None

    @property
    def env(self) -> dict:
        if self._env is None:
            self._env = _env_header()
        return self._env

    def out(self, figure: str):
        group = self.figures.setdefault(figure, {"rows": []})
        rows = group["rows"]

        def _out(line: str) -> None:
            print(line)
            name, us, derived = (line.split(",", 2) + ["", ""])[:3]
            rows.append({"name": name, "us_per_call": float(us), "derived": derived})

        return _out


def _executor_counts(tile_counts=(4, 8, 16), streams=(None, 4, 16)) -> list:
    """Fused-program vs staged batched-launch counts (plan-level, no exec)."""
    from repro.core import executor

    rows = []
    for m_tiles in tile_counts:
        q_tiles = max(m_tiles // 4, 1)
        for unc in (False, True):
            for ns in streams:
                plan = executor.program_plan(m_tiles, q_tiles, unc, ns)
                rows.append({
                    "m_tiles": m_tiles,
                    "q_tiles": q_tiles,
                    "uncertainty": unc,
                    "n_streams": ns,
                    "fused_batches": plan.n_batches,
                    "fused_waves": len(plan.levels),
                    "staged_batches": executor.staged_launch_count(
                        m_tiles, uncertainty=unc, n_streams=ns
                    ),
                })
    return rows


def _fused_vs_staged(n: int, out) -> list:
    """Wall-clock of the fused program vs the staged pipeline vs monolithic."""
    import jax
    import numpy as np
    import jax.numpy as jnp

    from benchmarks.common import bench, row
    from repro.core import predict as pred
    from repro.core.kernels_math import SEKernelParams

    rng = np.random.default_rng(0)
    d = 16
    params = SEKernelParams.paper_defaults()
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    xt = jnp.asarray(rng.standard_normal((max(n // 4, 8), d)).astype(np.float32))
    m = max(n // 8, 16)
    results = []
    for full_cov in (False, True):
        timings = {}
        for label, impl in (("fused", pred.predict), ("staged", pred.predict_staged)):
            fn = jax.jit(
                lambda a, b, c, impl=impl, full_cov=full_cov: impl(
                    a, b, c, params, m, full_cov=full_cov
                )
            )
            t, _ = bench(fn, x, y, xt)
            timings[label] = t
            out(row(f"pipeline/{label}/n{n}/m{m}/cov{int(full_cov)}", t))
        mono = jax.jit(
            lambda a, b, c, full_cov=full_cov: pred.predict_monolithic(
                a, b, c, params, full_cov=full_cov
            )
        )
        t, _ = bench(mono, x, y, xt)
        timings["monolithic"] = t
        out(row(
            f"pipeline/monolithic/n{n}/cov{int(full_cov)}", t,
            f"fused_speedup_vs_staged={timings['staged'] / timings['fused']:.3f}",
        ))
        results.append({
            "n": n,
            "m": m,
            "full_cov": full_cov,
            "us_fused": timings["fused"] * 1e6,
            "us_staged": timings["staged"] * 1e6,
            "us_monolithic": timings["monolithic"] * 1e6,
        })
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="problem size for fig3/fig4")
    ap.add_argument("--quick", action="store_true", help="smaller sweeps")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="minimal CI smoke run: tiny sizes, every figure module imported",
    )
    ap.add_argument(
        "--json",
        default="BENCH_pipeline.json",
        help="machine-readable output path ('' disables)",
    )
    args = ap.parse_args()

    from benchmarks import (
        fig3_streams_tiles,
        fig4_breakdown,
        fig5_schedule_trace,
        fig6_cholesky_scaling,
        fig7_predict_scaling,
        fig8_train_scaling,
        fig9_batched_fleet,
        fig10_online_update,
        fig11_ragged_fleet,
        fig12_sharded_fleet,
        fig13_kernel_zoo,
        fig14_lowrank_tradeoff,
        mem_tiles,
    )

    col = _Collector()
    print("name,us_per_call,derived")
    if args.smoke:
        fig3_streams_tiles.run(n=128, tile_counts=(4,), streams=(2, None), out=col.out("fig3"))
        fig5_schedule_trace.run(m_tiles=8, out=col.out("fig5"))
        fig6_cholesky_scaling.run(sizes=(128,), out=col.out("fig6"))
        fig8_train_scaling.run(sizes=(64,), out=col.out("fig8"))
        fleet = fig9_batched_fleet.run(n=128, bs=(1, 4), out=col.out("fig9"))
        online = fig10_online_update.run(ns=(128,), bs=(1, 8), out=col.out("fig10"))
        ragged = fig11_ragged_fleet.run(
            b=8, n_max=96, tile=16, bucket_counts=(1, 2), waves=1, batch=8,
            out=col.out("fig11"),
        )
        sharded = fig12_sharded_fleet.run(
            n_total=128, tile=16, bs=(1, 4), n_test=16, out=col.out("fig12")
        )
        kernel_zoo = fig13_kernel_zoo.run(
            n=96, n_test=16, tile=32, d=4, out=col.out("fig13")
        )
        lowrank = fig14_lowrank_tradeoff.run(
            sizes=(96,), ms=(16, 32), n_test=24, tile=32, d=3,
            out=col.out("fig14"),
        )
        mem_tiles.run(n=256, out=col.out("mem"))
        pipeline = _fused_vs_staged(128, col.out("pipeline"))
        counts = _executor_counts(tile_counts=(8,))
    else:
        n = min(args.n, 512) if args.quick else args.n
        fig3_streams_tiles.run(n=n, out=col.out("fig3"))
        fig4_breakdown.run(n=n, n_test=n, out=col.out("fig4"))
        fig5_schedule_trace.run(m_tiles=32, out=col.out("fig5"))
        sizes = (128, 256, 512) if args.quick else (128, 256, 512, 1024, 2048)
        fig6_cholesky_scaling.run(sizes=sizes, out=col.out("fig6"))
        psizes = (128, 256) if args.quick else (128, 256, 512, 1024)
        fig7_predict_scaling.run(sizes=psizes, out=col.out("fig7"))
        tsizes = (128, 256) if args.quick else (128, 256, 512, 1024, 2048)
        fig8_train_scaling.run(sizes=tsizes, out=col.out("fig8"))
        fbs = (1, 2, 4) if args.quick else (1, 2, 4, 8, 16)
        fleet = fig9_batched_fleet.run(n=min(n, 256), bs=fbs, out=col.out("fig9"))
        osizes = (256, 512) if args.quick else (256, 512, 1024)
        online = fig10_online_update.run(
            ns=osizes, bs=(1, 16, 64), out=col.out("fig10")
        )
        rb, rn = ((8, 256) if args.quick else (16, 512))
        ragged = fig11_ragged_fleet.run(
            b=rb, n_max=rn, tile=32, out=col.out("fig11")
        )
        sharded = fig12_sharded_fleet.run(
            n_total=(256 if args.quick else 512),
            bs=(1, 4) if args.quick else (1, 4, 16),
            out=col.out("fig12"),
        )
        kernel_zoo = fig13_kernel_zoo.run(
            n=(256 if args.quick else 512),
            tile=(32 if args.quick else 64),
            out=col.out("fig13"),
        )
        lowrank = fig14_lowrank_tradeoff.run(
            sizes=((1024,) if args.quick else (4096, 16384)),
            ms=((64, 128) if args.quick else (64, 128, 256, 512)),
            n_test=(128 if args.quick else 512),
            tile=(64 if args.quick else 256),
            out=col.out("fig14"),
        )
        mem_tiles.run(n=n, out=col.out("mem"))
        pipeline = _fused_vs_staged(min(n, 512), col.out("pipeline"))
        counts = _executor_counts()

    if args.json:
        payload = {
            "env": col.env,
            "figures": col.figures,
            "executor_batches": counts,
            "fused_vs_staged": pipeline,
            "batched_fleet": fleet,
            "online_update": online,
            "ragged_fleet": ragged,
            "sharded_fleet": sharded,
            "kernel_zoo": kernel_zoo,
            "lowrank": lowrank,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
