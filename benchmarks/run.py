# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--n N]

Emits ``name,us_per_call,derived`` CSV rows.  Sizes default to CPU-friendly
values (paper sizes n=32768 target the TPU dry-run path, not this host —
see EXPERIMENTS.md §Methodology).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="problem size for fig3/fig4")
    ap.add_argument("--quick", action="store_true", help="smaller sweeps")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="minimal CI smoke run: tiny sizes, every figure module imported",
    )
    args = ap.parse_args()

    from benchmarks import (
        fig3_streams_tiles,
        fig4_breakdown,
        fig5_schedule_trace,
        fig6_cholesky_scaling,
        fig7_predict_scaling,
        mem_tiles,
    )

    print("name,us_per_call,derived")
    if args.smoke:
        fig3_streams_tiles.run(n=128, tile_counts=(4,), streams=(2, None))
        fig5_schedule_trace.run(m_tiles=8)
        fig6_cholesky_scaling.run(sizes=(128,))
        mem_tiles.run(n=256)
        return
    n = min(args.n, 512) if args.quick else args.n
    fig3_streams_tiles.run(n=n)
    fig4_breakdown.run(n=n, n_test=n)
    fig5_schedule_trace.run(m_tiles=32)
    sizes = (128, 256, 512) if args.quick else (128, 256, 512, 1024, 2048)
    fig6_cholesky_scaling.run(sizes=sizes)
    psizes = (128, 256) if args.quick else (128, 256, 512, 1024)
    fig7_predict_scaling.run(sizes=psizes)
    mem_tiles.run(n=n)


if __name__ == "__main__":
    main()
