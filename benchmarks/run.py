# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--n N] [--json PATH]

Emits ``name,us_per_call,derived`` CSV rows on stdout AND writes a
machine-readable ``BENCH_pipeline.json`` (per-figure timings, executor
batch counts, fused-vs-staged pipeline timings) so the perf trajectory is
tracked across PRs.
"""

from __future__ import annotations

import argparse
import json


def _env_header() -> dict:
    """Execution environment stamped into every figure's row header."""
    import jax

    nd = jax.device_count()
    return {
        "devices": nd,
        "backend": jax.default_backend(),
        "mesh_shape": [nd],
        "mesh_axes": ["data"],
    }


class _Collector:
    """Print benchmark rows and keep them for the JSON artifact.

    The execution environment (device count, backend, fleet mesh shape) is
    stamped once at the payload's top level; figure groups carry only their
    ``rows`` — one run means one environment, so per-figure copies would be
    pure duplication."""

    def __init__(self) -> None:
        self.figures: dict = {}
        self._env: dict = None

    @property
    def env(self) -> dict:
        if self._env is None:
            self._env = _env_header()
        return self._env

    def out(self, figure: str):
        group = self.figures.setdefault(figure, {"rows": []})
        rows = group["rows"]

        def _out(line: str) -> None:
            print(line)
            name, us, derived = (line.split(",", 2) + ["", ""])[:3]
            rows.append({"name": name, "us_per_call": float(us), "derived": derived})

        return _out

    def run_fig(self, figure: str, fn, /, *args, **kwargs):
        """Run one figure module, stamping its wall-clock duration and the
        lru-cache tallies (cumulative across the run — per-figure deltas are
        derivable by diffing consecutive groups) into its JSON group."""
        import time

        import repro.obs as obs

        t0 = time.perf_counter()
        result = fn(*args, out=self.out(figure), **kwargs)
        group = self.figures[figure]
        group["duration_s"] = round(time.perf_counter() - t0, 3)
        group["cache_stats"] = obs.cache_stats()
        return result


def _executor_counts(tile_counts=(4, 8, 16), streams=(None, 4, 16)) -> list:
    """Fused-program vs staged batched-launch counts (plan-level, no exec)."""
    from repro.core import executor

    rows = []
    for m_tiles in tile_counts:
        q_tiles = max(m_tiles // 4, 1)
        for unc in (False, True):
            for ns in streams:
                plan = executor.program_plan(m_tiles, q_tiles, unc, ns)
                rows.append({
                    "m_tiles": m_tiles,
                    "q_tiles": q_tiles,
                    "uncertainty": unc,
                    "n_streams": ns,
                    "fused_batches": plan.n_batches,
                    "fused_waves": len(plan.levels),
                    "staged_batches": executor.staged_launch_count(
                        m_tiles, uncertainty=unc, n_streams=ns
                    ),
                })
    return rows


def _fused_vs_staged(n: int, out) -> list:
    """Wall-clock of the fused program vs the staged pipeline vs monolithic."""
    import jax
    import numpy as np
    import jax.numpy as jnp

    from benchmarks.common import bench, row
    from repro.core import predict as pred
    from repro.core.kernels_math import SEKernelParams

    rng = np.random.default_rng(0)
    d = 16
    params = SEKernelParams.paper_defaults()
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    xt = jnp.asarray(rng.standard_normal((max(n // 4, 8), d)).astype(np.float32))
    m = max(n // 8, 16)
    results = []
    for full_cov in (False, True):
        timings = {}
        for label, impl in (("fused", pred.predict), ("staged", pred.predict_staged)):
            fn = jax.jit(
                lambda a, b, c, impl=impl, full_cov=full_cov: impl(
                    a, b, c, params, m, full_cov=full_cov
                )
            )
            t, _ = bench(fn, x, y, xt)
            timings[label] = t
            out(row(f"pipeline/{label}/n{n}/m{m}/cov{int(full_cov)}", t))
        mono = jax.jit(
            lambda a, b, c, full_cov=full_cov: pred.predict_monolithic(
                a, b, c, params, full_cov=full_cov
            )
        )
        t, _ = bench(mono, x, y, xt)
        timings["monolithic"] = t
        out(row(
            f"pipeline/monolithic/n{n}/cov{int(full_cov)}", t,
            f"fused_speedup_vs_staged={timings['staged'] / timings['fused']:.3f}",
        ))
        results.append({
            "n": n,
            "m": m,
            "full_cov": full_cov,
            "us_fused": timings["fused"] * 1e6,
            "us_staged": timings["staged"] * 1e6,
            "us_monolithic": timings["monolithic"] * 1e6,
        })
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="problem size for fig3/fig4")
    ap.add_argument("--quick", action="store_true", help="smaller sweeps")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="minimal CI smoke run: tiny sizes, every figure module imported",
    )
    ap.add_argument(
        "--json",
        default="BENCH_pipeline.json",
        help="machine-readable output path ('' disables)",
    )
    args = ap.parse_args()

    from benchmarks import (
        fig3_streams_tiles,
        fig4_breakdown,
        fig5_schedule_trace,
        fig6_cholesky_scaling,
        fig7_predict_scaling,
        fig8_train_scaling,
        fig9_batched_fleet,
        fig10_online_update,
        fig11_ragged_fleet,
        fig12_sharded_fleet,
        fig13_kernel_zoo,
        fig14_lowrank_tradeoff,
        fig15_obs_overhead,
        mem_tiles,
    )

    col = _Collector()
    print("name,us_per_call,derived")
    if args.smoke:
        col.run_fig("fig3", fig3_streams_tiles.run, n=128, tile_counts=(4,), streams=(2, None))
        col.run_fig("fig5", fig5_schedule_trace.run, m_tiles=8)
        col.run_fig("fig6", fig6_cholesky_scaling.run, sizes=(128,))
        col.run_fig("fig8", fig8_train_scaling.run, sizes=(64,))
        fleet = col.run_fig("fig9", fig9_batched_fleet.run, n=128, bs=(1, 4))
        online = col.run_fig("fig10", fig10_online_update.run, ns=(128,), bs=(1, 8))
        ragged = col.run_fig(
            "fig11", fig11_ragged_fleet.run,
            b=8, n_max=96, tile=16, bucket_counts=(1, 2), waves=1, batch=8,
        )
        sharded = col.run_fig(
            "fig12", fig12_sharded_fleet.run, n_total=128, tile=16, bs=(1, 4), n_test=16
        )
        kernel_zoo = col.run_fig(
            "fig13", fig13_kernel_zoo.run, n=96, n_test=16, tile=32, d=4
        )
        lowrank = col.run_fig(
            "fig14", fig14_lowrank_tradeoff.run,
            sizes=(96,), ms=(16, 32), n_test=24, tile=32, d=3,
        )
        obs_overhead = col.run_fig(
            "fig15", fig15_obs_overhead.run,
            n=96, tile=32, d=4, b=4, n_max=64, batch=8, reps=3,
        )
        col.run_fig("mem", mem_tiles.run, n=256)
        pipeline = col.run_fig(
            "pipeline", lambda n, out: _fused_vs_staged(n, out), 128
        )
        counts = _executor_counts(tile_counts=(8,))
    else:
        n = min(args.n, 512) if args.quick else args.n
        col.run_fig("fig3", fig3_streams_tiles.run, n=n)
        col.run_fig("fig4", fig4_breakdown.run, n=n, n_test=n)
        col.run_fig("fig5", fig5_schedule_trace.run, m_tiles=32)
        sizes = (128, 256, 512) if args.quick else (128, 256, 512, 1024, 2048)
        col.run_fig("fig6", fig6_cholesky_scaling.run, sizes=sizes)
        psizes = (128, 256) if args.quick else (128, 256, 512, 1024)
        col.run_fig("fig7", fig7_predict_scaling.run, sizes=psizes)
        tsizes = (128, 256) if args.quick else (128, 256, 512, 1024, 2048)
        col.run_fig("fig8", fig8_train_scaling.run, sizes=tsizes)
        fbs = (1, 2, 4) if args.quick else (1, 2, 4, 8, 16)
        fleet = col.run_fig("fig9", fig9_batched_fleet.run, n=min(n, 256), bs=fbs)
        osizes = (256, 512) if args.quick else (256, 512, 1024)
        online = col.run_fig("fig10", fig10_online_update.run, ns=osizes, bs=(1, 16, 64))
        rb, rn = ((8, 256) if args.quick else (16, 512))
        ragged = col.run_fig("fig11", fig11_ragged_fleet.run, b=rb, n_max=rn, tile=32)
        sharded = col.run_fig(
            "fig12", fig12_sharded_fleet.run,
            n_total=(256 if args.quick else 512),
            bs=(1, 4) if args.quick else (1, 4, 16),
        )
        kernel_zoo = col.run_fig(
            "fig13", fig13_kernel_zoo.run,
            n=(256 if args.quick else 512),
            tile=(32 if args.quick else 64),
        )
        lowrank = col.run_fig(
            "fig14", fig14_lowrank_tradeoff.run,
            sizes=((1024,) if args.quick else (4096, 16384)),
            ms=((64, 128) if args.quick else (64, 128, 256, 512)),
            n_test=(128 if args.quick else 512),
            tile=(64 if args.quick else 256),
        )
        obs_overhead = col.run_fig(
            "fig15", fig15_obs_overhead.run,
            n=(256 if args.quick else 512),
            tile=(32 if args.quick else 64),
            b=6, n_max=(96 if args.quick else 128),
            reps=(5 if args.quick else 10),
        )
        col.run_fig("mem", mem_tiles.run, n=n)
        pipeline = col.run_fig(
            "pipeline", lambda n, out: _fused_vs_staged(n, out), min(n, 512)
        )
        counts = _executor_counts()

    if args.json:
        payload = {
            "env": col.env,
            "figures": col.figures,
            "executor_batches": counts,
            "fused_vs_staged": pipeline,
            "batched_fleet": fleet,
            "online_update": online,
            "ragged_fleet": ragged,
            "sharded_fleet": sharded,
            "kernel_zoo": kernel_zoo,
            "lowrank": lowrank,
            "obs_overhead": obs_overhead,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
