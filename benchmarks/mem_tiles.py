"""§4.2 memory claim: packed symmetric tile store vs dense matrix.

Analytic ratio ((M+1)/2M — the paper's 50-75 %) plus the *measured* argument
bytes of the compiled factorization programs for both layouts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core import cholesky as chol
from repro.core import tiling


def run(n: int = 1024, out=print):
    for m_tiles in (2, 4, 8, 32):
        m = n // m_tiles
        ratio = tiling.packed_bytes(m_tiles, m) / tiling.dense_bytes(n)
        out(row(f"mem/analytic/tiles{m_tiles}", 0.0, f"packed_over_dense={ratio:.4f}"))

    m = n // 8
    packed_sds = jax.ShapeDtypeStruct(
        (tiling.num_packed_tiles(8), m, m), jnp.float32
    )
    c_t = jax.jit(chol.tiled_cholesky).lower(packed_sds).compile()
    dense_sds = jax.ShapeDtypeStruct((n, n), jnp.float32)
    c_m = jax.jit(chol.monolithic_cholesky).lower(dense_sds).compile()
    bt = c_t.memory_analysis().argument_size_in_bytes
    bm = c_m.memory_analysis().argument_size_in_bytes
    out(row(f"mem/measured_args/n{n}", 0.0,
            f"tiled={bt};dense={bm};ratio={bt/bm:.4f}"))


if __name__ == "__main__":
    run()
