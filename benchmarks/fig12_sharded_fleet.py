"""Fig. 12 (ours): sharded fleets vs one sharded big GP — the crossover.

A fixed observation budget N can be spent two ways on a multi-device mesh:

* ``fleet``      — B independent GPs of n = N/B points each, stacked into a
  :class:`repro.core.gp.GPBatch` whose problem axis B is sharded over the
  mesh's DP axes (DESIGN.md §12).  Pure data parallelism: zero collectives,
  every device runs the same B-invariant fused program over its B/P slice.
* ``single_big`` — ONE GP over all N points, sharded over *tiles* through
  the block-cyclic SPMD pipeline (``core.distributed``): each device owns a
  2-D block-cyclic slice of the O(N^2) covariance and the factorization
  communicates panels every wave.

Small B (few, large problems) favors tile sharding — the fleet path leaves
devices idle once B < P and each problem's O(n^3) dominates.  Large B
(many, small problems) favors the fleet — no collectives, perfect scaling
in B, and the single big GP pays O(N^3) = O((B n)^3) for work that is
semantically block-diagonal.  This figure sweeps B at fixed N and charts
both wall times; the crossover point is the capacity-planning guidance
quoted in DESIGN.md §12.

Run directly (``python -m benchmarks.fig12_sharded_fleet [--smoke]``) or
through ``benchmarks.run`` (payload key ``sharded_fleet``).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench, row


def _grid(nd: int):
    """Closest-to-square (p, q) factorization of the device count."""
    p = max(k for k in range(1, int(np.sqrt(nd)) + 1) if nd % k == 0)
    return p, nd // p


def run(n_total=512, tile=32, bs=(1, 4, 16), d=4, n_test=64, out=print,
        backend="jnp", seed=0):
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.core import distributed as dist, tiling
    from repro.core.gp import GPBatch
    from repro.core.kernels_math import SEKernelParams
    from repro.launch.mesh import make_fleet_mesh

    rng = np.random.default_rng(seed)
    params = SEKernelParams.paper_defaults()
    nd = jax.device_count()
    fleet_mesh = make_fleet_mesh()
    xt = rng.standard_normal((n_test, d)).astype(np.float32)

    # -- the contrast: ONE big GP over all N points, tile-sharded -----------
    p, q = _grid(nd)
    m_tiles = n_total // tile
    if m_tiles % p or m_tiles % q:
        p = q = 1  # grid must divide the tile count; fall back to 1 device
    big_mesh = compat.make_mesh((p, q), ("data", "model"))
    x_big = rng.standard_normal((n_total, d)).astype(np.float32)
    y_big = rng.standard_normal(n_total).astype(np.float32)
    pfn = jax.jit(dist.distributed_gp_predict_fn(
        big_mesh, m_tiles=m_tiles, tile_size=tile, n_valid=n_total,
        n_test_valid=n_test, params=params, variances=False,
    ))
    xc = tiling.pad_features(jnp.asarray(x_big), tile)
    yc = tiling.pad_vector(jnp.asarray(y_big), tile)
    xtc = tiling.pad_features(jnp.asarray(xt), tile)
    t_big, _ = bench(pfn, xc, yc, xtc)
    out(row(
        f"fig12/single_big/N{n_total}", t_big,
        f"devices={nd} grid={p}x{q} m_tiles={m_tiles}",
    ))

    # -- the fleet: B problems of N/B points, B-sharded ---------------------
    results = []
    for b in bs:
        n = n_total // b
        if n < tile:  # below one tile the geometry degenerates
            continue
        x = rng.standard_normal((b, n, d)).astype(np.float32)
        y = rng.standard_normal((b, n)).astype(np.float32)
        batch = GPBatch(
            x, y, params=params, tile_size=min(tile, n),
            op_backend=backend, mesh=fleet_mesh,
        )

        def cold(batch=batch):
            batch.invalidate_cache()
            return batch.predict(xt)

        t_fleet, _ = bench(cold, reps=3)
        speedup = t_big / t_fleet
        out(row(
            f"fig12/fleet/B{b}/n{n}", t_fleet,
            f"devices={nd} dp_shards={min(b, nd)} "
            f"speedup_vs_single_big={speedup:.3f}",
        ))
        results.append({
            "N": n_total,
            "B": b,
            "n_each": n,
            "tile": min(tile, n),
            "devices": nd,
            "grid": [p, q],
            "us_fleet": t_fleet * 1e6,
            "us_single_big": t_big * 1e6,
            "speedup_vs_single_big": speedup,
        })

    # the crossover: smallest B at which the sharded fleet beats the
    # tile-sharded single GP (None when it never does in this sweep)
    cross = next(
        (r["B"] for r in results if r["speedup_vs_single_big"] > 1.0), None
    )
    for r in results:
        r["crossover_B"] = cross
    out(row(f"fig12/crossover/N{n_total}", 0.0, f"crossover_B={cross}"))
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    a = ap.parse_args()
    if a.smoke:
        run(n_total=128, tile=16, bs=(1, 4), n_test=16)
    else:
        run()
