"""Fig. 13 (ours): the kernel zoo through the fused tiled pipeline.

The paper's pipeline is SE-only; DESIGN.md §13 makes the covariance family a
pluggable registry.  The claim this figure backs: swapping kernels changes
*only* the assembly math — every other stage (POTRF/TRSM/GEMM wavefronts,
substitutions, prediction heads) and the executor's Plan cache are reused
bitwise across families.  Per kernel we report:

* packed-assembly wall time (the only stage whose cost varies by family);
* end-to-end fused predict wall time, with the SE baseline's ratio derived;
* Plan-cache misses accumulated while sweeping the zoo — 0 after the first
  kernel at each geometry proves the Plans are kernel-invariant.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench, row
from repro.core import executor
from repro.core import kernels_math as km
from repro.core import predict as pred
from repro.core import tiling


def zoo():
    """(label, kernel, params) cells: every registered family + a composite."""
    cells = []
    # SE first: it is the ratio baseline for every other row
    for name in sorted(km.KERNEL_REGISTRY, key=lambda k: (k != "se", k)):
        kern = km.get_kernel(name)
        cells.append((name, kern, kern.default_params()))
    arbo = km.Sum(km.Scaled(km.Matern52()), km.White())
    cells.append(("arbo_composite", arbo, arbo.default_params()))
    return cells


def run(n=512, n_test=64, tile=64, d=8, out=print, backend="jnp", seed=0):
    import jax

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((n_test, d)).astype(np.float32)
    xc = tiling.pad_features(x, tile)

    results = []
    t_se_pred = None
    plan0 = executor.program_plan.cache_info()
    for name, kern, params in zoo():
        asm = jax.jit(
            lambda c, p=params, k=kern: pred.assemble_packed_covariance(
                c, p, n, backend=backend, kernel=k
            )
        )
        t_asm, _ = bench(asm, xc)

        fn = jax.jit(
            lambda a, b, c, p=params, k=kern: pred.predict(
                a, b, c, p, tile, backend=backend, kernel=k
            )
        )
        t_pred, _ = bench(fn, x, y, xt)
        if name == "se":
            t_se_pred = t_pred
        ratio = t_pred / t_se_pred if t_se_pred else float("nan")
        out(row(
            f"fig13/{name}/n{n}/m{tile}",
            t_pred,
            f"us_assembly={t_asm * 1e6:.1f} vs_se={ratio:.3f}",
        ))
        results.append({
            "kernel": name,
            "kernel_id": kern.kernel_id(),
            "n": n,
            "tile": tile,
            "us_assembly": t_asm * 1e6,
            "us_predict": t_pred * 1e6,
            "predict_vs_se": ratio,
        })
    plan1 = executor.program_plan.cache_info()
    # the whole sweep shares one tile geometry: at most one Plan build total
    plan_misses = plan1.misses - plan0.misses
    out(row(
        f"fig13/plan_reuse/n{n}/m{tile}", 0.0,
        f"plan_misses_across_zoo={plan_misses} kernels={len(results)}",
    ))
    return {"rows": results, "plan_misses_across_zoo": int(plan_misses)}


if __name__ == "__main__":
    run()
