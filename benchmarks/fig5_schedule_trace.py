"""Fig. 5 analogue: schedule occupancy trace.

The paper shows an NVVP timeline of overlapping kernels.  Without a hardware
profiler, the equivalent structural artifact is the level schedule itself:
tasks per level, op mix, and the width/critical-path summary — this is what
bounds the achievable overlap on any backend.
"""

from __future__ import annotations

from benchmarks.common import row
from repro.core import scheduler as sch


def run(m_tiles: int = 16, out=print):
    s = sch.build_schedule(m_tiles)
    counts = s.op_counts()
    out(row(f"fig5/tasks/tiles{m_tiles}", 0.0, f"total={s.n_tasks}"))
    out(row(f"fig5/critical_path/tiles{m_tiles}", 0.0, f"levels={s.critical_path}"))
    out(row(f"fig5/max_width/tiles{m_tiles}", 0.0, f"width={s.max_width()}"))
    out(row(
        f"fig5/op_mix/tiles{m_tiles}", 0.0,
        f"potrf={counts['potrf']};trsm={counts['trsm']};"
        f"syrk={counts['syrk']};gemm={counts['gemm']}",
    ))
    # per-level occupancy (the 'timeline'): level -> number of parallel tasks
    widths = [len(l) for l in s.levels]
    head = ";".join(str(w) for w in widths[:12])
    out(row(f"fig5/level_widths/tiles{m_tiles}", 0.0, f"first12={head}"))
    avg = s.n_tasks / s.critical_path
    out(row(f"fig5/avg_parallelism/tiles{m_tiles}", 0.0, f"avg={avg:.2f}"))


if __name__ == "__main__":
    run()
