"""Fig. 5 analogue: schedule occupancy trace.

The paper shows an NVVP timeline of overlapping kernels.  Without a hardware
profiler, the equivalent structural artifact is the schedule itself: tasks
per level, op mix, width/critical-path summary — plus, since the Schedule is
now the real execution plan, the *executor's* per-level batch counts (which
must match ``Schedule.levels`` exactly) and the wavefront stream-pool
occupancy for finite ``n_streams`` (the static analogue of the paper's
timeline: how full the pool is per wave, and how often a wave co-issues
tasks of different columns).

The fused whole-pipeline program (DESIGN.md §7) gets its own trace: per-wave
op mixes showing substitution rows and cross-covariance assembly co-batched
into the tail of Cholesky columns, plus fused-vs-staged batched-launch
totals.
"""

from __future__ import annotations

from benchmarks.common import row
from repro.core import executor
from repro.core import scheduler as sch


def run(m_tiles: int = 16, out=print):
    s = sch.build_schedule(m_tiles)
    counts = s.op_counts()
    out(row(f"fig5/tasks/tiles{m_tiles}", 0.0, f"total={s.n_tasks}"))
    out(row(f"fig5/critical_path/tiles{m_tiles}", 0.0, f"levels={s.critical_path}"))
    out(row(f"fig5/max_width/tiles{m_tiles}", 0.0, f"width={s.max_width()}"))
    out(row(
        f"fig5/op_mix/tiles{m_tiles}", 0.0,
        f"potrf={counts['potrf']};trsm={counts['trsm']};"
        f"syrk={counts['syrk']};gemm={counts['gemm']}",
    ))
    # per-level occupancy (the 'timeline'): level -> number of parallel tasks
    widths = [len(l) for l in s.levels]
    head = ";".join(str(w) for w in widths[:12])
    out(row(f"fig5/level_widths/tiles{m_tiles}", 0.0, f"first12={head}"))
    avg = s.n_tasks / s.critical_path
    out(row(f"fig5/avg_parallelism/tiles{m_tiles}", 0.0, f"avg={avg:.2f}"))

    # -- executor plan: the schedule as the execution plan ------------------
    plan = executor.cholesky_plan(m_tiles, None)
    match = plan.level_task_counts() == widths
    out(row(
        f"fig5/executor_levels/tiles{m_tiles}", 0.0,
        f"match_schedule={match};levels={len(plan.levels)};batches={plan.n_batches}",
    ))
    assert match, "executor per-level batch counts diverged from Schedule.levels"

    # -- wavefront stream-pool occupancy (finite pools) ---------------------
    for ns in (1, 4, 16):
        wplan = executor.cholesky_plan(m_tiles, ns)
        waves = wplan.level_task_counts()
        occ = sum(waves) / (len(waves) * ns)
        cross = sum(
            1 for lvl in wplan.levels
            if len({t[2] for b in lvl for t in b.tasks}) > 1
        )
        out(row(
            f"fig5/wavefront/tiles{m_tiles}/streams{ns}", 0.0,
            f"waves={len(waves)};occupancy={occ:.3f};"
            f"cross_column_waves={cross};batches={wplan.n_batches}",
        ))

    # -- triangular-solve DAGs (the rest of the pipeline) -------------------
    for kind, lower in (("forward", True), ("backward", False)):
        ss = sch.build_solve_schedule(m_tiles, lower=lower)
        splan = executor.solve_plan(m_tiles, lower=lower, n_streams=None)
        match = splan.level_task_counts() == [len(l) for l in ss.levels]
        out(row(
            f"fig5/solve_{kind}/tiles{m_tiles}", 0.0,
            f"tasks={ss.n_tasks};levels={ss.critical_path};match_schedule={match}",
        ))

    # -- fused whole-pipeline program: the cross-stage wave trace -----------
    # The paper's Fig. 5 timeline shows substitution / cross-covariance
    # kernels overlapping the tail of the factorization; the static analogue
    # is the program wavefront's per-wave op mix.  Waves mixing a Cholesky op
    # with a solve/cross op are exactly the cross-stage overlap.
    chol_ops = {sch.POTRF, sch.TRSM, sch.SYRK, sch.GEMM}
    solve_cross_ops = {
        sch.TRSV, sch.GEMV, sch.TRSV_B, sch.GEMV_B,
        sch.CROSS, sch.VINIT, sch.VTRSV, sch.VGEMV, sch.XGEMV,
    }
    q_tiles = max(m_tiles // 4, 1)
    for ns in (4, 16):
        plan = executor.program_plan(m_tiles, q_tiles, True, ns)
        staged = executor.staged_launch_count(
            m_tiles, uncertainty=True, n_streams=ns
        )
        mixed = 0
        trace = []
        for wi, lvl in enumerate(plan.levels):
            ops = {}
            for b in lvl:
                for t in b.tasks:
                    ops[t[0]] = ops.get(t[0], 0) + 1
            is_mixed = set(ops) & chol_ops and set(ops) & solve_cross_ops
            if is_mixed:
                mixed += 1
                if len(trace) < 8:
                    mix = ",".join(f"{o}:{c}" for o, c in sorted(ops.items()))
                    trace.append(f"wave{wi}[{mix}]")
        out(row(
            f"fig5/program/tiles{m_tiles}/streams{ns}", 0.0,
            f"waves={len(plan.levels)};batches={plan.n_batches};"
            f"staged_batches={staged};cross_stage_waves={mixed}",
        ))
        for tr in trace:
            out(row(f"fig5/program_trace/tiles{m_tiles}/streams{ns}", 0.0, tr))


if __name__ == "__main__":
    run()
