"""Fig. 14 (ours): the Nyström low-rank accuracy/wall-time trade-off.

DESIGN.md §14 adds an O(nm²) ``method="lowrank"`` tier next to the exact
fused pipeline.  The claim this figure backs: on large problems the
low-rank cold path is many times faster than the exact fused predict while
staying close in accuracy, and the gap is a smooth function of the
inducing-set size.  Per (n, m_inducing) cell we report:

* cold-path predict wall time, with speedup vs the exact fused predict;
* test RMSE against the noiseless generating function, with the ratio to
  the exact posterior's RMSE;
* the Woodbury NLML gap (nlml_lowrank − nlml_exact, per point);
* low-rank Plan-cache misses across the m_inducing sweep — the sweep
  changes only the inducing tile count, so misses stay proportional to
  the distinct geometries, never to the number of timed calls.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench, row
from repro.core import executor
from repro.core import lowrank
from repro.core import mll
from repro.core import predict as pred
from repro.core.kernels_math import SEKernelParams


def _dataset(rng, n, n_test, d):
    """Smooth target + observation noise so RMSE is a meaningful axis.

    Low-dimensional by default (d=3): the low-rank tier is the right tool
    when a few hundred inducing points can cover the input space
    (DESIGN.md §14 "when to choose it") — that is the regime this figure
    charts.  Pass a larger d to watch the approximation degrade instead.
    """
    x = rng.uniform(-2.0, 2.0, (n, d)).astype(np.float32)
    xt = rng.uniform(-2.0, 2.0, (n_test, d)).astype(np.float32)

    def f(z):
        return np.sin(z[:, 0]) + 0.5 * np.cos(2.0 * z[:, 1 % d]) + 0.25 * z[:, 2 % d]

    y = (f(x) + 0.1 * rng.standard_normal(n)).astype(np.float32)
    ft = f(xt).astype(np.float32)
    return x, y, xt, ft


def _plan_misses():
    return (
        executor.cholesky_plan.cache_info().misses
        + executor.lowrank_plan.cache_info().misses
        + executor.program_plan.cache_info().misses
    )


def run(
    sizes=(4096, 16384),
    ms=(64, 128, 256, 512),
    n_test=512,
    tile=256,
    d=3,
    out=print,
    backend="jnp",
    seed=0,
    exact_reps=2,
):
    import jax

    rng = np.random.default_rng(seed)
    params = SEKernelParams(lengthscale=0.8, vertical=1.0, noise=0.05)
    results = []
    for n in sizes:
        x, y, xt, ft = _dataset(rng, n, n_test, d)

        # Exact fused baseline: O(n^3) factorization dominates at these n,
        # so time fewer repeats than the low-rank cells.
        exact_fn = jax.jit(lambda a, b, c: pred.predict(a, b, c, params, tile))
        t_exact, _ = bench(exact_fn, x, y, xt, reps=exact_reps)
        mu_exact = np.asarray(exact_fn(x, y, xt))
        rmse_exact = float(np.sqrt(np.mean((mu_exact - ft) ** 2)))
        nlml_exact = float(
            jax.jit(lambda a, b: mll.nlml_tiled(a, b, params, tile_size=tile))(x, y)
        )
        out(row(
            f"fig14/exact/n{n}/m{tile}", t_exact, f"rmse={rmse_exact:.4f}"
        ))

        plan0 = _plan_misses()
        for mi in ms:
            lr_fn = jax.jit(
                lambda a, b, c, mi=mi: lowrank.predict_lowrank(
                    a, b, c, params, mi, tile, backend=backend
                )
            )
            t_lr, _ = bench(lr_fn, x, y, xt)
            mu_lr = np.asarray(lr_fn(x, y, xt))
            rmse_lr = float(np.sqrt(np.mean((mu_lr - ft) ** 2)))
            state = lowrank.lowrank_state(x, y, params, mi, tile, backend=backend)
            nlml_lr = float(lowrank.nlml_from_lowrank_state(state))
            speedup = t_exact / t_lr
            gap = (nlml_lr - nlml_exact) / n
            out(row(
                f"fig14/lowrank/n{n}/mi{mi}",
                t_lr,
                f"speedup_vs_exact={speedup:.2f} rmse={rmse_lr:.4f} "
                f"rmse_vs_exact={rmse_lr / rmse_exact:.3f} "
                f"nlml_gap_per_point={gap:.4f}",
            ))
            results.append({
                "n": n,
                "m_inducing": mi,
                "tile": tile,
                "us_predict": t_lr * 1e6,
                "us_exact": t_exact * 1e6,
                "speedup_vs_exact": speedup,
                "rmse": rmse_lr,
                "rmse_exact": rmse_exact,
                "rmse_vs_exact": rmse_lr / rmse_exact,
                "nlml_per_point": nlml_lr / n,
                "nlml_exact_per_point": nlml_exact / n,
                "nlml_gap_per_point": gap,
            })
        misses = _plan_misses() - plan0
        out(row(
            f"fig14/plan_reuse/n{n}", 0.0,
            f"plan_misses_across_sweep={misses} m_values={len(ms)}",
        ))
    return {"rows": results}


def main() -> None:
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes")
    ap.add_argument(
        "--json",
        default="",
        help="merge a 'lowrank' key into this BENCH_pipeline.json ('' disables)",
    )
    args = ap.parse_args()
    if args.smoke:
        res = run(sizes=(96,), ms=(16, 32), n_test=24, tile=32, d=4)
    else:
        res = run()
    if args.json:
        payload = {}
        if os.path.exists(args.json):
            with open(args.json) as f:
                payload = json.load(f)
        payload["lowrank"] = res
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# merged lowrank into {args.json}")


if __name__ == "__main__":
    main()
