"""Kernel zoo (DESIGN.md §13): every registered family through the tiled pipeline.

The equivalence grid drives every registered kernel through the fused tiled
program on both op backends and pins predict / uncertainty / NLML against the
monolithic dense reference — the same contract the SE-only pipeline always
had, now a property of the registry.  Gradient cells check the autodiff VJP
(the fallback for kernels without a hand-derived dK/dtheta) against float64
central finite differences, and the composite acceptance test runs the
ARBO-style ``C * Matern52 + White`` model end to end: tiled NLML training,
prediction with uncertainty, and a streaming update — while the executor's
``program_plan`` cache stats prove the Plans are kernel-invariant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor
from repro.core import kernels_math as km
from repro.core import mll
from repro.core import predict as pred
from repro.core.gp import GaussianProcess, GPFleet


def _x64():
    return getattr(jax, "enable_x64", None) or jax.experimental.enable_x64


# one cell per registered family, plus composite instances that exercise
# Sum / Product / Scaled over nested params pytrees
def _zoo():
    cells = [(name, km.get_kernel(name)) for name in sorted(km.KERNEL_REGISTRY)]
    cells += [
        ("se_ard2", km.ARDSquaredExponential(ndim=2)),
        ("scaled_m52", km.Scaled(km.Matern52())),
        ("sum_m52_white", km.Sum(km.Scaled(km.Matern52()), km.White())),
        ("prod_se_m32", km.Product(km.SquaredExponential(), km.Matern32())),
    ]
    return cells


def _params_for(name, kern):
    p = kern.default_params()
    if name == "se_ard2":
        # distinct per-dim lengthscales so ARD actually differs from SE
        p = km.ARDKernelParams(lengthscales=jnp.asarray([0.7, 1.6]))
    return p


def _data(n, nh=11, d=2, seed=3):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = np.sin(x.sum(-1)).astype(np.float32) + 0.1 * rng.normal(size=n).astype(
        np.float32
    )
    xt = rng.normal(size=(nh, d)).astype(np.float32)
    return x, y, xt


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize(
    "n,m",
    [(64, 32), pytest.param(200, 64, marks=pytest.mark.slow)],
)
@pytest.mark.parametrize("name,kern", _zoo())
def test_zoo_equivalence_grid(name, kern, n, m, backend):
    """Tiled predict / uncertainty / NLML == monolithic dense, per kernel."""
    x, y, xt = _data(n)
    p = _params_for(name, kern)
    ref_mean, ref_cov = pred.predict_monolithic(
        x, y, xt, p, full_cov=True, kernel=kern
    )
    mean, cov = pred.predict(
        x, y, xt, p, m, full_cov=True, backend=backend, kernel=kern
    )
    np.testing.assert_allclose(mean, ref_mean, rtol=0, atol=5e-4)
    np.testing.assert_allclose(
        jnp.diagonal(cov), jnp.diagonal(ref_cov), rtol=0, atol=5e-3
    )
    ref_nlml = mll.negative_log_marginal_likelihood(x, y, p, kernel=kern)
    tiled = mll.nlml_tiled(x, y, p, tile_size=m, op_backend=backend, kernel=kern)
    # Product has no observation noise (child noise is ignored), so its K is
    # near-singular and tiled-vs-monolithic f32 accumulation orders diverge
    # more; every noised kernel holds the tight tolerance
    rtol = 2e-3 if float(kern.noise(p)) == 0.0 else 3e-4
    np.testing.assert_allclose(tiled, ref_nlml, rtol=rtol, atol=5e-3)


@pytest.mark.parametrize(
    "name,kern",
    [
        ("matern12", km.Matern12()),
        ("matern32", km.Matern32()),
        ("matern52", km.Matern52()),
        ("rq", km.RationalQuadratic()),
        ("se_ard2", km.ARDSquaredExponential(ndim=2)),
        ("sum_m52_white", km.Sum(km.Scaled(km.Matern52()), km.White())),
    ],
)
def test_zoo_autodiff_vjp_matches_finite_differences(name, kern):
    """The autodiff NLML gradient (the non-SE fallback) against f64 FD."""
    with _x64()():
        x, y, _ = _data(48)
        x64 = jnp.asarray(x, jnp.float64)
        y64 = jnp.asarray(y, jnp.float64)
        p = jax.tree_util.tree_map(
            lambda leaf: jnp.asarray(leaf, jnp.float64), _params_for(name, kern)
        )
        f = lambda pp: mll.nlml_tiled(
            x64, y64, pp, tile_size=16, dtype=jnp.float64, kernel=kern
        )
        grads = jax.grad(f)(p)
        leaves, treedef = jax.tree_util.tree_flatten(p)
        glv = jax.tree_util.tree_leaves(grads)
        eps = 1e-6
        for i, leaf in enumerate(leaves):
            leaf = jnp.asarray(leaf, jnp.float64)
            for idx in np.ndindex(*leaf.shape) if leaf.ndim else [()]:
                bump = jnp.zeros_like(leaf).at[idx].set(eps) if leaf.ndim \
                    else jnp.asarray(eps, jnp.float64)
                up = jax.tree_util.tree_unflatten(
                    treedef, leaves[:i] + [leaf + bump] + leaves[i + 1:]
                )
                dn = jax.tree_util.tree_unflatten(
                    treedef, leaves[:i] + [leaf - bump] + leaves[i + 1:]
                )
                fd = (f(up) - f(dn)) / (2 * eps)
                got = glv[i][idx] if leaf.ndim else glv[i]
                np.testing.assert_allclose(got, fd, rtol=5e-4, atol=5e-6)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fleet_ragged_matern32(backend):
    """GPFleet bucketed ragged cell on Matérn 3/2: predict + ragged update."""
    rng = np.random.default_rng(7)
    sizes = (20, 45, 90)
    xs = [rng.normal(size=(n, 2)).astype(np.float32) for n in sizes]
    ys = [rng.normal(size=(n,)).astype(np.float32) for n in sizes]
    xt = rng.normal(size=(6, 2)).astype(np.float32)
    fleet = GPFleet(xs, ys, tile_size=32, op_backend=backend, kernel="matern32")
    mean = fleet.predict(xt)
    for i in range(3):
        ref = pred.predict_monolithic(xs[i], ys[i], xt, fleet.params, kernel="matern32")
        np.testing.assert_allclose(mean[i], ref, rtol=0, atol=5e-4)
    counts = (4, 3, 2)
    xa = [rng.normal(size=(c, 2)).astype(np.float32) for c in counts]
    ya = [rng.normal(size=(c,)).astype(np.float32) for c in counts]
    fleet.update(xa, ya)
    mean2 = fleet.predict(xt)
    for i in range(3):
        ref = pred.predict_monolithic(
            fleet._xs[i], fleet._ys[i], xt, fleet.params, kernel="matern32"
        )
        np.testing.assert_allclose(mean2[i], ref, rtol=0, atol=5e-4)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_composite_workload_acceptance(backend):
    """ARBO-style ``C * Matern52 + White``: train, predict, stream updates.

    Also pins the Plan-reuse contract: running a *different* kernel family
    through the same tile geometry must add zero ``program_plan`` cache
    misses (Plans are kernel-invariant; only jit entries are per-kernel).
    """
    kern = km.Sum(km.Scaled(km.Matern52()), km.White())
    rng = np.random.default_rng(11)
    x = rng.normal(size=(70, 2)).astype(np.float32)
    y = np.sin(x.sum(-1)).astype(np.float32)
    xt = rng.normal(size=(9, 2)).astype(np.float32)
    m = 32

    # train through the tiled NLML (autodiff fallback — no analytic VJP)
    p0 = kern.default_params()
    p, losses = mll.optimize_hyperparameters(
        x, y, p0, steps=5, lr=0.05, method="tiled", tile_size=m,
        op_backend=backend, kernel=kern,
    )
    assert np.isfinite(np.asarray(losses)).all()
    assert losses[-1] <= losses[0]

    gp = GaussianProcess(
        x, y, params=p, tile_size=m, op_backend=backend, kernel=kern
    )
    mean, var = gp.predict_with_uncertainty(xt)
    ref_mean, ref_cov = pred.predict_monolithic(
        x, y, xt, p, full_cov=True, kernel=kern
    )
    np.testing.assert_allclose(mean, ref_mean, rtol=0, atol=5e-4)
    np.testing.assert_allclose(var, jnp.diagonal(ref_cov), rtol=0, atol=5e-3)

    # plan reuse: a different family through the same geometry — no new plans
    before = executor.program_plan.cache_info()
    gp_se = GaussianProcess(x, y, tile_size=m, op_backend=backend, kernel="se")
    gp_se.predict_with_uncertainty(xt)
    after = executor.program_plan.cache_info()
    assert after.misses == before.misses, "Plans must stay kernel-invariant"

    # streaming update: absorb observations, match the grown dense reference
    xn = rng.normal(size=(12, 2)).astype(np.float32)
    yn = np.sin(xn.sum(-1)).astype(np.float32)
    gp.update(xn, yn)
    mean2 = gp.predict(xt)
    ref2 = pred.predict_monolithic(
        np.vstack([x, xn]), np.concatenate([y, yn]), xt, p, kernel=kern
    )
    np.testing.assert_allclose(mean2, ref2, rtol=0, atol=5e-4)


def test_kernel_registry_contract():
    """Registry lookups, hashability, ids, and resolve_kernel round-trips."""
    assert isinstance(km.resolve_kernel(None), km.SquaredExponential)
    assert km.resolve_kernel("matern32") == km.get_kernel("matern32")
    k = km.Sum(km.Scaled(km.Matern52()), km.White())
    assert km.resolve_kernel(k) is k
    assert hash(k) == hash(km.Sum(km.Scaled(km.Matern52()), km.White()))
    assert k.kernel_id() == "sum(scaled(matern52),white)"
    with pytest.raises(KeyError):
        km.get_kernel("not-a-kernel")
    # params utilities are tree_maps: ARD leaves keep their base axis
    ard = km.ARDSquaredExponential(ndim=3)
    p = km.ARDKernelParams(lengthscales=jnp.asarray([1.0, 2.0, 3.0]))
    bp = km.broadcast_params(p, 4, ard)
    assert bp.lengthscales.shape == (4, 3)
    assert bp.noise.shape == (4,)
    gp = km.gather_params(bp, jnp.asarray([2, 0]), ard)
    assert gp.lengthscales.shape == (2, 3)
    np.testing.assert_allclose(gp.lengthscales[1], p.lengthscales)
