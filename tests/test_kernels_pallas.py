"""Per-kernel validation: Pallas (interpret mode) vs ref.py oracles,
swept over shapes and dtypes as required for every kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_math import SEKernelParams
from repro.kernels import ops, ref
from repro.kernels.cov_assembly import cov_tiles
from repro.kernels.trailing_update import trailing_update
from repro.kernels.trsm_tile import trsm_batched


def _spd(rng, n, dtype=np.float32):
    a = rng.standard_normal((n, n)).astype(dtype)
    return a @ a.T + n * np.eye(n, dtype=dtype)


# ---------------------------------------------------------------------------
# POTRF tile kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [8, 16, 64, 128])
def test_potrf_shapes(rng, m):
    k = _spd(rng, m)
    out = np.asarray(ops.potrf(jnp.asarray(k)))
    want = np.asarray(ref.ref_potrf(jnp.asarray(k)))
    np.testing.assert_allclose(out, want, atol=1e-4 * m)
    assert np.allclose(np.triu(out, 1), 0.0)


def test_potrf_f64(rng):
    enable_x64 = getattr(jax, "enable_x64", None) or jax.experimental.enable_x64
    with enable_x64():
        k = _spd(rng, 32, np.float64)
        out = np.asarray(ops.potrf(jnp.asarray(k)))
        np.testing.assert_allclose(out, np.linalg.cholesky(k), atol=1e-10)


# ---------------------------------------------------------------------------
# TRSM tile kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [8, 32, 128])
def test_trsm_shapes(rng, m):
    l = np.linalg.cholesky(_spd(rng, m)).astype(np.float32)
    b = rng.standard_normal((m, m)).astype(np.float32)
    out = np.asarray(ops.trsm(jnp.asarray(l), jnp.asarray(b)))
    want = np.asarray(ref.ref_trsm(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(out, want, atol=1e-3)


@pytest.mark.parametrize("batch", [1, 3, 7])
def test_trsm_panel_batched(rng, batch):
    m = 16
    l = np.linalg.cholesky(_spd(rng, m)).astype(np.float32)
    b = rng.standard_normal((batch, m, m)).astype(np.float32)
    out = np.asarray(trsm_batched(jnp.asarray(l), jnp.asarray(b), interpret=True))
    for i in range(batch):
        want = np.asarray(ref.ref_trsm(jnp.asarray(l), jnp.asarray(b[i])))
        np.testing.assert_allclose(out[i], want, atol=1e-3)


# ---------------------------------------------------------------------------
# Trailing-update kernel (batched SYRK/GEMM)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,block", [(16, 16), (64, 32), (128, 128), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_trailing_update_blocks(rng, m, block, dtype):
    bsz = 3
    c = jnp.asarray(rng.standard_normal((bsz, m, m)), dtype)
    a = jnp.asarray(rng.standard_normal((bsz, m, m)), dtype)
    b = jnp.asarray(rng.standard_normal((bsz, m, m)), dtype)
    out = np.asarray(trailing_update(c, a, b, block=block, interpret=True), np.float32)
    want = np.asarray(ref.ref_trailing_update(c, a, b), np.float32)
    tol = 1e-3 * m if dtype == jnp.float32 else 0.3 * np.sqrt(m)
    np.testing.assert_allclose(out, want, atol=tol)


def test_syrk_uses_same_kernel(rng):
    m = 32
    kii = jnp.asarray(_spd(rng, m))
    lij = jnp.asarray(rng.standard_normal((m, m)).astype(np.float32))
    out = np.asarray(ops.syrk(kii, lij))
    np.testing.assert_allclose(out, np.asarray(kii) - np.asarray(lij) @ np.asarray(lij).T, atol=1e-3)


# ---------------------------------------------------------------------------
# Covariance assembly kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,d", [(8, 1), (16, 4), (32, 16), (128, 8)])
def test_cov_tiles_shapes(rng, m, d):
    t = 4
    xa = rng.standard_normal((t, m, d)).astype(np.float32)
    xb = rng.standard_normal((t, m, d)).astype(np.float32)
    row0 = np.arange(t, dtype=np.int32) * m
    col0 = np.zeros(t, dtype=np.int32)
    out = cov_tiles(
        jnp.asarray(xa), jnp.asarray(xb), jnp.asarray(row0), jnp.asarray(col0),
        lengthscale=1.0, vertical=1.0, noise=0.1,
        n_valid_r=t * m, n_valid_c=t * m, symmetric=True, interpret=True,
    )
    for i in range(t):
        want = ref.ref_cov_tile(
            jnp.asarray(xa[i]), jnp.asarray(xb[i]), int(row0[i]), int(col0[i]),
            lengthscale=1.0, vertical=1.0, noise=0.1,
            n_valid_r=t * m, n_valid_c=t * m, symmetric=True,
        )
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want), atol=1e-5)


def test_cov_tiles_padding_and_diagonal(rng):
    """Padded region -> identity; diagonal carries the noise term."""
    m, d, n_valid = 16, 3, 24   # second tile is half padding
    x = np.zeros((2, m, d), np.float32)
    x[0] = rng.standard_normal((m, d))
    x[1, : n_valid - m] = rng.standard_normal((n_valid - m, d))
    out = np.asarray(cov_tiles(
        jnp.asarray(x), jnp.asarray(x),
        jnp.asarray([0, m], jnp.int32), jnp.asarray([0, m], jnp.int32),
        lengthscale=1.0, vertical=1.0, noise=0.1,
        n_valid_r=n_valid, n_valid_c=n_valid, symmetric=True, interpret=True,
    ))
    # tile 1: rows/cols beyond n_valid are identity
    pad = out[1][n_valid - m :, n_valid - m :]
    np.testing.assert_allclose(pad, np.eye(m - (n_valid - m)), atol=1e-6)
    # diagonal noise: k(x,x) = v + sigma^2
    np.testing.assert_allclose(np.diagonal(out[0]), 1.1, atol=1e-5)


def test_assembled_covariance_matches_jnp_path(rng):
    from repro.core import predict as pred
    from repro.core import tiling

    x = rng.standard_normal((50, 4)).astype(np.float32)
    xc = tiling.pad_features(jnp.asarray(x), 16)
    p = SEKernelParams.paper_defaults()
    a = np.asarray(ops.assemble_packed_covariance(xc, p, 50))
    b = np.asarray(pred.assemble_packed_covariance(xc, p, 50, backend="jnp"))
    np.testing.assert_allclose(a, b, atol=1e-5)
