"""Tiled Nyström low-rank tier (DESIGN.md §14).

Core invariants: (a) with m_inducing = n the DTC posterior equals the exact
GP up to the K_uu jitter; (b) predictive variances are never negative; (c)
the batched/fleet paths match a per-problem Python loop while adding ZERO
executor Plan-cache misses as B varies; (d) streaming absorb/forget through
the rank-m inner system matches a cold rebuild; (e) the Woodbury NLML trains
end-to-end on both op backends; (f) the serving loop batches low-rank
buckets with the same wave-ordering/masking contract as the exact tier.
"""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GaussianProcess, GPBatch, GPFleet
from repro.core import executor, lowrank, mll
from repro.core.kernels_math import SEKernelParams

M = 16
PARAMS = SEKernelParams(lengthscale=0.7, vertical=1.2, noise=0.05)


def _x64():
    return getattr(jax, "enable_x64", None) or jax.experimental.enable_x64


def _data(rng, n, d=2, nt=7):
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = (np.sin(x[:, 0]) + 0.1 * rng.standard_normal(n)).astype(np.float32)
    xt = rng.standard_normal((nt, d)).astype(np.float32)
    return x, y, xt


def _plan_misses():
    return tuple(
        c.cache_info().misses
        for c in (executor.cholesky_plan, executor.lowrank_plan, executor.program_plan)
    )


# ---------------------------------------------------------------------------
# Exactness / positivity / padding.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [48, 57])  # exact tile multiple and odd n
def test_lowrank_full_rank_matches_exact(rng, n):
    """m_inducing = n (u = x): DTC == exact GP up to the K_uu jitter."""
    x, y, xt = _data(rng, n)
    g_lr = GaussianProcess(
        x, y, params=PARAMS, tile_size=M,
        method="lowrank", m_inducing=n, inducing=x,
    )
    g_ex = GaussianProcess(x, y, params=PARAMS, tile_size=M)
    m_lr, c_lr = g_lr.predict_full_cov(xt)
    m_ex, c_ex = g_ex.predict_full_cov(xt)
    np.testing.assert_allclose(np.asarray(m_lr), np.asarray(m_ex), atol=2e-2)
    np.testing.assert_allclose(np.asarray(c_lr), np.asarray(c_ex), atol=2e-2)
    # NLML via Woodbury agrees with the exact tiled NLML
    np.testing.assert_allclose(
        float(g_lr.nlml()), float(g_ex.nlml()), rtol=2e-2
    )


def test_lowrank_variance_nonnegative_and_rmse_reasonable(rng):
    x, y, xt = _data(rng, 120, nt=21)
    g = GaussianProcess(
        x, y, params=PARAMS, tile_size=M, method="lowrank", m_inducing=32
    )
    mean, var = g.predict_with_uncertainty(xt)
    assert np.all(np.asarray(var) >= 0.0)
    ex = GaussianProcess(x, y, params=PARAMS, tile_size=M)
    rmse = float(jnp.sqrt(jnp.mean((mean - ex.predict(xt)) ** 2)))
    assert np.isfinite(rmse) and rmse < 0.5


@pytest.mark.parametrize("strategy", ["subset", "kmeans-lite"])
def test_inducing_strategies(rng, strategy):
    x, y, xt = _data(rng, 90)
    g = GaussianProcess(
        x, y, params=PARAMS, tile_size=M,
        method="lowrank", m_inducing=24, strategy=strategy,
    )
    mean, cov = g.predict_full_cov(xt)
    assert np.isfinite(np.asarray(mean)).all()
    assert np.all(np.diagonal(np.asarray(cov)) >= 0.0)
    assert np.isfinite(float(g.nlml()))


def test_method_validation():
    x = np.zeros((4, 1), np.float32)
    y = np.zeros(4, np.float32)
    with pytest.raises(ValueError, match="method"):
        GaussianProcess(x, y, method="nope")
    with pytest.raises(ValueError, match="m_inducing"):
        GaussianProcess(x, y, method="lowrank")
    with pytest.raises(ValueError, match="m_inducing"):
        GPBatch(x[None], y[None], method="lowrank")
    with pytest.raises(ValueError, match="m_inducing"):
        GPFleet([x], [y], method="lowrank")
    with pytest.raises(ValueError, match="inducing"):
        lowrank.select_inducing(jnp.asarray(x), 8, inducing=jnp.zeros((5, 1)))
    with pytest.raises(ValueError, match="strategy"):
        lowrank.select_inducing(jnp.asarray(x), 2, strategy="bogus")


def test_pallas_backend_parity(rng):
    x, y, xt = _data(rng, 64)
    outs = {}
    for backend in ("jnp", "pallas"):
        g = GaussianProcess(
            x, y, params=PARAMS, tile_size=M,
            method="lowrank", m_inducing=M, op_backend=backend,
        )
        outs[backend] = g.predict_full_cov(xt)
    np.testing.assert_allclose(
        np.asarray(outs["jnp"][0]), np.asarray(outs["pallas"][0]), atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(outs["jnp"][1]), np.asarray(outs["pallas"][1]), atol=2e-3
    )


# ---------------------------------------------------------------------------
# Streaming absorb / forget (the rank-m fast path; never O(n^3)).
# ---------------------------------------------------------------------------


def test_update_absorbs_warm_and_matches_cold_rebuild(rng):
    x, y, xt = _data(rng, 70)
    xb, yb, _ = _data(rng, 9)
    u = x[:24]  # pinned inducing set so warm and cold are the same model
    g = GaussianProcess(
        x, y, params=PARAMS, tile_size=M,
        method="lowrank", m_inducing=24, inducing=u,
    )
    g.predict(xt)  # warm the cache
    g.update(xb, yb)
    assert g._lowrank_warm(), "update must keep the low-rank cache warm"
    cold = GaussianProcess(
        np.concatenate([x, xb]), np.concatenate([y, yb]),
        params=PARAMS, tile_size=M, method="lowrank", m_inducing=24, inducing=u,
    )
    np.testing.assert_allclose(
        np.asarray(g.predict(xt)), np.asarray(cold.predict(xt)), atol=2e-3
    )
    np.testing.assert_allclose(float(g.nlml()), float(cold.nlml()), rtol=1e-3)


def test_forget_downdates_warm_any_k(rng):
    """sign=-1 absorb needs NO tile alignment — any k stays on the fast path."""
    x, y, xt = _data(rng, 80)
    u = x[40:64]
    g = GaussianProcess(
        x, y, params=PARAMS, tile_size=M,
        method="lowrank", m_inducing=24, inducing=u,
    )
    g.predict(xt)
    g.forget(13)  # deliberately NOT a multiple of tile_size
    assert g._lowrank_warm()
    cold = GaussianProcess(
        x[13:], y[13:], params=PARAMS, tile_size=M,
        method="lowrank", m_inducing=24, inducing=u,
    )
    np.testing.assert_allclose(
        np.asarray(g.predict(xt)), np.asarray(cold.predict(xt)), atol=5e-3
    )


def test_sliding_window_evicts_exact_count(rng):
    x, y, xt = _data(rng, 60)
    u = x[:16]
    g = GaussianProcess(
        x, y, params=PARAMS, tile_size=M, sliding_window=60,
        method="lowrank", m_inducing=16, inducing=u,
    )
    g.predict(xt)
    xb, yb, _ = _data(rng, 10)
    g.update(xb, yb)
    assert g.x_train.shape[0] == 60  # exact eviction, no tile rounding
    assert g._lowrank_warm()
    assert np.isfinite(float(g.nlml()))


# ---------------------------------------------------------------------------
# Batched / fleet equivalence + Plan-cache invariance across B.
# ---------------------------------------------------------------------------


def test_gpbatch_matches_per_problem_loop_f64(rng):
    """float64 pins the loop equivalence to 1e-5 (f32 einsum-order roundoff
    would dominate otherwise); also: growing B adds ZERO Plan-cache misses."""
    with _x64()():
        B, n, mi = 3, 64, 32
        x = rng.standard_normal((B, n, 2))
        y = rng.standard_normal((B, n))
        xt = rng.standard_normal((B, 5, 2))
        kw = dict(
            params=PARAMS, tile_size=M, method="lowrank", m_inducing=mi,
            jitter=1e-10, dtype=jnp.float64,
        )
        gb = GPBatch(x, y, **kw)
        mean, cov = gb.predict_full_cov(xt)
        nlml = np.asarray(gb.nlml())
        misses0 = _plan_misses()
        for i in range(B):
            gi = GaussianProcess(x[i], y[i], **kw)
            mi_, ci_ = gi.predict_full_cov(xt[i])
            np.testing.assert_allclose(
                np.asarray(mean[i]), np.asarray(mi_), atol=1e-5
            )
            np.testing.assert_allclose(
                np.asarray(cov[i]), np.asarray(ci_), atol=1e-5
            )
            np.testing.assert_allclose(nlml[i], float(gi.nlml()), rtol=1e-8)
        # doubling B reuses every executor Plan (geometry-keyed, B-invariant)
        misses1 = _plan_misses()
        x2, y2 = np.concatenate([x, x]), np.concatenate([y, y])
        gb2 = GPBatch(x2, y2, **kw)
        gb2.predict_full_cov(np.concatenate([xt, xt]))
        gb2.nlml()
        assert _plan_misses() == misses1, "growing B must not re-plan"
        del misses0


def test_gpbatch_update_forget_warm(rng):
    B, n = 3, 48
    x = rng.standard_normal((B, n, 2)).astype(np.float32)
    y = rng.standard_normal((B, n)).astype(np.float32)
    xt = rng.standard_normal((B, 4, 2)).astype(np.float32)
    u = x[:, :16]
    gb = GPBatch(
        x, y, params=PARAMS, tile_size=M,
        method="lowrank", m_inducing=16, inducing=u,
    )
    gb.predict(xt)
    xb = rng.standard_normal((B, 6, 2)).astype(np.float32)
    yb = rng.standard_normal((B, 6)).astype(np.float32)
    gb.update(xb, yb)
    assert gb._lowrank_warm()
    cold = GPBatch(
        np.concatenate([x, xb], 1), np.concatenate([y, yb], 1),
        params=PARAMS, tile_size=M, method="lowrank", m_inducing=16, inducing=u,
    )
    np.testing.assert_allclose(
        np.asarray(gb.predict(xt)), np.asarray(cold.predict(xt)), atol=2e-3
    )
    gb.forget(6)
    assert gb._lowrank_warm()
    np.testing.assert_allclose(
        np.asarray(gb.predict(xt)),
        np.asarray(GPBatch(
            np.concatenate([x[:, 6:], xb], 1), np.concatenate([y[:, 6:], yb], 1),
            params=PARAMS, tile_size=M,
            method="lowrank", m_inducing=16, inducing=u,
        ).predict(xt)),
        atol=5e-3,
    )


def test_gpfleet_lowrank_matches_per_problem_loop(rng):
    sizes = (30, 45, 70, 100)
    xs = [rng.standard_normal((n, 2)).astype(np.float32) for n in sizes]
    ys = [rng.standard_normal(n).astype(np.float32) for n in sizes]
    xt = rng.standard_normal((6, 2)).astype(np.float32)
    fl = GPFleet(xs, ys, params=PARAMS, tile_size=M, method="lowrank", m_inducing=16)
    mean, cov = fl.predict_full_cov(xt)
    nlml = np.asarray(fl.nlml())
    for i, n in enumerate(sizes):
        gi = GaussianProcess(
            xs[i], ys[i], params=PARAMS, tile_size=M,
            method="lowrank", m_inducing=16,
        )
        mu_i, cov_i = gi.predict_full_cov(xt)
        np.testing.assert_allclose(np.asarray(mean[i]), np.asarray(mu_i), atol=3e-4)
        np.testing.assert_allclose(np.asarray(cov[i]), np.asarray(cov_i), atol=3e-4)
        np.testing.assert_allclose(nlml[i], float(gi.nlml()), rtol=2e-5)
    # ragged per-problem test sets slice back through nt_valid masking
    tests = [rng.standard_normal((k, 2)).astype(np.float32) for k in (3, 0, 5, 2)]
    outs = fl.predict_each(tests)
    for i, out in enumerate(outs):
        assert out.shape == (tests[i].shape[0],)
        if tests[i].shape[0]:
            ref = GaussianProcess(
                xs[i], ys[i], params=PARAMS, tile_size=M,
                method="lowrank", m_inducing=16,
            ).predict(tests[i])
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4)


def test_gpfleet_lowrank_migration_is_a_row_gather(rng):
    """A problem outgrowing its bucket transfers by pure row gather (the
    low-rank state is mu-sized) and absorbs warm — no re-factorization."""
    sizes = (30, 45, 70, 100)
    xs = [rng.standard_normal((n, 2)).astype(np.float32) for n in sizes]
    ys = [rng.standard_normal(n).astype(np.float32) for n in sizes]
    xt = rng.standard_normal((5, 2)).astype(np.float32)
    u = rng.standard_normal((16, 2)).astype(np.float32)  # shared, pinned
    fl = GPFleet(
        xs, ys, params=PARAMS, tile_size=M,
        method="lowrank", m_inducing=16, inducing=u,
    )
    fl.predict(xt)  # warm every bucket
    arr_x = [rng.standard_normal((k, 2)).astype(np.float32) for k in (40, 0, 4, 10)]
    arr_y = [rng.standard_normal(k).astype(np.float32) for k in (40, 0, 4, 10)]
    assign_before = fl.bucket_assignment()
    fl.update(arr_x, arr_y)
    assert fl.bucket_assignment() != assign_before  # problem 0 migrated
    # every destination bucket stayed warm through the migration
    for cap, rec in fl._buckets.items():
        assert rec.state is not None, f"bucket {cap} went cold"
    cold = GPFleet(
        [np.concatenate([xs[i], arr_x[i]]) for i in range(4)],
        [np.concatenate([ys[i], arr_y[i]]) for i in range(4)],
        params=PARAMS, tile_size=M, method="lowrank", m_inducing=16, inducing=u,
    )
    np.testing.assert_allclose(
        np.asarray(fl.predict(xt)), np.asarray(cold.predict(xt)), atol=3e-3
    )
    np.testing.assert_allclose(
        np.asarray(fl.nlml()), np.asarray(cold.nlml()), rtol=1e-3
    )


# ---------------------------------------------------------------------------
# Training (Woodbury NLML through adam_scan; both backends).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_lowrank_training_improves(rng, backend):
    n = 64
    x = rng.uniform(-3, 3, (n, 1)).astype(np.float32)
    y = (np.sin(1.5 * x[:, 0]) + 0.1 * rng.standard_normal(n)).astype(np.float32)
    _, losses = mll.optimize_hyperparameters(
        jnp.asarray(x), jnp.asarray(y), SEKernelParams.paper_defaults(),
        steps=10, lr=0.05, method="lowrank",
        m_inducing=24, tile_size=M, op_backend=backend,
    )
    losses = np.asarray(losses)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_gp_optimize_routes_lowrank(rng):
    n = 64
    x = rng.uniform(-3, 3, (n, 1)).astype(np.float32)
    y = (np.sin(1.5 * x[:, 0]) + 0.1 * rng.standard_normal(n)).astype(np.float32)
    g = GaussianProcess(x, y, tile_size=M, method="lowrank", m_inducing=24)
    before = float(g.nlml())
    g.optimize(steps=10, lr=0.05)
    assert float(g.nlml()) < before


def test_gpbatch_optimize_lowrank(rng):
    B, n = 3, 48
    x = rng.uniform(-3, 3, (B, n, 1)).astype(np.float32)
    y = (np.sin(1.5 * x[..., 0]) + 0.1 * rng.standard_normal((B, n))).astype(
        np.float32
    )
    gb = GPBatch(x, y, tile_size=M, method="lowrank", m_inducing=16)
    before = np.asarray(gb.nlml())
    gb.optimize(steps=8, lr=0.05)
    after = np.asarray(gb.nlml())
    assert np.isfinite(after).all()
    assert (after < before).all()


def test_lowrank_custom_vjp_matches_autodiff(rng):
    n = 56
    x = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    raw = mll._pack(PARAMS)
    kw = dict(m_inducing=16, tile_size=M)
    g_c = np.asarray(jax.grad(
        lambda r: mll.nlml_lowrank(x, y, mll._unpack(r), vjp="custom", **kw)
    )(raw))
    g_a = np.asarray(jax.grad(
        lambda r: mll.nlml_lowrank(x, y, mll._unpack(r), vjp="autodiff", **kw)
    )(raw))
    np.testing.assert_allclose(g_c, g_a, rtol=2e-2, atol=2e-2 * np.abs(g_a).max())


def test_lowrank_ragged_batched_nlml_matches_loop(rng):
    """Zero-padded ragged problems through ONE batched low-rank build give
    per-problem NLMLs equal to the single-problem loop."""
    sizes = (40, 64)
    cap = 64
    xs = [rng.standard_normal((n, 2)).astype(np.float32) for n in sizes]
    ys = [rng.standard_normal(n).astype(np.float32) for n in sizes]
    x = jnp.stack([jnp.pad(jnp.asarray(x), ((0, cap - x.shape[0]), (0, 0)))
                   for x in xs])
    y = jnp.stack([jnp.pad(jnp.asarray(y), (0, cap - y.shape[0])) for y in ys])
    nv = jnp.asarray(sizes, jnp.int32)
    vals = mll.nlml_lowrank_batched(
        x, y, PARAMS, m_inducing=16, tile_size=M, n_valid=nv
    )
    for i, n in enumerate(sizes):
        ref = mll.nlml_lowrank(
            jnp.asarray(xs[i]), jnp.asarray(ys[i]), PARAMS,
            m_inducing=16, tile_size=M, vjp="autodiff",
        )
        np.testing.assert_allclose(float(vals[i]), float(ref), rtol=2e-3)


# ---------------------------------------------------------------------------
# Serving: continuous batching over a low-rank fleet (DESIGN.md §11 + §14).
# ---------------------------------------------------------------------------


def test_continuous_batcher_lowrank_bucket(rng):
    """The serving loop drives low-rank buckets with the exact tier's
    contract: observes land before predicts inside a wave, per-request rows
    slice back out of the shared nt_valid-masked launch, and post-update
    predictions equal a cold GP on the grown problem."""
    from repro.serve import ContinuousBatcher

    sizes = (40, 60)
    xs = [rng.standard_normal((n, 2)).astype(np.float32) for n in sizes]
    ys = [rng.standard_normal(n).astype(np.float32) for n in sizes]
    u = rng.standard_normal((16, 2)).astype(np.float32)
    kw = dict(
        params=PARAMS, tile_size=M, method="lowrank", m_inducing=16, inducing=u
    )
    fleet = GPFleet(xs, ys, **kw)
    ticks = iter(range(1000))
    srv = ContinuousBatcher(fleet, clock=lambda: float(next(ticks)))

    xt = rng.standard_normal((4, 2)).astype(np.float32)
    r1 = srv.submit_predict(0, xt)
    r2 = srv.submit_predict(0, xt[:2], uncertainty=True)
    xo = rng.standard_normal((30, 2)).astype(np.float32)
    yo = rng.standard_normal(30).astype(np.float32)
    r3 = srv.submit_observe(1, xo, yo)
    stats = srv.step()
    assert (stats.n_predict, stats.n_observe, stats.points_absorbed) == (2, 1, 30)
    assert stats.migrations == 1  # 60 + 30 crosses the cap-4 boundary at 64

    # wave-ordering + masking identical to the exact tier: both problem-0
    # requests share one launch and slice their own rows back out
    g0 = GaussianProcess(xs[0], ys[0], **kw)
    np.testing.assert_allclose(srv.result(r1), np.asarray(g0.predict(xt)), atol=3e-4)
    m2, v2 = srv.result(r2)
    np.testing.assert_allclose(m2, np.asarray(g0.predict(xt[:2])), atol=3e-4)
    assert (v2 >= 0).all()
    assert srv.result(r3) == 30

    # the post-update state answers like a fresh low-rank GP (same pinned u)
    rid = srv.submit_predict(1, xt)
    srv.run_until_idle()
    g1 = GaussianProcess(
        np.concatenate([xs[1], xo]), np.concatenate([ys[1], yo]), **kw
    )
    np.testing.assert_allclose(srv.result(rid), np.asarray(g1.predict(xt)), atol=3e-3)
