"""Tile layouts: pack/unpack, memory-saving claim, cyclic layout."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiling
from repro.core.distributed import from_cyclic_layout, to_cyclic_layout


def test_pack_unpack_roundtrip(rng):
    n, m = 48, 8
    a = rng.standard_normal((n, n)).astype(np.float32)
    a = a + a.T
    packed = tiling.pack_lower(jnp.asarray(a), m)
    assert packed.shape == (tiling.num_packed_tiles(n // m), m, m)
    back = tiling.unpack_lower(packed, fill="symmetric")
    np.testing.assert_allclose(np.asarray(back), a, rtol=1e-6)


def test_unpack_lower_zeroes_upper(rng):
    n, m = 32, 8
    a = rng.standard_normal((n, n)).astype(np.float32)
    a = a @ a.T + n * np.eye(n, dtype=np.float32)
    l_ref = np.linalg.cholesky(a)
    packed = tiling.pack_lower(jnp.asarray(np.tril(l_ref) + np.triu(np.ones_like(a), 1)), m)
    out = np.asarray(tiling.unpack_lower(packed, fill="lower"))
    assert np.allclose(np.triu(out, 1), 0.0)


@pytest.mark.parametrize("m_tiles", [2, 4, 8, 32])
def test_memory_saving_claim(m_tiles):
    """Paper §4.2: packed storage needs 50-75 % of the dense matrix."""
    m = 16
    n = m_tiles * m
    ratio = tiling.packed_bytes(m_tiles, m) / tiling.dense_bytes(n)
    assert 0.5 < ratio <= 0.75
    assert ratio == pytest.approx((m_tiles + 1) / (2 * m_tiles))


def test_packed_index_column_slices():
    m_tiles = 6
    seen = set()
    for j in range(m_tiles):
        lo, hi = tiling.column_slice(j, m_tiles)
        idxs = list(range(lo, hi))
        assert idxs[0] == tiling.packed_index(j, j, m_tiles)
        for off, i in enumerate(range(j, m_tiles)):
            assert tiling.packed_index(i, j, m_tiles) == lo + off
        seen.update(idxs)
    assert seen == set(range(tiling.num_packed_tiles(m_tiles)))


def test_cyclic_layout_roundtrip(rng):
    m_tiles, m, p, q = 8, 4, 4, 2
    tiles = jnp.asarray(rng.standard_normal((m_tiles, m_tiles, m, m)).astype(np.float32))
    cyc = to_cyclic_layout(tiles, p, q)
    back = from_cyclic_layout(cyc, p, q)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(tiles))


def test_tile_vector_roundtrip(rng):
    v = rng.standard_normal(64).astype(np.float32)
    chunks = tiling.tile_vector(jnp.asarray(v), 16)
    assert chunks.shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(tiling.untile_vector(chunks)), v)
