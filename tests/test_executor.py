"""Schedule-driven executor: equivalence vs monolithic references, plan
structure (batch counts match Schedule.levels), and GP factor caching."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GaussianProcess, SEKernelParams
from repro.core import cholesky as chol
from repro.core import executor, tiling, triangular
from repro.core import predict as pred
from repro.core import scheduler as sch


def _spd(rng, n, dtype=np.float32):
    a = rng.standard_normal((n, n)).astype(dtype)
    return a @ a.T + n * np.eye(n, dtype=dtype)


# ---------------------------------------------------------------------------
# Cholesky equivalence: executor vs monolithic reference.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_streams", [None, 1, 2])
@pytest.mark.parametrize("n,m", [(64, 16), (200, 40), (512, 128)])
def test_executor_cholesky_matches_monolithic(rng, n, m, n_streams):
    k = _spd(rng, n)
    l_e = np.asarray(
        chol.cholesky_dense_via_tiles(jnp.asarray(k), m, n_streams=n_streams)
    )
    l_m = np.asarray(chol.monolithic_cholesky(jnp.asarray(k)))
    np.testing.assert_allclose(l_e, l_m, atol=2e-3)


@pytest.mark.parametrize("n_streams", [None, 2])
def test_executor_pallas_backend(rng, n_streams):
    k = _spd(rng, 64)
    l_p = np.asarray(
        chol.cholesky_dense_via_tiles(
            jnp.asarray(k), 16, backend="pallas", n_streams=n_streams
        )
    )
    l_m = np.asarray(chol.monolithic_cholesky(jnp.asarray(k)))
    np.testing.assert_allclose(l_p, l_m, atol=1e-3)


def test_executor_mixed_precision(rng):
    k = _spd(rng, 64)
    l32 = np.asarray(chol.cholesky_dense_via_tiles(jnp.asarray(k), 16))
    lmp = np.asarray(
        chol.cholesky_dense_via_tiles(jnp.asarray(k), 16, update_dtype=jnp.bfloat16)
    )
    assert np.abs(lmp - l32).max() / np.abs(l32).max() < 0.02


# ---------------------------------------------------------------------------
# Schedule-driven triangular solves.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_streams", [None, 1, 2])
def test_solves_match_dense(rng, n_streams):
    n, m = 128, 16
    k = _spd(rng, n)
    lref = np.linalg.cholesky(k)
    lp = chol.tiled_cholesky(tiling.pack_lower(jnp.asarray(k), m))
    y = rng.standard_normal(n).astype(np.float32)
    b = triangular.forward_substitution(
        lp, jnp.asarray(y).reshape(-1, m), n_streams=n_streams
    )
    np.testing.assert_allclose(
        np.asarray(b).reshape(-1), np.linalg.solve(lref, y), atol=1e-3
    )
    a = triangular.backward_substitution(lp, b, n_streams=n_streams)
    np.testing.assert_allclose(
        np.asarray(a).reshape(-1), np.linalg.solve(k, y), rtol=2e-2, atol=2e-3
    )
    q = 32
    bm = rng.standard_normal((n, q)).astype(np.float32)
    bt = tiling.tile_dense(jnp.asarray(bm), m)
    v = triangular.forward_substitution_matrix(lp, bt, n_streams=n_streams)
    np.testing.assert_allclose(
        np.asarray(tiling.untile_dense(v)), np.linalg.solve(lref, bm), atol=1e-3
    )
    x = triangular.backward_substitution_matrix(lp, bt, n_streams=n_streams)
    np.testing.assert_allclose(
        np.asarray(tiling.untile_dense(x)), np.linalg.solve(lref.T, bm), atol=1e-3
    )


# ---------------------------------------------------------------------------
# End-to-end prediction equivalence (padding remainders included).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_streams", [None, 1, 2])
@pytest.mark.parametrize("n,m", [(64, 16), (200, 48), (512, 128)])
def test_predict_matches_monolithic(rng, n, m, n_streams):
    # (200, 48) and (512, 128)→n=512 exact; 200 % 48 != 0 exercises padding
    d, nt = 3, 29
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((nt, d)).astype(np.float32)
    p = SEKernelParams.paper_defaults()
    mu_t, cov_t = pred.predict(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, m,
        full_cov=True, n_streams=n_streams,
    )
    mu_m, cov_m = pred.predict_monolithic(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, full_cov=True
    )
    np.testing.assert_allclose(np.asarray(mu_t), np.asarray(mu_m), atol=5e-3)
    np.testing.assert_allclose(np.asarray(cov_t), np.asarray(cov_m), atol=5e-3)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_predict_backends_agree(rng, backend):
    n, nt, d, m = 70, 11, 2, 16  # padding remainder on both train and test
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((nt, d)).astype(np.float32)
    p = SEKernelParams.paper_defaults()
    mu = np.asarray(
        pred.predict(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, m,
            backend=backend, n_streams=2,
        )
    )
    mu_m = np.asarray(
        pred.predict_monolithic(jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p)
    )
    np.testing.assert_allclose(mu, mu_m, atol=2e-3)


# ---------------------------------------------------------------------------
# Fused whole-pipeline program: equivalence vs the staged baseline.
# ---------------------------------------------------------------------------


# tile size per (n, backend): padding remainders covered (200 % 32 != 0) and
# the Pallas trailing-update kernel needs power-of-two-divisible tiles; the
# larger Pallas cases use coarser tiles because interpret mode pays per launch
_FUSED_M = {(64, "jnp"): 16, (200, "jnp"): 32, (512, "jnp"): 128,
            (64, "pallas"): 16, (200, "pallas"): 64, (512, "pallas"): 128}
_FUSED_DATA = {}


def _fused_case(n, uncertainty, backend):
    """Deterministic inputs + the staged reference, shared across the
    n_streams sweep (staged results differ across n_streams only by fp
    noise orders below the 1e-4 acceptance rtol)."""
    key = (n, uncertainty, backend)
    if key not in _FUSED_DATA:
        d, nt, m = 3, 29, _FUSED_M[(n, backend)]
        r = np.random.default_rng(n)
        x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
        y = jnp.asarray(r.standard_normal(n).astype(np.float32))
        xt = jnp.asarray(r.standard_normal((nt, d)).astype(np.float32))
        p = SEKernelParams.paper_defaults()
        staged = pred.predict_staged(
            x, y, xt, p, m,
            full_cov=uncertainty, n_streams=4, backend=backend,
        )
        _FUSED_DATA[key] = (x, y, xt, p, m, staged)
    return _FUSED_DATA[key]


@pytest.mark.parametrize("n_streams", [None, 1, 4])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("uncertainty", [False, True])
@pytest.mark.parametrize("n", [64, 200, 512])
def test_fused_matches_staged(n, uncertainty, backend, n_streams):
    """Acceptance grid: the fused program and the staged pipeline must agree
    to <= 1e-4 rtol for n x uncertainty x op_backend x n_streams."""
    x, y, xt, p, m, staged = _fused_case(n, uncertainty, backend)
    fused = pred.predict(
        x, y, xt, p, m,
        full_cov=uncertainty, n_streams=n_streams, backend=backend,
    )
    if not uncertainty:
        fused, staged = (fused,), (staged,)
    for f, s in zip(fused, staged):
        # atol floors the rtol for near-zero predictive means, where jit
        # (fused) vs eager (staged) reduction order leaves ~1e-5 fp noise
        np.testing.assert_allclose(
            np.asarray(f), np.asarray(s), rtol=1e-4, atol=1e-4
        )


def test_fused_state_slice_matches_staged_state(rng):
    """PosteriorState sliced from the program env == the staged builder's."""
    n, d, m = 96, 2, 16
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    xt = jnp.asarray(rng.standard_normal((5, d)).astype(np.float32))
    p = SEKernelParams.paper_defaults()
    _, st_f = pred.predict_fused(x, y, xt, p, m, with_state=True)
    st_s = pred.posterior_state(x, y, p, m)
    np.testing.assert_allclose(
        np.asarray(st_f.lpacked), np.asarray(st_s.lpacked), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(st_f.alpha), np.asarray(st_s.alpha), atol=2e-4
    )


# ---------------------------------------------------------------------------
# Acceptance: for M >= 8 the fused program issues strictly fewer batched
# launches than the sum of the staged pipeline's launches.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("uncertainty", [False, True])
@pytest.mark.parametrize("m_tiles", [8, 12, 16])
def test_fused_program_fewer_launches(m_tiles, uncertainty):
    q_tiles = max(m_tiles // 4, 1)
    for ns in (None, 4, 16):
        fused = executor.program_plan(m_tiles, q_tiles, uncertainty, ns).n_batches
        staged = executor.staged_launch_count(
            m_tiles, uncertainty=uncertainty, n_streams=ns
        )
        assert fused < staged, (m_tiles, uncertainty, ns, fused, staged)
    # n_streams=1 is the fully sequential baseline: one task per launch
    # leaves nothing to fuse — the program must still never be *worse*.
    # Likewise the n_streams == M boundary may tie.
    for ns in (1, 8):
        fused = executor.program_plan(m_tiles, q_tiles, uncertainty, ns).n_batches
        staged = executor.staged_launch_count(
            m_tiles, uncertainty=uncertainty, n_streams=ns
        )
        assert fused <= staged, (m_tiles, uncertainty, ns, fused, staged)


@pytest.mark.parametrize("uncertainty", [False, True])
@pytest.mark.parametrize("n_streams", [None, 1, 4])
def test_program_plan_covers_dag(uncertainty, n_streams):
    m_tiles, q_tiles = 6, 2
    plan = executor.program_plan(m_tiles, q_tiles, uncertainty, n_streams)
    tasks = sch.program_tasks(m_tiles, q_tiles, uncertainty=uncertainty)
    assert sorted(plan.flat_tasks()) == sorted(tasks)
    level_of = {
        t: li for li, lvl in enumerate(plan.levels) for b in lvl for t in b.tasks
    }
    for t in tasks:
        for d in sch.program_deps(t, m_tiles, q_tiles):
            assert level_of[d] < level_of[t], (t, d)


# ---------------------------------------------------------------------------
# Plan structure: batch counts must match the Schedule's levels.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m_tiles", [1, 2, 5, 8])
def test_cholesky_plan_counts_match_schedule(m_tiles):
    s = sch.build_schedule(m_tiles)
    plan = executor.cholesky_plan(m_tiles, None)
    assert plan.level_task_counts() == [len(l) for l in s.levels]
    assert sorted(plan.flat_tasks()) == sorted(sch.all_tasks(m_tiles))


@pytest.mark.parametrize("m_tiles", [1, 2, 5, 8])
@pytest.mark.parametrize("n_streams", [1, 3])
def test_cholesky_wavefront_plan_covers_dag(m_tiles, n_streams):
    s = sch.build_wavefront_schedule(m_tiles, n_streams)
    plan = executor.cholesky_plan(m_tiles, n_streams)
    assert plan.level_task_counts() == [len(l) for l in s.levels]
    assert sorted(plan.flat_tasks()) == sorted(sch.all_tasks(m_tiles))
    assert all(b.size <= n_streams for lvl in plan.levels for b in lvl)
    assert all(len(lvl) <= n_streams for lvl in s.levels)


@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("m_tiles", [1, 2, 6])
def test_solve_plan_counts_match_schedule(m_tiles, lower):
    s = sch.build_solve_schedule(m_tiles, lower=lower)
    plan = executor.solve_plan(m_tiles, lower=lower, n_streams=None)
    assert plan.level_task_counts() == [len(l) for l in s.levels]
    assert sorted(plan.flat_tasks()) == sorted(sch.solve_tasks(m_tiles, lower=lower))


def test_wavefront_batches_across_columns():
    """The executor's raison d'être: with a finite stream pool, trailing
    updates of column j co-batch with panel tasks of column j+1."""
    plan = executor.cholesky_plan(8, 4)
    mixed_wave = any(
        len({t[2] for b in lvl for t in b.tasks}) > 1 for lvl in plan.levels
    )
    assert mixed_wave, "no wave ever contained tasks from multiple columns"
    mixed_batch = any(
        len({t[2] for t in b.tasks}) > 1 for lvl in plan.levels for b in lvl
    )
    assert mixed_batch, "no single batched launch mixed columns"


# ---------------------------------------------------------------------------
# GaussianProcess factor caching.
# ---------------------------------------------------------------------------


def _counting(monkeypatch):
    """Count O(n^3) posterior builds: staged (posterior_state) or fused
    (predict_fused populating the cache via with_state=True)."""
    calls = {"n": 0}
    orig_state = pred.posterior_state
    orig_fused = pred.predict_fused

    def wrapped_state(*a, **kw):
        calls["n"] += 1
        return orig_state(*a, **kw)

    def wrapped_fused(*a, **kw):
        if kw.get("with_state"):
            calls["n"] += 1
        return orig_fused(*a, **kw)

    monkeypatch.setattr(pred, "posterior_state", wrapped_state)
    monkeypatch.setattr(pred, "predict_fused", wrapped_fused)
    return calls


def test_gp_caches_factor_across_predicts(rng, monkeypatch):
    calls = _counting(monkeypatch)
    n, d = 48, 2
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=16)
    xt = rng.standard_normal((7, d)).astype(np.float32)
    mu1 = gp.predict(xt)
    gp.predict(rng.standard_normal((5, d)).astype(np.float32))
    gp.predict_full_cov(xt)
    assert calls["n"] == 1
    assert gp.posterior() is gp.posterior()
    # param change invalidates
    gp.params = SEKernelParams(0.5, 1.0, 0.1)
    mu2 = gp.predict(xt)
    assert calls["n"] == 2
    assert not np.allclose(np.asarray(mu1), np.asarray(mu2))


def test_gp_data_rebind_invalidates_cache(rng):
    n, d = 48, 2
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((5, d)).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=16)
    mu1 = np.asarray(gp.predict(xt))
    gp.y_train = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    mu2 = np.asarray(gp.predict(xt))  # must not serve the stale factor
    assert not np.allclose(mu1, mu2)


def test_gp_optimize_invalidates_cache(rng, monkeypatch):
    calls = _counting(monkeypatch)
    x = rng.uniform(-3, 3, (32, 1)).astype(np.float32)
    y = np.sin(2 * x[:, 0]).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=16)
    gp.predict(x[:4])
    assert calls["n"] == 1
    gp.optimize(steps=2, lr=0.05)
    gp.predict(x[:4])
    assert calls["n"] == 2


def test_cached_prediction_matches_uncached(rng):
    n, d = 100, 3  # not a tile multiple
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((13, d)).astype(np.float32)
    p = SEKernelParams.paper_defaults()
    gp = GaussianProcess(x, y, tile_size=16)
    mu_gp = np.asarray(gp.predict(xt))       # populates the cache
    mu_gp2 = np.asarray(gp.predict(xt))      # served from the cache
    mu_ref = np.asarray(
        pred.predict(jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, 16)
    )
    np.testing.assert_allclose(mu_gp, mu_ref, atol=1e-5)
    np.testing.assert_allclose(mu_gp2, mu_ref, atol=1e-5)
