"""Regression: the softplus re-parameterization must round-trip extreme values.

Pre-fix, ``mll._pack`` used ``log(expm1(p))`` directly: ``expm1`` overflows
float32 at p ≈ 90 (inf -> inf raw values, NaN gradients), and a hard 1e-6
floor silently distorted any hyperparameter below it.  The fixed inverse
softplus branches at p = 20 — ``log(expm1(p))`` below, the asymptotically
exact ``p + log1p(-exp(-p))`` above — so the whole f32 range [1e-8, 1e6]
round-trips through pack -> unpack.

The sweep is a seeded log-uniform property (the ``hypothesis`` package is
optional in this environment; the explicit grid + random sweep below covers
the same space deterministically).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_math as km
from repro.core import mll


def _x64():
    return getattr(jax, "enable_x64", None) or jax.experimental.enable_x64


# endpoints, the old overflow knee (~90), the branch point (20), and a
# seeded log-uniform sweep across the full range
def _values(n=64, seed=5):
    rng = np.random.default_rng(seed)
    sweep = 10.0 ** rng.uniform(-8, 6, size=n)
    return np.concatenate(
        [[1e-8, 1e-6, 1.0, 19.5, 20.0, 20.5, 89.0, 95.0, 1e3, 1e6], sweep]
    )


def test_roundtrip_float32():
    v = jnp.asarray(_values(), jnp.float32)
    back = mll.unpack_params(mll.pack_params(v))
    assert back.dtype == jnp.float32
    np.testing.assert_allclose(back, v, rtol=3e-6, atol=0)


def test_roundtrip_float64():
    with _x64()():
        v = jnp.asarray(_values(), jnp.float64)
        back = mll.unpack_params(mll.pack_params(v))
        assert back.dtype == jnp.float64
        np.testing.assert_allclose(back, v, rtol=1e-12, atol=0)


def test_no_overflow_above_old_knee():
    """p >~ 90 used to produce inf raw values (expm1 overflow in f32)."""
    v = jnp.asarray([95.0, 1e3, 1e6], jnp.float32)
    raw = mll.pack_params(v)
    assert np.isfinite(np.asarray(raw)).all()
    # large p: softplus^-1(p) ~= p; the raw value must track it, not clamp
    np.testing.assert_allclose(raw, v, rtol=1e-5)


def test_tiny_values_not_floored():
    """Values below the old 1e-6 floor must survive (no silent distortion)."""
    v = jnp.asarray([1e-8, 5e-8, 1e-7], jnp.float32)
    back = np.asarray(mll.unpack_params(mll.pack_params(v)))
    assert np.isfinite(back).all()
    np.testing.assert_allclose(back, v, rtol=3e-6)


def test_gradients_finite_across_range():
    g = jax.vmap(jax.grad(lambda r: mll.unpack_params(r)))(
        mll.pack_params(jnp.asarray(_values(), jnp.float32))
    )
    assert np.isfinite(np.asarray(g)).all()


def test_pack_roundtrip_over_params_pytree():
    """pack/unpack are tree_maps: composite kernel params round-trip whole."""
    kern = km.Sum(km.Scaled(km.Matern52()), km.White())
    p = kern.default_params()
    back = mll.unpack_params(mll.pack_params(p))
    for a, b in zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(b, a, rtol=3e-6)


def test_stacked_se_pack_api_unchanged():
    """The legacy stacked (…, 3) SE raw layout still round-trips bit-for-bit
    with the generic path on each column."""
    p = km.SEKernelParams(lengthscale=2.0, vertical=0.5, noise=1e-4)
    raw = mll._pack(p)
    assert raw.shape == (3,)
    back = mll._unpack(raw)
    np.testing.assert_allclose(back.lengthscale, 2.0, rtol=3e-6)
    np.testing.assert_allclose(back.vertical, 0.5, rtol=3e-6)
    np.testing.assert_allclose(back.noise, 1e-4, rtol=3e-6)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=30, deadline=None)
    @given(v=st.floats(1e-8, 1e6))
    def test_property_roundtrip(v):
        x = jnp.asarray(v, jnp.float32)
        back = mll.unpack_params(mll.pack_params(x))
        np.testing.assert_allclose(back, x, rtol=3e-6)
except ImportError:  # pragma: no cover - the explicit sweep above stands in
    pass
