"""Gradient-verification suite for the differentiable tiled NLML (DESIGN.md §8).

The tiled NLML (`mll.nlml_tiled`, the fused program with q_tiles=0) must be
value-equivalent to the monolithic reference AND produce matching gradients —
via the blocked reverse-mode custom VJP (default) and via plain autodiff
through the program — across tile counts, padding, backends, stream pools and
dtypes.  float64 cells additionally check against central finite differences.
"""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_math as km
from repro.core import mll, tiling
from repro.core import predict as pred
from repro.core.kernels_math import SEKernelParams

# tile sizes chosen so the grid covers M in {2, 4} with and without padding
# (n=200 pads to 256; n=16/64/512 are exact multiples)
_TILE = {16: 8, 64: 16, 200: 64, 512: 128}

# float32 acceptance: <= 1e-3 rtol vs the monolithic gradients; float64: 1e-6.
# The Pallas kernels compute internally in float32 regardless of the storage
# dtype (trsm_tile casts operands to f32, trailing_update accumulates with
# preferred_element_type=f32 — the TPU MXU has no f64), so pallas cells are
# held to the float32 tolerance even when storage is float64.
_GRAD_RTOL = {"float32": 1e-3, "float64": 1e-6}
_VALUE_RTOL = {"float32": 1e-4, "float64": 1e-10}


def _tols(backend, dt):
    eff = "float32" if backend == "pallas" else dt
    return _VALUE_RTOL[eff], _GRAD_RTOL[eff]


def _x64():
    return getattr(jax, "enable_x64", None) or jax.experimental.enable_x64


def _ctx(dt):
    return _x64()() if dt == "float64" else contextlib.nullcontext()


def _data(n, dt):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal((n, 2)).astype(dt))
    y = jnp.asarray(rng.standard_normal(n).astype(dt))
    return x, y


def _params(dtype):
    return SEKernelParams(
        jnp.asarray(0.8, dtype), jnp.asarray(1.3, dtype), jnp.asarray(0.2, dtype)
    )


def _grid():
    cells = []
    for n in (16, 64, 200, 512):
        for backend in ("jnp", "pallas"):
            for ns in (None, 1, 4):
                for dt in ("float32", "float64"):
                    marks = []
                    if n == 512 or (backend == "pallas" and n >= 200):
                        marks.append(pytest.mark.slow)
                    cells.append(
                        pytest.param(
                            n, backend, ns, dt,
                            marks=marks,
                            id=f"n{n}-{backend}-ns{ns}-{dt}",
                        )
                    )
    return cells


@pytest.mark.parametrize("n,backend,ns,dt", _grid())
def test_nlml_tiled_value_and_grad_match_monolithic(n, backend, ns, dt):
    with _ctx(dt):
        dtype = jnp.dtype(dt)
        x, y = _data(n, dt)
        params = _params(dtype)
        kw = dict(
            tile_size=_TILE[n], n_streams=ns, op_backend=backend, dtype=dtype
        )

        value_rtol, grad_rtol = _tols(backend, dt)

        # value equivalence: nlml_tiled == negative_log_marginal_likelihood
        v_t = float(mll.nlml_tiled(x, y, params, **kw))
        v_m = float(mll.negative_log_marginal_likelihood(x, y, params, dtype=dtype))
        assert v_t == pytest.approx(v_m, rel=value_rtol)

        # gradient equivalence in unconstrained space (what the optimizer sees)
        raw = mll._pack(params, dtype=dtype)
        g_m = np.asarray(
            jax.grad(
                lambda r: mll.negative_log_marginal_likelihood(
                    x, y, mll._unpack(r), dtype=dtype
                )
            )(raw)
        )
        g_t = np.asarray(
            jax.grad(lambda r: mll.nlml_tiled(x, y, mll._unpack(r), **kw))(raw)
        )
        np.testing.assert_allclose(
            g_t, g_m, rtol=grad_rtol, atol=grad_rtol * np.abs(g_m).max()
        )


@pytest.mark.parametrize(
    "n,backend",
    [(16, "jnp"), (64, "jnp"), (200, "jnp"), (16, "pallas")],
    ids=lambda v: str(v),
)
def test_nlml_tiled_grad_matches_finite_differences(n, backend):
    """Central finite differences in float64 pin the analytic VJP.

    The jnp backend is f64 end-to-end, so a tiny step resolves the gradient
    to ~1e-9; the Pallas forward rounds internally through f32, so its step
    must be large enough for the secant to dominate that rounding noise."""
    with _x64()():
        dtype = jnp.float64
        x, y = _data(n, "float64")
        params = _params(dtype)
        kw = dict(tile_size=_TILE[n], op_backend=backend, dtype=dtype)
        raw = mll._pack(params, dtype=dtype)
        g = np.asarray(
            jax.grad(lambda r: mll.nlml_tiled(x, y, mll._unpack(r), **kw))(raw)
        )
        eps, rtol = (1e-6, 1e-5) if backend == "jnp" else (1e-3, 5e-3)
        fd = []
        for i in range(3):
            e = jnp.zeros(3, raw.dtype).at[i].set(eps)
            hi = mll.nlml_tiled(x, y, mll._unpack(raw + e), **kw)
            lo = mll.nlml_tiled(x, y, mll._unpack(raw - e), **kw)
            fd.append((float(hi) - float(lo)) / (2 * eps))
        fd = np.asarray(fd)
        np.testing.assert_allclose(g, fd, rtol=rtol, atol=rtol * np.abs(fd).max())


@pytest.mark.parametrize("method", ["tiled", "lowrank"])
def test_matern52_analytic_vjp_matches_finite_differences(method):
    """The hand-derived Matérn-5/2 kfree VJP, contracted by both blocked
    custom rules (exact tier and Woodbury low-rank tier), against central
    finite differences in float64."""
    with _x64()():
        dtype = jnp.float64
        n = 48
        x, y = _data(n, "float64")
        kern = km.get_kernel("matern52")
        raw = mll.pack_params(_params(dtype), dtype=dtype)

        if method == "tiled":
            def loss(r):
                return mll.nlml_tiled(
                    x, y, mll.unpack_params(r),
                    tile_size=16, dtype=dtype, kernel=kern, vjp="custom",
                )
        else:
            def loss(r):
                return mll.nlml_lowrank(
                    x, y, mll.unpack_params(r),
                    m_inducing=16, tile_size=16, jitter=1e-10,
                    dtype=dtype, kernel=kern, vjp="custom",
                )

        g_leaves = jax.tree_util.tree_leaves(jax.grad(loss)(raw))
        leaves, tree = jax.tree_util.tree_flatten(raw)
        eps, rtol = 1e-6, 1e-5
        fd = []
        for i in range(len(leaves)):
            hi = list(leaves)
            hi[i] = leaves[i] + eps
            lo = list(leaves)
            lo[i] = leaves[i] - eps
            fd.append((
                float(loss(jax.tree_util.tree_unflatten(tree, hi)))
                - float(loss(jax.tree_util.tree_unflatten(tree, lo)))
            ) / (2 * eps))
        fd = np.asarray(fd)
        g = np.asarray([float(v) for v in g_leaves])
        assert np.abs(fd).max() > 1e-3, "degenerate cell: all-zero gradients"
        np.testing.assert_allclose(g, fd, rtol=rtol, atol=rtol * np.abs(fd).max())


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_custom_vjp_matches_autodiff_through_program(backend):
    """The blocked reverse-mode rule equals differentiating every wavefront
    launch (jnp ops natively; Pallas tile ops via their reference VJPs)."""
    n = 48
    x, y = _data(n, "float32")
    params = _params(jnp.float32)
    raw = mll._pack(params)
    kw = dict(tile_size=16, n_streams=4, op_backend=backend)
    g_c = np.asarray(
        jax.grad(lambda r: mll.nlml_tiled(x, y, mll._unpack(r), vjp="custom", **kw))(raw)
    )
    g_a = np.asarray(
        jax.grad(lambda r: mll.nlml_tiled(x, y, mll._unpack(r), vjp="autodiff", **kw))(raw)
    )
    np.testing.assert_allclose(g_c, g_a, rtol=1e-3, atol=1e-3 * np.abs(g_a).max())


def test_nlml_tiled_grads_wrt_inputs_match_monolithic():
    """The custom VJP also carries exact cotangents for x and y."""
    n = 30
    x, y = _data(n, "float32")
    params = _params(jnp.float32)
    gm_x, gm_y = jax.grad(
        lambda a, b: mll.negative_log_marginal_likelihood(a, b, params), argnums=(0, 1)
    )(x, y)
    gt_x, gt_y = jax.grad(
        lambda a, b: mll.nlml_tiled(a, b, params, tile_size=8), argnums=(0, 1)
    )(x, y)
    np.testing.assert_allclose(
        np.asarray(gt_x), np.asarray(gm_x), rtol=1e-3,
        atol=1e-4 * np.abs(np.asarray(gm_x)).max(),
    )
    np.testing.assert_allclose(
        np.asarray(gt_y), np.asarray(gm_y), rtol=1e-3,
        atol=1e-4 * np.abs(np.asarray(gm_y)).max(),
    )


def test_pack_preserves_float64():
    """Regression: _pack hard-coded float32, silently rounding f64 params."""
    with _x64()():
        p = SEKernelParams(
            jnp.asarray(1.5, jnp.float64),
            jnp.asarray(2.0, jnp.float64),
            jnp.asarray(0.3, jnp.float64),
        )
        raw = mll._pack(p)
        assert raw.dtype == jnp.float64
        rt = mll._unpack(raw)
        np.testing.assert_allclose(float(rt.lengthscale), 1.5, rtol=1e-12)
        np.testing.assert_allclose(float(rt.vertical), 2.0, rtol=1e-12)
        np.testing.assert_allclose(float(rt.noise), 0.3, rtol=1e-12)
    # default stays float32 when given plain python floats
    assert mll._pack(SEKernelParams.paper_defaults()).dtype == jnp.float32


def test_tiled_optimizer_matches_monolithic_trajectory():
    """Same init, same step count: the lax.scan Adam loop over the tiled NLML
    follows the monolithic loss curve and lands on the same hyperparameters."""
    rng = np.random.default_rng(7)
    n = 40
    x = jnp.asarray(rng.uniform(-3, 3, (n, 1)).astype(np.float32))
    y = jnp.asarray(
        (np.sin(2 * np.asarray(x)[:, 0]) + 0.1 * rng.standard_normal(n)).astype(
            np.float32
        )
    )
    init = SEKernelParams.paper_defaults()
    p_t, l_t = mll.optimize_hyperparameters(
        x, y, init, steps=20, lr=0.05, method="tiled", tile_size=16
    )
    p_m, l_m = mll.optimize_hyperparameters(
        x, y, init, steps=20, lr=0.05, method="monolithic"
    )
    np.testing.assert_allclose(np.asarray(l_t), np.asarray(l_m), rtol=1e-3, atol=1e-2)
    for a, b in zip(
        (p_t.lengthscale, p_t.vertical, p_t.noise),
        (p_m.lengthscale, p_m.vertical, p_m.noise),
    ):
        np.testing.assert_allclose(float(a), float(b), rtol=2e-2, atol=1e-4)
    assert float(l_t[-1]) < float(l_t[0])


def test_gp_optimize_tiled_runs_zero_monolithic_choleskys(rng, monkeypatch):
    """pipeline="tiled" training must never touch the monolithic path."""
    from repro.core import GaussianProcess
    from repro.core import cholesky as chol

    n = 32
    x = rng.uniform(-3, 3, (n, 1)).astype(np.float32)
    y = (np.sin(2 * x[:, 0]) + 0.1 * rng.standard_normal(n)).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=16)
    before = float(gp.nlml())
    calls = {"n": 0}
    orig = chol.monolithic_cholesky

    def wrapped(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(chol, "monolithic_cholesky", wrapped)
    gp.optimize(steps=10, lr=0.05)
    assert calls["n"] == 0, "tiled optimize() ran the monolithic Cholesky"
    after = float(gp.nlml())
    assert after < before


def test_nlml_program_env_matches_posterior_state(rng):
    """The q_tiles=0 program env slices equal the staged posterior state."""
    n = 50
    x = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    params = _params(jnp.float32)
    env, yc = pred.nlml_program_env(x, y, params, 16)
    state = pred.posterior_state(x, y, params, 16)
    np.testing.assert_allclose(
        np.asarray(env["packed"]), np.asarray(state.lpacked), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(env["alpha"]), np.asarray(state.alpha), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(yc), np.asarray(tiling.pad_vector(y, 16)), rtol=0, atol=0
    )
