"""Batched multi-GP execution (DESIGN.md §9).

The batched program must be *bit-for-purpose* equivalent to a Python loop of
single GPs: same predictions, uncertainties, NLMLs and gradients, while the
executor reuses the exact same lru-cached Plan for every B (the DAG depends
only on the tile geometry).  Heavy grid cells are marked ``slow``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GaussianProcess, GPBatch, SEKernelParams
from repro.core import executor, mll, tiling
from repro.core import predict as pred


def _problems(rng, b, n, d=2, nh=13):
    x = rng.standard_normal((b, n, d)).astype(np.float32)
    y = rng.standard_normal((b, n)).astype(np.float32)
    xt = rng.standard_normal((b, nh, d)).astype(np.float32)
    params = SEKernelParams(
        jnp.asarray(rng.uniform(0.6, 1.4, b).astype(np.float32)),
        jnp.asarray(rng.uniform(0.8, 1.2, b).astype(np.float32)),
        jnp.asarray(rng.uniform(0.05, 0.2, b).astype(np.float32)),
    )
    return x, y, xt, params


def _single(params, i):
    return SEKernelParams(
        float(params.lengthscale[i]),
        float(params.vertical[i]),
        float(params.noise[i]),
    )


def _grid():
    """B x n x backend x n_streams equivalence grid; heavy cells slow."""
    cells = []
    for b in (1, 3, 8):
        for n in (64, 200):
            for backend in ("jnp", "pallas"):
                for ns in (1, 4, None):
                    # fast lane keeps the full jnp n=64 grid and ONE pallas
                    # interpret cell; everything else is slow-lane (coverage
                    # ratchet: interpret-mode pallas cells dominate runtime
                    # without adding line coverage beyond the first cell)
                    heavy = n == 200 or (
                        backend == "pallas" and not (b == 1 and ns is None)
                    )
                    marks = [pytest.mark.slow] if heavy else []
                    cells.append(
                        pytest.param(b, n, backend, ns, marks=marks,
                                     id=f"B{b}-n{n}-{backend}-ns{ns}")
                    )
    return cells


@pytest.mark.parametrize("b,n,backend,ns", _grid())
def test_gpbatch_matches_loop(rng, b, n, backend, ns):
    """GPBatch predict / uncertainty / nlml == a loop of GaussianProcess."""
    x, y, xt, params = _problems(rng, b, n)
    m = 16 if n == 64 else 64
    fleet = GPBatch(x, y, params=params, tile_size=m, n_streams=ns, op_backend=backend)
    mu_b, var_b = fleet.predict_with_uncertainty(xt)
    nlml_b = fleet.nlml()
    assert mu_b.shape == (b, xt.shape[1]) and nlml_b.shape == (b,)
    for i in range(b):
        gp = GaussianProcess(
            x[i], y[i], params=_single(params, i), tile_size=m,
            n_streams=ns, op_backend=backend,
        )
        mu_i, var_i = gp.predict_with_uncertainty(xt[i])
        np.testing.assert_allclose(np.asarray(mu_b[i]), np.asarray(mu_i),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(var_b[i]), np.asarray(var_i),
                                   rtol=1e-3, atol=1e-4)
        ref = float(gp.nlml())
        assert abs(float(nlml_b[i]) - ref) < 1e-3 * abs(ref) + 5e-2


@pytest.mark.parametrize("vjp", ["custom", "autodiff"])
def test_batched_nlml_gradients_match_loop(rng, vjp):
    """d(sum_i NLML_i)/d(params, x, y) == the stacked per-problem gradients."""
    b, n, d, m = 3, 48, 2, 16
    x, y, _, params = _problems(rng, b, n, d=d)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss(xa, ya, p):
        return jnp.sum(mll.nlml_tiled_batched(xa, ya, p, tile_size=m, vjp=vjp))

    g_x, g_y, g_p = jax.grad(loss, argnums=(0, 1, 2))(xj, yj, params)
    for i in range(b):
        pi = _single(params, i)
        gi_x, gi_y, gi_p = jax.grad(
            lambda xa, ya, p: mll.nlml_tiled(xa, ya, p, tile_size=m, vjp=vjp),
            argnums=(0, 1, 2),
        )(xj[i], yj[i], pi)
        np.testing.assert_allclose(np.asarray(g_x[i]), np.asarray(gi_x),
                                   rtol=2e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(g_y[i]), np.asarray(gi_y),
                                   rtol=2e-3, atol=1e-4)
        for leaf, ref in (
            (g_p.lengthscale[i], gi_p.lengthscale),
            (g_p.vertical[i], gi_p.vertical),
            (g_p.noise[i], gi_p.noise),
        ):
            np.testing.assert_allclose(float(leaf), float(ref), rtol=2e-3, atol=1e-4)


def test_plan_reuse_across_batch_sizes(rng):
    """Acceptance: the B=8, n=200 batched program executes with the SAME
    number of executor launches as B=1 — literally the same lru-cached Plan
    object; B never enters the plan key."""
    n, nh, m = 200, 50, 64
    x1, y1, xt1, params1 = _problems(rng, 1, n, nh=nh)
    pred.predict_fused_batched(x1, y1, xt1, params1, m)
    m_tiles = (n + m - 1) // m
    q_tiles = (nh + m - 1) // m
    info_after_b1 = executor.program_plan.cache_info()
    plan_b1 = executor.program_plan(m_tiles, q_tiles, False, None)

    x8, y8, xt8, params8 = _problems(rng, 8, n, nh=nh)
    pred.predict_fused_batched(x8, y8, xt8, params8, m)
    info_after_b8 = executor.program_plan.cache_info()
    plan_b8 = executor.program_plan(m_tiles, q_tiles, False, None)

    assert plan_b1 is plan_b8, "plan must be B-invariant (same cached object)"
    assert info_after_b8.misses == info_after_b1.misses, (
        "running B=8 compiled a new plan — the executor launch count changed"
    )
    # the launch count both runs executed is the plan's batch count
    assert plan_b8.n_batches == plan_b1.n_batches


def test_batched_optimize_matches_independent_runs(rng):
    """One jitted batched Adam scan == B independent single-GP Adam runs."""
    b, n, m, steps = 2, 48, 16, 12
    x, y, _, params = _problems(rng, b, n)
    opt_b, losses_b = mll.optimize_hyperparameters_batched(
        x, y, params, steps=steps, lr=0.1, tile_size=m
    )
    assert losses_b.shape == (steps, b)
    for i in range(b):
        opt_i, losses_i = mll.optimize_hyperparameters(
            x[i], y[i], _single(params, i), steps=steps, lr=0.1,
            method="tiled", tile_size=m,
        )
        np.testing.assert_allclose(np.asarray(losses_b[:, i]),
                                   np.asarray(losses_i), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(opt_b.lengthscale[i]),
                                   float(opt_i.lengthscale), rtol=1e-3, atol=1e-4)


def test_gpbatch_cache_contract(rng):
    """Posterior cache populated by cold predict, reused warm, invalidated
    by optimize — the GaussianProcess contract, stacked."""
    b, n = 3, 40
    x, y, xt, params = _problems(rng, b, n)
    fleet = GPBatch(x, y, params=params, tile_size=16)
    assert fleet._posterior is None
    mu_cold = fleet.predict(xt)
    assert fleet._posterior is not None, "cold fused predict must populate cache"
    assert fleet._posterior.lpacked.shape[0] == b
    mu_warm = fleet.predict(xt)
    np.testing.assert_allclose(np.asarray(mu_warm), np.asarray(mu_cold),
                               rtol=1e-4, atol=1e-5)
    # warm full-covariance tail off the cached stacked factor
    mu_w, sig_w = fleet.predict_full_cov(xt)
    assert sig_w.shape == (b, xt.shape[1], xt.shape[1])
    np.testing.assert_allclose(np.asarray(mu_w), np.asarray(mu_cold),
                               rtol=1e-4, atol=1e-5)
    fleet.optimize(steps=3, lr=0.05)
    assert fleet._posterior is None, "optimize must invalidate the cache"
    assert fleet.params.lengthscale.shape == (b,)
    nl = fleet.nlml()  # repopulates via the q_tiles=0 program
    assert nl.shape == (b,) and np.isfinite(np.asarray(nl)).all()


def test_gpbatch_validation_and_broadcast(rng):
    x = rng.standard_normal((3, 40, 2)).astype(np.float32)
    y = rng.standard_normal((3, 40)).astype(np.float32)
    with pytest.raises(ValueError, match="GPBatch"):
        GPBatch(x[0], y)  # unstacked x
    with pytest.raises(ValueError, match="GPBatch"):
        GPBatch(x, y[:2])  # mismatched B
    # shared scalar params stay scalar (keeps Pallas assembly usable);
    # wrong-length per-problem leaves raise
    fleet = GPBatch(x, y, tile_size=16)
    assert jnp.ndim(fleet.params.lengthscale) == 0
    with pytest.raises(ValueError, match="params"):
        GPBatch(x, y, params=SEKernelParams(jnp.ones(2), 1.0, 0.1), tile_size=16)
    # shared (n̂, D) test block broadcasts; wrong leading axis raises
    assert fleet.predict(x[0, :7]).shape == (3, 7)
    with pytest.raises(ValueError, match="x_test"):
        fleet.predict(rng.standard_normal((2, 5, 2)).astype(np.float32))
    # (B, n) 1-D convenience, incl. stacked/shared test-point forms
    f1 = GPBatch(y, y, tile_size=16)
    assert f1.x_train.shape == (3, 40, 1)
    assert f1.predict(rng.standard_normal((3, 5)).astype(np.float32)).shape == (3, 5)
    assert f1.predict(rng.standard_normal(7).astype(np.float32)).shape == (3, 7)
    assert f1.predict(rng.standard_normal((7, 1)).astype(np.float32)).shape == (3, 7)
    # mixed scalar/(B,) hyperparameter leaves are legal end-to-end
    mixed = GPBatch(
        x, y, params=SEKernelParams(jnp.ones(3), 1.0, 0.1), tile_size=16
    )
    assert mixed.predict(x[:, :5]).shape == (3, 5)
    assert mixed.nlml().shape == (3,)


def test_padding_helpers_batched(rng):
    """tiling.pad_* are batch-aware (the predict.pad_* deprecation aliases
    were removed; tiling owns the implementations)."""
    assert not hasattr(pred, "pad_features")
    assert not hasattr(pred, "pad_vector")
    x = jnp.asarray(rng.standard_normal((3, 10, 2)).astype(np.float32))
    xc = tiling.pad_features(x, 4)
    assert xc.shape == (3, 3, 4, 2)
    np.testing.assert_array_equal(np.asarray(xc[:, 2, 2:]), 0.0)
    y = jnp.asarray(rng.standard_normal((3, 10)).astype(np.float32))
    yc = tiling.pad_vector(y, 4)
    assert yc.shape == (3, 3, 4)
    # unbatched layout unchanged
    assert tiling.pad_features(x[0], 4).shape == (3, 4, 2)
    assert tiling.pad_vector(y[0], 4).shape == (3, 4)
    # dtype kw casts; default preserves
    assert tiling.pad_vector(y, 4, dtype=jnp.bfloat16).dtype == jnp.bfloat16
    assert tiling.pad_vector(y, 4).dtype == y.dtype


def test_run_cholesky_batched_matches_loop(rng, spd):
    """The executor's factorization itself accepts a leading B axis."""
    b, m_tiles, m = 3, 3, 8
    n = m_tiles * m
    ks = np.stack([spd(rng, n) for _ in range(b)])
    packed = jnp.stack([tiling.pack_lower(jnp.asarray(k), m) for k in ks])
    for dispatch in ("flat", "vmap"):
        lb = executor.run_cholesky(packed, batch_dispatch=dispatch)
        for i in range(b):
            li = executor.run_cholesky(packed[i])
            np.testing.assert_allclose(np.asarray(lb[i]), np.asarray(li),
                                       rtol=1e-4, atol=1e-4)


def test_dtype_flows_float64(rng):
    """The dtype knob reaches padding + assembly end-to-end (no implicit
    float32): float64 GPs stay float64 through predict and nlml."""
    enable_x64 = getattr(jax, "enable_x64", None) or jax.experimental.enable_x64
    with enable_x64():
        n, d = 40, 2
        x = rng.standard_normal((n, d))
        y = rng.standard_normal(n)
        xt = rng.standard_normal((11, d))
        gp = GaussianProcess(x, y, tile_size=16, dtype=jnp.float64)
        mu, var = gp.predict_with_uncertainty(xt)
        assert mu.dtype == jnp.float64 and var.dtype == jnp.float64
        assert gp.posterior().lpacked.dtype == jnp.float64
        mu_m = pred.predict_monolithic(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), gp.params,
            dtype=jnp.float64,
        )
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_m),
                                   rtol=1e-8, atol=1e-10)
        # batched fleet in float64
        xs = np.stack([x, x + 0.1])
        ys = np.stack([y, y * 0.5])
        fleet = GPBatch(xs, ys, tile_size=16, dtype=jnp.float64)
        mu_b = fleet.predict(np.stack([xt, xt]))
        assert mu_b.dtype == jnp.float64
        np.testing.assert_allclose(np.asarray(mu_b[0]), np.asarray(mu),
                                   rtol=1e-8, atol=1e-10)
        assert fleet.nlml().dtype == jnp.float64


try:
    import hypothesis  # noqa: F401

    _HAVE_HYP = True
except ImportError:
    _HAVE_HYP = False

if _HAVE_HYP:
    from hypothesis import given, settings, strategies as st

    @given(
        b=st.integers(1, 4),
        n=st.integers(8, 40),
        d=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_batched_equals_loop(b, n, d, seed):
        """Any ragged-free stacked problem set: batched == per-problem loop."""
        rng = np.random.default_rng(seed)
        x, y, xt, params = _problems(rng, b, n, d=d, nh=max(n // 3, 2))
        mu_b = pred.predict_fused_batched(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), params, 16
        )
        for i in range(b):
            mu_i = pred.predict_fused(
                jnp.asarray(x[i]), jnp.asarray(y[i]), jnp.asarray(xt[i]),
                _single(params, i), 16,
            )
            np.testing.assert_allclose(np.asarray(mu_b[i]), np.asarray(mu_i),
                                       rtol=1e-3, atol=2e-3)
