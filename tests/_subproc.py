"""Run python snippets in a subprocess with N fake XLA host devices."""

import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nstdout:\n{proc.stdout}\n"
            f"stderr:\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
