"""Tiled Cholesky vs the monolithic reference, across stream counts,
tile counts, dtypes, backends, and mixed precision."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cholesky as chol
from repro.core import tiling


def _spd(rng, n, dtype=np.float32):
    a = rng.standard_normal((n, n)).astype(dtype)
    return a @ a.T + n * np.eye(n, dtype=dtype)


@pytest.mark.parametrize("n_streams", [None, 1, 2, 5])
@pytest.mark.parametrize("m", [8, 16, 32])
def test_tiled_matches_monolithic(rng, n_streams, m):
    k = _spd(rng, 64)
    l_t = np.asarray(chol.cholesky_dense_via_tiles(jnp.asarray(k), m, n_streams=n_streams))
    l_m = np.asarray(chol.monolithic_cholesky(jnp.asarray(k)))
    np.testing.assert_allclose(l_t, l_m, atol=1e-3)


def test_reconstruction(rng):
    k = _spd(rng, 96)
    l = np.asarray(chol.cholesky_dense_via_tiles(jnp.asarray(k), 16))
    np.testing.assert_allclose(l @ l.T, k, rtol=2e-2, atol=2e-2)
    assert np.allclose(np.triu(l, 1), 0.0)


def test_single_tile_degenerates_to_monolithic(rng):
    """M=1 is the paper's pure-cuSOLVER case."""
    k = _spd(rng, 32)
    l = np.asarray(chol.cholesky_dense_via_tiles(jnp.asarray(k), 32))
    np.testing.assert_allclose(l, np.linalg.cholesky(k), atol=1e-4)


def test_mixed_precision_update(rng):
    """bf16 trailing updates (paper future work): bounded deviation."""
    k = _spd(rng, 64).astype(np.float32)
    l32 = np.asarray(chol.cholesky_dense_via_tiles(jnp.asarray(k), 16))
    lmp = np.asarray(
        chol.cholesky_dense_via_tiles(jnp.asarray(k), 16, update_dtype=jnp.bfloat16)
    )
    rel = np.abs(lmp - l32).max() / np.abs(l32).max()
    assert rel < 0.02, rel


def test_pallas_backend_matches(rng):
    k = _spd(rng, 64)
    l_p = np.asarray(
        chol.cholesky_dense_via_tiles(jnp.asarray(k), 16, backend="pallas")
    )
    l_m = np.asarray(chol.monolithic_cholesky(jnp.asarray(k)))
    np.testing.assert_allclose(l_p, l_m, atol=1e-3)


def test_float64(rng):
    # f64 path (CPU validation dtype; TPU runs f32/bf16 — DESIGN.md §2)
    k = _spd(rng, 64, np.float64)
    enable_x64 = getattr(jax, "enable_x64", None) or jax.experimental.enable_x64
    with enable_x64():
        l_t = np.asarray(chol.cholesky_dense_via_tiles(jnp.asarray(k), 16))
        np.testing.assert_allclose(l_t, np.linalg.cholesky(k), atol=1e-10)


def test_jit_compilable(rng):
    k = jnp.asarray(_spd(rng, 64))
    packed = tiling.pack_lower(k, 16)
    fn = jax.jit(chol.tiled_cholesky)
    out = fn(packed)
    ref = chol.tiled_cholesky(packed)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
