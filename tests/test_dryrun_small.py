"""Reduced-mesh dry-run: lower+compile representative arch×shape cells on an
8-device subprocess mesh — exercises the exact production code path of
launch/dryrun.py without the 512-device compile times."""

import pytest

from _subproc import run_with_devices

pytestmark = pytest.mark.slow


def test_train_prefill_decode_cells_compile():
    out = run_with_devices(
        r"""
import dataclasses, jax, jax.numpy as jnp
from repro import compat
from repro import configs
from repro.launch import specs as sp
from repro.train.train_step import make_train_step
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.optim import Adam
from repro.configs.base import ShapeConfig

mesh = compat.make_mesh((4, 2), ("data", "model"))
compat.set_mesh(mesh)
shape_train = ShapeConfig("t", 64, 8, "train")
shape_dec = ShapeConfig("d", 64, 8, "decode")

for arch in ("olmo-1b", "gemma2-2b", "qwen3-moe-235b-a22b", "mamba2-1.3b",
             "recurrentgemma-2b"):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              router_group_size=64)
    ins = sp.input_specs(cfg, shape_train)
    fn, _ = make_train_step(cfg, Adam(1e-3), mesh, shape_train, donate=False)
    ps = sp.params_shape(cfg)
    oss = jax.eval_shape(Adam(1e-3).init, ps)
    c = fn.lower(ps, oss, ins["inputs"], ins["labels"]).compile()
    from repro import compat
    assert compat.cost_analysis(c).get("flops", 0) > 0
    dfn, _ = make_decode_step(cfg, mesh, shape_dec)
    ins_d = sp.input_specs(cfg, shape_dec)
    c2 = dfn.lower(ps, ins_d["token"], ins_d["pos"], ins_d["caches"]).compile()
    print(arch, "OK")
print("DRYRUN_SMALL_OK")
""",
        n_devices=8,
        timeout=900,
    )
    assert "DRYRUN_SMALL_OK" in out


def test_gp_cell_compiles_multiaxis():
    out = run_with_devices(
        r"""
import jax, jax.numpy as jnp
from repro import compat
from repro.core import distributed as dist
from repro.core.kernels_math import SEKernelParams

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
m_tiles, m, n, nt = 8, 16, 128, 32
fn = dist.distributed_gp_predict_fn(
    mesh, m_tiles=m_tiles, tile_size=m, n_valid=n, n_test_valid=nt,
    params=SEKernelParams.paper_defaults(),
    row_axes=("pod", "data"), col_axes=("model",))
xc = jax.ShapeDtypeStruct((m_tiles, m, 3), jnp.float32)
yc = jax.ShapeDtypeStruct((m_tiles, m), jnp.float32)
xtc = jax.ShapeDtypeStruct((nt // m, m, 3), jnp.float32)
c = jax.jit(fn).lower(xc, yc, xtc).compile()
txt = c.as_text()
assert "all-gather" in txt or "all-reduce" in txt
print("GP_MULTIAXIS_OK")
""",
        n_devices=8,
    )
    assert "GP_MULTIAXIS_OK" in out
