"""Regression: the assembled covariance diagonal must be *exactly* v + sigma^2.

Pre-fix, the symmetric assembly computed K(i, i) through the kernel function
itself: ``k(x_i, x_i) = v * exp(-0.5 * d2(x_i, x_i))``.  In float32 the
squared distance of a point to itself is not exactly zero once coordinates
carry a large common offset (the expanded |a|^2 + |b|^2 - 2ab^T form cancels
catastrophically), so diagonals came out as ``v * exp(-eps)`` — off by ~5e-4
at offset ~256 — eroding the noise regularization and, at larger offsets,
breaking positive-definiteness.  The fix pins on-diagonal entries to the
``diag + noise`` constant with a ``jnp.where`` in both the jnp assembly tile
and the Pallas cov-assembly kernel (DESIGN.md §13).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_math as km
from repro.core import predict as pred
from repro.core import tiling


def _offset_data(n=64, offset=256.0, seed=0):
    # moderate offset: enough that f32 distance cancellation corrupts a
    # naively-computed diagonal (~5e-4 error, breaking bitwise equality),
    # small enough that off-diagonal structure survives and K stays PD
    rng = np.random.default_rng(seed)
    return (offset + 10.0 * rng.random((n, 2))).astype(np.float32)


def _dense_from_packed(packed, n, m):
    full = np.asarray(tiling.unpack_lower(packed, fill="symmetric"))
    return full[:n, :n]


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_diagonal_bitwise_exact(backend):
    x = _offset_data()
    m = 32
    p = km.SEKernelParams(lengthscale=1.0, vertical=1.0, noise=0.1)
    xc = tiling.pad_features(jnp.asarray(x), m)
    packed = pred.assemble_packed_covariance(xc, p, x.shape[0], backend=backend)
    full = _dense_from_packed(np.asarray(packed), x.shape[0], m)
    d = np.diagonal(full)
    # bitwise: the fixed assembly writes the f32 constant v + sigma^2 directly
    assert np.all(d == np.float32(1.1)), np.unique(d)
    # and the pinned diagonal keeps the matrix factorizable
    np.linalg.cholesky(np.asarray(full, np.float64))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_offset_data_end_to_end(backend):
    """Tiled predict on offset data still matches the dense reference."""
    x = _offset_data()
    rng = np.random.default_rng(1)
    y = np.sin(x.sum(-1) / 50.0).astype(np.float32)
    xt = x[:7] + rng.normal(scale=0.5, size=(7, 2)).astype(np.float32)
    p = km.SEKernelParams(lengthscale=2.0, vertical=1.0, noise=0.1)
    ref = pred.predict_monolithic(x, y, xt, p)
    mean = pred.predict(x, y, xt, p, 32, backend=backend)
    np.testing.assert_allclose(mean, ref, rtol=0, atol=5e-3)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_diagonal_exact_for_composites(backend):
    """The pin uses kernel.diag + kernel.noise, so composites get it too."""
    x = _offset_data(n=48)
    m = 32
    kern = km.Sum(km.Scaled(km.Matern52()), km.White())
    p = kern.default_params()
    want = np.float32(float(kern.diag(p)) + float(kern.noise(p)))
    xc = tiling.pad_features(jnp.asarray(x), m)
    packed = pred.assemble_packed_covariance(
        xc, p, x.shape[0], backend=backend, kernel=kern
    )
    full = _dense_from_packed(np.asarray(packed), x.shape[0], m)
    assert np.all(np.diagonal(full) == want), np.unique(np.diagonal(full))
