"""Streaming updates (DESIGN.md §10): block Cholesky append / evict.

Correctness bar: a posterior maintained incrementally (extend / shrink)
must match a from-scratch fit of the same dataset — factor, weights and
predictions — across backends, dtypes and the problem-batch axis, and the
numerical-stability guardrail (NaN heads -> CholeskyUpdateError -> full
refactorization) must actually fire.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GaussianProcess, GPBatch, SEKernelParams
from repro.core import executor, scheduler, tiling, triangular, update
from repro.core import predict as pred

PARAMS = SEKernelParams.paper_defaults()


def _data(rng, n, d=2, dtype=np.float32):
    x = rng.standard_normal((n, d)).astype(dtype)
    y = rng.standard_normal(n).astype(dtype)
    return x, y


def _scratch(x, y, m, **kw):
    return pred.posterior_state(jnp.asarray(x), jnp.asarray(y), PARAMS, m, **kw)


# ---------------------------------------------------------------------------
# Scheduler: the two update-DAG families.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r", [0, 1, 3, 6])
def test_append_dag_invariants(r):
    """Task counts, topological order, and wavefront antichains."""
    sched = scheduler.build_update_schedule(r, kind="update_append")
    counts = sched.op_counts()
    assert counts.get(scheduler.UASM, 0) == r
    assert counts[scheduler.UASMD] == 1
    assert counts.get(scheduler.UTRSM, 0) == r
    assert counts.get(scheduler.UGEMM, 0) == r * (r - 1) // 2
    assert counts.get(scheduler.USYRK, 0) == r
    assert counts[scheduler.UPOTRF] == 1
    level_of = {t: i for i, lv in enumerate(sched.levels) for t in lv}
    for t, lv in level_of.items():
        for d in scheduler.append_deps(t, r):
            assert level_of[d] < lv, (t, d)


@pytest.mark.parametrize("m_tiles", [1, 2, 4, 7])
@pytest.mark.parametrize("ns", [None, 1, 4])
def test_rank_update_dag_invariants(m_tiles, ns):
    if ns is None:
        sched = scheduler.build_update_schedule(m_tiles, kind="update_rank")
    else:
        sched = scheduler.build_wavefront_schedule(
            m_tiles, ns, kind="update_rank"
        )
    counts = sched.op_counts()
    assert counts[scheduler.UPREP] == m_tiles
    assert counts.get(scheduler.UPROW, 0) == m_tiles * (m_tiles - 1) // 2
    assert counts.get(scheduler.UCARRY, 0) == m_tiles * (m_tiles - 1) // 2
    level_of = {t: i for i, lv in enumerate(sched.levels) for t in lv}
    assert len(level_of) == sched.n_tasks  # no task lost or duplicated
    for t, lv in level_of.items():
        for d in scheduler.rank_update_deps(t, m_tiles):
            assert level_of[d] < lv, (t, d)


def test_update_plans_are_cached():
    executor.update_append_plan.cache_clear()
    p1 = executor.update_append_plan(3, 3, None)
    p2 = executor.update_append_plan(3, 3, None)
    assert p1 is p2
    assert executor.update_append_plan.cache_info().misses == 1
    # a plan's flat tasks cover the DAG exactly once
    sched = scheduler.build_update_schedule(3, kind="update_append")
    assert sorted(p1.flat_tasks()) == sorted(
        t for lv in sched.levels for t in lv
    )


# ---------------------------------------------------------------------------
# extend: incremental factor == from-scratch factorization of the grown set.
# ---------------------------------------------------------------------------


def _extend_grid():
    cells = []
    for n0, b in [(32, 5), (30, 5), (30, 40), (10, 3), (48, 16)]:
        for backend in ("jnp", "pallas"):
            heavy = backend == "pallas" and (n0 + b) > 50
            marks = [pytest.mark.slow] if heavy else []
            cells.append(
                pytest.param(n0, b, backend, marks=marks,
                             id=f"n{n0}-b{b}-{backend}")
            )
    return cells


@pytest.mark.parametrize("n0,b,backend", _extend_grid())
def test_extend_matches_scratch(rng, n0, b, backend):
    m = 16
    x, y = _data(rng, n0 + b)
    state = _scratch(x[:n0], y[:n0], m, backend=backend)
    grown = state.extend(x[n0:], y[n0:], backend=backend)
    ref = _scratch(x, y, m, backend=backend)
    assert grown.n == n0 + b
    np.testing.assert_allclose(
        np.asarray(grown.lpacked), np.asarray(ref.lpacked), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(grown.alpha), np.asarray(ref.alpha), rtol=1e-3, atol=1e-4
    )
    xt = rng.standard_normal((7, x.shape[1])).astype(np.float32)
    mu, cov = pred.predict_from_state(grown, jnp.asarray(xt), full_cov=True)
    mu_r, cov_r = pred.predict_from_state(ref, jnp.asarray(xt), full_cov=True)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cov), np.asarray(cov_r), atol=1e-4)


def test_extend_float64_exactish(rng):
    """The f64 guardrail path: append error at the 1e-12 level."""
    enable_x64 = getattr(jax, "enable_x64", None) or jax.experimental.enable_x64
    with enable_x64():
        n0, b, m = 40, 13, 16
        x, y = _data(rng, n0 + b, dtype=np.float64)
        state = pred.posterior_state(
            jnp.asarray(x[:n0]), jnp.asarray(y[:n0]), PARAMS, m, dtype=jnp.float64
        )
        grown = state.extend(x[n0:], y[n0:])
        ref = pred.posterior_state(
            jnp.asarray(x), jnp.asarray(y), PARAMS, m, dtype=jnp.float64
        )
        assert grown.lpacked.dtype == jnp.float64
        np.testing.assert_allclose(
            np.asarray(grown.lpacked), np.asarray(ref.lpacked), atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(grown.alpha), np.asarray(ref.alpha), atol=1e-10
        )


def test_extend_legacy_state_without_live_fields(rng):
    """Pre-§10 states (beta/y_chunks None) are reconstructed on the fly."""
    n0, b, m = 32, 7, 16
    x, y = _data(rng, n0 + b)
    s = _scratch(x[:n0], y[:n0], m)
    legacy = pred.PosteriorState(
        lpacked=s.lpacked, alpha=s.alpha, x_chunks=s.x_chunks,
        n=s.n, m=s.m, params=s.params,
    )
    grown = legacy.extend(x[n0:], y[n0:])
    ref = _scratch(x, y, m)
    np.testing.assert_allclose(
        np.asarray(grown.alpha), np.asarray(ref.alpha), rtol=1e-3, atol=1e-4
    )


def test_packed_matvec_roundtrip(rng):
    """beta = L^T alpha and y = L beta reconstruct the live chunks."""
    n, m = 48, 16
    x, y = _data(rng, n)
    s = _scratch(x, y, m)
    beta = triangular.packed_matvec(s.lpacked, s.alpha, transpose=True)
    np.testing.assert_allclose(
        np.asarray(beta), np.asarray(s.beta), rtol=1e-4, atol=1e-5
    )
    yc = triangular.packed_matvec(s.lpacked, beta, transpose=False)
    np.testing.assert_allclose(
        np.asarray(yc), np.asarray(s.y_chunks), rtol=1e-3, atol=1e-4
    )


# ---------------------------------------------------------------------------
# shrink / rank updates / downdate round-trip.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k", [(48, 16), (50, 16), (64, 32)])
def test_shrink_matches_scratch(rng, n, k):
    m = 16
    x, y = _data(rng, n)
    state = _scratch(x, y, m)
    kept = state.shrink(k)
    ref = _scratch(x[k:], y[k:], m)
    assert kept.n == n - k
    np.testing.assert_allclose(
        np.asarray(kept.lpacked), np.asarray(ref.lpacked), rtol=1e-3, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(kept.alpha), np.asarray(ref.alpha), rtol=1e-3, atol=2e-4
    )


def test_shrink_validates(rng):
    x, y = _data(rng, 48)
    state = _scratch(x, y, 16)
    with pytest.raises(ValueError, match="multiple"):
        state.shrink(10)
    with pytest.raises(ValueError, match="evict"):
        state.shrink(48)


def _spd_factor(rng, n, m):
    a = rng.standard_normal((n, n))
    k = a @ a.T + n * np.eye(n)
    return k, tiling.pack_lower(jnp.asarray(np.linalg.cholesky(k), jnp.float32), m)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_rank_update_matches_dense(rng, backend):
    n, m, r = 48, 16, 5
    k, lp = _spd_factor(rng, n, m)
    w = np.zeros((n // m, m, m), np.float32)
    wv = rng.standard_normal((n, r)).astype(np.float32) * 0.3
    w[:, :, :r] = wv.reshape(n // m, m, r)
    up = update.update_factor(lp, jnp.asarray(w), backend=backend)
    ref = tiling.pack_lower(
        jnp.asarray(np.linalg.cholesky(k + wv @ wv.T), jnp.float32), m
    )
    np.testing.assert_allclose(np.asarray(up), np.asarray(ref), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_downdate_then_update_roundtrip(rng, backend):
    """downdate(update(L, W), W) == L — the hyperbolic sweep inverts the
    positive one (and exercises the new Pallas carry kernel)."""
    n, m, r = 48, 16, 4
    _, lp = _spd_factor(rng, n, m)
    w = np.zeros((n // m, m, m), np.float32)
    w[:, :, :r] = (rng.standard_normal((n, r)) * 0.5).reshape(n // m, m, r)
    wj = jnp.asarray(w)
    up = update.update_factor(lp, wj, backend=backend)
    back = update.downdate_factor(up, wj, backend=backend)
    np.testing.assert_allclose(np.asarray(back), np.asarray(lp), rtol=1e-3, atol=1e-3)


def test_nonpd_downdate_raises(rng):
    n, m = 48, 16
    _, lp = _spd_factor(rng, n, m)
    w = jnp.asarray(
        rng.standard_normal((n // m, m, m)).astype(np.float32) * 100.0
    )
    with pytest.raises(update.CholeskyUpdateError, match="refactorization"):
        update.downdate_factor(lp, w)


# ---------------------------------------------------------------------------
# GaussianProcess / GPBatch front-ends: cache contract + fleet equivalence.
# ---------------------------------------------------------------------------


def test_gp_update_extends_warm_cache(rng, monkeypatch):
    """A warm update must extend the cached posterior — zero refactorizations
    — and the following predict must match a from-scratch GP."""
    x, y = _data(rng, 50)
    xt = rng.standard_normal((9, 2)).astype(np.float32)
    gp = GaussianProcess(x[:40], y[:40], tile_size=16)
    gp.predict(xt)  # warm the cache
    calls = {"n": 0}
    orig = pred.posterior_state

    def counted(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(pred, "posterior_state", counted)
    gp.update(x[40:], y[40:])
    assert gp._cache_warm(), "warm update must keep the posterior cache"
    mu = gp.predict(xt)
    assert calls["n"] == 0, "update ran a full refactorization"
    ref = GaussianProcess(x, y, tile_size=16).predict(xt)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(ref), atol=1e-4)
    assert float(gp.nlml()) == pytest.approx(
        float(GaussianProcess(x, y, tile_size=16).nlml()), rel=1e-4
    )


def test_gp_update_cold_cache_invalidates(rng):
    x, y = _data(rng, 50)
    xt = rng.standard_normal((5, 2)).astype(np.float32)
    gp = GaussianProcess(x[:40], y[:40], tile_size=16)
    gp.update(x[40:], y[40:])  # nothing cached yet
    assert gp._posterior is None, "cold update must leave the cache cold"
    mu = gp.predict(xt)
    ref = GaussianProcess(x, y, tile_size=16).predict(xt)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(ref), atol=1e-5)


def test_gp_update_numerical_fallback(rng, monkeypatch):
    """A numerically failed append falls back to cache invalidation; the
    next predict refactorizes and stays correct."""
    x, y = _data(rng, 50)
    xt = rng.standard_normal((5, 2)).astype(np.float32)
    gp = GaussianProcess(x[:40], y[:40], tile_size=16)
    gp.predict(xt)

    def boom(*a, **kw):
        raise update.CholeskyUpdateError("synthetic instability")

    monkeypatch.setattr(update, "extend_state", boom)
    gp.update(x[40:], y[40:])
    assert gp._posterior is None, "failed append must invalidate the cache"
    monkeypatch.undo()
    mu = gp.predict(xt)
    ref = GaussianProcess(x, y, tile_size=16).predict(xt)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(ref), atol=1e-5)


def test_gp_update_validates_shapes(rng):
    x, y = _data(rng, 32)
    gp = GaussianProcess(x, y, tile_size=16)
    with pytest.raises(ValueError, match="update"):
        gp.update(rng.standard_normal((3, 2)).astype(np.float32), np.zeros(4, np.float32))


def test_gp_sliding_window(rng):
    """update() with sliding_window evicts the oldest rows and keeps the
    cache warm end-to-end (append + evict both on the fast path)."""
    x, y = _data(rng, 48)
    xt = rng.standard_normal((7, 2)).astype(np.float32)
    gp = GaussianProcess(x[:32], y[:32], tile_size=16, sliding_window=32)
    gp.predict(xt)
    gp.update(x[32:48], y[32:48])  # 48 > 32: evict the oldest 16
    assert gp.y_train.shape[0] == 32
    assert gp._cache_warm()
    ref = GaussianProcess(x[16:48], y[16:48], tile_size=16).predict(xt)
    np.testing.assert_allclose(
        np.asarray(gp.predict(xt)), np.asarray(ref), atol=1e-4
    )


def test_gp_forget_unaligned_falls_back(rng):
    x, y = _data(rng, 40)
    xt = rng.standard_normal((5, 2)).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=16)
    gp.predict(xt)
    gp.forget(10)  # not tile-aligned: cache must invalidate, result correct
    assert gp._posterior is None
    ref = GaussianProcess(x[10:], y[10:], tile_size=16).predict(xt)
    np.testing.assert_allclose(np.asarray(gp.predict(xt)), np.asarray(ref), atol=1e-5)
    with pytest.raises(ValueError, match="forget"):
        gp.forget(40)


def test_gpbatch_update_matches_loop(rng):
    """Fleet update == per-problem single-GP updates (one batched sweep)."""
    b, n0, badd, m = 3, 30, 10, 16
    xs = rng.standard_normal((b, n0 + badd, 2)).astype(np.float32)
    ys = rng.standard_normal((b, n0 + badd)).astype(np.float32)
    xt = rng.standard_normal((6, 2)).astype(np.float32)
    fleet = GPBatch(xs[:, :n0], ys[:, :n0], tile_size=m)
    fleet.predict(xt)
    fleet.update(xs[:, n0:], ys[:, n0:])
    assert fleet._cache_warm(), "fleet update must keep the stacked cache"
    mu = fleet.predict(xt)
    for i in range(b):
        gp = GaussianProcess(xs[i, :n0], ys[i, :n0], tile_size=m)
        gp.predict(xt)
        gp.update(xs[i, n0:], ys[i, n0:])
        np.testing.assert_allclose(
            np.asarray(mu[i]), np.asarray(gp.predict(xt)), rtol=1e-4, atol=1e-4
        )
    # fleet eviction
    fleet.forget(m)
    assert fleet._cache_warm()
    mu2 = fleet.predict(xt)
    ref = GaussianProcess(xs[1, m:], ys[1, m:], tile_size=m).predict(xt)
    np.testing.assert_allclose(np.asarray(mu2[1]), np.asarray(ref), rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError, match="GPBatch.update"):
        fleet.update(xs[:2, :2], ys[:2, :2])


# ---------------------------------------------------------------------------
# Property: any sequence of small appends converges to the from-scratch fit.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401

    _HAVE_HYP = True
except ImportError:
    _HAVE_HYP = False

if _HAVE_HYP:
    from hypothesis import given, settings, strategies as st

    @given(
        n0=st.integers(4, 40),
        chunks=st.lists(st.integers(1, 12), min_size=1, max_size=4),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_repeated_appends(n0, chunks, seed):
        rng = np.random.default_rng(seed)
        m = 16
        total = n0 + sum(chunks)
        x = rng.standard_normal((total, 2)).astype(np.float32)
        y = rng.standard_normal(total).astype(np.float32)
        state = pred.posterior_state(
            jnp.asarray(x[:n0]), jnp.asarray(y[:n0]), PARAMS, m
        )
        pos = n0
        for c in chunks:
            state = state.extend(x[pos : pos + c], y[pos : pos + c])
            pos += c
        ref = pred.posterior_state(jnp.asarray(x), jnp.asarray(y), PARAMS, m)
        assert state.n == total
        np.testing.assert_allclose(
            np.asarray(state.alpha), np.asarray(ref.alpha), rtol=5e-3, atol=5e-4
        )
