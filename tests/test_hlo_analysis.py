"""Collective parser: shapes, group sizes, wire-byte model."""

from repro.launch.hlo_analysis import CollectiveStats, _shape_bytes, parse_collectives


def test_shape_bytes():
    assert _shape_bytes("f32[16,256]{1,0}") == 16 * 256 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4], bf16[2,2]{1,0})") == 16 + 8
    assert _shape_bytes("pred[7]") == 7
    assert _shape_bytes("f32[]") == 4


def test_parse_allreduce_iota_groups():
    hlo = (
        "%all-reduce = f32[16,256]{1,0} all-reduce(%dot), channel_id=1, "
        "replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%add\n"
    )
    st = parse_collectives(hlo, 8)
    assert st.ops == {"all-reduce": 1}
    b = 16 * 256 * 4
    assert st.operand_bytes["all-reduce"] == b
    assert st.wire_bytes["all-reduce"] == 2 * b * (2 - 1) / 2


def test_parse_allgather_and_permute():
    hlo = (
        "%all-gather = bf16[32,64]{1,0} all-gather(%x), channel_id=2, "
        "replica_groups=[2,4]<=[8], dimensions={0}\n"
        "%collective-permute = f32[8,8]{1,0} collective-permute(%y), "
        "source_target_pairs={{0,1},{1,0}}\n"
    )
    st = parse_collectives(hlo, 8)
    assert st.ops == {"all-gather": 1, "collective-permute": 1}
    ag = 32 * 64 * 2
    assert st.wire_bytes["all-gather"] == ag * 3 / 4
    assert st.wire_bytes["collective-permute"] == 8 * 8 * 4


def test_fusion_lines_not_counted():
    hlo = "%wrapped = f32[1,8]{1,0} fusion(%all-reduce, %c), kind=kLoop\n"
    st = parse_collectives(hlo, 8)
    assert st.ops == {}


def test_explicit_group_list():
    hlo = (
        "%rs = f32[4,4]{1,0} reduce-scatter(%x), "
        "replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}\n"
    )
    st = parse_collectives(hlo, 8)
    b = 4 * 4 * 4
    assert st.wire_bytes["reduce-scatter"] == b * 3


def test_start_ops_counted_once():
    hlo = (
        "%ag = bf16[16]{0} all-gather-start(%x), replica_groups=[1,8]<=[8]\n"
        "%agd = bf16[16]{0} all-gather-done(%ag)\n"
    )
    st = parse_collectives(hlo, 8)
    assert st.ops == {"all-gather": 1}


def test_merged_scaling():
    a = CollectiveStats({"all-reduce": 1}, {"all-reduce": 10.0}, {"all-reduce": 20.0})
    b = CollectiveStats({"all-reduce": 2}, {"all-reduce": 5.0}, {"all-reduce": 7.0})
    m = a.merged(b, scale=3.0)
    assert m.ops["all-reduce"] == 7
    assert m.wire_bytes["all-reduce"] == 20.0 + 21.0
