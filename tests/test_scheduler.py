"""Tile-task DAG scheduler: counts, dependencies, critical path, chunking."""

import pytest

from repro.core import scheduler as sch


@pytest.mark.parametrize("m", [1, 2, 3, 5, 8, 16])
def test_task_counts(m):
    s = sch.build_schedule(m)
    assert s.op_counts() == sch.theoretical_task_counts(m)
    assert s.n_tasks == sum(sch.theoretical_task_counts(m).values())


@pytest.mark.parametrize("m", [2, 4, 8, 16])
def test_critical_path(m):
    # right-looking tiled Cholesky ASAP critical path is 3M - 2 levels
    assert sch.build_schedule(m).critical_path == 3 * m - 2


def test_levels_are_antichains():
    """No task may depend on another task in its own level."""
    m = 6
    s = sch.build_schedule(m)
    for level in s.levels:
        level_set = set(level)
        for t in level:
            for d in sch._deps(t, m):
                assert d not in level_set, (t, d)


def test_dependencies_respect_level_order():
    m = 5
    s = sch.build_schedule(m)
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    for t, lv in level_of.items():
        for d in sch._deps(t, m):
            assert level_of[d] < lv


@pytest.mark.parametrize("n_streams", [1, 2, 3, None])
def test_chunking(n_streams):
    tasks = list(range(7))
    chunks = sch.chunk_tasks(tasks, n_streams)
    flat = [t for c in chunks for t in c]
    assert flat == tasks
    if n_streams is not None:
        assert all(len(c) <= n_streams for c in chunks)
    else:
        assert len(chunks) == 1


def test_max_width_grows_with_m():
    w4 = sch.build_schedule(4).max_width()
    w8 = sch.build_schedule(8).max_width()
    assert w8 > w4  # more tiles -> more exposed concurrency (paper Fig. 3)


# ---------------------------------------------------------------------------
# Triangular-solve DAGs.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("m", [1, 2, 5, 9])
def test_solve_schedule_counts_and_critical_path(m, lower):
    s = sch.build_solve_schedule(m, lower=lower)
    assert s.n_tasks == m + m * (m - 1) // 2  # M TRSVs + one GEMV per tile
    assert s.critical_path == 2 * m - 1       # TRSV/GEMV levels alternate
    counts = s.op_counts()
    assert counts[sch.TRSV] == m
    assert counts.get(sch.GEMV, 0) == m * (m - 1) // 2


@pytest.mark.parametrize("lower", [True, False])
def test_solve_dependencies_respect_level_order(lower):
    m = 6
    s = sch.build_solve_schedule(m, lower=lower)
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    assert len(level_of) == s.n_tasks
    for t, lv in level_of.items():
        for d in sch.task_deps(t, s):
            assert level_of[d] < lv, (t, d)


@pytest.mark.parametrize("lower", [True, False])
def test_solve_levels_are_antichains(lower):
    m = 7
    s = sch.build_solve_schedule(m, lower=lower)
    for level in s.levels:
        level_set = set(level)
        for t in level:
            for d in sch.solve_deps(t, m, lower=lower):
                assert d not in level_set, (t, d)


# ---------------------------------------------------------------------------
# Whole-pipeline program DAG (DESIGN.md §7).
# ---------------------------------------------------------------------------


def _program_task_count(m, q, uncertainty):
    chol = sum(sch.theoretical_task_counts(m).values())
    solve = m + m * (m - 1) // 2
    count = m * (m + 1) // 2 + q * m + chol + 2 * solve + q  # +cross +xgemv
    if uncertainty:
        count += q * q + m + solve + 1  # prior + vinit + matrix solve + gram
    return count


@pytest.mark.parametrize("uncertainty", [False, True])
@pytest.mark.parametrize("m,q", [(1, 1), (4, 1), (8, 2)])
def test_program_task_counts(m, q, uncertainty):
    tasks = sch.program_tasks(m, q, uncertainty=uncertainty)
    assert len(tasks) == len(set(tasks)) == _program_task_count(m, q, uncertainty)
    s = sch.build_program_schedule(m, q, uncertainty=uncertainty)
    assert s.n_tasks == len(tasks)


@pytest.mark.parametrize("uncertainty", [False, True])
def test_program_deps_respect_level_order(uncertainty):
    m, q = 6, 2
    s = sch.build_program_schedule(m, q, uncertainty=uncertainty)
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    assert len(level_of) == s.n_tasks
    for t, lv in level_of.items():
        for d in sch.program_deps(t, m, q):
            assert level_of[d] < lv, (t, d)


def test_program_cross_stage_edges():
    """The defining property: solve/cross tasks wait for *tiles*, not stages.
    TRSV(0) depends only on POTRF@col0; CROSS tiles are ready at level 0."""
    m, q = 6, 2
    assert sch.program_deps((sch.TRSV, 0, 0, -1), m, q) == [(sch.POTRF, 0, 0, -1)]
    assert sch.program_deps((sch.CROSS, 0, 3, -1), m, q) == []
    s = sch.build_program_schedule(m, q)
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    # forward substitution of row 0 fires long before the last POTRF
    assert level_of[(sch.TRSV, 0, 0, -1)] < level_of[(sch.POTRF, m - 1, m - 1, -1)]
    assert level_of[(sch.CROSS, 0, 0, -1)] == 0


@pytest.mark.parametrize("uncertainty", [False, True])
@pytest.mark.parametrize("m", [4, 8])
def test_program_wavefront_mixes_stages(m, uncertainty):
    """Acceptance: for M >= 4 the fused wavefront has at least one wave
    mixing Cholesky tasks with solve/cross tasks (the paper's Fig. 5)."""
    chol_ops = {sch.POTRF, sch.TRSM, sch.SYRK, sch.GEMM}
    solve_cross = {
        sch.TRSV, sch.GEMV, sch.TRSV_B, sch.GEMV_B,
        sch.CROSS, sch.VINIT, sch.VTRSV, sch.VGEMV,
    }
    s = sch.build_wavefront_schedule(
        m, 4, kind="program", q_tiles=2, uncertainty=uncertainty
    )
    mixed = [
        lvl for lvl in s.levels
        if {t[0] for t in lvl} & chol_ops and {t[0] for t in lvl} & solve_cross
    ]
    assert mixed, "no wave mixed Cholesky with solve/cross tasks"


@pytest.mark.parametrize("n_streams", [2, 4])
def test_program_waves_are_antichains(n_streams):
    """Wavefront waves must stay antichains under bulk ride-along and
    op-affinity packing."""
    m, q = 5, 2
    s = sch.build_wavefront_schedule(
        m, n_streams, kind="program", q_tiles=q, uncertainty=True
    )
    for level in s.levels:
        level_set = set(level)
        for t in level:
            for d in sch.program_deps(t, m, q):
                assert d not in level_set, (t, d)


# ---------------------------------------------------------------------------
# Level-batched executor plans must issue tasks in dependency order.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_streams", [None, 1, 2, 4])
def test_cholesky_plan_order_respects_deps(n_streams):
    from repro.core import executor

    m = 6
    plan = executor.cholesky_plan(m, n_streams)
    pos = {t: i for i, t in enumerate(plan.flat_tasks())}
    assert len(pos) == sch.build_schedule(m).n_tasks
    for t, i in pos.items():
        for d in sch._deps(t, m):
            assert pos[d] < i, (t, d)
    # within a level, batches only ever contain independent tasks, so the
    # stronger property also holds: every dep lives in an *earlier level*
    level_of = {t: li for li, lvl in enumerate(plan.levels) for b in lvl for t in b.tasks}
    for t, li in level_of.items():
        for d in sch._deps(t, m):
            assert level_of[d] < li, (t, d)


@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("n_streams", [None, 2])
def test_solve_plan_order_respects_deps(lower, n_streams):
    from repro.core import executor

    m = 6
    plan = executor.solve_plan(m, lower=lower, n_streams=n_streams)
    pos = {t: i for i, t in enumerate(plan.flat_tasks())}
    for t, i in pos.items():
        for d in sch.solve_deps(t, m, lower=lower):
            assert pos[d] < i, (t, d)


# ---------------------------------------------------------------------------
# The trainable NLML prefix (q_tiles=0 program, DESIGN.md §8).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 4, 6])
def test_nlml_schedule_is_program_prefix(m):
    """q_tiles=0 drops exactly the test-point stages: no CROSS/PRIOR tiles,
    no prediction heads — just assembly, factorization and both solves."""
    s = sch.build_nlml_schedule(m)
    counts = {}
    for lvl in s.levels:
        for t in lvl:
            counts[t[0]] = counts.get(t[0], 0) + 1
    for op in (sch.CROSS, sch.PRIOR, sch.XGEMV, sch.VINIT, sch.VTRSV, sch.VGEMV, sch.GRAM):
        assert op not in counts, op
    solve = m + m * (m - 1) // 2
    assert counts[sch.ASSEMBLE] == m * (m + 1) // 2
    assert counts[sch.TRSV] == counts[sch.TRSV_B] == m
    assert s.n_tasks == (
        m * (m + 1) // 2
        + sum(sch.theoretical_task_counts(m).values())
        + 2 * solve
    )
    # dependency-faithful leveling
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    for t, lv in level_of.items():
        for d in sch.program_deps(t, m, 0):
            assert level_of[d] < lv, (t, d)


@pytest.mark.parametrize("n_streams", [1, 3])
def test_nlml_wavefront_schedule(n_streams):
    """The finite-pool wavefront handles the q_tiles=0 program too."""
    m = 5
    s = sch.build_wavefront_schedule(m, n_streams, kind="program", q_tiles=0)
    assert s.n_tasks == sch.build_nlml_schedule(m).n_tasks
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    for t, lv in level_of.items():
        for d in sch.program_deps(t, m, 0):
            assert level_of[d] < lv, (t, d)
