"""Tile-task DAG scheduler: counts, dependencies, critical path, chunking."""

import pytest

from repro.core import scheduler as sch


@pytest.mark.parametrize("m", [1, 2, 3, 5, 8, 16])
def test_task_counts(m):
    s = sch.build_schedule(m)
    assert s.op_counts() == sch.theoretical_task_counts(m)
    assert s.n_tasks == sum(sch.theoretical_task_counts(m).values())


@pytest.mark.parametrize("m", [2, 4, 8, 16])
def test_critical_path(m):
    # right-looking tiled Cholesky ASAP critical path is 3M - 2 levels
    assert sch.build_schedule(m).critical_path == 3 * m - 2


def test_levels_are_antichains():
    """No task may depend on another task in its own level."""
    m = 6
    s = sch.build_schedule(m)
    for level in s.levels:
        level_set = set(level)
        for t in level:
            for d in sch._deps(t, m):
                assert d not in level_set, (t, d)


def test_dependencies_respect_level_order():
    m = 5
    s = sch.build_schedule(m)
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    for t, lv in level_of.items():
        for d in sch._deps(t, m):
            assert level_of[d] < lv


@pytest.mark.parametrize("n_streams", [1, 2, 3, None])
def test_chunking(n_streams):
    tasks = list(range(7))
    chunks = sch.chunk_tasks(tasks, n_streams)
    flat = [t for c in chunks for t in c]
    assert flat == tasks
    if n_streams is not None:
        assert all(len(c) <= n_streams for c in chunks)
    else:
        assert len(chunks) == 1


def test_max_width_grows_with_m():
    w4 = sch.build_schedule(4).max_width()
    w8 = sch.build_schedule(8).max_width()
    assert w8 > w4  # more tiles -> more exposed concurrency (paper Fig. 3)
