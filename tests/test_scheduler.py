"""Tile-task DAG scheduler: counts, dependencies, critical path, chunking."""

import pytest

from repro.core import scheduler as sch


@pytest.mark.parametrize("m", [1, 2, 3, 5, 8, 16])
def test_task_counts(m):
    s = sch.build_schedule(m)
    assert s.op_counts() == sch.theoretical_task_counts(m)
    assert s.n_tasks == sum(sch.theoretical_task_counts(m).values())


@pytest.mark.parametrize("m", [2, 4, 8, 16])
def test_critical_path(m):
    # right-looking tiled Cholesky ASAP critical path is 3M - 2 levels
    assert sch.build_schedule(m).critical_path == 3 * m - 2


def test_levels_are_antichains():
    """No task may depend on another task in its own level."""
    m = 6
    s = sch.build_schedule(m)
    for level in s.levels:
        level_set = set(level)
        for t in level:
            for d in sch._deps(t, m):
                assert d not in level_set, (t, d)


def test_dependencies_respect_level_order():
    m = 5
    s = sch.build_schedule(m)
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    for t, lv in level_of.items():
        for d in sch._deps(t, m):
            assert level_of[d] < lv


@pytest.mark.parametrize("n_streams", [1, 2, 3, None])
def test_chunking(n_streams):
    tasks = list(range(7))
    chunks = sch.chunk_tasks(tasks, n_streams)
    flat = [t for c in chunks for t in c]
    assert flat == tasks
    if n_streams is not None:
        assert all(len(c) <= n_streams for c in chunks)
    else:
        assert len(chunks) == 1


def test_max_width_grows_with_m():
    w4 = sch.build_schedule(4).max_width()
    w8 = sch.build_schedule(8).max_width()
    assert w8 > w4  # more tiles -> more exposed concurrency (paper Fig. 3)


# ---------------------------------------------------------------------------
# Triangular-solve DAGs.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("m", [1, 2, 5, 9])
def test_solve_schedule_counts_and_critical_path(m, lower):
    s = sch.build_solve_schedule(m, lower=lower)
    assert s.n_tasks == m + m * (m - 1) // 2  # M TRSVs + one GEMV per tile
    assert s.critical_path == 2 * m - 1       # TRSV/GEMV levels alternate
    counts = s.op_counts()
    assert counts[sch.TRSV] == m
    assert counts.get(sch.GEMV, 0) == m * (m - 1) // 2


@pytest.mark.parametrize("lower", [True, False])
def test_solve_dependencies_respect_level_order(lower):
    m = 6
    s = sch.build_solve_schedule(m, lower=lower)
    level_of = {t: i for i, lvl in enumerate(s.levels) for t in lvl}
    assert len(level_of) == s.n_tasks
    for t, lv in level_of.items():
        for d in sch.task_deps(t, s):
            assert level_of[d] < lv, (t, d)


@pytest.mark.parametrize("lower", [True, False])
def test_solve_levels_are_antichains(lower):
    m = 7
    s = sch.build_solve_schedule(m, lower=lower)
    for level in s.levels:
        level_set = set(level)
        for t in level:
            for d in sch.solve_deps(t, m, lower=lower):
                assert d not in level_set, (t, d)


# ---------------------------------------------------------------------------
# Level-batched executor plans must issue tasks in dependency order.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_streams", [None, 1, 2, 4])
def test_cholesky_plan_order_respects_deps(n_streams):
    from repro.core import executor

    m = 6
    plan = executor.cholesky_plan(m, n_streams)
    pos = {t: i for i, t in enumerate(plan.flat_tasks())}
    assert len(pos) == sch.build_schedule(m).n_tasks
    for t, i in pos.items():
        for d in sch._deps(t, m):
            assert pos[d] < i, (t, d)
    # within a level, batches only ever contain independent tasks, so the
    # stronger property also holds: every dep lives in an *earlier level*
    level_of = {t: li for li, lvl in enumerate(plan.levels) for b in lvl for t in b.tasks}
    for t, li in level_of.items():
        for d in sch._deps(t, m):
            assert level_of[d] < li, (t, d)


@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("n_streams", [None, 2])
def test_solve_plan_order_respects_deps(lower, n_streams):
    from repro.core import executor

    m = 6
    plan = executor.solve_plan(m, lower=lower, n_streams=n_streams)
    pos = {t: i for i, t in enumerate(plan.flat_tasks())}
    for t, i in pos.items():
        for d in sch.solve_deps(t, m, lower=lower):
            assert pos[d] < i, (t, d)
