"""Fault tolerance: kill a training run mid-flight, restart, verify resume.
Also: straggler detection and data determinism across restarts."""

import os
import signal
import subprocess
import sys
import time

from _subproc import SRC

SCRIPT = r"""
import sys, jax, jax.numpy as jnp
from repro import configs
from repro.models import transformer as tf
from repro.optim import Adam
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer
from repro.data.synthetic import token_batches

ckdir, steps = sys.argv[1], int(sys.argv[2])
cfg = configs.get_smoke_config("qwen1.5-0.5b")
params = tf.init_model(jax.random.PRNGKey(0), cfg)
opt = Adam(learning_rate=1e-3)
step_fn, _ = make_train_step(cfg, opt, donate=False)

def data_fn(step):
    t, l = next(token_batches(cfg.vocab_size, 4, 16, seed=step))
    return jnp.asarray(t), jnp.asarray(l)

tr = Trainer(step_fn, params, opt.init(params), data_fn,
             ckpt_dir=ckdir, ckpt_every=5, ckpt_async=False, log_every=0)
print(f"RESUMED_FROM={tr.report.resumed_from}", flush=True)
rep = tr.run(steps)
print(f"FINAL_STEP={rep.steps} LOSS={rep.last_loss:.4f}", flush=True)
"""


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_kill_and_resume(tmp_path):
    ck = str(tmp_path / "ck")
    # start a 60-step run and kill it after the first checkpoints appear
    proc = subprocess.Popen(
        [sys.executable, "-c", SCRIPT, ck, "60"],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
    )
    deadline = time.time() + 300
    killed = False
    while time.time() < deadline:
        if os.path.isdir(ck) and any(d.startswith("step_") for d in os.listdir(ck)):
            time.sleep(0.5)
            proc.send_signal(signal.SIGKILL)
            killed = True
            break
        if proc.poll() is not None:
            break
        time.sleep(0.2)
    proc.wait(timeout=60)
    assert killed, "run finished before a checkpoint appeared — lower ckpt_every"

    # restart: must resume from the persisted step (> 0) and complete
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, ck, "10"],
        env=_env(), capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    resumed = [l for l in out.stdout.splitlines() if l.startswith("RESUMED_FROM=")]
    assert resumed and resumed[0] != "RESUMED_FROM=None", out.stdout
    step = int(resumed[0].split("=")[1])
    assert step >= 5
    final = [l for l in out.stdout.splitlines() if l.startswith("FINAL_STEP=")]
    assert final and int(final[0].split()[0].split("=")[1]) == step + 10


def test_straggler_detection():
    import jax.numpy as jnp

    from repro.train.trainer import Trainer

    calls = {"n": 0}

    def slow_step(params, opt, x, y):
        calls["n"] += 1
        if calls["n"] == 12:
            time.sleep(0.3)  # injected straggler
        return params, opt, jnp.float32(1.0)

    tr = Trainer(
        slow_step, {}, {}, lambda s: (None, None),
        straggler_factor=3.0, log_every=0,
    )
    rep = tr.run(20)
    assert rep.stragglers >= 1
