"""Optimizers, schedules, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import Adafactor, Adam, cosine_warmup
from repro.optim.adam import global_norm
from repro.optim.compression import compress_with_feedback, decompress


def _quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)), jnp.float32)
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

    return params, loss


def test_adam_converges():
    params, loss = _quadratic_problem()
    opt = Adam(learning_rate=0.1)
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_adafactor_converges():
    params, loss = _quadratic_problem()
    init_loss = float(loss(params))
    opt = Adafactor(learning_rate=0.3)
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    # RMS-clipped factored updates converge slower than Adam on the tail;
    # two orders of magnitude in 300 steps is the expected envelope.
    assert float(loss(params)) < 0.02 * init_loss


def test_adafactor_memory_is_factored():
    opt = Adafactor()
    params = {"big": jnp.zeros((512, 256)), "small": jnp.zeros((8,))}
    state = opt.init(params)
    v_big = state["v"]["big"]
    assert set(v_big) == {"vr", "vc"}
    assert v_big["vr"].shape == (512,) and v_big["vc"].shape == (256,)
    assert state["v"]["small"]["v"].shape == (8,)


def test_adam_clip_norm():
    opt = Adam(learning_rate=1.0, clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    new_params, _ = opt.update(huge, state, params)
    # with clipping, the first Adam step is bounded by lr
    assert float(jnp.abs(new_params["w"]).max()) < 2.0


def test_cosine_warmup_schedule():
    s = cosine_warmup(1.0, warmup=10, total=110, floor=0.1)
    assert float(s(0)) == 0.0
    assert float(s(10)) == 1.0
    assert abs(float(s(110)) - 0.1) < 1e-6
    assert float(s(5)) == 0.5


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-6


def test_error_feedback_accumulates():
    """Error feedback makes the *running sum* of dequantized grads track the
    running sum of true grads to within one quantization step."""
    rng = np.random.default_rng(1)
    g_total = np.zeros(100, np.float32)
    d_total = np.zeros(100, np.float32)
    err = jnp.zeros((100,), jnp.float32)
    for i in range(20):
        g = jnp.asarray(rng.standard_normal(100).astype(np.float32))
        q, s, err = compress_with_feedback(g, err, chunk=50)
        d = decompress(q, s, g.shape, g.size)
        g_total += np.asarray(g)
        d_total += np.asarray(d)
    # residual bounded by the last error-feedback buffer, not growing in t
    resid = np.abs(g_total - d_total).max()
    assert resid <= float(jnp.abs(err).max()) + 1e-5
