"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + finiteness asserts, plus prefill↔decode cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf

B, S = 2, 32


def _inputs(cfg, key):
    if cfg.input_mode == "embeddings":
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_finite(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_model(key, cfg)
    inputs = _inputs(cfg, jax.random.PRNGKey(1))
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    loss, grads = jax.value_and_grad(tf.loss_fn)(params, cfg, inputs, labels)
    assert np.isfinite(float(loss))
    gn = np.sqrt(sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads)))
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_and_decode_shapes(arch):
    cfg = configs.get_smoke_config(arch)
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    inputs = _inputs(cfg, jax.random.PRNGKey(1))
    logits, caches = tf.prefill_fn(params, cfg, inputs)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, caches2 = tf.decode_fn(params, cfg, tok, jnp.int32(S), caches)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen1.5-0.5b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "gemma2-2b"])
def test_decode_matches_prefill(arch):
    """Decoding token t+1 with prefilled caches must match a full forward
    over the extended sequence — the strongest cache-correctness check."""
    cfg = configs.get_smoke_config(arch)
    if cfg.input_mode == "embeddings":
        pytest.skip("token-path check")
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    # full forward over S+1 tokens: logits at the last position
    logits_full, _ = tf.prefill_fn(params, cfg, toks)
    # prefill S tokens then decode token S
    _, caches = tf.prefill_fn(params, cfg, toks[:, :S])
    logits_dec, _ = tf.decode_fn(params, cfg, toks[:, S:], jnp.int32(S), caches)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        atol=5e-2, rtol=5e-2,
    )


def test_mamba2_seq_matches_steps():
    """SSD chunked sequence mode == sequential single-step recurrence."""
    from repro.models import mamba2 as m2

    cfg = configs.get_smoke_config("mamba2-1.3b")
    p = m2.init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model)) * 0.3
    out_seq, _ = m2.apply_mamba2_seq(p, x, cfg)
    state = m2.init_mamba2_state(cfg, 1, jnp.float32)
    outs = []
    for t in range(16):
        o, state = m2.apply_mamba2_step(p, x[:, t : t + 1], state, cfg)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_seq), np.asarray(out_step), atol=2e-3, rtol=2e-2
    )


def test_rglru_seq_matches_steps():
    from repro.models import rglru as rg

    cfg = configs.get_smoke_config("recurrentgemma-2b")
    p = rg.init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.3
    out_seq, _ = rg.apply_rglru_seq(p, x, cfg)
    state = rg.init_rglru_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(12):
        o, state = rg.apply_rglru_step(p, x[:, t : t + 1], state, cfg)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(out_seq), np.asarray(jnp.concatenate(outs, 1)), atol=2e-4
    )


def test_local_attention_masks_window():
    """A token far outside the local window must not influence the output."""
    cfg = configs.get_smoke_config("gemma2-2b")  # window 16, pattern local/global
    from repro.models import attention as attn

    p = attn.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
    y1, _ = attn.attend_full(p, x, pos, cfg, local=True)
    x2 = x.at[:, 0].set(x[:, 0] + 100.0)  # outside window of the last token
    y2, _ = attn.attend_full(p, x2, pos, cfg, local=True)
    np.testing.assert_allclose(
        np.asarray(y1[:, -1]), np.asarray(y2[:, -1]), atol=1e-4
    )


def test_param_counts_match_configs():
    """Full configs should land near their nominal sizes."""
    expected = {
        "gemma2-2b": (2.0e9, 3.5e9),
        "olmo-1b": (0.9e9, 1.5e9),
        "qwen1.5-0.5b": (0.3e9, 0.7e9),
        "chatglm3-6b": (5.5e9, 7.5e9),
        # real rg-2b is 2.7B; the RG-LRU gate parameterization is impl-defined
        # (dense a/i gates here) — band covers both
        "recurrentgemma-2b": (1.6e9, 3.6e9),
        "qwen3-moe-235b-a22b": (2.0e11, 2.6e11),
        "arctic-480b": (4.3e11, 5.2e11),
        "llava-next-34b": (3.0e10, 3.9e10),
        "musicgen-large": (2.0e9, 3.6e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
    }
    for arch, (lo, hi) in expected.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_below_total():
    cfg = configs.get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()
