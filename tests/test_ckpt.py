"""Checkpointing: atomicity, retention, async, elastic restore."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager


def _state(x=1.0):
    return {
        "params": {"w": jnp.full((4, 4), x), "b": jnp.arange(3.0)},
        "opt": {"m": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((3,))}, "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state(3.5)
    mgr.save(10, state)
    step, restored = mgr.restore(_state(0.0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]), 3.5)
    assert int(restored["opt"]["step"]) == 7


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]
    _, restored = mgr.restore(_state())
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]), 4.0)


def test_no_tmp_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state())
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1.0), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_structure_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    with pytest.raises((ValueError, KeyError)):
        mgr.restore({"other": jnp.zeros((2,))})


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    bad = _state()
    bad["params"]["w"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        mgr.save(s, _state(float(s)))
    step, restored = mgr.restore(_state(), step=2)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]), 2.0)
