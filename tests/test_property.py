"""Hypothesis property-based tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cholesky as chol
from repro.core import predict as pred
from repro.core import tiling
from repro.core.kernels_math import SEKernelParams, se_kernel

_settings = dict(max_examples=20, deadline=None)


@given(
    n=st.integers(4, 40),
    d=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
    l=st.floats(0.3, 3.0),
    v=st.floats(0.3, 3.0),
)
@settings(**_settings)
def test_se_kernel_matrix_is_psd(n, d, seed, l, v):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    p = SEKernelParams(l, v, 0.0)
    k = np.asarray(se_kernel(jnp.asarray(x), jnp.asarray(x), p), np.float64)
    evals = np.linalg.eigvalsh((k + k.T) / 2)
    assert evals.min() > -1e-4 * v * n


@given(
    m_tiles=st.integers(1, 6),
    m=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    n_streams=st.sampled_from([None, 1, 3]),
)
@settings(**_settings)
def test_tiled_cholesky_reconstructs(m_tiles, m, seed, n_streams):
    n = m_tiles * m
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    k = a @ a.T + n * np.eye(n, dtype=np.float32)
    l = np.asarray(
        chol.cholesky_dense_via_tiles(jnp.asarray(k), m, n_streams=n_streams)
    )
    np.testing.assert_allclose(l @ l.T, k, rtol=5e-2, atol=5e-2 * n)
    assert np.allclose(np.triu(l, 1), 0.0)


@given(
    n=st.integers(3, 50),
    m=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**_settings)
def test_padding_never_changes_predictions(n, m, seed):
    """Any (n, tile) combination gives the same result as the dense path."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((5, 2)).astype(np.float32)
    p = SEKernelParams.paper_defaults()
    mu_t = np.asarray(pred.predict(jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, m))
    mu_m = np.asarray(
        pred.predict_monolithic(jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p)
    )
    np.testing.assert_allclose(mu_t, mu_m, atol=5e-3)


@given(m_tiles=st.integers(1, 12))
@settings(**_settings)
def test_packed_tile_count(m_tiles):
    assert tiling.num_packed_tiles(m_tiles) == m_tiles * (m_tiles + 1) // 2
    rows, cols = tiling._packed_coords(m_tiles)
    assert len(rows) == tiling.num_packed_tiles(m_tiles)
    assert (rows >= cols).all()


@given(
    n=st.sampled_from([16, 24, 33]),
    seed=st.integers(0, 2**31 - 1),
    freq=st.floats(0.5, 2.0),
)
@settings(max_examples=8, deadline=None)
def test_tiled_scan_optimizer_loss_curve_improves(n, seed, freq):
    """The jitted lax.scan Adam loop over the tiled NLML: every loss along
    the curve is finite and the final loss never exceeds the initial one."""
    from repro.core import mll

    rng = np.random.default_rng(seed)
    x = rng.uniform(-3, 3, (n, 1)).astype(np.float32)
    y = (np.sin(freq * x[:, 0]) + 0.1 * rng.standard_normal(n)).astype(np.float32)
    _, losses = mll.optimize_hyperparameters(
        jnp.asarray(x),
        jnp.asarray(y),
        SEKernelParams.paper_defaults(),
        steps=12,
        lr=0.05,
        method="tiled",
        tile_size=8,
    )
    losses = np.asarray(losses)
    assert np.isfinite(losses).all()
    assert losses[-1] <= losses[0]


@given(n=st.sampled_from([24, 40]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_tiled_optimization_recovers_monolithic_hyperparameters(n, seed):
    """From the same init, seeds and step count, training through the tiled
    program lands within tolerance of the monolithic optimizer."""
    from repro.core import mll

    rng = np.random.default_rng(seed)
    x = rng.uniform(-3, 3, (n, 1)).astype(np.float32)
    y = (np.sin(1.5 * x[:, 0]) + 0.1 * rng.standard_normal(n)).astype(np.float32)
    init = SEKernelParams.paper_defaults()
    p_t, l_t = mll.optimize_hyperparameters(
        jnp.asarray(x), jnp.asarray(y), init,
        steps=15, lr=0.05, method="tiled", tile_size=8,
    )
    p_m, l_m = mll.optimize_hyperparameters(
        jnp.asarray(x), jnp.asarray(y), init, steps=15, lr=0.05, method="monolithic"
    )
    np.testing.assert_allclose(np.asarray(l_t), np.asarray(l_m), rtol=1e-3, atol=1e-2)
    for a, b in zip(
        (p_t.lengthscale, p_t.vertical, p_t.noise),
        (p_m.lengthscale, p_m.vertical, p_m.noise),
    ):
        np.testing.assert_allclose(float(a), float(b), rtol=2e-2, atol=1e-4)


@given(
    n=st.integers(8, 48),
    mi=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    l=st.floats(0.4, 2.0),
)
@settings(max_examples=10, deadline=None)
def test_lowrank_variance_is_nonnegative(n, mi, seed, l):
    """The whitened Nyström head never produces a negative predictive
    variance — the clamp plus B's unit eigenvalue floor hold for any
    (n, m_inducing, lengthscale) the strategies can see."""
    from repro.core import lowrank

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((6, 2)).astype(np.float32)
    p = SEKernelParams(l, 1.0, 0.1)
    state = lowrank.lowrank_state(x, y, p, min(mi, n), 8)
    _, cov = lowrank.predict_from_lowrank_state(
        state, jnp.asarray(xt), full_cov=True
    )
    var = np.diag(np.asarray(cov))
    assert np.isfinite(var).all()
    assert (var >= 0.0).all()


@given(
    n=st.integers(10, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_lowrank_converges_to_exact_at_full_rank(n, seed):
    """With the inducing set equal to the training inputs the Nyström
    posterior mean collapses onto the exact posterior."""
    from repro.core import lowrank

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 2)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((5, 2)).astype(np.float32)
    p = SEKernelParams.paper_defaults()
    mu_exact = np.asarray(
        pred.predict(jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, 8)
    )
    state = lowrank.lowrank_state(x, y, p, n, 8, inducing=jnp.asarray(x))
    mu_lr = np.asarray(lowrank.predict_from_lowrank_state(state, jnp.asarray(xt)))
    np.testing.assert_allclose(mu_lr, mu_exact, atol=5e-2)


@given(
    seed=st.integers(0, 2**31 - 1),
    chunk=st.sampled_from([64, 256, 1024]),
    size=st.integers(10, 5000),
)
@settings(**_settings)
def test_compression_error_bound(seed, chunk, size):
    from repro.optim.compression import compress, decompress

    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(size).astype(np.float32) * 10)
    q, s = compress(g, chunk=chunk)
    d = decompress(q, s, g.shape, g.size)
    # per-chunk error bound: half a quantization step
    err = np.abs(np.asarray(d) - np.asarray(g)).max()
    assert err <= float(s.max()) / 2 + 1e-6
