"""B-axis sharding (DESIGN.md §12): sharded fleets match unsharded fleets.

Fast tests drive the mesh plumbing on a 1-device mesh (covered by the
tier-1 coverage lane); the slow subprocess tests force 8 host devices and
assert the three acceptance properties — numerical equivalence at 1e-5,
Plan shard-invariance (`program_plan.cache_info` identical across device
counts), and a true B/P per-device shard of every stacked buffer.
"""

import numpy as np
import pytest

from _subproc import run_with_devices
from repro.core import executor
from repro.core.gp import GPBatch, GPFleet
from repro.core.kernels_math import SEKernelParams
from repro.launch.mesh import make_fleet_mesh
from repro.train import attach_mesh, make_gp_serve_step, make_gp_train_step


def _fleet_data(b=4, n=48, d=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, n, d)).astype(np.float32)
    y = rng.standard_normal((b, n)).astype(np.float32)
    xt = rng.standard_normal((8, d)).astype(np.float32)
    return x, y, xt


# -- fast: 1-device mesh exercises every mesh code path --------------------


def test_gpbatch_mesh_equivalence_1device():
    x, y, xt = _fleet_data()
    params = SEKernelParams.paper_defaults()
    plain = GPBatch(x, y, params=params, tile_size=16)
    sharded = GPBatch(x, y, params=params, tile_size=16, mesh=make_fleet_mesh())
    np.testing.assert_allclose(
        np.asarray(plain.predict(xt)), np.asarray(sharded.predict(xt)),
        atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(plain.nlml()), np.asarray(sharded.nlml()), atol=1e-4
    )
    # warm streaming append stays on the sharded path
    rng = np.random.default_rng(7)
    xa = rng.standard_normal((4, 16, 3)).astype(np.float32)
    ya = rng.standard_normal((4, 16)).astype(np.float32)
    plain.update(xa, ya)
    sharded.update(xa, ya)
    assert sharded._posterior is not None  # warm append, not invalidation
    np.testing.assert_allclose(
        np.asarray(plain.predict(xt)), np.asarray(sharded.predict(xt)),
        atol=1e-5,
    )


def test_gpfleet_mesh_equivalence_1device():
    rng = np.random.default_rng(1)
    d = 2
    sizes = [20, 33, 70, 120]
    xs = [rng.standard_normal((n, d)).astype(np.float32) for n in sizes]
    ys = [rng.standard_normal(n).astype(np.float32) for n in sizes]
    plain = GPFleet(xs, ys, tile_size=16)
    sharded = GPFleet(xs, ys, tile_size=16, mesh=make_fleet_mesh())
    xt = rng.standard_normal((6, d)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(plain.predict(xt)), np.asarray(sharded.predict(xt)),
        atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(plain.nlml()), np.asarray(sharded.nlml()), atol=1e-4
    )
    tests = [rng.standard_normal((k, d)).astype(np.float32) for k in (3, 0, 5, 2)]
    for a, bb in zip(
        plain.predict_each(tests), sharded.predict_each(tests)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5)
    # ragged update with migration stays equivalent under the mesh
    xa = [rng.standard_normal((k, d)).astype(np.float32) for k in (0, 40, 2, 10)]
    ya = [rng.standard_normal(k).astype(np.float32) for k in (0, 40, 2, 10)]
    plain.update(xa, ya)
    sharded.update(xa, ya)
    np.testing.assert_allclose(
        np.asarray(plain.predict(xt)), np.asarray(sharded.predict(xt)),
        atol=1e-5,
    )


def test_plan_shard_invariance_1device():
    """A mesh must never mint a new executor Plan (layout, not semantics)."""
    x, y, xt = _fleet_data(b=3, n=32)
    params = SEKernelParams.paper_defaults()
    GPBatch(x, y, params=params, tile_size=16).predict(xt)
    before = executor.program_plan.cache_info()
    GPBatch(
        x, y, params=params, tile_size=16, mesh=make_fleet_mesh()
    ).predict(xt)
    after = executor.program_plan.cache_info()
    assert after.misses == before.misses


def test_gp_step_factories():
    x, y, xt = _fleet_data(b=3, n=32)
    mesh = make_fleet_mesh()
    batch = GPBatch(x, y, tile_size=16)
    serve, sh = make_gp_serve_step(batch, mesh)
    assert batch.mesh is mesh and sh is not None
    assert "batch_axes" in sh
    mean = serve(xt)
    assert mean.shape == (3, xt.shape[0])
    np.testing.assert_allclose(
        np.asarray(mean),
        np.asarray(GPBatch(x, y, tile_size=16).predict(xt)),
        atol=1e-5,
    )
    serve_u, _ = make_gp_serve_step(GPBatch(x, y, tile_size=16), mesh,
                                    uncertainty=True)
    mu, var = serve_u(xt)
    assert mu.shape == var.shape == (3, xt.shape[0])

    train, _ = make_gp_train_step(GPBatch(x, y, tile_size=16), mesh, lr=0.05)
    nlml0 = np.asarray(GPBatch(x, y, tile_size=16).nlml())
    nlml1 = np.asarray(train(steps=3))
    assert nlml1.shape == nlml0.shape
    assert float(nlml1.sum()) < float(nlml0.sum())  # Adam made progress


def test_gp_step_factories_fleet_and_single():
    rng = np.random.default_rng(3)
    xs = [rng.standard_normal((n, 2)).astype(np.float32) for n in (20, 40)]
    ys = [rng.standard_normal(n).astype(np.float32) for n in (20, 40)]
    fleet = GPFleet(xs, ys, tile_size=16)
    mesh = make_fleet_mesh()
    serve, sh = make_gp_serve_step(fleet, mesh)
    assert fleet.mesh is mesh and sh == {"mesh": mesh}
    tests = [rng.standard_normal((k, 2)).astype(np.float32) for k in (3, 5)]
    outs = serve(tests)  # list input routes to predict_each
    assert [o.shape[0] for o in outs] == [3, 5]
    train, _ = make_gp_train_step(fleet, mesh)
    with pytest.raises(NotImplementedError):
        train()

    # single GP: mesh documented-ignored, still serves/trains
    from repro.core.gp import GaussianProcess

    gp = GaussianProcess(xs[1], ys[1], tile_size=16)
    serve1, sh1 = make_gp_serve_step(gp, mesh)
    assert sh1 is None
    assert serve1(tests[0]).shape == (3,)
    with pytest.raises(TypeError):
        attach_mesh(object(), mesh)


# -- slow: forced 8-device host mesh (the acceptance criteria) -------------


@pytest.mark.slow
def test_sharded_fleet_8dev_equivalence_and_plan_invariance():
    out = run_with_devices(
        r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import executor
from repro.core.gp import GPBatch
from repro.core.kernels_math import SEKernelParams
from repro.launch.mesh import make_fleet_mesh

assert jax.device_count() == 8
rng = np.random.default_rng(0)
B, n, d = 16, 48, 3
x = rng.standard_normal((B, n, d)).astype(np.float32)
y = rng.standard_normal((B, n)).astype(np.float32)
xt = rng.standard_normal((8, d)).astype(np.float32)
params = SEKernelParams.paper_defaults()

plain = GPBatch(x, y, params=params, tile_size=16)
mu0 = np.asarray(plain.predict(xt))
nl0 = np.asarray(plain.nlml())
before = executor.program_plan.cache_info()

mesh = make_fleet_mesh()
sharded = GPBatch(x, y, params=params, tile_size=16, mesh=mesh)
mu1 = np.asarray(sharded.predict(xt))
after = executor.program_plan.cache_info()
assert after.misses == before.misses, (before, after)  # Plan shard-invariant
assert np.abs(mu0 - mu1).max() < 1e-5

# per-device shard is B/8 along the problem axis
st = sharded._posterior
shards = st.lpacked.addressable_shards
assert len(shards) == 8
assert shards[0].data.shape[0] == B // 8, shards[0].data.shape

nl1 = np.asarray(sharded.nlml())
assert np.abs(nl0 - nl1).max() < 1e-4

# warm sharded update matches unsharded update
xa = rng.standard_normal((B, 16, d)).astype(np.float32)
ya = rng.standard_normal((B, 16)).astype(np.float32)
plain.update(xa, ya); sharded.update(xa, ya)
assert sharded._posterior is not None
mu0u = np.asarray(plain.predict(xt)); mu1u = np.asarray(sharded.predict(xt))
assert np.abs(mu0u - mu1u).max() < 1e-5
print("SHARDED_FLEET_OK")
""",
        n_devices=8,
    )
    assert "SHARDED_FLEET_OK" in out


@pytest.mark.slow
def test_sharded_ragged_fleet_8dev():
    out = run_with_devices(
        r"""
import numpy as np, jax
from repro.core.gp import GPFleet
from repro.launch.mesh import make_fleet_mesh

rng = np.random.default_rng(1)
d = 2
sizes = [20, 33, 70, 120, 18, 45, 90, 130]   # mixed buckets, widths 8/...
xs = [rng.standard_normal((n, d)).astype(np.float32) for n in sizes]
ys = [rng.standard_normal(n).astype(np.float32) for n in sizes]
plain = GPFleet(xs, ys, tile_size=16)
sharded = GPFleet(xs, ys, tile_size=16, mesh=make_fleet_mesh())
xt = rng.standard_normal((6, d)).astype(np.float32)
assert np.abs(np.asarray(plain.predict(xt))
              - np.asarray(sharded.predict(xt))).max() < 1e-5
assert np.abs(np.asarray(plain.nlml())
              - np.asarray(sharded.nlml())).max() < 1e-4
xa = [rng.standard_normal((k, d)).astype(np.float32)
      for k in (0, 40, 2, 10, 5, 0, 33, 1)]
ya = [rng.standard_normal(k).astype(np.float32)
      for k in (0, 40, 2, 10, 5, 0, 33, 1)]
plain.update(xa, ya); sharded.update(xa, ya)
assert np.abs(np.asarray(plain.predict(xt))
              - np.asarray(sharded.predict(xt))).max() < 1e-5
print("RAGGED_SHARDED_OK")
""",
        n_devices=8,
    )
    assert "RAGGED_SHARDED_OK" in out
