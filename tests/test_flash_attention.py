"""Flash-attention kernel vs the materialized-softmax reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels.flash_attention import flash_attention, flash_attention_single
from repro.models.attention import _causal_mask, _scores_softmax_out


def _ref_single(q, k, v, causal=True, softcap=None):
    s = (np.asarray(q, np.float64) @ np.asarray(k, np.float64).T) / np.sqrt(q.shape[-1])
    if softcap is not None:
        s = softcap * np.tanh(s / softcap)
    if causal:
        mask = np.tril(np.ones(s.shape, bool))
        s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(1, keepdims=True))
    p /= p.sum(1, keepdims=True)
    return p @ np.asarray(v, np.float64)


@pytest.mark.parametrize("shape", [(64, 64, 16), (128, 128, 32), (96, 192, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_single_shapes(rng, shape, causal):
    s, t, hd = shape
    q = jnp.asarray(rng.standard_normal((s, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((t, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((t, hd)).astype(np.float32))
    out = flash_attention_single(q, k, v, causal=causal, block_q=32, block_k=32)
    ref = _ref_single(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), ref, atol=5e-5)


def test_flash_softcap(rng):
    s, hd = 64, 32
    q = jnp.asarray(rng.standard_normal((s, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((s, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((s, hd)).astype(np.float32))
    out = flash_attention_single(q, k, v, causal=True, softcap=10.0, block_q=32, block_k=32)
    ref = _ref_single(q, k, v, causal=True, softcap=10.0)
    np.testing.assert_allclose(np.asarray(out), ref, atol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_gqa_matches_xla_attention(rng, dtype):
    b, s, h, kv, hd = 2, 64, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    cfg = configs.get_smoke_config("olmo-1b")
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ref = _scores_softmax_out(q, k, v, _causal_mask(pos, pos, None), cfg)
    tol = 5e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_removes_score_traffic():
    """The whole point: HBM bytes scale with S·hd, not S² (compare the
    compiled cost-analysis bytes of flash vs materialized attention)."""
    s, hd = 512, 64
    q = jax.ShapeDtypeStruct((s, hd), jnp.float32)

    def mat(q, k, v):
        sc = q @ k.T / np.sqrt(hd)
        sc = jnp.where(jnp.tril(jnp.ones((s, s), bool)), sc, -1e30)
        return jax.nn.softmax(sc, axis=-1) @ v

    c_mat = jax.jit(mat).lower(q, q, q).compile()
    flash = lambda q, k, v: flash_attention_single(q, k, v, causal=True)
    c_fl = jax.jit(flash).lower(q, q, q).compile()
    from repro import compat

    b_mat = compat.cost_analysis(c_mat)["bytes accessed"]
    b_fl = compat.cost_analysis(c_fl)["bytes accessed"]
    # interpret-mode custom calls under-report compute bytes, but the S²
    # buffers must be visible in the materialized path and absent here
    assert b_mat > 4 * s * s, b_mat
    assert b_fl < b_mat, (b_fl, b_mat)
