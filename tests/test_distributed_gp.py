"""Distributed tiled GP on an 8-device subprocess mesh: block-cyclic
Cholesky, end-to-end predict, and the compressed-DP train step."""

import pytest

from _subproc import run_with_devices

pytestmark = pytest.mark.slow


def test_distributed_cholesky_and_predict():
    out = run_with_devices(
        r"""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.core import distributed as dist, tiling, predict as pred
from repro.core.kernels_math import SEKernelParams

mesh = compat.make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(2)
n, m = 128, 16
A = rng.standard_normal((n, n)).astype(np.float32)
K = A @ A.T + n*np.eye(n, dtype=np.float32)
tiles = tiling.tile_dense(jnp.asarray(K), m)
cyc = dist.to_cyclic_layout(tiles, 4, 2)
for unroll in (False, True):
    fn = dist.distributed_cholesky_fn(mesh, m_tiles=8, unroll=unroll)
    cycL = jax.jit(fn)(jax.device_put(cyc, dist.local_tiles_sharding(mesh)))
    L = np.tril(np.asarray(tiling.untile_dense(dist.from_cyclic_layout(cycL, 4, 2))))
    assert np.abs(L - np.linalg.cholesky(K)).max() < 1e-3, unroll

ntr, nte = 128, 32
X = rng.standard_normal((ntr, 3)).astype(np.float32)
Y = rng.standard_normal(ntr).astype(np.float32)
Xt = rng.standard_normal((nte, 3)).astype(np.float32)
params = SEKernelParams.paper_defaults()
pfn = dist.distributed_gp_predict_fn(mesh, m_tiles=8, tile_size=m, n_valid=ntr,
                                     n_test_valid=nte, params=params)
mu, var = jax.jit(pfn)(tiling.pad_features(jnp.asarray(X), m),
                       tiling.pad_vector(jnp.asarray(Y), m),
                       tiling.pad_features(jnp.asarray(Xt), m))
mu_ref, cov_ref = pred.predict(jnp.asarray(X), jnp.asarray(Y), jnp.asarray(Xt),
                               params, m, full_cov=True)
assert np.allclose(np.asarray(mu).reshape(-1)[:nte], np.asarray(mu_ref), atol=1e-3)
assert np.allclose(np.asarray(var).reshape(-1)[:nte],
                   np.diagonal(np.asarray(cov_ref)), atol=1e-3)
print("DIST_GP_OK")
""",
        n_devices=8,
    )
    assert "DIST_GP_OK" in out


def test_mixed_precision_distributed_cholesky():
    out = run_with_devices(
        r"""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.core import distributed as dist, tiling
mesh = compat.make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(0)
n, m = 64, 8
A = rng.standard_normal((n, n)).astype(np.float32)
K = A @ A.T + n*np.eye(n, dtype=np.float32)
tiles = tiling.tile_dense(jnp.asarray(K), m)
cyc = dist.to_cyclic_layout(tiles, 2, 2)
fn = dist.distributed_cholesky_fn(mesh, m_tiles=8, update_dtype=jnp.bfloat16)
cycL = jax.jit(fn)(jax.device_put(cyc, dist.local_tiles_sharding(mesh)))
L = np.tril(np.asarray(tiling.untile_dense(dist.from_cyclic_layout(cycL, 2, 2))))
rel = np.abs(L - np.linalg.cholesky(K)).max() / np.abs(L).max()
assert rel < 0.02, rel
print("MP_OK")
""",
        n_devices=8,
    )
    assert "MP_OK" in out


def test_compressed_dp_step_matches_uncompressed():
    out = run_with_devices(
        r"""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro import configs
from repro.models import transformer as tf
from repro.optim import Adam
from repro.train.train_step import make_train_step, make_compressed_dp_step

mesh = compat.make_mesh((2, 4, 1), ("pod", "data", "model"))
cfg = configs.get_smoke_config("olmo-1b")
params = tf.init_model(jax.random.PRNGKey(0), cfg)
opt = Adam(learning_rate=1e-3)
opt_state = opt.init(params)
key = jax.random.PRNGKey(1)
B, S = 8, 16
tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

plain, _ = make_train_step(cfg, opt, donate=False)
p1, o1, loss1 = plain(params, opt_state, tokens, labels)

comp, init_err = make_compressed_dp_step(cfg, opt, mesh, compress_axis="pod")
err = init_err(params)
p2, o2, err, loss2 = comp(params, opt_state, err, tokens, labels)

assert abs(float(loss1) - float(loss2)) < 1e-2, (float(loss1), float(loss2))
d = max(float(jnp.abs(a - b).max()) for a, b in
        zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 5e-2, d   # int8 quantization error on one Adam step is small
print("COMPRESSED_OK")
""",
        n_devices=8,
    )
    assert "COMPRESSED_OK" in out
