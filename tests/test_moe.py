"""MoE routing: table-dispatch correctness vs a naive dense reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import moe as moe_mod


def _cfg(**kw):
    base = configs.get_smoke_config("qwen3-moe-smoke" if False else "qwen3-moe-235b-a22b")
    return dataclasses.replace(base, **kw)


def naive_moe(p, x, cfg):
    """Dense reference: every token × every expert, combine top-k."""
    t, d = x.shape
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    vals = vals / vals.sum(-1, keepdims=True)
    act = jax.nn.silu
    # all experts for all tokens
    g = act(jnp.einsum("td,edf->tef", x, p["w_gate"]))
    h = g * jnp.einsum("td,edf->tef", x, p["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"])
    oh = jax.nn.one_hot(idx, cfg.n_experts, dtype=x.dtype)      # (t, k, E)
    w = jnp.einsum("tk,tke->te", vals.astype(x.dtype), oh)
    return jnp.einsum("ted,te->td", y_all, w)


def test_moe_matches_naive_when_no_drops():
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(cfg, capacity_factor=50.0)  # no capacity drops
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model)) * 0.5
    got = moe_mod.apply_moe(p, x, cfg)
    want = naive_moe(p, x[0], cfg)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want), atol=2e-4)


def test_moe_capacity_drops_reduce_output():
    """With tiny capacity most tokens are dropped -> output mostly zero."""
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(cfg, capacity_factor=0.01, router_group_size=256)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, cfg.d_model))
    got = np.asarray(moe_mod.apply_moe(p, x, cfg))
    frac_zero = (np.abs(got).max(-1) < 1e-7).mean()
    assert frac_zero > 0.5


def test_dense_residual_added():
    cfg = configs.get_smoke_config("arctic-480b")
    cfg = dataclasses.replace(cfg, capacity_factor=50.0)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model)) * 0.5
    with_res = moe_mod.apply_moe(p, x, cfg)
    cfg_nores = dataclasses.replace(cfg, dense_residual=False)
    without = moe_mod.apply_moe(p, x, cfg_nores)
    from repro.models.layers import apply_mlp

    np.testing.assert_allclose(
        np.asarray(with_res - without),
        np.asarray(apply_mlp(p["dense"], x, cfg.mlp)),
        atol=1e-4,
    )


def test_load_balance_loss_range():
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    aux = float(moe_mod.aux_load_balance_loss(p, x, cfg))
    # perfectly balanced -> k (top-k selected fraction sums to k); skewed -> larger
    assert 0.5 * cfg.experts_per_token < aux < 10 * cfg.experts_per_token


def test_routing_is_permutation_invariant_per_token():
    """Each kept token's output must not depend on other tokens (token-choice
    routing computes per-token results; capacity only causes drops)."""
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(cfg, capacity_factor=50.0)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model)) * 0.5
    out = np.asarray(moe_mod.apply_moe(p, x, cfg))[0]
    perm = np.arange(16)[::-1].copy()
    out_p = np.asarray(moe_mod.apply_moe(p, x[:, perm], cfg))[0]
    np.testing.assert_allclose(out_p, out[perm], atol=2e-4)
