"""End-to-end tiled GP prediction vs the monolithic reference pipeline."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GaussianProcess, SEKernelParams
from repro.core import predict as pred


@pytest.fixture
def data(rng):
    n, nt, d = 100, 37, 4   # deliberately NOT tile multiples (padding path)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((nt, d)).astype(np.float32)
    return x, y, xt


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_tiled_vs_monolithic(data, backend):
    x, y, xt = data
    p = SEKernelParams.paper_defaults()
    mu_t, cov_t = pred.predict(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, 16,
        full_cov=True, backend=backend,
    )
    mu_m, cov_m = pred.predict_monolithic(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, full_cov=True
    )
    np.testing.assert_allclose(np.asarray(mu_t), np.asarray(mu_m), atol=1e-3)
    np.testing.assert_allclose(np.asarray(cov_t), np.asarray(cov_m), atol=1e-3)


def test_padding_invariance(data):
    """Results must be identical for any tile size (different padding)."""
    x, y, xt = data
    p = SEKernelParams.paper_defaults()
    mus = [
        np.asarray(pred.predict(jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, m))
        for m in (8, 16, 25, 50)
    ]
    for mu in mus[1:]:
        np.testing.assert_allclose(mu, mus[0], atol=2e-3)


def test_posterior_covariance_is_psd(data):
    x, y, xt = data
    p = SEKernelParams.paper_defaults()
    _, cov = pred.predict(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, 16, full_cov=True
    )
    evals = np.linalg.eigvalsh(np.asarray(cov, np.float64))
    assert evals.min() > -1e-3, evals.min()


def test_variances_positive_and_bounded(data):
    x, y, xt = data
    gp = GaussianProcess(x, y, tile_size=16)
    _, var = gp.predict_with_uncertainty(xt)
    var = np.asarray(var)
    assert (var > -1e-4).all()
    # posterior variance cannot exceed the prior (v = 1)
    assert (var <= 1.0 + 1e-4).all()


def test_gp_class_pipelines_agree(data):
    x, y, xt = data
    gp_t = GaussianProcess(x, y, tile_size=16)
    gp_m = GaussianProcess(x, y, pipeline="monolithic")
    np.testing.assert_allclose(
        np.asarray(gp_t.predict(xt)), np.asarray(gp_m.predict(xt)), atol=1e-3
    )


def test_interpolation_of_noiseless_points(rng):
    """GP mean should pass near training targets when noise is tiny."""
    x = np.linspace(-2, 2, 20)[:, None].astype(np.float32)
    y = np.sin(x[:, 0]).astype(np.float32)
    gp = GaussianProcess(
        x, y, params=SEKernelParams(lengthscale=0.5, vertical=1.0, noise=1e-4),
        tile_size=8,
    )
    mu = np.asarray(gp.predict(x))
    assert np.abs(mu - y).max() < 1e-2


def test_gp_rejects_transposed_features(rng):
    """(D, n) inputs must raise, not be silently transposed (ambiguous for
    square inputs, masks genuinely wrong data)."""
    x = rng.standard_normal((3, 40)).astype(np.float32)  # transposed (D, n)
    y = rng.standard_normal(40).astype(np.float32)
    with pytest.raises(ValueError, match="x_train"):
        GaussianProcess(x, y, tile_size=8)
    # (n,) 1-D convenience still works
    gp = GaussianProcess(y, y, tile_size=8)
    assert gp.x_train.shape == (40, 1)
    # valid square (n, n) input passes through untransposed
    xs = rng.standard_normal((8, 8)).astype(np.float32)
    gp = GaussianProcess(xs, y[:8], tile_size=4)
    np.testing.assert_array_equal(np.asarray(gp.x_train), xs)


def test_gp_nlml_matches_monolithic(rng):
    from repro.core import mll

    n, d = 100, 2  # not a tile multiple: exercises padding exactness
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=16)
    ref = float(
        mll.negative_log_marginal_likelihood(
            jnp.asarray(x), jnp.asarray(y), gp.params
        )
    )
    tiled = float(gp.nlml())
    assert abs(tiled - ref) < 1e-3 * abs(ref) + 1e-3
    assert float(gp.log_marginal_likelihood()) == pytest.approx(-ref, rel=1e-5)


def test_gp_nlml_reuses_cached_posterior(rng, monkeypatch):
    from repro.core import mll

    n, d = 48, 2
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=16)
    gp.predict(x[:4])  # populates the posterior cache (fused program)
    calls = {"n": 0}
    orig = pred.posterior_state

    def wrapped(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(pred, "posterior_state", wrapped)
    monolithic = {"n": 0}
    orig_chol = mll.chol.monolithic_cholesky

    def wrapped_chol(*a, **kw):
        monolithic["n"] += 1
        return orig_chol(*a, **kw)

    monkeypatch.setattr(mll.chol, "monolithic_cholesky", wrapped_chol)
    gp.nlml()
    assert calls["n"] == 0, "nlml rebuilt the posterior instead of reusing it"
    assert monolithic["n"] == 0, "nlml re-ran the monolithic Cholesky"


def test_gp_fused_cold_equals_staged_cold(rng):
    n, nt, d = 90, 17, 3
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((nt, d)).astype(np.float32)
    mu_f, var_f = GaussianProcess(x, y, tile_size=16, fused=True).predict_with_uncertainty(xt)
    mu_s, var_s = GaussianProcess(x, y, tile_size=16, fused=False).predict_with_uncertainty(xt)
    np.testing.assert_allclose(np.asarray(mu_f), np.asarray(mu_s), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var_f), np.asarray(var_s), rtol=1e-4, atol=1e-5)


def test_gp_log_marginal_likelihood_uses_tiled_path(rng, monkeypatch):
    """Regression: log_marginal_likelihood() always ran the monolithic path
    even for pipeline="tiled", inconsistent with nlml().  It must now be
    -nlml() off the cached tiled posterior — zero monolithic Choleskys."""
    from repro.core import cholesky as chol
    from repro.core import mll

    n, d = 48, 2
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    gp = GaussianProcess(x, y, tile_size=16)
    expected = -float(gp.nlml())  # populates the posterior cache
    calls = {"n": 0}
    orig = chol.monolithic_cholesky

    def wrapped(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(chol, "monolithic_cholesky", wrapped)
    lml = float(gp.log_marginal_likelihood())
    assert calls["n"] == 0, "tiled log_marginal_likelihood ran a monolithic Cholesky"
    assert lml == pytest.approx(expected, rel=1e-6)
    # monolithic pipeline still computes the true monolithic -NLML
    gp_m = GaussianProcess(x, y, pipeline="monolithic")
    ref = float(
        mll.negative_log_marginal_likelihood(jnp.asarray(x), jnp.asarray(y), gp_m.params)
    )
    assert float(gp_m.log_marginal_likelihood()) == pytest.approx(-ref, rel=1e-6)


def test_mll_optimization_improves(rng):
    from repro.core import mll

    x = rng.uniform(-3, 3, (64, 1)).astype(np.float32)
    y = (np.sin(2 * x[:, 0]) + 0.1 * rng.standard_normal(64)).astype(np.float32)
    init = SEKernelParams.paper_defaults()
    before = mll.negative_log_marginal_likelihood(jnp.asarray(x), jnp.asarray(y), init)
    opt, losses = mll.optimize_hyperparameters(
        jnp.asarray(x), jnp.asarray(y), init, steps=40, lr=0.1
    )
    after = mll.negative_log_marginal_likelihood(jnp.asarray(x), jnp.asarray(y), opt)
    assert float(after) < float(before)
    assert float(losses[-1]) <= float(losses[0])
