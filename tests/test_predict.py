"""End-to-end tiled GP prediction vs the monolithic reference pipeline."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GaussianProcess, SEKernelParams
from repro.core import predict as pred


@pytest.fixture
def data(rng):
    n, nt, d = 100, 37, 4   # deliberately NOT tile multiples (padding path)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xt = rng.standard_normal((nt, d)).astype(np.float32)
    return x, y, xt


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_tiled_vs_monolithic(data, backend):
    x, y, xt = data
    p = SEKernelParams.paper_defaults()
    mu_t, cov_t = pred.predict(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, 16,
        full_cov=True, backend=backend,
    )
    mu_m, cov_m = pred.predict_monolithic(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, full_cov=True
    )
    np.testing.assert_allclose(np.asarray(mu_t), np.asarray(mu_m), atol=1e-3)
    np.testing.assert_allclose(np.asarray(cov_t), np.asarray(cov_m), atol=1e-3)


def test_padding_invariance(data):
    """Results must be identical for any tile size (different padding)."""
    x, y, xt = data
    p = SEKernelParams.paper_defaults()
    mus = [
        np.asarray(pred.predict(jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, m))
        for m in (8, 16, 25, 50)
    ]
    for mu in mus[1:]:
        np.testing.assert_allclose(mu, mus[0], atol=2e-3)


def test_posterior_covariance_is_psd(data):
    x, y, xt = data
    p = SEKernelParams.paper_defaults()
    _, cov = pred.predict(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), p, 16, full_cov=True
    )
    evals = np.linalg.eigvalsh(np.asarray(cov, np.float64))
    assert evals.min() > -1e-3, evals.min()


def test_variances_positive_and_bounded(data):
    x, y, xt = data
    gp = GaussianProcess(x, y, tile_size=16)
    _, var = gp.predict_with_uncertainty(xt)
    var = np.asarray(var)
    assert (var > -1e-4).all()
    # posterior variance cannot exceed the prior (v = 1)
    assert (var <= 1.0 + 1e-4).all()


def test_gp_class_pipelines_agree(data):
    x, y, xt = data
    gp_t = GaussianProcess(x, y, tile_size=16)
    gp_m = GaussianProcess(x, y, pipeline="monolithic")
    np.testing.assert_allclose(
        np.asarray(gp_t.predict(xt)), np.asarray(gp_m.predict(xt)), atol=1e-3
    )


def test_interpolation_of_noiseless_points(rng):
    """GP mean should pass near training targets when noise is tiny."""
    x = np.linspace(-2, 2, 20)[:, None].astype(np.float32)
    y = np.sin(x[:, 0]).astype(np.float32)
    gp = GaussianProcess(
        x, y, params=SEKernelParams(lengthscale=0.5, vertical=1.0, noise=1e-4),
        tile_size=8,
    )
    mu = np.asarray(gp.predict(x))
    assert np.abs(mu - y).max() < 1e-2


def test_mll_optimization_improves(rng):
    from repro.core import mll

    x = rng.uniform(-3, 3, (64, 1)).astype(np.float32)
    y = (np.sin(2 * x[:, 0]) + 0.1 * rng.standard_normal(64)).astype(np.float32)
    init = SEKernelParams.paper_defaults()
    before = mll.negative_log_marginal_likelihood(jnp.asarray(x), jnp.asarray(y), init)
    opt, losses = mll.optimize_hyperparameters(
        jnp.asarray(x), jnp.asarray(y), init, steps=40, lr=0.1
    )
    after = mll.negative_log_marginal_likelihood(jnp.asarray(x), jnp.asarray(y), opt)
    assert float(after) < float(before)
    assert float(losses[-1]) <= float(losses[0])
