"""Shared test helpers.  NOTE: no XLA_FLAGS here — tests must see the single
real device; multi-device tests spawn subprocesses (see _subproc.py)."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_spd(rng, n, dtype=np.float32, jitter=None):
    a = rng.standard_normal((n, n)).astype(dtype)
    k = a @ a.T + (n if jitter is None else jitter) * np.eye(n, dtype=dtype)
    return k


@pytest.fixture
def spd():
    return make_spd
