"""Ragged fleets (DESIGN.md §11): per-problem n_valid masking, bucketing,
plan reuse, ragged streaming updates, and the continuous-batching loop.

The load-bearing invariant: executing B zero-padded problems of different
sizes through ONE fused bucket program (frontiers as traced operands) is
numerically identical — within backend tolerance — to a Python loop of
single-problem programs, for every head (mean, uncertainty, NLML)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GaussianProcess, GPFleet
from repro.core import executor, mll, tiling, triangular
from repro.core import predict as pred
from repro.core import update as upd
from repro.core.kernels_math import SEKernelParams

M = 32
NS_MIX = (48, 64, 200)            # spans <1 tile slack, exact fit, 7 tiles
PARAMS = SEKernelParams(lengthscale=0.6, vertical=1.1, noise=0.05)


def _problems(rng, ns=NS_MIX, d=2):
    xs = [rng.standard_normal((n, d)).astype(np.float32) for n in ns]
    ys = [rng.standard_normal(n).astype(np.float32) for n in ns]
    return xs, ys


def _stack(xs, ys, cap):
    """Zero-pad to a shared capacity (the bucket contract)."""
    x = jnp.stack([jnp.pad(jnp.asarray(x), ((0, cap - x.shape[0]), (0, 0)))
                   for x in xs])
    y = jnp.stack([jnp.pad(jnp.asarray(y), (0, cap - y.shape[0])) for y in ys])
    nv = jnp.asarray([x.shape[0] for x in xs], jnp.int32)
    return x, y, nv


# ---------------------------------------------------------------------------
# The equivalence grid: ragged fused vs per-problem loop.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("n_streams", [1, None])
def test_ragged_fused_matches_per_problem_loop(rng, backend, n_streams):
    xs, ys = _problems(rng)
    cap = -(-max(NS_MIX) // M) * M
    xst, yst, nv = _stack(xs, ys, cap)
    nh = 9
    xt = rng.standard_normal((nh, 2)).astype(np.float32)
    xtb = jnp.broadcast_to(jnp.asarray(xt)[None], (len(xs), nh, 2))

    atol_m, atol_s = (3e-4, 3e-3) if backend == "jnp" else (5e-4, 5e-3)
    (mean, sigma), state = pred.predict_fused_batched(
        xst, yst, xtb, PARAMS, M, full_cov=True, n_streams=n_streams,
        backend=backend, with_state=True, n_valid=nv,
    )
    nlml = mll.nlml_from_state(state, yst)
    for i, (x, y) in enumerate(zip(xs, ys)):
        mr, sr = pred.predict_fused(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), PARAMS, M,
            full_cov=True, n_streams=n_streams, backend=backend,
        )
        np.testing.assert_allclose(np.asarray(mean[i]), np.asarray(mr), atol=atol_m)
        np.testing.assert_allclose(np.asarray(sigma[i]), np.asarray(sr), atol=atol_s)
        st = pred.posterior_state(
            jnp.asarray(x), jnp.asarray(y), PARAMS, M,
            n_streams=n_streams, backend=backend,
        )
        ref = mll.nlml_from_state(st, jnp.asarray(y))
        np.testing.assert_allclose(
            float(nlml[i]), float(ref), rtol=2e-4, atol=5e-3
        )

    # warm path off the ragged state must mask the cross covariance too
    xt2 = rng.standard_normal((5, 2)).astype(np.float32)
    warm = pred.predict_from_state_batched(
        state, jnp.broadcast_to(jnp.asarray(xt2)[None], (len(xs), 5, 2)),
        n_streams=n_streams,
    )
    for i, (x, y) in enumerate(zip(xs, ys)):
        mr = pred.predict_fused(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt2), PARAMS, M,
            n_streams=n_streams, backend=backend,
        )
        np.testing.assert_allclose(np.asarray(warm[i]), np.asarray(mr), atol=atol_m)


def test_ragged_nt_valid_masks_test_rows(rng):
    """Per-problem test counts: rows past a problem's own n̂_i come back 0."""
    xs, ys = _problems(rng, ns=(20, 40))
    xst, yst, nv = _stack(xs, ys, 64)
    xtb = jnp.asarray(rng.standard_normal((2, 6, 2)).astype(np.float32))
    mean = pred.predict_fused_batched(
        xst, yst, xtb, PARAMS, M, n_valid=nv, nt_valid=jnp.asarray([3, 6]),
    )
    np.testing.assert_array_equal(np.asarray(mean[0, 3:]), 0.0)
    ref = pred.predict_fused(
        jnp.asarray(xs[0]), jnp.asarray(ys[0]), xtb[0, :3], PARAMS, M
    )
    np.testing.assert_allclose(np.asarray(mean[0, :3]), np.asarray(ref), atol=3e-4)


# ---------------------------------------------------------------------------
# Plan / trace reuse: one Plan per bucket geometry — never per size mix or B.
# ---------------------------------------------------------------------------


def test_ragged_plan_and_trace_reuse(rng):
    # the ragged program fn is ONE lru-cached object regardless of frontiers
    fn_a = pred._fused_program_fn(False, None, "jnp", None, None, None, "flat")
    fn_b = pred._fused_program_fn(False, None, "jnp", None, None, None, "flat")
    assert fn_a is fn_b

    cap = 4 * M
    xt = jnp.asarray(rng.standard_normal((2, 5, 2)).astype(np.float32))
    xs, ys = _problems(rng, ns=(40, 100))
    xst, yst, nv = _stack(xs, ys, cap)
    pred.predict_fused_batched(xst, yst, xt, PARAMS, M, n_valid=nv)
    before = executor.program_plan.cache_info()

    # same geometry, different per-problem sizes: no new plan
    xs2, ys2 = _problems(rng, ns=(17, 128))
    xst2, yst2, nv2 = _stack(xs2, ys2, cap)
    pred.predict_fused_batched(xst2, yst2, xt, PARAMS, M, n_valid=nv2)
    # same geometry, different batch width B=3: no new plan either
    xs3, ys3 = _problems(rng, ns=(33, 65, 97))
    xst3, yst3, nv3 = _stack(xs3, ys3, cap)
    xt3 = jnp.asarray(rng.standard_normal((3, 5, 2)).astype(np.float32))
    pred.predict_fused_batched(xst3, yst3, xt3, PARAMS, M, n_valid=nv3)

    after = executor.program_plan.cache_info()
    assert after.misses == before.misses, "a size mix or B change re-planned"
    assert after.hits > before.hits


# ---------------------------------------------------------------------------
# Ragged streaming updates + migration embedding.
# ---------------------------------------------------------------------------


def test_extend_state_ragged_matches_rebuild(rng):
    xs, ys = _problems(rng, ns=(30, 64, 90))
    cap = 4 * M
    xst, yst, nv = _stack(xs, ys, cap)
    env, yc = pred.nlml_program_env(xst, yst, PARAMS, M, n_valid=nv)
    state = pred.PosteriorState(
        lpacked=env["packed"], alpha=env["alpha"],
        x_chunks=tiling.pad_features(xst, M), n=cap, m=M, params=PARAMS,
        beta=env["y"], y_chunks=yc, n_valid=nv,
    )
    counts = np.array([5, 0, 33])
    b_max = counts.max()
    xn = [rng.standard_normal((c, 2)).astype(np.float32) for c in counts]
    yn = [rng.standard_normal(c).astype(np.float32) for c in counts]
    xa = jnp.stack([jnp.pad(jnp.asarray(x), ((0, b_max - len(x)), (0, 0)))
                    for x in xn])
    ya = jnp.stack([jnp.pad(jnp.asarray(y), (0, b_max - len(y))) for y in yn])
    new = upd.extend_state_ragged(state, xa, ya, counts)
    assert np.array_equal(np.asarray(new.n_valid), np.asarray(nv) + counts)

    xt = rng.standard_normal((7, 2)).astype(np.float32)
    warm = pred.predict_from_state_batched(
        new, jnp.broadcast_to(jnp.asarray(xt)[None], (3, 7, 2))
    )
    for i in range(3):
        x2 = np.concatenate([xs[i], xn[i]])
        y2 = np.concatenate([ys[i], yn[i]])
        ref = pred.predict_fused(
            jnp.asarray(x2), jnp.asarray(y2), jnp.asarray(xt), PARAMS, M
        )
        np.testing.assert_allclose(np.asarray(warm[i]), np.asarray(ref), atol=1e-3)

    # outgrowing the capacity is a migration — rejected here, GPFleet's job
    wide = cap - 90 + 1
    with pytest.raises(ValueError, match="migrate"):
        upd.extend_state_ragged(
            state,
            jnp.zeros((3, wide, 2)),
            jnp.zeros((3, wide)),
            np.array([0, 0, wide]),
        )


def test_embed_packed_is_blockdiag_identity(rng):
    """Migration re-embed: factor at the larger geometry == blockdiag(L, I)."""
    n, m = 48, 16
    a = rng.standard_normal((n, n)).astype(np.float32)
    k = a @ a.T + n * np.eye(n, dtype=np.float32)
    from repro.core import cholesky as chol

    lp = chol.tiled_cholesky(tiling.pack_lower(jnp.asarray(k), m))
    lp_big = tiling.embed_packed(lp, 3, 5)
    kpad = np.eye(80, dtype=np.float32)
    kpad[:n, :n] = k
    ref = chol.tiled_cholesky(tiling.pack_lower(jnp.asarray(kpad), m))
    np.testing.assert_allclose(np.asarray(lp_big), np.asarray(ref), atol=1e-5)
    # logdet of the embedded factor is unchanged (identity padding)
    np.testing.assert_allclose(
        float(triangular.logdet_from_factor(lp_big, 5, n_valid=n)),
        float(triangular.logdet_from_factor(lp, 3)),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# GPFleet: bucketed front-end, migration on update.
# ---------------------------------------------------------------------------


def test_gpfleet_matches_single_gps(rng):
    xs, ys = _problems(rng, ns=(48, 64, 200, 17))
    fleet = GPFleet(xs, ys, params=PARAMS, tile_size=M)
    assert fleet.bucket_assignment() == {1: [3], 2: [0, 1], 8: [2]}
    xt = rng.standard_normal((9, 2)).astype(np.float32)
    mean, var = fleet.predict_with_uncertainty(xt)
    nlml = fleet.nlml()
    tests = [rng.standard_normal((k, 2)).astype(np.float32) for k in (3, 0, 7, 1)]
    each = fleet.predict_each(tests)
    for i, (x, y) in enumerate(zip(xs, ys)):
        g = GaussianProcess(x, y, params=PARAMS, tile_size=M)
        mr, vr = g.predict_with_uncertainty(xt)
        np.testing.assert_allclose(np.asarray(mean[i]), np.asarray(mr), atol=3e-4)
        np.testing.assert_allclose(np.asarray(var[i]), np.asarray(vr), atol=3e-3)
        ref = mll.nlml_from_state(g.posterior(), jnp.asarray(y))
        np.testing.assert_allclose(float(nlml[i]), float(ref), rtol=2e-4, atol=5e-3)
        assert each[i].shape == (tests[i].shape[0],)
        if tests[i].shape[0]:
            np.testing.assert_allclose(
                np.asarray(each[i]), np.asarray(g.predict(tests[i])), atol=3e-4
            )


def test_gpfleet_bucket_migration_on_update(rng):
    xs, ys = _problems(rng, ns=(40, 60, 120))
    fleet = GPFleet(xs, ys, params=PARAMS, tile_size=M)
    xt = rng.standard_normal((6, 2)).astype(np.float32)
    fleet.predict(xt)                         # warm every bucket
    assert fleet.bucket_assignment() == {2: [0, 1], 4: [2]}

    # problem 1 crosses 64 -> cap 4; problem 2 crosses 128 -> cap 8
    xn = [np.zeros((0, 2), np.float32),
          rng.standard_normal((30, 2)).astype(np.float32),
          rng.standard_normal((20, 2)).astype(np.float32)]
    yn = [np.zeros((0,), np.float32),
          rng.standard_normal(30).astype(np.float32),
          rng.standard_normal(20).astype(np.float32)]
    fleet.update(xn, yn)
    assert fleet.bucket_assignment() == {2: [0], 4: [1], 8: [2]}
    # migration kept every bucket warm — no cold re-factorization pending
    assert all(rec.state is not None for rec in fleet._buckets.values())

    mean = fleet.predict(xt)
    for i in range(3):
        x2 = np.concatenate([xs[i], xn[i]])
        y2 = np.concatenate([ys[i], yn[i]])
        g = GaussianProcess(x2, y2, params=PARAMS, tile_size=M)
        np.testing.assert_allclose(
            np.asarray(mean[i]), np.asarray(g.predict(xt)), atol=1e-3
        )


def test_gpfleet_validation(rng):
    xs, ys = _problems(rng, ns=(20, 30))
    with pytest.raises(ValueError, match="equal-length"):
        GPFleet(xs, ys[:1])
    with pytest.raises(ValueError, match="share D"):
        GPFleet([xs[0], rng.standard_normal((30, 3))], ys)
    with pytest.raises(ValueError, match="per-problem"):
        GPFleet(xs, ys, params=SEKernelParams(jnp.ones(3), 1.0, 0.1))
    fleet = GPFleet(xs, ys, params=PARAMS, tile_size=M)
    with pytest.raises(ValueError, match="one test set per problem"):
        fleet.predict_each([xs[0]])
    with pytest.raises(ValueError, match="one arrival block per problem"):
        fleet.update([xs[0]], [ys[0]])


# ---------------------------------------------------------------------------
# Bucketing invariance: boundaries change cost, never results.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("boundaries", [1, 2, "pow2", (2, 8)])
def test_bucketing_never_changes_results(rng, boundaries):
    xs, ys = _problems(rng, ns=(18, 48, 70, 200))
    xt = rng.standard_normal((5, 2)).astype(np.float32)
    base = GPFleet(xs, ys, params=PARAMS, tile_size=M, boundaries="pow2")
    got = GPFleet(xs, ys, params=PARAMS, tile_size=M, boundaries=boundaries)
    np.testing.assert_allclose(
        np.asarray(got.predict(xt)), np.asarray(base.predict(xt)), atol=5e-4
    )
    np.testing.assert_allclose(
        np.asarray(got.nlml()), np.asarray(base.nlml()), rtol=2e-4, atol=5e-3
    )


def test_bucket_boundaries_and_assignment():
    assert tiling.bucket_boundaries(8, "pow2") == (1, 2, 4, 8)
    assert tiling.bucket_boundaries(5, "pow2") == (1, 2, 4, 8)
    assert tiling.bucket_boundaries(9, 1) == (9,)
    assert tiling.bucket_boundaries(16, (2, 8)) == (2, 8, 16)
    assign = tiling.bucket_problems((10, 33, 64, 65, 256), 32, "pow2")
    assert assign == {1: [0], 2: [1, 2], 4: [3], 8: [4]}
    with pytest.raises(ValueError):
        tiling.bucket_problems((0,), 32, "pow2")


# ---------------------------------------------------------------------------
# Continuous-batching serving loop.
# ---------------------------------------------------------------------------


def test_continuous_batcher_waves(rng):
    from repro.serve import ContinuousBatcher

    xs, ys = _problems(rng, ns=(40, 60))
    fleet = GPFleet(xs, ys, params=PARAMS, tile_size=M)
    ticks = iter(range(1000))
    srv = ContinuousBatcher(fleet, clock=lambda: float(next(ticks)))

    xt = rng.standard_normal((4, 2)).astype(np.float32)
    r1 = srv.submit_predict(0, xt)
    r2 = srv.submit_predict(0, xt[:2], uncertainty=True)
    xo = rng.standard_normal((30, 2)).astype(np.float32)
    yo = rng.standard_normal(30).astype(np.float32)
    r3 = srv.submit_observe(1, xo, yo)
    assert srv.pending == 3
    stats = srv.step()
    assert srv.pending == 0
    assert (stats.n_predict, stats.n_observe, stats.points_absorbed) == (2, 1, 30)
    assert stats.migrations == 1                  # 60 + 30 crosses cap 2 -> 4
    assert fleet.bucket_assignment() == {2: [0], 4: [1]}

    # observations land before predictions inside a wave; both requests on
    # problem 0 share one launch and slice their own rows back out
    g0 = GaussianProcess(xs[0], ys[0], params=PARAMS, tile_size=M)
    np.testing.assert_allclose(srv.result(r1), np.asarray(g0.predict(xt)), atol=3e-4)
    m2, v2 = srv.result(r2)
    np.testing.assert_allclose(m2, np.asarray(g0.predict(xt[:2])), atol=3e-4)
    assert (v2 > 0).all()
    assert srv.result(r3) == 30
    with pytest.raises(KeyError):
        srv.result(r3)                            # results pop exactly once

    s = srv.summary()
    assert s["requests"] == 3.0 and s["waves"] == 1.0
    assert s["p99_ms"] >= s["p50_ms"] >= 0.0

    # the post-update state answers like a fresh GP on the grown problem
    rid = srv.submit_predict(1, xt)
    srv.run_until_idle()
    g1 = GaussianProcess(
        np.concatenate([xs[1], xo]), np.concatenate([ys[1], yo]),
        params=PARAMS, tile_size=M,
    )
    np.testing.assert_allclose(srv.result(rid), np.asarray(g1.predict(xt)), atol=1e-3)


def test_continuous_batcher_dispatch_overlap_ordering(rng):
    """Waves dispatch without blocking; results arrive one wave late but
    are computed against the state snapshot at dispatch time."""
    from repro.serve import ContinuousBatcher

    xs, ys = _problems(rng, ns=(40, 60))
    fleet = GPFleet(xs, ys, params=PARAMS, tile_size=M)
    srv = ContinuousBatcher(fleet)
    xt = rng.standard_normal((4, 2)).astype(np.float32)

    # wave 0: predict against the initial state
    r0 = srv.submit_predict(0, xt)
    s0 = srv.step()
    assert s0.n_predict == 1
    assert srv._inflight is not None          # dispatched, NOT fetched
    assert r0 not in srv._done

    # wave 1: observe problem 0, predict again.  Entering step() flushes
    # wave 0 FIRST, so r0 must reflect the pre-observation snapshot even
    # though its result is fetched after the update was enqueued.
    xo = rng.standard_normal((8, 2)).astype(np.float32)
    yo = rng.standard_normal(8).astype(np.float32)
    srv.submit_observe(0, xo, yo)
    r1 = srv.submit_predict(0, xt)
    srv.step()
    assert r0 in srv._done                    # one wave late, now finished
    assert r1 not in srv._done

    g_before = GaussianProcess(xs[0], ys[0], params=PARAMS, tile_size=M)
    np.testing.assert_allclose(
        srv.result(r0), np.asarray(g_before.predict(xt)), atol=3e-4
    )
    # flush() via result() materializes the in-flight wave 1: r1 sees the
    # post-observation state — wave N predictions see waves 0..N observes
    g_after = GaussianProcess(
        np.concatenate([xs[0], xo]), np.concatenate([ys[0], yo]),
        params=PARAMS, tile_size=M,
    )
    np.testing.assert_allclose(
        srv.result(r1), np.asarray(g_after.predict(xt)), atol=1e-3
    )
    assert srv._inflight is None
    assert srv.flush() == 0                   # idempotent when drained


try:
    from hypothesis import given, settings, strategies as st

    @given(
        seed=st.integers(0, 2**31 - 1),
        sizes=st.lists(st.integers(1, 80), min_size=1, max_size=5),
        k=st.sampled_from([1, 2, 3, "pow2"]),
    )
    @settings(max_examples=10, deadline=None)
    def test_bucketing_invariance_property(seed, sizes, k):
        rng = np.random.default_rng(seed)
        xs, ys = _problems(rng, ns=tuple(sizes), d=1)
        xt = rng.standard_normal((3, 1)).astype(np.float32)
        a = GPFleet(xs, ys, params=PARAMS, tile_size=16, boundaries="pow2")
        b = GPFleet(xs, ys, params=PARAMS, tile_size=16, boundaries=k)
        np.testing.assert_allclose(
            np.asarray(a.predict(xt)), np.asarray(b.predict(xt)), atol=5e-4
        )
except ImportError:  # pragma: no cover - hypothesis absent in minimal envs
    pass
