"""End-to-end behaviour tests for the paper's system.

The paper's workload: GP regression for system identification of a coupled
mass-spring-damper chain, fully device-resident, tiled pipeline.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GaussianProcess, SEKernelParams
from repro.data.msd import MSDConfig, make_dataset, nfir_features, simulate


def test_simulator_is_deterministic():
    u1, y1 = simulate(64, seed=5)
    u2, y2 = simulate(64, seed=5)
    np.testing.assert_array_equal(u1, u2)
    np.testing.assert_array_equal(y1, y2)
    u3, _ = simulate(64, seed=6)
    assert not np.allclose(u1, u3)


def test_nfir_features_lag_structure():
    u = np.arange(10.0)
    y = np.arange(10.0) * 2
    x, yy = nfir_features(u, y, 3)
    assert x.shape == (8, 3)
    # x_t = [u_t, u_{t-1}, u_{t-2}]
    np.testing.assert_array_equal(x[0], [2.0, 1.0, 0.0])
    np.testing.assert_array_equal(x[-1], [9.0, 8.0, 7.0])
    np.testing.assert_array_equal(yy, y[2:])


def test_gp_solves_system_identification():
    """The paper's end-to-end task: predict the last mass's position from
    lagged forces.  The tiled GP must clearly beat the mean predictor."""
    x_tr, y_tr, x_te, y_te = make_dataset(512, 128, MSDConfig(), seed=7)
    gp = GaussianProcess(x_tr, y_tr, tile_size=64)
    mu, var = gp.predict_with_uncertainty(x_te)
    mse = float(np.mean((np.asarray(mu) - y_te) ** 2))
    r2 = 1 - mse / float(np.var(y_te))
    assert r2 > 0.5, r2
    # uncertainty sanity: most residuals inside 3 sigma (+ observation noise)
    sd = np.sqrt(np.asarray(var) + float(gp.params.noise))
    frac = float(np.mean(np.abs(np.asarray(mu) - y_te) < 3 * sd))
    assert frac > 0.9, frac


def test_device_residency_single_jit():
    """The whole prediction pipeline compiles as one device program (the
    GPU-residency claim: data in, results out, nothing host-side between)."""
    import jax

    from repro.core import predict as pred

    x_tr, y_tr, x_te, _ = make_dataset(96, 32, MSDConfig(), seed=1)
    fn = jax.jit(
        lambda a, b, c: pred.predict(
            a, b, c, SEKernelParams.paper_defaults(), 32, full_cov=True
        )
    )
    mu, cov = fn(jnp.asarray(x_tr), jnp.asarray(y_tr), jnp.asarray(x_te))
    mu2, cov2 = pred.predict(
        jnp.asarray(x_tr), jnp.asarray(y_tr), jnp.asarray(x_te),
        SEKernelParams.paper_defaults(), 32, full_cov=True,
    )
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(cov), np.asarray(cov2), atol=1e-5)


def test_stream_knob_does_not_change_results():
    """Paper Fig. 3 sweeps streams for speed; results must be invariant."""
    x_tr, y_tr, x_te, _ = make_dataset(128, 32, MSDConfig(), seed=2)
    mus = []
    for ns in (None, 1, 4, 16):
        gp = GaussianProcess(x_tr, y_tr, tile_size=32, n_streams=ns)
        mus.append(np.asarray(gp.predict(x_te)))
    for mu in mus[1:]:
        np.testing.assert_allclose(mu, mus[0], atol=1e-4)
