"""Tiled triangular solves (forward/backward substitution) vs dense refs."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cholesky as chol
from repro.core import tiling, triangular


@pytest.fixture
def factored(rng):
    n, m = 64, 16
    a = rng.standard_normal((n, n)).astype(np.float32)
    k = a @ a.T + n * np.eye(n, dtype=np.float32)
    lp = chol.tiled_cholesky(tiling.pack_lower(jnp.asarray(k), m))
    lref = np.linalg.cholesky(k)
    return lp, lref, n, m


def test_forward_substitution(factored, rng):
    lp, lref, n, m = factored
    y = rng.standard_normal(n).astype(np.float32)
    b = triangular.forward_substitution(lp, jnp.asarray(y).reshape(-1, m))
    np.testing.assert_allclose(
        np.asarray(b).reshape(-1), np.linalg.solve(lref, y), atol=1e-3
    )


def test_backward_substitution(factored, rng):
    lp, lref, n, m = factored
    y = rng.standard_normal(n).astype(np.float32)
    a = triangular.backward_substitution(lp, jnp.asarray(y).reshape(-1, m))
    np.testing.assert_allclose(
        np.asarray(a).reshape(-1), np.linalg.solve(lref.T, y), atol=1e-3
    )


def test_full_solve_roundtrip(factored, rng):
    """forward then backward == K^{-1} y (the paper's alpha)."""
    lp, lref, n, m = factored
    y = rng.standard_normal(n).astype(np.float32)
    k = lref @ lref.T
    beta = triangular.forward_substitution(lp, jnp.asarray(y).reshape(-1, m))
    alpha = triangular.backward_substitution(lp, beta)
    np.testing.assert_allclose(
        np.asarray(alpha).reshape(-1), np.linalg.solve(k, y), rtol=2e-2, atol=2e-3
    )


def test_forward_matrix(factored, rng):
    lp, lref, n, m = factored
    q = 32
    b = rng.standard_normal((n, q)).astype(np.float32)
    b_tiles = tiling.tile_dense(jnp.asarray(b), m)
    v = triangular.forward_substitution_matrix(lp, b_tiles)
    np.testing.assert_allclose(
        np.asarray(tiling.untile_dense(v)), np.linalg.solve(lref, b), atol=1e-3
    )


def test_backward_matrix(factored, rng):
    lp, lref, n, m = factored
    q = 16
    b = rng.standard_normal((n, q)).astype(np.float32)
    b_tiles = tiling.tile_dense(jnp.asarray(b), m)
    x = triangular.backward_substitution_matrix(lp, b_tiles)
    np.testing.assert_allclose(
        np.asarray(tiling.untile_dense(x)), np.linalg.solve(lref.T, b), atol=1e-3
    )


def test_tiled_gram(rng):
    n, m, q = 32, 8, 16
    v = rng.standard_normal((n, q)).astype(np.float32)
    vt = tiling.tile_dense(jnp.asarray(v), 8)
    w = triangular.tiled_gram(vt)
    np.testing.assert_allclose(
        np.asarray(tiling.untile_dense(w)), v.T @ v, atol=1e-4
    )


def test_logdet(factored):
    lp, lref, n, m = factored
    ld = triangular.logdet_from_factor(lp, n // m)
    np.testing.assert_allclose(
        float(ld), 2 * np.sum(np.log(np.diagonal(lref))), rtol=1e-5
    )


def test_logdet_masks_padding(rng):
    """Regression (DESIGN.md §11): a factor padded past its frontier must
    log-det only its valid rows — unmasked, the padding corrupts the value.

    The padded store is factored from blockdiag(K, c*I) with c != 1, so the
    padding's diagonal contributes log(c) per padded row: n_valid MUST mask
    it out (the old signature deleted n_valid and summed every row)."""
    n, cap, m = 40, 64, 16
    a = rng.standard_normal((n, n)).astype(np.float32)
    k = a @ a.T + n * np.eye(n, dtype=np.float32)
    kpad = 4.0 * np.eye(cap, dtype=np.float32)  # padding diag 4 -> log != 0
    kpad[:n, :n] = k
    lp = chol.tiled_cholesky(tiling.pack_lower(jnp.asarray(kpad), m))
    ref = 2 * np.sum(np.log(np.diagonal(np.linalg.cholesky(k))))

    masked = triangular.logdet_from_factor(lp, cap // m, n_valid=n)
    np.testing.assert_allclose(float(masked), ref, rtol=1e-5)
    unmasked = triangular.logdet_from_factor(lp, cap // m)
    assert abs(float(unmasked) - ref) > 1.0  # the padding would corrupt it

    # per-problem (B,) frontiers on a stacked store
    lps = jnp.stack([lp, lp])
    lds = triangular.logdet_from_factor(
        lps, cap // m, n_valid=jnp.asarray([n, cap])
    )
    np.testing.assert_allclose(float(lds[0]), ref, rtol=1e-5)
    np.testing.assert_allclose(float(lds[1]), float(unmasked), rtol=1e-5)
