"""Tiled triangular solves (forward/backward substitution) vs dense refs."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cholesky as chol
from repro.core import tiling, triangular


@pytest.fixture
def factored(rng):
    n, m = 64, 16
    a = rng.standard_normal((n, n)).astype(np.float32)
    k = a @ a.T + n * np.eye(n, dtype=np.float32)
    lp = chol.tiled_cholesky(tiling.pack_lower(jnp.asarray(k), m))
    lref = np.linalg.cholesky(k)
    return lp, lref, n, m


def test_forward_substitution(factored, rng):
    lp, lref, n, m = factored
    y = rng.standard_normal(n).astype(np.float32)
    b = triangular.forward_substitution(lp, jnp.asarray(y).reshape(-1, m))
    np.testing.assert_allclose(
        np.asarray(b).reshape(-1), np.linalg.solve(lref, y), atol=1e-3
    )


def test_backward_substitution(factored, rng):
    lp, lref, n, m = factored
    y = rng.standard_normal(n).astype(np.float32)
    a = triangular.backward_substitution(lp, jnp.asarray(y).reshape(-1, m))
    np.testing.assert_allclose(
        np.asarray(a).reshape(-1), np.linalg.solve(lref.T, y), atol=1e-3
    )


def test_full_solve_roundtrip(factored, rng):
    """forward then backward == K^{-1} y (the paper's alpha)."""
    lp, lref, n, m = factored
    y = rng.standard_normal(n).astype(np.float32)
    k = lref @ lref.T
    beta = triangular.forward_substitution(lp, jnp.asarray(y).reshape(-1, m))
    alpha = triangular.backward_substitution(lp, beta)
    np.testing.assert_allclose(
        np.asarray(alpha).reshape(-1), np.linalg.solve(k, y), rtol=2e-2, atol=2e-3
    )


def test_forward_matrix(factored, rng):
    lp, lref, n, m = factored
    q = 32
    b = rng.standard_normal((n, q)).astype(np.float32)
    b_tiles = tiling.tile_dense(jnp.asarray(b), m)
    v = triangular.forward_substitution_matrix(lp, b_tiles)
    np.testing.assert_allclose(
        np.asarray(tiling.untile_dense(v)), np.linalg.solve(lref, b), atol=1e-3
    )


def test_backward_matrix(factored, rng):
    lp, lref, n, m = factored
    q = 16
    b = rng.standard_normal((n, q)).astype(np.float32)
    b_tiles = tiling.tile_dense(jnp.asarray(b), m)
    x = triangular.backward_substitution_matrix(lp, b_tiles)
    np.testing.assert_allclose(
        np.asarray(tiling.untile_dense(x)), np.linalg.solve(lref.T, b), atol=1e-3
    )


def test_tiled_gram(rng):
    n, m, q = 32, 8, 16
    v = rng.standard_normal((n, q)).astype(np.float32)
    vt = tiling.tile_dense(jnp.asarray(v), 8)
    w = triangular.tiled_gram(vt)
    np.testing.assert_allclose(
        np.asarray(tiling.untile_dense(w)), v.T @ v, atol=1e-4
    )


def test_logdet(factored):
    lp, lref, n, m = factored
    ld = triangular.logdet_from_factor(lp, n // m)
    np.testing.assert_allclose(
        float(ld), 2 * np.sum(np.log(np.diagonal(lref))), rtol=1e-5
    )
