"""repro.obs (DESIGN.md §15): registry semantics, zero-cost toggling,
JSONL/Prometheus export, executor wave-trace events, factorization-health
counters, the jitter-retry recovery, and the NLML drift monitor — including
the serving loop's automatic off-hot-path re-optimize.
"""

import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.core import GaussianProcess, GPFleet
from repro.core import executor, lowrank
from repro.core import predict as pred
from repro.core import update as upd
from repro.core.kernels_math import SEKernelParams
from repro.serve import ContinuousBatcher

PARAMS = SEKernelParams(lengthscale=0.6, vertical=1.1, noise=0.05)


@pytest.fixture(autouse=True)
def _clean_registry():
    """Every test starts disabled and empty, and leaves no global state."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


# -- registry semantics ------------------------------------------------------


def test_counter_gauge_semantics():
    obs.enable()
    obs.inc("a")
    obs.inc("a", 4)
    obs.set_gauge("g", 2.5)
    obs.set_gauge("g", 7.0)  # gauge keeps the last write only
    snap = obs.snapshot()
    assert snap["counters"]["a"] == 5.0
    assert snap["gauges"]["g"] == 7.0


def test_disabled_helpers_record_nothing():
    obs.inc("a")
    obs.observe("h", 1.0)
    obs.event("e", x=1)
    obs.health_event("boom")
    snap = obs.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}
    assert snap["events"] == []
    # re-enable: recording resumes on the same registry
    obs.enable()
    obs.inc("a")
    assert obs.snapshot()["counters"]["a"] == 1.0


def test_histogram_percentiles_tiny_samples():
    h = obs.Histogram(obs.DEFAULT_EDGES)
    assert math.isnan(h.percentile(50))  # empty -> NaN, not garbage
    h.observe(3.0)
    # a single sample is every percentile (clamped to [min, max])
    assert h.percentile(0) == h.percentile(50) == h.percentile(99) == 3.0
    h.observe(5.0)
    h.observe(100.0)
    qs = [h.percentile(q) for q in (1, 25, 50, 75, 99)]
    assert qs == sorted(qs)  # monotone in q
    assert qs[0] >= 3.0 and qs[-1] <= 100.0  # clamped to observed range


def test_histogram_overflow_bucket_and_sum():
    h = obs.Histogram(edges=(1.0, 2.0))
    for v in (0.5, 1.5, 99.0):
        h.observe(v)
    assert h.counts == [1, 1, 1]  # last is the implicit +inf bucket
    assert h.sum == pytest.approx(101.0) and h.count == 3
    assert h.percentile(99) <= 99.0


def test_event_ring_buffer_caps_memory():
    obs.enable()
    for i in range(obs.MAX_EVENTS + 10):
        obs.event("e", i=i)
    events = obs.registry().events
    assert len(events) == obs.MAX_EVENTS
    assert events[0]["i"] == 10  # oldest dropped


# -- export round-trips ------------------------------------------------------


def test_jsonl_sink_round_trip(tmp_path):
    path = tmp_path / "m.jsonl"
    obs.enable(str(path))
    obs.event("alpha", v=1)
    obs.event("beta", v=[1, 2])
    obs.disable()  # closes the sink
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["kind"] for r in recs] == ["alpha", "beta"]
    assert all("ts" in r for r in recs)
    assert recs[1]["v"] == [1, 2]


def test_to_json_and_prometheus():
    obs.enable()
    obs.inc("serve.requests", 3)
    obs.set_gauge("pool.occupancy", 0.5)
    obs.observe("lat_ms", 2.0, edges=(1.0, 4.0))
    parsed = json.loads(obs.to_json())
    assert parsed["counters"]["serve.requests"] == 3.0
    prom = obs.to_prometheus()
    assert "# TYPE repro_serve_requests counter" in prom
    assert "repro_serve_requests 3" in prom
    assert "repro_pool_occupancy 0.5" in prom
    # histogram exposition: cumulative buckets + +Inf + sum/count
    assert 'repro_lat_ms_bucket{le="4"} 1' in prom
    assert 'repro_lat_ms_bucket{le="+Inf"} 1' in prom
    assert "repro_lat_ms_count 1" in prom


# -- executor wave traces ----------------------------------------------------


def test_plan_wave_stats_shape():
    plan = executor.program_plan(4, 1, False, 2)
    st = executor.plan_wave_stats(plan)
    assert st["plan"] == "program" and st["n_streams"] == 2
    assert st["tasks"] == st["bulk_tasks"] + st["pool_tasks"]
    assert 0.0 < st["occupancy"] <= 1.0
    assert sum(st["by_op"].values()) == st["tasks"]
    assert executor.plan_wave_stats(plan) is st  # memoized per Plan


def test_fused_predict_emits_wave_event(rng):
    x = rng.standard_normal((40, 2)).astype(np.float32)
    y = rng.standard_normal(40).astype(np.float32)
    gp = GaussianProcess(x, y, params=PARAMS, tile_size=16)
    obs.enable()
    gp.predict(x[:4])
    snap = obs.snapshot()
    assert snap["counters"]["executor.dispatch.run_program"] == 1.0
    assert snap["counters"]["cache.posterior.cold"] == 1.0
    waves = [e for e in snap["events"] if e["kind"] == "executor.wave"]
    assert len(waves) == 1
    ev = waves[0]
    assert ev["dispatch"] == "run_program" and ev["plan"] == "program"
    assert ev["launches"] > 0 and ev["tasks"] > 0
    # second predict: warm tail, NO new program dispatch
    gp.predict(x[:4])
    snap = obs.snapshot()
    assert snap["counters"]["executor.dispatch.run_program"] == 1.0
    assert snap["counters"]["predict.warm_tail"] == 1.0
    assert snap["counters"]["cache.posterior.warm"] == 1.0


def test_update_append_counts_dispatches(rng):
    x = rng.standard_normal((32, 2)).astype(np.float32)
    y = rng.standard_normal(32).astype(np.float32)
    gp = GaussianProcess(x, y, params=PARAMS, tile_size=16)
    gp.posterior()
    obs.enable()
    gp.update(rng.standard_normal((16, 2)).astype(np.float32),
              rng.standard_normal(16).astype(np.float32))
    c = obs.snapshot()["counters"]
    assert c.get("executor.dispatch.run_append", 0) >= 1


def test_cache_stats_reports_plan_caches():
    executor.program_plan(4, 1, False, 2)
    stats = obs.cache_stats()
    assert "executor.program_plan" in stats
    st = stats["executor.program_plan"]
    assert set(st) == {"hits", "misses", "size"} and st["size"] >= 1
    before = st["hits"]
    executor.program_plan(4, 1, False, 2)  # lru hit
    assert obs.cache_stats()["executor.program_plan"]["hits"] == before + 1


# -- factorization health ----------------------------------------------------


def test_refactorize_fallback_counter(rng, monkeypatch):
    x = rng.standard_normal((32, 2)).astype(np.float32)
    y = rng.standard_normal(32).astype(np.float32)
    gp = GaussianProcess(x, y, params=PARAMS, tile_size=16)
    gp.posterior()

    def boom(self, *a, **k):
        raise upd.CholeskyUpdateError("forced")

    monkeypatch.setattr(pred.PosteriorState, "extend", boom)
    obs.enable()
    gp.update(rng.standard_normal((8, 2)).astype(np.float32),
              rng.standard_normal(8).astype(np.float32))
    snap = obs.snapshot()
    assert snap["counters"]["health.refactorize_fallback"] == 1.0
    ev = [e for e in snap["events"] if e["kind"] == "health.refactorize_fallback"]
    assert ev and ev[0]["site"] == "gp.update"
    assert gp._posterior is None  # contract unchanged: cache invalidated


def test_nan_guard_trip_counter():
    obs.enable()
    with pytest.raises(upd.CholeskyUpdateError):
        upd._check((jnp.asarray([np.nan]),), "append")
    c = obs.snapshot()["counters"]
    assert c["health.nan_guard_trip"] == 1.0


def test_lowrank_jitter_retry_recovers(rng):
    # duplicate inducing rows + zero jitter: K_uu is exactly singular, the
    # cold factorization NaNs, and the escalating-jitter retry must recover
    x = np.repeat(rng.standard_normal((4, 2)), 8, axis=0).astype(np.float32)
    y = rng.standard_normal(32).astype(np.float32)
    ind = np.repeat(x[:1], 8, axis=0)  # 8 identical inducing points
    obs.enable()
    gp = GaussianProcess(
        x, y, params=PARAMS, tile_size=16, method="lowrank",
        m_inducing=8, inducing=ind, jitter=0.0,
    )
    mean = np.asarray(gp.predict(x[:4]))
    assert np.isfinite(mean).all()
    c = obs.snapshot()["counters"]
    assert c["health.lowrank_jitter_retry"] >= 1.0
    assert c["cache.lowrank.cold"] == 1.0


# -- zero-cost-when-off ------------------------------------------------------


def test_disabled_obs_is_bitwise_invisible(rng):
    x = rng.standard_normal((48, 2)).astype(np.float32)
    y = rng.standard_normal(48).astype(np.float32)
    xt = rng.standard_normal((8, 2)).astype(np.float32)

    def run():
        gp = GaussianProcess(x, y, params=PARAMS, tile_size=16)
        return np.asarray(gp.predict(xt))

    base = run()
    obs.enable()
    on = run()
    obs.disable()
    off = run()
    assert np.array_equal(base, on) and np.array_equal(base, off)
    # disable stops recording but keeps the data (export still works) ...
    c = obs.snapshot()["counters"]
    assert c["cache.posterior.cold"] == 1.0  # only the enabled run recorded
    # ... and reset wipes it without touching the flag
    obs.reset()
    assert obs.snapshot()["counters"] == {}


# -- drift monitor -----------------------------------------------------------


def test_drift_monitor_stationary_never_triggers():
    rng = np.random.default_rng(0)
    mon = obs.DriftMonitor(alpha=0.3, threshold=0.05, warmup=3, cooldown=8)
    assert not any(mon.observe(1.0 + 0.01 * rng.standard_normal())
                   for _ in range(200))
    assert mon.triggers == 0
    assert mon.level == pytest.approx(1.0, abs=0.05)


def test_drift_monitor_rising_triggers_once():
    mon = obs.DriftMonitor(alpha=0.5, threshold=0.05, warmup=2, cooldown=10 ** 6)
    fired = [i for i in range(50) if mon.observe(1.0 + 0.2 * i)]
    assert len(fired) == 1 and mon.triggers == 1  # cooldown gates repeats
    mon.reset()
    assert mon.level is None and mon.triggers == 1  # lifetime stat survives


def test_drift_monitor_ignores_nan_and_respects_warmup():
    mon = obs.DriftMonitor(alpha=0.5, threshold=0.01, warmup=5, cooldown=0)
    assert mon.observe(float("nan")) is False
    assert mon.level is None  # NaN never becomes the level
    assert not any(mon.observe(1.0 + i) for i in range(4))  # inside warmup


# -- serving loop ------------------------------------------------------------


def _fleet(rng, ns=(20, 33, 50)):
    xs = [rng.uniform(size=(n, 1)).astype(np.float32) for n in ns]
    ys = [np.sin(6 * x[:, 0]).astype(np.float32) for x in xs]
    return GPFleet(xs, ys, tile_size=16)


def test_summary_empty_and_single_request_nan_safe(rng):
    srv = ContinuousBatcher(_fleet(rng))
    s = srv.summary()
    assert s["requests"] == 0.0
    for k in ("p50_ms", "p99_ms", "max_ms", "req_per_s"):
        assert math.isfinite(s[k]) and s[k] >= 0.0
    srv.submit_predict(0, rng.uniform(size=(3, 1)))
    srv.step()
    srv.flush()
    s = srv.summary()
    assert s["requests"] == 1.0
    assert math.isfinite(s["p99_ms"])
    assert s["max_ms"] >= s["p99_ms"] >= s["p50_ms"] > 0.0


def test_serve_wave_metrics_and_events(rng):
    srv = ContinuousBatcher(_fleet(rng))
    obs.enable()
    for i in range(3):
        srv.submit_predict(i, rng.uniform(size=(4, 1)))
    srv.submit_observe(0, rng.uniform(size=(3, 1)), rng.standard_normal(3))
    srv.step()
    srv.flush()
    ev = [e for e in obs.registry().events if e["kind"] == "serve.wave"]
    assert len(ev) == 1
    assert ev[0]["n_predict"] == 3 and ev[0]["n_observe"] == 1
    assert 0.0 < ev[0]["bucket_occupancy"] <= 1.0
    assert 0.0 <= ev[0]["padded_flop_waste"] < 1.0
    snap = srv.metrics_snapshot()
    assert snap["counters"]["serve.waves"] == 1.0
    assert snap["counters"]["serve.points_absorbed"] == 3.0
    assert snap["histograms"]["serve.queue_depth"]["count"] == 1
    # private registry works with global telemetry OFF too
    obs.disable()
    srv.submit_predict(0, rng.uniform(size=(2, 1)))
    srv.step()
    assert srv.metrics_snapshot()["counters"]["serve.waves"] == 2.0
    assert len([e for e in obs.registry().events
                if e["kind"] == "serve.wave"]) == 1


def test_drift_triggers_exactly_one_reoptimize(rng):
    fleet = _fleet(rng)
    mon = obs.DriftMonitor(alpha=0.5, threshold=0.02, warmup=1, cooldown=10 ** 6)
    calls = []
    srv = ContinuousBatcher(
        fleet, drift_monitor=mon, reoptimize=lambda: calls.append(1)
    )
    reopt_waves = 0
    for w in range(6):
        # drifting targets: the per-point NLML trend rises wave over wave
        for i in range(3):
            srv.submit_observe(
                i, rng.uniform(size=(2, 1)),
                np.full(2, 3.0 * w, np.float32),
            )
        reopt_waves += srv.step().reoptimized
    srv.flush()
    assert len(calls) == 1  # exactly one re-optimize (cooldown holds)
    assert reopt_waves == 1 and mon.triggers == 1
    assert srv.summary()["reoptimizations"] == 1.0


def test_drift_default_reoptimize_fits_fleet(rng):
    fleet = _fleet(rng, ns=(18, 22))
    mon = obs.DriftMonitor(alpha=0.5, threshold=0.02, warmup=1, cooldown=10 ** 6)
    srv = ContinuousBatcher(fleet, drift_monitor=mon)
    before = fleet.params
    for w in range(6):
        for i in range(2):
            srv.submit_observe(
                i, rng.uniform(size=(2, 1)), np.full(2, 3.0 * w, np.float32)
            )
        srv.step()
    srv.flush()
    assert mon.triggers == 1
    # the default reoptimize ran fleet.optimize(): new per-problem leaves
    after_leaves = [np.asarray(l) for l in
                    __import__("jax").tree_util.tree_leaves(fleet.params)]
    before_leaves = [np.asarray(l) for l in
                     __import__("jax").tree_util.tree_leaves(before)]
    assert any(b.shape != a.shape or not np.array_equal(b, a)
               for b, a in zip(before_leaves, after_leaves))
    # and serving still works against the re-fitted fleet
    rid = srv.submit_predict(0, rng.uniform(size=(3, 1)))
    srv.step()
    assert np.isfinite(np.asarray(srv.result(rid))).all()


def test_fleet_optimize_improves_nlml(rng):
    fleet = _fleet(rng, ns=(20, 33))
    n0 = np.asarray(fleet.nlml())
    fleet.optimize(steps=30, lr=0.1)
    n1 = np.asarray(fleet.nlml())
    assert (n1 <= n0 + 1e-3).all()  # every problem at least as good
    assert n1.sum() < n0.sum()      # and the fleet strictly improved
